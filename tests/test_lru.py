"""repro._lru.LRUCache: bounded-LRU semantics + thread safety.

The serve layer hits one cache from a BackgroundServer flush thread, a
user thread, and the stop() drain concurrently (ISSUE 9 satellite); a
plain OrderedDict corrupts or double-builds under that load. These
tests hammer a single cache from many threads and assert (a) no
corruption, (b) ``get_or_create`` builds each key's value exactly once,
(c) counters are consistent (no lost updates).
"""

import threading

import pytest

from repro._lru import LRUCache


def test_get_put_hit_miss_counters():
    c = LRUCache(maxsize=2)
    assert c.get("a") is None
    c.put("a", 1)
    assert c.get("a") == 1
    assert c.stats() == {"hits": 1, "misses": 1, "evictions": 0,
                         "size": 1, "maxsize": 2}


def test_eviction_order_and_on_evict_callback():
    evicted = []
    c = LRUCache(maxsize=2, on_evict=lambda k, v: evicted.append((k, v)))
    c.put("a", 1)
    c.put("b", 2)
    c.get("a")          # refresh "a" — "b" is now coldest
    c.put("c", 3)
    assert evicted == [("b", 2)]
    assert "a" in c and "c" in c and "b" not in c


def test_on_evict_may_reenter_cache():
    # on_evict runs outside the lock (docstring contract): re-entering
    # the cache from the callback must not deadlock.
    c = LRUCache(maxsize=1)
    seen = []
    c._on_evict = lambda k, v: seen.append((k, c.get(k)))
    c.put("a", 1)
    c.put("b", 2)
    assert seen == [("a", None)]


def test_get_or_create_builds_once_per_key():
    c = LRUCache(maxsize=4)
    calls = []
    v1 = c.get_or_create("k", lambda: calls.append(1) or "built")
    v2 = c.get_or_create("k", lambda: calls.append(1) or "rebuilt")
    assert v1 == v2 == "built"
    assert len(calls) == 1
    assert c.hits == 1 and c.misses == 1


def test_pop_removes_without_eviction_accounting():
    c = LRUCache(maxsize=4)
    c.put("a", 1)
    assert c.pop("a") == 1
    assert c.pop("a", "gone") == "gone"
    assert c.evictions == 0 and len(c) == 0


@pytest.mark.parametrize("n_threads", [8])
def test_concurrent_get_or_create_single_build(n_threads):
    """N threads race get_or_create on the same keys: each key's
    factory runs exactly once, and hits + misses == total calls."""
    c = LRUCache(maxsize=64)
    n_keys, rounds = 16, 50
    builds = [0] * n_keys
    build_lock = threading.Lock()
    barrier = threading.Barrier(n_threads)
    errors = []

    def factory(k):
        with build_lock:
            builds[k] += 1
        return ("value", k)

    def worker():
        try:
            barrier.wait()
            for r in range(rounds):
                for k in range(n_keys):
                    v = c.get_or_create(k, lambda k=k: factory(k))
                    assert v == ("value", k)
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert not errors
    assert builds == [1] * n_keys
    total = n_threads * rounds * n_keys
    st = c.stats()
    assert st["hits"] + st["misses"] == total
    assert st["misses"] == n_keys
    assert st["size"] == n_keys


def test_concurrent_put_get_under_eviction_pressure():
    """Hammer a tiny cache (constant eviction) from many threads —
    no corruption, eviction counter consistent with insert volume."""
    c = LRUCache(maxsize=4)
    n_threads, rounds, n_keys = 8, 200, 32
    barrier = threading.Barrier(n_threads)
    errors = []

    def worker(tid):
        try:
            barrier.wait()
            for r in range(rounds):
                k = (tid * rounds + r) % n_keys
                c.put(k, k * 10)
                got = c.get(k)
                assert got is None or got == k * 10
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert not errors
    assert len(c) <= 4
    st = c.stats()
    # every put either landed in the final 4 or was evicted
    assert st["evictions"] + st["size"] <= n_threads * rounds
    for k in c.keys():
        assert c.get(k) == k * 10
