"""Scenario engine: declarative experiment grids, batched execution.

* :mod:`repro.experiments.scenario` — :class:`Scenario` specs, the
  energy-profile factory, and the named-grid registry.
* :mod:`repro.experiments.engine` — :func:`run_grid`, which executes a
  whole scheduler × arrival × seed grid as one compiled computation per
  component structure (vmap over stacked pytree leaves), plus the
  sequential per-cell baseline for cross-checks and benchmarking.
* :mod:`repro.experiments.placement` — device placement for
  ``run_grid(..., mesh=...)``: each group's (scenario × seed) cells are
  flattened into one cell axis, padded to a device-divisible count, and
  executed under ``shard_map`` (DESIGN.md §5).
"""

from repro.experiments.engine import (
    CellResult,
    clear_cache,
    grid_summary,
    run_grid,
    run_grid_sequential,
)
from repro.experiments.placement import make_cell_mesh
from repro.experiments.scenario import (
    ARRIVAL_KINDS,
    FIG1_SCHEDULERS,
    PAPER_TAUS,
    Scenario,
    default_taus,
    get_grid,
    grid_names,
    make_energy_process,
    register_grid,
    scenario_grid,
)

__all__ = [
    "ARRIVAL_KINDS", "FIG1_SCHEDULERS", "PAPER_TAUS",
    "CellResult", "Scenario", "clear_cache", "default_taus", "get_grid",
    "grid_names", "make_cell_mesh",
    "grid_summary", "make_energy_process", "register_grid", "run_grid",
    "run_grid_sequential", "scenario_grid",
]
