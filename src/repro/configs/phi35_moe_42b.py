"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE decoder.

[hf:microsoft/Phi-3.5-MoE-instruct] 32L, d_model=4096, 32 heads (GQA
kv=8), d_ff=6400 per expert, vocab=32064, 16 experts top-2 (~42B total,
~6.6B active).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab=32064,
    n_experts=16,
    top_k=2,
    rope_theta=10000.0,
    long_context_window=8192,
    norm="rmsnorm",
    act="silu",
    dtype_name="bfloat16",
    remat=True,
    citation="[hf:microsoft/Phi-3.5-MoE-instruct]",
)
