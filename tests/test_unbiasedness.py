"""Lemma 1 (unbiasedness) — Monte-Carlo tests.

The paper's central lemma: E[Σ_{i∈S_t} p_i·scale_i·g_i] = Σ_i p_i·g_i.
We verify it for all three arrival models by checking the *expected
aggregation weight* per client is exactly p_i. Randomized
schedule/period variants (hypothesis) live in
``test_unbiasedness_properties.py``, skipped cleanly when ``hypothesis``
is unavailable.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import client_weights
from repro.core.energy import (
    BinaryArrivals,
    DeterministicArrivals,
    UniformArrivals,
)
from repro.core.scheduling import make_scheduler


def mean_weights(scheduler, process, p, horizon, seed=0):
    """Time-average of ω_i = p_i·mask_i·scale_i over the run."""
    key = jax.random.PRNGKey(seed)
    sstate, estate = scheduler.init(key), process.init(key)
    p = jnp.asarray(p, jnp.float32)

    def body(carry, t):
        sstate, estate, key = carry
        key, k1, k2 = jax.random.split(key, 3)
        estate, arr = process.arrivals(estate, t, k1)
        sstate, dec = scheduler.step(sstate, t, k2, arr)
        return (sstate, estate, key), client_weights(p, dec)

    _, w = jax.lax.scan(body, (sstate, estate, key), jnp.arange(horizon))
    return np.asarray(w).mean(0)


def test_alg1_unbiased_periodic():
    taus = [1, 4, 8]
    p = np.array([0.5, 0.3, 0.2])
    det = DeterministicArrivals.periodic(taus, horizon=8 * 400)
    w = mean_weights(make_scheduler("alg1", 3), det, p, 8 * 400)
    np.testing.assert_allclose(w, p, rtol=0.08)


def test_alg2_unbiased_binary():
    p = np.array([0.25, 0.25, 0.5])
    proc = BinaryArrivals([0.2, 0.5, 0.9])
    w = mean_weights(make_scheduler("alg2", 3), proc, p, 5000)
    np.testing.assert_allclose(w, p, rtol=0.08)


def test_alg2_unbiased_uniform():
    p = np.array([0.6, 0.4])
    proc = UniformArrivals([3, 9])
    w = mean_weights(make_scheduler("alg2", 2), proc, p, 9 * 400)
    np.testing.assert_allclose(w, p, rtol=0.08)


def test_benchmark1_is_biased():
    """The failure mode the paper highlights: without scaling, expected
    weights are p_i/τ_i — biased toward energy-rich clients."""
    taus = np.array([1, 10])
    p = np.array([0.5, 0.5])
    det = DeterministicArrivals.periodic(taus, horizon=2000)
    w = mean_weights(make_scheduler("benchmark1", 2), det, p, 2000)
    np.testing.assert_allclose(w, p / taus, rtol=0.05)
    assert w[0] > 5 * w[1]  # strong bias
