"""Pre-jax-import environment helpers.

This module must stay free of jax (and jax-importing repro) imports:
its callers run *before* the first jax import, which is the only moment
XLA client flags can still take effect.
"""

from __future__ import annotations

import os
import sys


def ensure_host_device_count(n: int = 8) -> bool:
    """Merge ``--xla_force_host_platform_device_count=n`` into XLA_FLAGS.

    Gives the CPU backend ``n`` placeholder devices so the sharded grid
    path (DESIGN.md §5) can run on hosts without accelerators. Existing
    ``XLA_FLAGS`` content is preserved; an explicit device-count flag
    from the environment wins; real TPU/GPU backends ignore the flag.

    Returns True if the flag was added, False if it was too late (jax
    already imported) or a device-count flag was already present.
    """
    if "jax" in sys.modules:
        return False
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return False
    os.environ["XLA_FLAGS"] = \
        f"{flags} --xla_force_host_platform_device_count={n}".strip()
    return True
