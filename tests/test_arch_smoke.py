"""Per-architecture smoke tests: REDUCED variant of each assigned config
(≤2 layers, d_model ≤ 512, ≤4 experts) — one forward/train step + one
decode step on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, arch_names, get_config
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import encode, init_decode_state, init_lm
from repro.models.transformer import decode_cache_len

B, S = 2, 16
N_CLIENTS = 2


def make_batch(cfg):
    batch = {
        "tokens": jnp.full((B, S), 3, jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
        "client_ids": jnp.asarray([0, 1], jnp.int32),
    }
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jnp.ones(
            (B, cfg.n_vision_tokens, cfg.d_model), cfg.dtype)
    if cfg.enc_dec:
        batch["audio_feats"] = jnp.ones((B, cfg.enc_len, cfg.d_model),
                                        cfg.dtype)
    return batch


@pytest.fixture(scope="module", params=arch_names())
def arch(request):
    return request.param


def test_reduced_config_limits(arch):
    red = get_config(arch).reduced()
    assert red.d_model <= 512
    assert red.total_layers <= 4 or red.n_super <= 2
    assert red.n_experts <= 4
    assert red.vocab <= 512


def test_train_step_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    init_state, train_step = make_train_step(cfg, N_CLIENTS, lr=1e-3)
    state = init_state(params)
    batch = make_batch(cfg)
    mask = jnp.asarray([1.0, 0.0])
    scale = jnp.asarray([2.0, 2.0])
    state2, metrics = jax.jit(train_step)(state, batch, mask, scale)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["weighted_loss"])
    assert float(metrics["active_clients"]) == 1.0
    # params changed
    before = jax.tree_util.tree_leaves(state.params)[1]
    after = jax.tree_util.tree_leaves(state2.params)[1]
    assert before.shape == after.shape
    finite = [bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
              for l in jax.tree_util.tree_leaves(state2.params)]
    assert all(finite)


def test_serve_step_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    cache_len = decode_cache_len(cfg, 32)
    states = init_decode_state(cfg, B, cache_len)
    serve = make_serve_step(cfg)
    memory = None
    if cfg.enc_dec:
        memory = encode(params, cfg,
                        jnp.ones((B, cfg.enc_len, cfg.d_model), cfg.dtype))
    tok = jnp.full((B, 1), 3, jnp.int32)
    next_tok, logits, states2 = jax.jit(
        lambda p, t, s: serve(p, t, s, jnp.asarray(5), memory=memory)
    )(params, tok, states)
    assert next_tok.shape == (B,)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    expect = {
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }
    assert set(expect) == set(REGISTRY)
    for name, (nl, dm, nh, kv, ff, vocab) in expect.items():
        cfg = REGISTRY[name]
        assert cfg.n_layers == nl, name
        assert cfg.d_model == dm, name
        assert cfg.n_heads == nh, name
        assert cfg.n_kv_heads == kv, name
        assert cfg.d_ff == ff, name
        assert cfg.vocab == vocab, name
        assert cfg.citation, name
    assert REGISTRY["phi3.5-moe-42b-a6.6b"].n_experts == 16
    assert REGISTRY["phi3.5-moe-42b-a6.6b"].top_k == 2
    assert REGISTRY["llama4-scout-17b-a16e"].top_k == 1
    assert REGISTRY["zamba2-2.7b"].ssm_state == 64
    assert REGISTRY["zamba2-2.7b"].total_layers == 54
    assert REGISTRY["xlstm-1.3b"].total_layers == 48
