"""Study-as-a-service walkthrough: one compiled trace serves a mixed batch.

Eight clients submit serialized Study manifests concurrently — all the
same scheduler × arrival structure but *different population sizes* —
to a background StudyService. The service batches them into a single
structure-grouped dispatch, so the whole burst compiles exactly one
trace (the PR 4 padding invariant, applied across requests), and a
repeat submission afterwards is a pure executable-cache hit: zero new
compiles.

The final act is preemption-safe serving (DESIGN.md §12): the same
burst is served with checkpointing, "killed" mid-dispatch, and then
recovered by a brand-new service pointed at the checkpoint root — the
resumed responses are bitwise identical to the uninterrupted ones.

    PYTHONPATH=src python examples/serve_batch.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convergence import make_quadratic
from repro.experiments import ExecutionConfig, Study
from repro.optim import sgd
from repro.serve import BackgroundServer, StudyService

CAPACITY = 8
DIM = 8
POPULATIONS = [3, 4, 5, 6, 7, 8, 3, 5]  # 8 requests, 6 distinct sizes


def make_manifest(i: int, n_clients: int) -> str:
    """One client's request: same structure every time, its own N."""
    study = (Study(f"client{i}", num_steps=80)
             .axis("scheduler", "alg2")
             .axis("arrivals", "binary")
             .axis("n_clients", n_clients)
             .axis("seeds", [0, 1, 2, 3]))
    return study.to_json()


def main():
    prob = make_quadratic(jax.random.PRNGKey(0), CAPACITY, dim=DIM)
    service = StudyService(
        grads_fn=lambda w, k, t: prob.all_grads(w), p=prob.p,
        optimizer=sgd(0.05), loss_fn=prob.suboptimality,
        params0=jnp.zeros(DIM), cache_size=16)

    manifests = [make_manifest(i, n) for i, n in enumerate(POPULATIONS)]
    print(f"submitting {len(manifests)} manifests, populations "
          f"{POPULATIONS}, capacity N_cap={CAPACITY}\n")

    with BackgroundServer(service) as _server:
        rids = [service.submit(m) for m in manifests]
        responses = [service.wait(rid, timeout=300) for rid in rids]

    for resp in responses:
        rec = resp.records[0]
        print(f"  {resp.request_id} {resp.study:>8}  N={rec['n_clients']}  "
              f"metric={rec['mean']:.4e}  "
              f"latency={resp.timings['latency_us'] / 1e3:8.1f} ms  "
              f"quarantined={resp.quarantined}")

    stats = service.stats()
    batch = responses[0].batch
    print(f"\nbatched {batch['requests']} requests / {batch['cells']} cells "
          f"into {batch['dispatches']} structure dispatch(es)")
    print(f"compiles={stats['compiles']} "
          f"(one trace for all {len(set(POPULATIONS))} population sizes), "
          f"executable entries={stats['executable_entries']}")
    assert stats["compiles"] == 1, "mixed batch should compile once"

    # Repeat traffic: the identical manifest set again -> the executable
    # cache serves the stored runner and its compiled trace, zero new
    # compiles.
    for m in manifests:
        service.submit(m)
    service.flush()
    again = service.stats()
    print(f"repeat submission: compiles={again['compiles']} (unchanged), "
          f"cache hits={again['hits']}")
    assert again["compiles"] == stats["compiles"]

    preemption_demo(prob, manifests)
    return responses


def preemption_demo(prob, manifests):
    """Serve the burst checkpointed, kill it mid-dispatch, recover it
    bitwise from the checkpoint root with a brand-new service."""
    from repro.checkpoint.checkpoint import CheckpointManager

    def make_service(root):
        return StudyService(
            grads_fn=lambda w, k, t: prob.all_grads(w), p=prob.p,
            optimizer=sgd(0.05), loss_fn=prob.suboptimality,
            params0=jnp.zeros(DIM), cache_size=16, checkpoint_root=root)

    cfg = ExecutionConfig(checkpoint_every=20)  # 80 steps -> 4 chunks
    root = tempfile.mkdtemp(prefix="serve-ck-")

    # the uninterrupted reference dispatch, same composition
    ref_root = tempfile.mkdtemp(prefix="serve-ck-ref-")
    ref_service = make_service(ref_root)
    for m in manifests:
        ref_service.submit(m, cfg)
    reference = {r.study: r for r in ref_service.flush()}

    # "preempt" a dispatch: the second checkpoint save raises, killing
    # the flush mid-run and leaving a partial checkpoint directory
    doomed = make_service(root)
    real_save, saves = CheckpointManager.save, [0]

    def dying_save(self, step, state):
        if saves[0] >= 2:
            raise RuntimeError("simulated preemption")
        saves[0] += 1
        return real_save(self, step, state)

    CheckpointManager.save = dying_save
    try:
        for m in manifests:
            doomed.submit(m, cfg)
        (failed, *_) = doomed.flush()
    finally:
        CheckpointManager.save = real_save
    print(f"\npreempted dispatch: {failed.error}")

    # a brand-new service discovers the partial dispatch and resumes it
    fresh = make_service(root)
    rids = fresh.recover()
    resumed = [fresh.result(r) for r in rids]
    batch = resumed[0].batch
    print(f"recovered {len(rids)} request(s): resumed from step "
          f"{batch['resumed_steps']}, {batch['chunks']} chunk(s) replayed, "
          f"new compiles={batch['new_compiles']}")
    for resp in resumed:
        ref = reference[resp.study].result
        for cell in ref.cells:
            for a, b in zip(
                    jax.tree_util.tree_leaves(ref.cells[cell]),
                    jax.tree_util.tree_leaves(resp.result.cells[cell])):
                assert np.array_equal(np.asarray(a), np.asarray(b),
                                      equal_nan=True)
    print("resumed responses bitwise equal to the uninterrupted dispatch")


if __name__ == "__main__":
    main()
