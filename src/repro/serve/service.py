"""Structure-batched Study service: manifests in, labeled results out.

:class:`StudyService` is the request-driven front end of the scenario
engine (DESIGN.md §11–§12). The service owns the *model context* — one
:class:`~repro.core.trainer.ClientSimulator` (grads_fn, weights,
optimizer) and the initial parameters — while clients submit
**manifests** (:mod:`repro.experiments.manifest`): what to run, never
code. The pipeline per batch:

1. **Admit** — :meth:`submit` parses/validates the manifest (unknown
   registry names fail here, naming the registry), resolves its cells,
   and checks the population capacity. Invalid requests raise at submit;
   admitted requests queue.
2. **Batch** — :meth:`flush` drains the queue and groups requests by
   dispatch signature (step budget, seed list, ExecutionConfig). Each
   group's cells — across *all* its requests — go to
   :func:`repro.experiments.engine.execute_cells` as one scenario list,
   so the engine's structure grouping applies across requests: any mix
   of population sizes of one component structure shares a single
   compiled trace (the PR 4 invariant), and repeat structures are pure
   dispatch through the keyed :class:`~repro.serve.cache.
   ExecutableCache`.
3. **Demux** — results are split back per request (cell names are
   namespaced on the wire and restored in responses), each response
   carrying its own labeled :class:`~repro.experiments.GridResult`,
   summary records, quarantine report (diverged cells are *reported*,
   per PR 7 semantics — they never fail sibling cells or sibling
   requests), cache/batching counters and timings.

Execution errors fail only the dispatch group that raised — sibling
groups' responses still complete, and every waiter is released.

**Resumable dispatches** (DESIGN.md §12): a request whose config sets
``checkpoint_dir``/``checkpoint_every`` routes through
:func:`repro.experiments.engine.execute_cells_resumable` instead. The
dispatch group gets its own checkpoint subdirectory
``<root>/d<fingerprint>`` — named by the PR 7 study fingerprint of the
*canonically ordered* merged scenario list, so the directory is a pure
function of what is being computed, never of volatile request ids — and
a ``serve-dispatch/v1`` record (``dispatch.json``) holding the member
study manifests. A service killed mid-dispatch (including ``kill -9``)
is recovered by pointing a fresh service at the same ``checkpoint_root``
and calling :meth:`StudyService.recover`: partial dispatches resume
from their newest checkpoints and return responses bitwise identical to
the uninterrupted run; completed ones restore without re-execution.
Warm resumes are zero-compile — chunk advances route through the keyed
executable cache's :meth:`~repro.serve.cache.ExecutableCache.
chunk_runner`.

:class:`BackgroundServer` runs the flush loop on a worker thread with a
small batching window, which is what gives concurrent submitters the
cross-request structure collapse. Its :meth:`~BackgroundServer.stop`
closes admissions, drains the queue until verifiably empty, then
reopens admissions — a request is either served or refused at submit,
never silently stranded.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os
import threading
import time
from typing import Any, Callable, Sequence

from repro._lru import LRUCache
from repro.experiments import engine, manifest as manifest_mod
from repro.experiments.results import GridResult
from repro.experiments.study import ExecutionConfig, Study
from repro.serve.cache import ExecutableCache

#: ExecutionConfig fields a manifest-driven request must leave at their
#: defaults: they carry live objects (mesh, eval_fn) or select the
#: sequential baseline, none of which the batching engine serves. The
#: admission check compares against the dataclass *defaults* — not
#: truthiness — so falsy-but-set values cannot slip through.
_UNSERVABLE = ("mesh", "eval_fn", "sequential")

#: Fields that only have meaning on the checkpointed (resumable) path;
#: set without ``checkpoint_dir``/``checkpoint_every`` they would be
#: silently ignored, so admission raises a located error instead.
_RESUMABLE_ONLY = ("checkpoint_keep", "halt_on_divergence")

_CONFIG_DEFAULTS = {f.name: f.default
                    for f in dataclasses.fields(ExecutionConfig)}

#: Schema tag of the per-dispatch recovery record (``dispatch.json``).
DISPATCH_FORMAT = "serve-dispatch/v1"


@dataclasses.dataclass
class ServeResponse:
    """One request's result envelope.

    ``records`` are :meth:`GridResult.to_records` rows (per-cell seed
    stats + quarantine fields); ``quarantined`` names the cells with at
    least one diverged seed; ``batch`` describes the dispatch this
    request shared (sibling request count, merged cell count, structure
    dispatches, new compiles — plus, for resumable dispatches, the
    checkpoint dir, chunk count and the step the run resumed from);
    ``cache`` is the executable-cache snapshot after the dispatch;
    ``timings`` carries per-request ``latency_us`` (submit → response)
    and the batch's ``execute_us``. ``error`` is set — and result
    fields empty — when the request's dispatch group failed.
    """

    request_id: str
    study: str
    records: list = dataclasses.field(default_factory=list)
    divergence: dict = dataclasses.field(default_factory=dict)
    quarantined: list = dataclasses.field(default_factory=list)
    batch: dict = dataclasses.field(default_factory=dict)
    cache: dict = dataclasses.field(default_factory=dict)
    timings: dict = dataclasses.field(default_factory=dict)
    result: GridResult | None = None
    error: str | None = None


@dataclasses.dataclass
class _Request:
    rid: str
    study: Study
    config: ExecutionConfig
    cells: list  # [(Scenario, labels)] resolved at submit
    seeds_key: tuple
    submitted_at: float
    done: threading.Event


class StudyService:
    """Request-driven scenario-evaluation service (module docstring).

    Parameters mirror :meth:`repro.experiments.Study.run`'s simulator
    ingredients — the service is the long-lived owner of exactly one
    simulator, so every request's jit keys agree. ``cache_size`` bounds
    the keyed executable cache; ``response_cache_size`` bounds the
    response store (a long-lived service would otherwise pin every
    GridResult ever served — the same leak class PR 8 fixed for
    executables); ``checkpoint_root`` is where resumable dispatches
    that don't name their own ``checkpoint_dir`` land, and the
    directory :meth:`recover` scans after a restart; ``metric``
    (``cell -> (R,)``) customizes the per-seed scalar behind response
    records.
    """

    def __init__(self, *, params0, grads_fn=None, p=None, optimizer=None,
                 loss_fn=None, use_kernel: bool = False, sim=None,
                 cache_size: int = 32, response_cache_size: int = 256,
                 checkpoint_root: str | None = None,
                 metric: Callable | None = None):
        self._sim = engine._resolve_sim(sim, grads_fn, p, optimizer,
                                        loss_fn, use_kernel)
        self._params0 = params0
        self._cache = ExecutableCache(maxsize=cache_size)
        self._checkpoint_root = checkpoint_root
        self._metric = metric
        self._lock = threading.Lock()
        self._pending: list[_Request] = []
        self._requests: dict[str, _Request] = {}
        self._responses = LRUCache(maxsize=response_cache_size,
                                   on_evict=self._drop_request)
        self._progress: dict[str, dict] = {}
        self._ids = itertools.count()
        self._draining = False
        self._n_requests = 0
        self._n_cells = 0
        self._n_flushes = 0

    # ------------------------------------------------------------ admission

    @property
    def capacity(self) -> int:
        """Population capacity N_cap = len(sim.p) — the ceiling every
        request's ``n_clients`` must respect."""
        return int(self._sim.p.shape[0])

    def _parse(self, manifest, config):
        if isinstance(manifest, Study):
            return manifest, config
        if isinstance(manifest, str):
            manifest = manifest_mod.loads(manifest)
        study, mconfig = manifest_mod.request_from_manifest(manifest)
        if config is not None and mconfig is not None:
            raise ValueError(
                "request carries an execution config both in the manifest "
                "and as the config= argument — pass one")
        return study, (mconfig if config is None else config)

    def _check_config(self, config: ExecutionConfig) -> bool:
        """Admission-validate ``config``; returns whether it selects the
        resumable (checkpointed) dispatch path.

        Every check compares against the :class:`ExecutionConfig` field
        *default* and raises a located error naming the field — a
        truthiness check would silently pass ``sequential=False``-style
        falsy-but-set values and silently ignore e.g.
        ``checkpoint_every=20`` without a directory to write to.
        """
        bad = [f for f in _UNSERVABLE
               if getattr(config, f) != _CONFIG_DEFAULTS[f]]
        if bad:
            raise ValueError(
                f"ExecutionConfig fields {bad} are not serveable — the "
                f"service batches requests on the vmap engine; run those "
                f"studies through Study.run directly")
        resumable = (config.checkpoint_dir is not None
                     or config.checkpoint_every != 0)
        if config.checkpoint_every < 0:
            raise ValueError(
                f"ExecutionConfig.checkpoint_every="
                f"{config.checkpoint_every} must be >= 0")
        if resumable and config.checkpoint_dir is None \
                and self._checkpoint_root is None:
            raise ValueError(
                f"ExecutionConfig.checkpoint_every="
                f"{config.checkpoint_every} requests checkpointing but "
                f"there is nowhere to write: the config has no "
                f"checkpoint_dir and the service has no checkpoint_root")
        if not resumable:
            stray = [f"{f}={getattr(config, f)!r}" for f in _RESUMABLE_ONLY
                     if getattr(config, f) != _CONFIG_DEFAULTS[f]]
            if stray:
                raise ValueError(
                    f"ExecutionConfig fields [{', '.join(stray)}] only "
                    f"apply to checkpointed dispatches — set "
                    f"checkpoint_dir/checkpoint_every too, or drop them")
        else:
            if config.client_reduction != _CONFIG_DEFAULTS[
                    "client_reduction"]:
                raise ValueError(
                    f"ExecutionConfig.client_reduction="
                    f"{config.client_reduction!r} has no effect on the "
                    f"checkpointed dispatch path (it is not client-"
                    f"sharded) — leave it at the default")
            if config.degrade != _CONFIG_DEFAULTS["degrade"]:
                raise ValueError(
                    "ExecutionConfig.degrade has no effect on the "
                    "checkpointed dispatch path — leave it at the default")
        return resumable

    def submit(self, manifest, config: ExecutionConfig | None = None) -> str:
        """Admit one request; returns its id.

        ``manifest`` is a JSON string, a ``study/v1`` or
        ``study-request/v1`` dict, or a Study instance. Invalid requests
        — malformed manifest, unknown registry name, unserveable config,
        population above capacity — raise here, before anything queues.
        Raises ``RuntimeError`` while a :class:`BackgroundServer` drain
        is closing the queue (so no request is admitted without a
        flusher to serve it).
        """
        study, config = self._parse(manifest, config)
        config = config or ExecutionConfig()
        self._check_config(config)
        cells = study._resolve_labeled()  # validates axes & unique names
        over = [f"{sc.name} (N={sc.n_clients})" for sc, _ in cells
                if sc.n_clients > self.capacity]
        if over:
            raise ValueError(
                f"request exceeds the service population capacity "
                f"N_cap={self.capacity}: {over}")
        with self._lock:
            if self._draining:
                raise RuntimeError(
                    "service is draining (BackgroundServer.stop()) — "
                    "resubmit after shutdown completes")
            rid = f"r{next(self._ids):04d}"
            req = _Request(
                rid=rid, study=study, config=config, cells=cells,
                seeds_key=study._seed_values(),
                submitted_at=time.perf_counter(),
                done=threading.Event())
            self._pending.append(req)
            self._requests[rid] = req
            self._n_requests += 1
        return rid

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    def _begin_drain(self) -> None:
        with self._lock:
            self._draining = True

    def _end_drain(self) -> None:
        with self._lock:
            self._draining = False

    # ------------------------------------------------------------- dispatch

    def flush(self) -> list[ServeResponse]:
        """Execute every pending request, batched, and release waiters.

        Requests group by dispatch signature (num_steps, seeds, config);
        each group's cells merge into one ``execute_cells`` call, where
        the engine collapses same-structure cells — across requests —
        onto shared compiled traces via the keyed executable cache.
        Groups whose config requests checkpointing run through the
        chunked resumable path instead (module docstring).
        """
        with self._lock:
            batch, self._pending = self._pending, []
        if not batch:
            return []
        self._n_flushes += 1

        dispatch: dict[tuple, list[_Request]] = {}
        for req in batch:
            key = (req.study.num_steps, req.seeds_key, req.config)
            dispatch.setdefault(key, []).append(req)

        responses = []
        for (num_steps, seeds_key, config), reqs in dispatch.items():
            responses.extend(
                self._run_dispatch(num_steps, seeds_key, config, reqs))
        return responses

    @staticmethod
    def _canonical_order(reqs: list[_Request]) -> list[_Request]:
        """Sort a resumable dispatch group by the canonical JSON of each
        request's study manifest — a pure function of the *study*, so a
        restarted service (fresh rids) reproduces the same merged
        scenario list, the same fingerprint, and therefore the same
        checkpoint subdirectory."""
        return sorted(reqs, key=lambda r: json.dumps(
            manifest_mod.study_to_manifest(r.study), sort_keys=True))

    def _run_dispatch(self, num_steps, seeds_key, config, reqs):
        resumable = (config.checkpoint_dir is not None
                     or config.checkpoint_every != 0)
        if resumable:
            reqs = self._canonical_order(reqs)
        merged, wires = [], {}
        for j, req in enumerate(reqs):
            prefix = f"q{j:04d}" if resumable else req.rid
            for sc, _labels in req.cells:
                wire = f"{prefix}/{sc.name}"
                merged.append(dataclasses.replace(sc, name=wire))
                wires[(req.rid, sc.name)] = wire
        before = self._cache.stats()
        t0 = time.perf_counter()
        try:
            if resumable:
                results, extra = self._execute_resumable(
                    merged, num_steps, seeds_key, config, reqs)
            else:
                results = engine.execute_cells(
                    merged, sim=self._sim, params0=self._params0,
                    num_steps=num_steps, seeds=list(seeds_key),
                    client_reduction=config.client_reduction,
                    executable_cache=self._cache.bind(config))
                extra = {}
        except Exception as e:  # noqa: BLE001 — fail this group, not siblings
            responses = []
            for req in reqs:
                resp = ServeResponse(request_id=req.rid,
                                     study=req.study.name,
                                     error=f"{type(e).__name__}: {e}")
                self._finish(req, resp)
                responses.append(resp)
            return responses
        execute_us = (time.perf_counter() - t0) * 1e6
        after = self._cache.stats()
        delta = {k: after[k] - before[k]
                 for k in ("hits", "misses", "evictions", "compiles")}
        self._n_cells += len(merged)

        now = time.perf_counter()
        responses = []
        for req in reqs:
            cells = {sc.name: results[wires[(req.rid, sc.name)]]
                     for sc, _ in req.cells}
            labels = {sc.name: lab for sc, lab in req.cells}
            axes = dict(req.study._sweep_axes())
            axes["seed"] = seeds_key
            grid = GridResult(cells=cells, labels=labels, axes=axes,
                              name=req.study.name)
            div = grid.divergence()
            resp = ServeResponse(
                request_id=req.rid,
                study=req.study.name,
                records=grid.to_records(self._metric),
                divergence=div,
                quarantined=sorted(n for n, d in div.items()
                                   if d["n_diverged"] > 0),
                batch={"requests": len(reqs), "cells": len(merged),
                       "dispatches": delta["hits"] + delta["misses"],
                       "cache_hits": delta["hits"],
                       "new_compiles": delta["compiles"], **extra},
                cache=after,
                timings={"latency_us": (now - req.submitted_at) * 1e6,
                         "execute_us": execute_us},
                result=grid)
            self._finish(req, resp)
            responses.append(resp)
        return responses

    def _execute_resumable(self, merged, num_steps, seeds_key, config, reqs):
        """One checkpointed dispatch group: fingerprint-keyed subdir,
        ``dispatch.json`` recovery record, chunked execution through the
        keyed executable cache. Returns ``(results, batch_extras)``."""
        from repro.checkpoint import write_json_atomic

        seed_list = list(seeds_key)
        fingerprint = engine.study_fingerprint(
            merged, int(num_steps), seed_list, self._params0)
        root = config.checkpoint_dir or self._checkpoint_root
        cdir = os.path.join(root, f"d{fingerprint[:16]}")
        os.makedirs(cdir, exist_ok=True)
        write_json_atomic(os.path.join(cdir, "dispatch.json"), {
            "format": DISPATCH_FORMAT,
            "fingerprint": fingerprint,
            "num_steps": int(num_steps),
            "seeds": seed_list,
            "config": manifest_mod.execution_config_to_manifest(config),
            "studies": [manifest_mod.study_to_manifest(r.study)
                        for r in reqs],
            "rids": [r.rid for r in reqs],
        })

        first_step: dict[str, int] = {}
        chunks = {"n": 0}

        def _progress(gid, step, total):
            if gid not in first_step:
                first_step[gid] = int(step)
            else:
                chunks["n"] += 1
            with self._lock:
                self._progress.setdefault(fingerprint[:16], {})[gid] = (
                    int(step), int(total))

        try:
            results = engine.execute_cells_resumable(
                merged, sim=self._sim, params0=self._params0,
                num_steps=num_steps, seeds=seed_list,
                checkpoint_dir=cdir,
                checkpoint_every=config.checkpoint_every,
                keep=config.checkpoint_keep,
                halt_on_divergence=config.halt_on_divergence,
                executable_cache=self._cache.bind(config),
                progress=_progress)
        finally:
            with self._lock:
                self._progress.pop(fingerprint[:16], None)
        extra = {"resumable": True, "checkpoint_dir": cdir,
                 "chunks": chunks["n"],
                 "resumed_steps": int(sum(first_step.values()))}
        return results, extra

    # ------------------------------------------------------------- recovery

    def recover(self, *, flush: bool = True) -> list[str]:
        """Resubmit every dispatch recorded under ``checkpoint_root``.

        Scans the root for ``d*/dispatch.json`` (``serve-dispatch/v1``)
        records — written atomically *before* each resumable dispatch
        executes — and resubmits their member studies with the stored
        execution config. Because resumable wire names and ordering are
        canonical (rid-independent), each resubmission lands on the
        *same* fingerprint subdirectory: partial dispatches resume from
        their newest checkpoints (bitwise equal to the uninterrupted
        run), completed ones restore without re-execution, and warm
        resumes add zero compiles. Records are flushed one at a time so
        recovered dispatches keep their original grouping. Returns the
        new request ids (responses via :meth:`result` / :meth:`wait`).
        """
        if self._checkpoint_root is None:
            raise RuntimeError(
                "recover() needs a service checkpoint_root — construct "
                "StudyService(..., checkpoint_root=...)")
        root = self._checkpoint_root
        rids: list[str] = []
        if not os.path.isdir(root):
            return rids
        for entry in sorted(os.listdir(root)):
            path = os.path.join(root, entry, "dispatch.json")
            if not os.path.isfile(path):
                continue
            with open(path) as f:
                rec = json.load(f)
            if rec.get("format") != DISPATCH_FORMAT:
                raise ValueError(
                    f"{path}: unknown dispatch record format "
                    f"{rec.get('format')!r} (want {DISPATCH_FORMAT})")
            config = manifest_mod.execution_config_from_manifest(
                rec["config"])
            batch = [self.submit(manifest_mod.study_from_manifest(doc),
                                 config)
                     for doc in rec["studies"]]
            rids.extend(batch)
            if flush:
                self.flush()
        return rids

    # ------------------------------------------------------------- results

    def _drop_request(self, rid: str, _resp) -> None:
        # response-store eviction also forgets the request record, so
        # the pair of dicts can never diverge into a slow leak
        with self._lock:
            self._requests.pop(rid, None)

    def _finish(self, req: _Request, resp: ServeResponse) -> None:
        self._responses.put(req.rid, resp)
        req.done.set()

    def result(self, rid: str) -> ServeResponse:
        """The response for ``rid`` (KeyError if not yet flushed, or
        already evicted from the bounded response store)."""
        resp = self._responses.get(rid)
        if resp is None:
            raise KeyError(
                f"no response for request {rid!r} — not yet flushed "
                f"(call flush() or run a BackgroundServer) or evicted "
                f"from the response store")
        return resp

    def wait(self, rid: str, timeout: float | None = None) -> ServeResponse:
        """Block until ``rid`` has been served (by any flushing thread)."""
        with self._lock:
            req = self._requests.get(rid)
        if req is None:
            raise KeyError(f"unknown request id {rid!r}")
        if not req.done.wait(timeout):
            raise TimeoutError(f"request {rid!r} not served in {timeout}s")
        return self.result(rid)

    def dispatch_progress(self) -> dict:
        """Per-chunk progress of in-flight resumable dispatches:
        ``{fingerprint: {gid: (step, num_steps)}}`` snapshot."""
        with self._lock:
            return {fp: dict(groups)
                    for fp, groups in self._progress.items()}

    def stats(self) -> dict:
        """Service lifetime counters + executable-cache stats + the
        bounded response-store policy/occupancy."""
        with self._lock:
            out = {"requests": self._n_requests, "flushes": self._n_flushes,
                   "cells": self._n_cells,
                   "resumable_in_flight": len(self._progress)}
        out.update(self._cache.stats())
        out["executable_entries"] = self._cache.cache_entries()
        out["response_store"] = self._responses.stats()
        return out


class BackgroundServer:
    """Worker thread that flushes a :class:`StudyService` continuously.

    ``window_s`` is the batching window: once the queue goes non-empty
    the server waits that long before flushing, so a burst of
    submissions lands in one batch (and one structure-grouped dispatch)
    instead of N. Use as a context manager::

        with BackgroundServer(service):
            rids = [service.submit(m) for m in manifests]
            responses = [service.wait(r) for r in rids]

    :meth:`stop` closes admissions, joins the worker, then flushes
    until the queue is verifiably empty — a submit that raced the old
    single final flush used to strand its request with no flusher;
    now it is either drained here or refused at submit with a
    ``RuntimeError``. Admissions reopen after the drain (requests
    submitted after shutdown queue for a manual ``flush()``).
    """

    def __init__(self, service: StudyService, window_s: float = 0.002,
                 poll_s: float = 0.0005):
        self._service = service
        self._window_s = float(window_s)
        self._poll_s = float(poll_s)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> "BackgroundServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="study-serve")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            if self._service.pending:
                time.sleep(self._window_s)  # let the burst accumulate
                self._service.flush()
            else:
                time.sleep(self._poll_s)

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        # close admissions, then drain: with no concurrent submitter able
        # to enqueue, `pending` can only fall, so this verifiably empties
        # the queue before the last flusher (this thread) walks away.
        self._service._begin_drain()
        try:
            while self._service.pending:
                self._service.flush()
        finally:
            self._service._end_drain()

    def __enter__(self) -> "BackgroundServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
