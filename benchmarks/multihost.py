"""Benchmark: simulated 2-process ``jax.distributed`` grid execution.

Runs the canonical differential job (``repro.launch.distributed``: a
ragged Fig-1 sub-grid, 2 scheduler structures × ragged populations) in
three configurations and compares them (DESIGN.md §13):

  multihost_baseline_1proc   single-process clients-sharded dispatch
                             (8 local placeholder devices) — the
                             single-host side of the overhead ratio
  multihost_2proc_psum       the same job across 2 simulated processes
                             (4 local devices each, gloo collectives),
                             psum reduction; derived carries
                             us_per_step and overhead_pct vs baseline
  multihost_2proc_gather     ditto with the gather (bitwise-oracle)
                             reduction
  multihost_step_collective  per-step cost of the cross-process
                             collective in both modes (us=0,
                             derived-only, timing_ref'd)
  multihost_bitwise          process-0 gather results bitwise equal to
                             the single-process vmap engine (us=0)

All series are validated by ``run.check_multihost_series``.
"""

from __future__ import annotations

import json
import os
import tempfile
import time


def _time_study(study, sim, params0, config, iters: int) -> float:
    """Warm wall time per ``study.run`` dispatch, microseconds."""
    import numpy as np

    study.run(sim=sim, params0=params0, config=config)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = study.run(sim=sim, params0=params0, config=config)
    np.asarray(next(iter(out.cells.values())).params)  # sync
    return (time.perf_counter() - t0) / iters * 1e6


def run(fast: bool = False) -> list[str]:
    import numpy as np

    from repro.experiments import ExecutionConfig, placement
    from repro.launch import distributed as dist

    steps = 10 if fast else 25
    seeds = 2
    iters = 2 if fast else 3

    sim = dist.make_job_sim()
    study = dist.make_job_study(steps, seeds)
    params0 = dist.job_params0()

    # Single-process side: same mesh shape (8 clients shards), one host.
    mesh = placement.make_client_mesh()
    base_us = {
        red: _time_study(study, sim, params0,
                         ExecutionConfig(mesh=mesh, client_reduction=red),
                         iters)
        for red in ("psum", "gather")
    }

    with tempfile.TemporaryDirectory(prefix="bench_multihost_") as out_dir:
        dist.launch_simulated(2, 4, argv=[
            "--mesh", "clients", "--reduction", "gather,psum",
            "--steps", str(steps), "--seeds", str(seeds),
            "--timing-iters", str(iters), "--out", out_dir])
        with open(os.path.join(out_dir, "report_p0.json")) as f:
            report = json.load(f)
        got = dict(np.load(os.path.join(out_dir, "results.npz")))

    ref = dist.flatten_results("ref", dist.reference_results(steps, seeds))
    bitwise = all(
        np.array_equal(arr, ref["ref|%s|%s" % tuple(key.split("|")[1:])])
        for key, arr in got.items() if key.startswith("clients-gather|"))

    rows = [
        "multihost_baseline_1proc,%.1f,processes=1;devices=%d;"
        "gather_us=%.1f;steps=%d" % (
            base_us["psum"], mesh.size, base_us["gather"], steps),
    ]
    two_us = {}
    for red in ("psum", "gather"):
        combo = report["combos"][f"clients-{red}"]
        us = combo["dispatch_us"]
        two_us[red] = us
        rows.append(
            "multihost_2proc_%s,%.1f,processes=%d;global_devices=%d;"
            "us_per_step=%.1f;overhead_pct=%.1f;compiles=%d" % (
                red, us, report["process_count"],
                report["global_devices"], combo["us_per_step"],
                (us - base_us[red]) / base_us[red] * 100.0,
                combo["compiles"]))
    rows.append(
        "multihost_step_collective,0,psum_us_per_step=%.1f;"
        "gather_us_per_step=%.1f;baseline_psum_us_per_step=%.1f;"
        "timing_ref=multihost_2proc_psum" % (
            two_us["psum"] / steps, two_us["gather"] / steps,
            base_us["psum"] / steps))
    rows.append(
        "multihost_bitwise,0,bitwise=%s;cells=%d;"
        "timing_ref=multihost_2proc_gather" % (
            bitwise, len(study.resolve())))
    return rows
