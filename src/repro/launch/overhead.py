from repro._env import ensure_host_device_count

ensure_host_device_count(512)

"""Zero-collective-overhead validation (EXPERIMENTS.md §Energy-overhead).

DESIGN.md §2 claims the paper's energy weighting — per-example loss
coefficients from (mask, scale) — adds NO collective traffic over plain
data-parallel SGD. This lowers BOTH steps for an arch on the single-pod
mesh and diffs the per-kind collective bytes from the compiled HLO.

    PYTHONPATH=src python -m repro.launch.overhead --arch stablelm-1.6b
"""

import argparse  # noqa: E402
import json      # noqa: E402

import jax       # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import DEFAULT_N_CLIENTS, INPUT_SHAPES, get_config  # noqa: E402
from repro.configs.shapes import train_input_specs  # noqa: E402
from repro.core.trainer import TrainState  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import parse_collective_bytes  # noqa: E402
from repro.launch.steps import make_train_step  # noqa: E402
from repro.models import init_lm, transformer  # noqa: E402
from repro.optim import adamw, apply_updates  # noqa: E402
from repro.sharding import batch_specs, param_specs  # noqa: E402


def make_plain_step(cfg, optimizer):
    """Conventional distributed SGD step (no energy weighting)."""

    def loss_fn(params, batch):
        losses, aux = transformer.per_example_loss(params, cfg, batch)
        return jnp.mean(losses), jnp.mean(losses)

    def step(state, batch):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        (_, mean_loss), grads = grad_fn(state.params, batch)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = apply_updates(state.params, updates)
        return TrainState(params=params, opt_state=opt_state,
                          step=state.step + 1), {"loss": mean_loss}

    return step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh()
    shape = INPUT_SHAPES[args.shape]
    ns = lambda t: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))

    with mesh:
        params_s = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
        init_state, energy_step = make_train_step(cfg, DEFAULT_N_CLIENTS)
        state_s = jax.eval_shape(init_state, params_s)
        st_specs = param_specs(state_s, mesh)
        batch_s, sched_s = train_input_specs(cfg, shape)
        b_specs = batch_specs(batch_s, mesh)

        lowered_e = jax.jit(
            energy_step,
            in_shardings=(ns(st_specs), ns(b_specs), ns(P()), ns(P())),
            donate_argnums=(0,),
        ).lower(state_s, batch_s, sched_s["mask"], sched_s["scale"])
        coll_e = parse_collective_bytes(lowered_e.compile().as_text())

        plain_step = make_plain_step(cfg, adamw(1e-4))
        lowered_p = jax.jit(
            plain_step,
            in_shardings=(ns(st_specs), ns(b_specs)),
            donate_argnums=(0,),
        ).lower(state_s, batch_s)
        coll_p = parse_collective_bytes(lowered_p.compile().as_text())

    print(json.dumps({
        "arch": args.arch,
        "shape": args.shape,
        "energy_weighted": coll_e["per_kind"],
        "plain_dp_sgd": coll_p["per_kind"],
        "total_energy": coll_e["total"],
        "total_plain": coll_p["total"],
        "overhead_bytes": coll_e["total"] - coll_p["total"],
        "overhead_frac": (coll_e["total"] - coll_p["total"])
        / max(coll_p["total"], 1),
    }, indent=1))


if __name__ == "__main__":
    main()
