"""Regression tests pinning the BENCH_*.json series schema and the
--bench-out non-clobbering rule (benchmarks/run.py).

The perf-trajectory files are compared across PRs, so their shape is a
contract: every emitted series must carry ``name`` / ``values`` /
``units`` keys, and same-date files must uniquify with ``.N`` suffixes
that keep counting past ``.2``.
"""

import json
import os
import sys

import pytest

# benchmarks/ is a repo-root package (like run.py's own `sys.path.insert`);
# derive the root from this file so collection works from any cwd.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks import run as bench_run  # noqa: E402


ROWS = [
    ("fig1", "fig1_alg1_periodic,123,acc_mean=0.5;acc_std=0.01;n_nan=0"),
    ("fig1", "quadgrid_sharded_speedup,4567,speedup=3.82;devices=8;"
             "sharded_faster=True"),
    ("theory", "bound_floor,0,floor=1.733"),
    ("fig1", "largeN_sharded_N10240,99,devices=8;iters=10"),
]


def test_every_series_has_name_values_units_keys():
    for suite, row in ROWS:
        rec = bench_run._parse_row(suite, row)
        for key in ("name", "values", "units"):
            assert key in rec, f"series missing {key!r}: {rec}"
        assert isinstance(rec["values"], dict) and rec["values"]
        assert rec["units"]["us_per_call"] == "us"
        # us_per_call is a value like any other, so downstream tooling
        # can read one flat dict per series.
        assert rec["values"]["us_per_call"] == rec["us_per_call"]


def test_parse_row_values_are_typed():
    rec = bench_run._parse_row(
        "fig1", "x,10,speedup=2.5;devices=8;ok=True;label=warm")
    assert rec["values"]["speedup"] == 2.5
    assert rec["values"]["devices"] == 8.0
    assert rec["values"]["ok"] is True
    assert rec["values"]["label"] == "warm"
    assert rec["values"]["us_per_call"] == 10.0


def test_build_doc_schema_and_roundtrip():
    records = [bench_run._parse_row(s, r) for s, r in ROWS]
    doc = bench_run.build_doc(["fig1", "theory"], True, 8, records, [])
    assert doc["schema"] == bench_run.SCHEMA
    assert doc["device_count"] == 8
    loaded = json.loads(json.dumps(doc))
    for rec in loaded["results"]:
        assert {"name", "values", "units"} <= set(rec)


def test_bench_out_keeps_counting_suffixes(tmp_path):
    """Non-clobbering must keep appending .N past .2 — a PR landing
    fourth on one date writes BENCH_d.4.json, overwriting nothing."""
    d, date = str(tmp_path), "2026-07-27"
    paths = []
    for expected in ("BENCH_2026-07-27.json", "BENCH_2026-07-27.2.json",
                     "BENCH_2026-07-27.3.json", "BENCH_2026-07-27.4.json"):
        path = bench_run.bench_out_path(d, date)
        assert path == str(tmp_path / expected)
        (tmp_path / expected).write_text("{}")
        paths.append(path)
    assert len(set(paths)) == 4


def _rec(name, us, derived=None, suite="fig1"):
    return {"suite": suite, "name": name, "us_per_call": us,
            "derived": derived or {}, "values": {"us_per_call": us},
            "units": {"us_per_call": "us"}}


def test_duplicated_timings_across_names_rejected():
    """The fig1 attribution bug: many distinct series quoting one grid
    total. Three or more unattributed names on one value must fail."""
    records = [_rec(f"fig1_s{i}", 4321.0) for i in range(3)]
    with pytest.raises(ValueError, match="fig1_s0"):
        bench_run.check_distinct_timings(records)


def test_duplicated_timings_allowed_with_timing_ref():
    """Speedup/summary rows may quote another row's measurement when
    they say so via timing_ref."""
    records = [
        _rec("largeN_fused_N4096", 777.0),
        _rec("largeN_speedup_N4096", 777.0,
             {"timing_ref": "largeN_fused_N4096"}),
        _rec("largeN_summary", 777.0, {"timing_ref": "largeN_fused_N4096"}),
    ]
    bench_run.check_distinct_timings(records)  # no raise


def test_two_way_collisions_and_zero_rows_tolerated():
    """Pairs can legitimately tie (quantised clocks); 0/None mark
    derived rows that never claim to be timings."""
    records = [
        _rec("a", 5.0), _rec("b", 5.0),                 # pair: fine
        _rec("bound_floor", 0, suite="theory"),          # 0 exempt
        _rec("bound_tail", 0, suite="theory"),
        _rec("largeN_crossover", 0),
        _rec("roofline_x", None, suite="roofline_table"),
        _rec("roofline_y", None, suite="roofline_table"),
        _rec("roofline_z", None, suite="roofline_table"),
    ]
    bench_run.check_distinct_timings(records)  # no raise


def test_duplicates_grouped_per_suite():
    """The same value in different suites is coincidence, not
    mass-attribution — grouping is (suite, us)."""
    records = [_rec("a", 9.0, suite="fig1"),
               _rec("b", 9.0, suite="theory"),
               _rec("c", 9.0, suite="kernels_bench")]
    bench_run.check_distinct_timings(records)  # no raise
    records.append(_rec("d", 9.0, suite="fig1"))
    records.append(_rec("e", 9.0, suite="fig1"))
    with pytest.raises(ValueError, match="suite='fig1'"):
        bench_run.check_distinct_timings(records)


def test_bench_out_is_gap_tolerant(tmp_path):
    """A hole in the sequence (say .2 was deleted) is refilled without
    touching later files."""
    (tmp_path / "BENCH_2026-07-27.json").write_text("{}")
    (tmp_path / "BENCH_2026-07-27.3.json").write_text("{}")
    path = bench_run.bench_out_path(str(tmp_path), "2026-07-27")
    assert path.endswith("BENCH_2026-07-27.2.json")


# ------------------------------------------------------- serve_* validation

def _serve_records(**overrides):
    derived = {
        "serve_throughput": {"scenarios_per_s": 356.0, "requests": 8,
                             "cells": 8, "rounds": 3},
        "serve_latency": {"p50_us": 20000.0, "p99_us": 21000.0, "n": 24},
        "serve_cache": {"hit_rate": 1.0, "hits": 3, "misses": 0,
                        "evictions": 0, "compiles": 1, "warm_compiles": 0},
        "serve_collapse": {"populations": 6, "compiles": 1,
                           "single_trace": True, "executable_entries": 1},
        "serve_resume_uninterrupted": {"chunks": 4, "checkpoint_every": 50,
                                       "rounds": 4},
        "serve_resume_latency": {"resume_us": 60000.0,
                                 "partial_us": 55000.0,
                                 "uninterrupted_us": 100000.0,
                                 "overhead_pct": 15.0,
                                 "resumed_steps": 100, "new_compiles": 0},
        "serve_resume_bitwise": {"bitwise": True, "requests": 8},
    }
    for name, kv in overrides.items():
        derived[name] = {**derived[name], **kv}
    return [_rec(n, 100.0 * (i + 1), d, suite="serve_bench")
            for i, (n, d) in enumerate(derived.items())]


def test_serve_series_valid_set_passes():
    bench_run.check_serve_series(_serve_records())  # no raise


def test_serve_series_validation_only_applies_to_serve_suite():
    bench_run.check_serve_series([_rec("fig1_x", 5.0)])  # no raise


def test_serve_series_missing_series_named():
    records = [r for r in _serve_records() if r["name"] != "serve_latency"]
    with pytest.raises(ValueError, match="'serve_latency' missing"):
        bench_run.check_serve_series(records)


def test_serve_series_missing_derived_field_named():
    records = _serve_records()
    for r in records:
        if r["name"] == "serve_cache":
            del r["derived"]["hit_rate"]
    with pytest.raises(ValueError,
                       match=r"'serve_cache'.*missing derived.*hit_rate"):
        bench_run.check_serve_series(records)


def test_serve_series_inverted_percentiles_rejected():
    records = _serve_records(serve_latency={"p50_us": 30000.0})
    with pytest.raises(ValueError, match=r"p50_us=30000.0 > p99_us"):
        bench_run.check_serve_series(records)


def test_serve_series_hit_rate_out_of_range_rejected():
    records = _serve_records(serve_cache={"hit_rate": 1.5})
    with pytest.raises(ValueError, match=r"hit_rate=1.5 outside"):
        bench_run.check_serve_series(records)


def test_serve_series_warm_recompiles_rejected():
    """Repeat traffic recompiling means the executable cache is broken —
    the bench must fail loudly, not record a regression silently."""
    records = _serve_records(serve_cache={"warm_compiles": 2})
    with pytest.raises(ValueError, match=r"warm_compiles=2"):
        bench_run.check_serve_series(records)


def test_serve_resume_warm_recompile_rejected():
    """A warm resume that recompiles defeats the keyed chunk-runner
    cache — the bench fails loudly instead of logging the regression."""
    records = _serve_records(serve_resume_latency={"new_compiles": 1})
    with pytest.raises(ValueError, match=r"new_compiles=1.*recompiled"):
        bench_run.check_serve_series(records)


def test_serve_resume_bitwise_drift_rejected():
    records = _serve_records(serve_resume_bitwise={"bitwise": False})
    with pytest.raises(ValueError, match=r"bitwise=False.*drifted"):
        bench_run.check_serve_series(records)


def test_serve_series_foreign_name_in_suite_rejected():
    records = _serve_records() + [_rec("sneaky_row", 9.0,
                                       suite="serve_bench")]
    with pytest.raises(ValueError, match=r"sneaky_row.*named\s+serve_\*"):
        bench_run.check_serve_series(records)


# ------------------------------------------- multihost_* series family

def _multihost_records(**overrides):
    derived = {
        "multihost_baseline_1proc": {"processes": 1, "devices": 8},
        "multihost_2proc_psum": {"processes": 2, "overhead_pct": 120.0,
                                 "us_per_step": 2000.0},
        "multihost_2proc_gather": {"processes": 2, "overhead_pct": 150.0,
                                   "us_per_step": 2500.0},
        "multihost_step_collective": {
            "psum_us_per_step": 2000.0, "gather_us_per_step": 2500.0,
            "timing_ref": "multihost_2proc_psum"},
        "multihost_bitwise": {"bitwise": True,
                              "timing_ref": "multihost_2proc_gather"},
    }
    for name, kv in overrides.items():
        derived[name] = {**derived[name], **kv}
    return [_rec(n, 100.0 * (i + 1), d, suite="multihost")
            for i, (n, d) in enumerate(derived.items())]


def test_multihost_series_valid_set_passes():
    bench_run.check_multihost_series(_multihost_records())  # no raise
    bench_run.check_multihost_series([_rec("fig1_x", 5.0)])  # other suite


def test_multihost_series_missing_series_named():
    records = [r for r in _multihost_records()
               if r["name"] != "multihost_bitwise"]
    with pytest.raises(ValueError, match="'multihost_bitwise' missing"):
        bench_run.check_multihost_series(records)


def test_multihost_bitwise_drift_rejected():
    """The 2-process gather run drifting from the vmap engine is THE
    failure the multihost path must never log as a perf data point."""
    records = _multihost_records(multihost_bitwise={"bitwise": False})
    with pytest.raises(ValueError, match=r"bitwise=False.*drifted"):
        bench_run.check_multihost_series(records)


def test_multihost_single_process_run_rejected():
    records = _multihost_records(multihost_2proc_psum={"processes": 1})
    with pytest.raises(ValueError, match=r"processes=1.*did not span"):
        bench_run.check_multihost_series(records)
