"""deepseek-coder-33b — llama-architecture dense decoder.

[arXiv:2401.14196] 62L, d_model=7168, 56 heads (GQA kv=8), d_ff=19200,
vocab=32256.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab=32256,
    rope_theta=100000.0,
    long_context_window=8192,
    norm="rmsnorm",
    act="silu",
    dtype_name="bfloat16",
    remat=True,
    citation="[arXiv:2401.14196]",
)
