"""Lemma 1 (unbiasedness) — Monte-Carlo + hypothesis property tests.

The paper's central lemma: E[Σ_{i∈S_t} p_i·scale_i·g_i] = Σ_i p_i·g_i.
We verify it for all three arrival models, over random schedules/weights
(hypothesis), by checking the *expected aggregation weight* per client is
exactly p_i.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import client_weights
from repro.core.energy import (
    BinaryArrivals,
    DeterministicArrivals,
    UniformArrivals,
)
from repro.core.scheduling import make_scheduler


def mean_weights(scheduler, process, p, horizon, seed=0):
    """Time-average of ω_i = p_i·mask_i·scale_i over the run."""
    key = jax.random.PRNGKey(seed)
    sstate, estate = scheduler.init(key), process.init(key)
    p = jnp.asarray(p, jnp.float32)

    def body(carry, t):
        sstate, estate, key = carry
        key, k1, k2 = jax.random.split(key, 3)
        estate, arr = process.arrivals(estate, t, k1)
        sstate, dec = scheduler.step(sstate, t, k2, arr)
        return (sstate, estate, key), client_weights(p, dec)

    _, w = jax.lax.scan(body, (sstate, estate, key), jnp.arange(horizon))
    return np.asarray(w).mean(0)


def test_alg1_unbiased_periodic():
    taus = [1, 4, 8]
    p = np.array([0.5, 0.3, 0.2])
    det = DeterministicArrivals.periodic(taus, horizon=8 * 400)
    w = mean_weights(make_scheduler("alg1", 3), det, p, 8 * 400)
    np.testing.assert_allclose(w, p, rtol=0.08)


def test_alg2_unbiased_binary():
    p = np.array([0.25, 0.25, 0.5])
    proc = BinaryArrivals([0.2, 0.5, 0.9])
    w = mean_weights(make_scheduler("alg2", 3), proc, p, 5000)
    np.testing.assert_allclose(w, p, rtol=0.08)


def test_alg2_unbiased_uniform():
    p = np.array([0.6, 0.4])
    proc = UniformArrivals([3, 9])
    w = mean_weights(make_scheduler("alg2", 2), proc, p, 9 * 400)
    np.testing.assert_allclose(w, p, rtol=0.08)


def test_benchmark1_is_biased():
    """The failure mode the paper highlights: without scaling, expected
    weights are p_i/τ_i — biased toward energy-rich clients."""
    taus = np.array([1, 10])
    p = np.array([0.5, 0.5])
    det = DeterministicArrivals.periodic(taus, horizon=2000)
    w = mean_weights(make_scheduler("benchmark1", 2), det, p, 2000)
    np.testing.assert_allclose(w, p / taus, rtol=0.05)
    assert w[0] > 5 * w[1]  # strong bias


@settings(max_examples=20, deadline=None)
@given(
    taus=st.lists(st.integers(1, 12), min_size=2, max_size=5),
    seed=st.integers(0, 2**30),
)
def test_alg1_unbiased_random_periods(taus, seed):
    n = len(taus)
    horizon = int(np.lcm.reduce(taus)) * 60
    horizon = min(max(horizon, 600), 6000)
    p = np.random.default_rng(seed).dirichlet([2.0] * n)
    det = DeterministicArrivals.periodic(taus, horizon=horizon)
    w = mean_weights(make_scheduler("alg1", n), det, p, horizon, seed=seed)
    np.testing.assert_allclose(w, p, rtol=0.35, atol=5e-3)


@settings(max_examples=15, deadline=None)
@given(
    schedule=st.lists(
        st.lists(st.booleans(), min_size=24, max_size=24),
        min_size=1, max_size=4),
    seed=st.integers(0, 2**30),
)
def test_alg1_unbiased_arbitrary_schedules(schedule, seed):
    """Arbitrary deterministic arrival patterns (not just periodic): the
    time-summed weight over the run must equal p_i × (#covered steps),
    because Alg-1 books exactly one appointment per inter-arrival interval
    with scale = interval length.

    Steps before a client's first arrival are uncovered by construction —
    the expectation identity holds per covered interval [I_i, Ī_i)."""
    sched = np.asarray(schedule, dtype=np.float32)
    n, horizon = sched.shape
    if sched.sum() == 0:
        return
    p = np.full((n,), 1.0 / n, dtype=np.float32)
    det = DeterministicArrivals(sched)
    reps = 40
    acc = np.zeros(n)
    for r in range(reps):
        w = mean_weights(make_scheduler("alg1", n), det, p, horizon,
                         seed=seed + r)
        acc += w * horizon
    acc /= reps
    covered = np.zeros(n)
    for i in range(n):
        ts = np.flatnonzero(sched[i])
        if len(ts):
            covered[i] = horizon - ts[0]
    np.testing.assert_allclose(acc, p * covered, rtol=0.25, atol=0.15)
