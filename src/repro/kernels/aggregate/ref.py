"""Pure-jnp oracle for the masked/scaled aggregation kernel."""

import jax.numpy as jnp


def masked_scaled_aggregate_ref(g, w, mask=None):
    """g: (N, P); w: (N,) -> (P,). ``mask``: optional (N,) active rows —
    masked rows are dropped (selected to zero) before the reduction."""
    g32 = g.astype(jnp.float32)
    if mask is not None:
        g32 = jnp.where(mask.reshape(-1, 1) > 0, g32, 0.0)
    return jnp.einsum("n,np->p", w.astype(jnp.float32), g32).astype(g.dtype)


def masked_scaled_aggregate_update_ref(g, w, eta, params=None, mask=None):
    """Oracle for the fused reduce-and-update kernel: with ``params``
    returns ``params − eta·(w_sel @ g)`` (in ``params.dtype``), without
    it the f32 delta ``−eta·(w_sel @ g)``. Accumulation f32 throughout;
    masked rows are dropped by a row select, never a multiply."""
    g32 = g.astype(jnp.float32)
    if mask is not None:
        g32 = jnp.where(mask.reshape(-1, 1) > 0, g32, 0.0)
    acc = jnp.einsum("n,np->p", w.astype(jnp.float32), g32)
    eta = jnp.asarray(eta, jnp.float32)
    if params is None:
        return -eta * acc
    return (params.astype(jnp.float32) - eta * acc).astype(params.dtype)
