"""Jit'd public wrapper for the aggregation kernel.

On CPU (this container) the kernel runs in interpret mode — the kernel
body executes in Python per grid step, validating the exact TPU program.
On TPU it compiles to Mosaic. VMEM budgeting: shrink the parameter tile
so the (N, bp) block stays ≤ ~8 MB.
"""

from __future__ import annotations

import jax

from repro.core.aggregation import compose_masks  # noqa: F401  (re-export:
# the mask operand of every kernel below accepts a composed product of
# active/delivery masks — canonical impl lives with the mask machinery)
from repro.kernels.aggregate.aggregate import (
    masked_scaled_aggregate_kernel,
    masked_scaled_aggregate_update_kernel,
)

_VMEM_BUDGET = 8 * 1024 * 1024


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _fit_block(n: int, itemsize: int, block_p: int) -> int:
    while block_p > 128 and n * block_p * itemsize > _VMEM_BUDGET:
        block_p //= 2
    return block_p


def masked_scaled_aggregate(g, w, block_p: int = 2048, out_dtype=None,
                            mask=None):
    """out[p] = Σ_n w[n]·g[n,p].  g: (N, P); w: (N,) -> (P,).

    ``out_dtype`` optionally overrides the output dtype (f32 in-kernel
    accumulation either way). ``mask`` is an optional (N,) 0/1
    active-row operand: masked rows are zero-selected inside the tile
    (exact-zero contribution even for non-finite rows).
    """
    block_p = _fit_block(g.shape[0], g.dtype.itemsize, block_p)
    return masked_scaled_aggregate_kernel(
        g, w, mask, block_p=block_p, interpret=_interpret(),
        out_dtype=out_dtype)


def masked_scaled_aggregate_update(g, w, eta, params=None, mask=None, *,
                                   block_p: int = 2048, out_dtype=None):
    """Fused reduce-and-update (DESIGN.md §9), one tiled launch:

    * ``params`` given: ``params − eta·(w_sel @ g)`` — the full flat SGD
      server step (output in ``params.dtype`` unless overridden).
    * ``params`` None: the local delta ``−eta·(w_sel @ g)`` in f32 (the
      client-sharded form; the caller psums the delta across shards).

    ``mask`` rows are zero-selected inside the tile (exact-zero
    contribution even for non-finite rows); in-kernel accumulation is
    f32 either way.
    """
    block_p = _fit_block(g.shape[0], g.dtype.itemsize, block_p)
    return masked_scaled_aggregate_update_kernel(
        g, w, eta, params, mask, block_p=block_p, interpret=_interpret(),
        out_dtype=out_dtype)


def masked_scaled_aggregate_sharded(g, w, *, axis_name: str,
                                    block_p: int = 2048, out_dtype=None,
                                    mask=None):
    """Client-sharded operands (DESIGN.md §8): each device launches the
    tiled kernel over its local ``(n_local, P)`` gradient rows, then the
    ``(P,)`` partials psum across ``axis_name``. The in-kernel and
    cross-device accumulation both stay f32; the result is cast to
    ``out_dtype`` only after the collective, so low-precision outputs
    never round-trip through the reduction."""
    import jax.numpy as jnp

    partial = masked_scaled_aggregate(g, w, block_p=block_p,
                                      out_dtype=jnp.float32, mask=mask)
    out = jax.lax.psum(partial, axis_name)
    od = jnp.dtype(out_dtype) if out_dtype is not None else g.dtype
    return out.astype(od)
