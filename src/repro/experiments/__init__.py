"""Scenario engine: declarative studies, batched execution, labeled results.

* :mod:`repro.experiments.axes` — the registry of composable sweep axes
  (scheduler, arrivals, capacity, n_clients, taus_profile, seeds) that a
  study cross-multiplies into cells.
* :mod:`repro.experiments.study` — :class:`Study` specs +
  :class:`ExecutionConfig` + the named-study registry (``fig1``,
  ``fig1_grid``, ``capacity_sweep``, ``day_night``,
  ``population_scaling``); :meth:`Study.run` owns simulator construction
  and dispatch.
* :mod:`repro.experiments.results` — :class:`GridResult`, the labeled
  result table (``.sel`` / ``.reduce`` / ``.to_records`` / ``.to_json``)
  with NaN-aware seed statistics.
* :mod:`repro.experiments.scenario` — :class:`Scenario` cell specs and
  the legacy grid-registry shims (:func:`get_grid`).
* :mod:`repro.experiments.engine` — :func:`execute_cells`, the single
  execution core: one compiled computation per component structure
  (vmap over stacked pytree leaves), a sequential per-cell baseline, and
  the legacy :func:`run_grid` shims.
* :mod:`repro.experiments.placement` — device placement for
  ``mesh=``-sharded execution: each group's (scenario × seed) cells are
  flattened into one cell axis, padded to a device-divisible count, and
  executed under ``shard_map`` (DESIGN.md §5).
"""

from repro.experiments.axes import (
    AxisSpec,
    axis_names,
    get_axis,
    register_axis,
    register_taus_profile,
    resolve_taus_profile,
)
from repro.experiments.engine import (
    CellResult,
    DowngradeRecord,
    check_unique_names,
    clear_cache,
    divergence_summary,
    execute_cells,
    execute_cells_resumable,
    grid_summary,
    last_downgrades,
    make_group_runner,
    population_mask,
    run_grid,
    run_grid_sequential,
    structure_fingerprint,
    subpopulation_p,
)
from repro.experiments.manifest import (
    EXEC_FORMAT,
    REQUEST_FORMAT,
    STUDY_FORMAT,
    execution_config_from_manifest,
    execution_config_to_manifest,
    request_from_manifest,
    request_to_manifest,
    study_from_manifest,
    study_to_manifest,
)
from repro.experiments.placement import (
    make_cell_mesh,
    make_client_mesh,
    make_grid_mesh,
    run_client_sharded,
)
from repro.experiments.results import GridResult, default_metric, seed_stats
from repro.experiments.scenario import (
    ARRIVAL_KINDS,
    FIG1_SCHEDULERS,
    PAPER_TAUS,
    Scenario,
    default_taus,
    get_grid,
    grid_names,
    make_energy_process,
    register_grid,
    scenario_grid,
)
from repro.experiments.study import (
    ExecutionConfig,
    Study,
    build_components,
    get_study,
    register_study,
    study_names,
)

__all__ = [
    "ARRIVAL_KINDS", "EXEC_FORMAT", "FIG1_SCHEDULERS", "PAPER_TAUS",
    "REQUEST_FORMAT", "STUDY_FORMAT",
    "AxisSpec", "CellResult", "DowngradeRecord", "ExecutionConfig",
    "GridResult", "Scenario", "Study",
    "axis_names", "build_components", "check_unique_names", "clear_cache",
    "default_metric", "default_taus", "divergence_summary", "execute_cells",
    "execute_cells_resumable", "execution_config_from_manifest",
    "execution_config_to_manifest", "get_axis", "get_grid",
    "get_study", "grid_names", "grid_summary", "last_downgrades",
    "make_cell_mesh",
    "make_client_mesh", "make_energy_process", "make_grid_mesh",
    "make_group_runner",
    "population_mask", "register_axis",
    "register_grid", "register_study", "register_taus_profile",
    "request_from_manifest", "request_to_manifest",
    "resolve_taus_profile", "run_client_sharded", "run_grid",
    "run_grid_sequential",
    "scenario_grid", "seed_stats", "structure_fingerprint",
    "study_from_manifest", "study_names", "study_to_manifest",
    "subpopulation_p",
]
