"""Optimizer + schedule tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adam,
    adamw,
    apply_updates,
    chain_clip,
    constant_schedule,
    cosine_schedule,
    inverse_time_schedule,
    momentum,
    sgd,
    warmup_cosine_schedule,
)


def rosenbrock_ish(params):
    # simple convex bowl with different curvatures
    return jnp.sum(params["a"] ** 2) + 10.0 * jnp.sum(params["b"] ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.05),
    lambda: momentum(0.02, 0.9),
    lambda: momentum(0.02, 0.9, nesterov=True),
    lambda: adam(0.1),
    lambda: adamw(0.1, weight_decay=0.001),
    lambda: chain_clip(adam(0.1), 1.0),
])
def test_optimizers_minimize(make_opt):
    opt = make_opt()
    params = {"a": jnp.asarray([1.0, -2.0]), "b": jnp.asarray([[0.5]])}
    state = opt.init(params)
    loss0 = rosenbrock_ish(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(rosenbrock_ish)(params)
        updates, state = opt.update(grads, state, params)
        return apply_updates(params, updates), state

    for _ in range(200):
        params, state = step(params, state)
    assert float(rosenbrock_ish(params)) < 1e-3 * float(loss0)


def test_adam_moments_are_f32_under_bf16_params():
    opt = adam(1e-3)
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.float32
    grads = {"w": jnp.ones((4,), jnp.bfloat16)}
    updates, state2 = opt.update(grads, state, params)
    assert updates["w"].dtype == jnp.bfloat16
    assert state2.nu["w"].dtype == jnp.float32


def test_clip_bounds_update_norm():
    opt = chain_clip(sgd(1.0), max_norm=0.5)
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    grads = {"w": jnp.asarray([30.0, 40.0, 0.0])}  # norm 50
    updates, _ = opt.update(grads, state, params)
    np.testing.assert_allclose(jnp.linalg.norm(updates["w"]), 0.5, rtol=1e-5)


def test_schedules():
    s = jnp.asarray(0), jnp.asarray(100)
    assert float(constant_schedule(0.1)(s[0])) == pytest.approx(0.1)
    inv = inverse_time_schedule(1.0, 0.1)
    assert float(inv(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(inv(jnp.asarray(90))) == pytest.approx(0.1)
    cos = cosine_schedule(1.0, 100, lr_min=0.1)
    assert float(cos(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.1)
    wc = warmup_cosine_schedule(1.0, 10, 110)
    assert float(wc(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)


def test_inverse_time_schedule_kills_error_floor():
    """Remark 1: with η_t = η₀/(1+κt), SGD on a noisy quadratic converges
    below the constant-step error floor."""
    key = jax.random.PRNGKey(0)

    def run(lr):
        opt = sgd(lr)
        w = jnp.asarray([5.0])
        state = opt.init(w)
        k = key
        for _ in range(3000):
            k, kn = jax.random.split(k)
            g = 2 * w + jax.random.normal(kn, (1,))
            updates, state = opt.update(g, state, w)
            w = apply_updates(w, updates)
        return float(w[0] ** 2)

    const_floor = run(0.1)
    decayed = run(inverse_time_schedule(0.1, 0.01))
    assert decayed < const_floor
