"""StudyService acceptance suite (DESIGN.md §11).

The tentpole invariants:

* a mixed-population batch of ≥ 8 manifests sharing one component
  structure compiles exactly ONE trace (jit-cache-entry assertion), and
  each request's result is bitwise equal to running its Study alone on
  the vmap engine;
* repeat submission of the identical manifest set is an executable-cache
  hit — zero new compiles;
* the cache is a bounded LRU — overflow evicts, counters tell the story;
* a fault-poisoned request is quarantined in its own response without
  failing sibling requests sharing the dispatch.
"""

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.convergence import make_quadratic
from repro.experiments import ExecutionConfig, Study
from repro.optim import sgd
from repro.serve import BackgroundServer, StudyService

pytestmark = pytest.mark.serve

CAPACITY, DIM, STEPS = 8, 4, 20
POPULATIONS = [3, 4, 5, 6, 7, 8, 3, 5]


@pytest.fixture(scope="module")
def prob():
    return make_quadratic(jax.random.PRNGKey(0), CAPACITY, dim=DIM)


@pytest.fixture(scope="module")
def grads_fn(prob):
    return lambda w, k, t: prob.all_grads(w)


def make_service(prob, grads_fn, **kw):
    kw.setdefault("cache_size", 8)
    return StudyService(grads_fn=grads_fn, p=prob.p, optimizer=sgd(0.05),
                       params0=jnp.zeros(DIM), **kw)


def make_study(name: str, n: int, *, scheduler="alg1", arrivals="periodic",
               steps=STEPS, faults=None, seeds=(0, 1)) -> Study:
    study = (Study(name, num_steps=steps).axis("scheduler", scheduler)
             .axis("arrivals", arrivals).axis("n_clients", n)
             .axis("seeds", list(seeds)))
    if faults is not None:
        study.axis("faults", faults)
    return study


# ----------------------------------------------------- single-trace collapse

def test_mixed_population_batch_compiles_one_trace(prob, grads_fn):
    """≥ 8 manifests, 6 distinct population sizes, one structure ->
    exactly one compile and one live jit-cache entry."""
    svc = make_service(prob, grads_fn)
    for i, n in enumerate(POPULATIONS):
        svc.submit(make_study(f"s{i}", n).to_json())
    responses = svc.flush()
    assert len(responses) == len(POPULATIONS)
    assert all(r.error is None for r in responses)
    stats = svc.stats()
    assert stats["compiles"] == 1
    assert stats["executable_entries"] == 1  # ONE compiled program total
    assert responses[0].batch == {
        "requests": 8, "cells": 8, "dispatches": 1, "cache_hits": 0,
        "new_compiles": 1}


def test_batched_result_bitwise_equals_solo_study_run(prob, grads_fn):
    """Every request demuxed from the shared dispatch must be bitwise
    identical to running its Study alone through the vmap engine."""
    svc = make_service(prob, grads_fn)
    studies = [make_study(f"s{i}", n) for i, n in enumerate(POPULATIONS)]
    rids = [svc.submit(s.to_json()) for s in studies]
    svc.flush()
    for rid, study in zip(rids, studies):
        served = svc.result(rid).result
        solo = study.run(grads_fn=grads_fn, p=prob.p, optimizer=sgd(0.05),
                         params0=jnp.zeros(DIM))
        assert set(served.cells) == set(solo.cells)
        for name in solo.cells:
            for a, b in zip(jax.tree_util.tree_leaves(solo.cells[name]),
                            jax.tree_util.tree_leaves(served.cells[name])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_repeat_submission_is_pure_cache_hit(prob, grads_fn):
    svc = make_service(prob, grads_fn)
    manifests = [make_study(f"s{i}", n).to_json()
                 for i, n in enumerate(POPULATIONS)]
    for m in manifests:
        svc.submit(m)
    svc.flush()
    first = svc.stats()
    for m in manifests:  # identical manifest set again
        svc.submit(m)
    responses = svc.flush()
    second = svc.stats()
    assert second["compiles"] == first["compiles"] == 1
    assert second["hits"] == first["hits"] + 1
    assert responses[0].batch["new_compiles"] == 0
    assert responses[0].batch["cache_hits"] == 1


# ------------------------------------------------------------ cache bounds

def test_executable_cache_eviction_is_bounded_lru(prob, grads_fn):
    svc = make_service(prob, grads_fn, cache_size=1)
    a = make_study("a", 4).to_json()  # structure 1
    b = make_study("b", 4, scheduler="alg2", arrivals="binary").to_json()
    for m in (a, b, a):  # b evicts a; the re-run of a evicts b
        svc.submit(m)
        svc.flush()
    stats = svc.stats()
    assert stats["evictions"] == 2
    assert stats["size"] == 1
    assert stats["executable_entries"] >= 1
    assert stats["compiles"] == 3  # the third submit recompiled structure 1


def test_distinct_execution_configs_never_share_entries(prob, grads_fn):
    svc = make_service(prob, grads_fn)
    m = make_study("a", 4).to_json()
    svc.submit(m, config=ExecutionConfig(client_reduction="psum"))
    svc.flush()
    svc.submit(m, config=ExecutionConfig(client_reduction="gather"))
    svc.flush()
    assert svc.stats()["size"] == 2  # one entry per (structure, config)


# --------------------------------------------------------------- quarantine

def test_poisoned_request_quarantined_without_failing_siblings(prob, grads_fn):
    """PR 7 semantics at the request level: a fault-poisoned cell is
    reported in ITS response's quarantine list; sibling requests in the
    same flush complete clean."""
    svc = make_service(prob, grads_fn)
    clean = [svc.submit(make_study(f"c{i}", n).to_json())
             for i, n in enumerate((3, 5))]
    poisoned = svc.submit(make_study(
        "p", 4, faults=("corrupt", {"rate": 1.0, "scale": float("nan")}),
    ).to_json())
    responses = svc.flush()
    assert len(responses) == 3 and all(r.error is None for r in responses)
    bad = svc.result(poisoned)
    assert bad.quarantined  # every seed poisoned from step 0
    assert bad.divergence[bad.quarantined[0]]["n_diverged"] == 2
    assert all(r["first_bad_step"] == 0 for r in bad.records)
    for rid in clean:
        resp = svc.result(rid)
        assert resp.quarantined == []
        assert all(r["n_diverged"] == 0 for r in resp.records)


def test_dispatch_failure_isolated_to_its_group(prob, grads_fn, monkeypatch):
    """An engine error fails only the dispatch group that raised; other
    groups in the same flush still answer, and every waiter is
    released."""
    from repro.experiments import engine

    real = engine.execute_cells

    def exploding(scenarios, **kw):
        if kw.get("num_steps") == STEPS + 5:  # the doomed dispatch group
            raise RuntimeError("injected engine failure")
        return real(scenarios, **kw)

    monkeypatch.setattr(engine, "execute_cells", exploding)
    svc = make_service(prob, grads_fn)
    ok = svc.submit(make_study("fine", 4).to_json())
    # different num_steps -> its own dispatch group
    bad = svc.submit(make_study("boom", 4, steps=STEPS + 5).to_json())
    responses = svc.flush()
    assert len(responses) == 2
    assert svc.result(bad).error is not None
    assert "injected engine failure" in svc.result(bad).error
    assert svc.result(bad).records == []
    assert svc.result(ok).error is None and svc.result(ok).records


# ---------------------------------------------------------------- admission

def test_unserveable_config_rejected_at_submit(prob, grads_fn):
    """Live-object / sequential configs still refuse at submit — and the
    check compares against field *defaults*, not truthiness."""
    svc = make_service(prob, grads_fn)
    study = make_study("s", 4)
    for field, value in (("sequential", True), ("eval_fn", lambda p: p),
                         ("mesh", object())):
        cfg = ExecutionConfig(**{field: value})
        with pytest.raises(ValueError, match=rf"{field}.*not serveable"):
            svc.submit(study, config=cfg)
    assert svc.pending == 0


def test_incoherent_checkpoint_config_raises_located_error(prob, grads_fn):
    """checkpoint_every without anywhere to write, and resumable-only or
    resumable-meaningless fields set on the wrong path, must raise an
    error naming the offending field — not pass silently (the old
    truthiness check let checkpoint_every=20 through with no dir)."""
    svc = make_service(prob, grads_fn)  # no checkpoint_root
    study = make_study("s", 4)
    cases = (
        (dict(checkpoint_every=20), r"checkpoint_every=20"),
        (dict(checkpoint_every=-1), r"checkpoint_every=-1"),
        (dict(checkpoint_keep=5), r"checkpoint_keep=5"),
        (dict(halt_on_divergence=True), r"halt_on_divergence=True"),
        (dict(checkpoint_every=5, checkpoint_dir="/tmp/x",
              client_reduction="gather"), r"client_reduction='gather'"),
        (dict(checkpoint_every=5, checkpoint_dir="/tmp/x", degrade=True),
         r"degrade"),
    )
    for fields, pattern in cases:
        with pytest.raises(ValueError, match=pattern):
            svc.submit(study, config=ExecutionConfig(**fields))
    assert svc.pending == 0


def test_checkpoint_every_admitted_with_service_root(prob, grads_fn,
                                                     tmp_path):
    """The same checkpoint_every-only config that raises without a root
    is serveable once the service owns one."""
    svc = make_service(prob, grads_fn, checkpoint_root=str(tmp_path))
    rid = svc.submit(make_study("s", 4), ExecutionConfig(checkpoint_every=10))
    (resp,) = svc.flush()
    assert resp.error is None and resp.request_id == rid
    assert resp.batch["resumable"] is True


def test_capacity_overflow_rejected_at_submit(prob, grads_fn):
    svc = make_service(prob, grads_fn)
    with pytest.raises(ValueError, match=rf"N_cap={CAPACITY}.*N=40"):
        svc.submit(make_study("big", 40).to_json())
    assert svc.pending == 0


def test_unknown_registry_name_rejected_at_submit(prob, grads_fn):
    svc = make_service(prob, grads_fn)
    doc = make_study("s", 4).to_manifest()
    doc["axes"][0]["values"] = ["sgd_magic"]
    with pytest.raises(ValueError, match=r"scheduler registry"):
        svc.submit(doc)


def test_duplicate_config_sources_rejected(prob, grads_fn):
    from repro.experiments import request_to_manifest

    svc = make_service(prob, grads_fn)
    doc = request_to_manifest(make_study("s", 4),
                              ExecutionConfig(client_reduction="gather"))
    with pytest.raises(ValueError, match=r"both in the manifest"):
        svc.submit(doc, config=ExecutionConfig())


# -------------------------------------------------------------------- demux

def test_demux_restores_request_local_names_and_labels(prob, grads_fn):
    """Two requests may use identical study/cell names — the service
    namespaces on the wire and restores local names in each response."""
    svc = make_service(prob, grads_fn)
    r1 = svc.submit(make_study("same", 3).to_json())
    r2 = svc.submit(make_study("same", 5).to_json())
    svc.flush()
    g1, g2 = svc.result(r1).result, svc.result(r2).result
    assert list(g1.cells) == list(g2.cells) == ["alg1_periodic"]
    assert g1.labels("alg1_periodic")["n_clients"] == 3
    assert g2.labels("alg1_periodic")["n_clients"] == 5
    assert svc.result(r1).records[0]["n_clients"] == 3


def test_wait_via_background_server(prob, grads_fn):
    svc = make_service(prob, grads_fn)
    with BackgroundServer(svc):
        rids = [svc.submit(make_study(f"s{i}", n).to_json())
                for i, n in enumerate(POPULATIONS)]
        responses = [svc.wait(rid, timeout=300) for rid in rids]
    assert all(r.error is None for r in responses)
    assert svc.stats()["compiles"] <= 2  # burst may split into <=2 batches
    with pytest.raises(KeyError, match="unknown request id"):
        svc.wait("r9999")


def test_result_before_flush_raises(prob, grads_fn):
    svc = make_service(prob, grads_fn)
    rid = svc.submit(make_study("s", 4).to_json())
    with pytest.raises(KeyError, match="no response"):
        svc.result(rid)
    svc.flush()
    assert svc.result(rid).request_id == rid


# -------------------------------------------------- resumable dispatch (§12)

def _assert_grids_bitwise(a, b):
    assert set(a.cells) == set(b.cells)
    for name in a.cells:
        for la, lb in zip(jax.tree_util.tree_leaves(a.cells[name]),
                          jax.tree_util.tree_leaves(b.cells[name])):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_resumable_dispatch_bitwise_equals_unchunked(prob, grads_fn,
                                                     tmp_path):
    """A checkpointed (chunked) serve dispatch returns results bitwise
    equal to the plain unchunked vmap engine — chunking a scan never
    changes a bit (PR 7 invariant, now on the serve path)."""
    svc = make_service(prob, grads_fn, checkpoint_root=str(tmp_path))
    cfg = ExecutionConfig(checkpoint_every=5)
    studies = [make_study(f"s{i}", n) for i, n in enumerate((3, 5, 8))]
    rids = [svc.submit(s, cfg) for s in studies]
    responses = svc.flush()
    assert all(r.error is None for r in responses)
    assert responses[0].batch["chunks"] == STEPS // 5
    for rid, study in zip(rids, studies):
        solo = study.run(grads_fn=grads_fn, p=prob.p, optimizer=sgd(0.05),
                         params0=jnp.zeros(DIM))
        _assert_grids_bitwise(solo, svc.result(rid).result)


def test_interrupted_dispatch_warm_resume_zero_new_compiles(
        prob, grads_fn, tmp_path, monkeypatch):
    """Kill a checkpointed dispatch mid-run (save raises after 2 chunks),
    resubmit the same manifests: the retry resumes from the checkpoint
    tail with ZERO new compiles (chunk runners come from the keyed
    executable cache) and the result is bitwise equal to an
    uninterrupted run."""
    from repro.checkpoint import CheckpointManager

    svc = make_service(prob, grads_fn, checkpoint_root=str(tmp_path))
    cfg = ExecutionConfig(checkpoint_every=5)
    manifests = [make_study(f"s{i}", n).to_json() for i, n in
                 enumerate((3, 5, 8))]

    real_save, saves = CheckpointManager.save, [0]

    def dying_save(self, step, state):
        if saves[0] >= 2:
            raise RuntimeError("injected preemption")
        saves[0] += 1
        return real_save(self, step, state)

    monkeypatch.setattr(CheckpointManager, "save", dying_save)
    for m in manifests:
        svc.submit(m, ExecutionConfig(checkpoint_every=5))
    (first, *_) = svc.flush()
    assert first.error is not None and "injected preemption" in first.error

    monkeypatch.setattr(CheckpointManager, "save", real_save)
    rids = [svc.submit(m, cfg) for m in manifests]
    before = svc.stats()["compiles"]
    responses = svc.flush()
    assert all(r.error is None for r in responses)
    assert responses[0].batch["resumed_steps"] == 10  # 2 chunks survived
    assert responses[0].batch["new_compiles"] == 0
    assert svc.stats()["compiles"] == before  # warm resume: pure dispatch
    for rid, n in zip(rids, (3, 5, 8)):
        solo = make_study(f"s{rids.index(rid)}", n).run(
            grads_fn=grads_fn, p=prob.p, optimizer=sgd(0.05),
            params0=jnp.zeros(DIM))
        _assert_grids_bitwise(solo, svc.result(rid).result)


def test_recover_restores_completed_dispatch_without_execution(
        prob, grads_fn, tmp_path):
    """A fresh service pointed at the checkpoint root rediscovers a
    finished dispatch from its dispatch.json and serves it by pure
    checkpoint restore — zero compiles, zero chunks, bitwise equal."""
    root = str(tmp_path)
    cfg = ExecutionConfig(checkpoint_every=5)
    svc = make_service(prob, grads_fn, checkpoint_root=root)
    rid = svc.submit(make_study("s", 5), cfg)
    svc.flush()
    original = svc.result(rid).result

    fresh = make_service(prob, grads_fn, checkpoint_root=root)
    (rid2,) = fresh.recover()
    resp = fresh.result(rid2)
    assert resp.error is None
    assert resp.batch["resumed_steps"] == STEPS
    assert resp.batch["chunks"] == 0
    assert fresh.stats()["compiles"] == 0
    _assert_grids_bitwise(original, resp.result)


def test_recover_without_root_raises(prob, grads_fn):
    with pytest.raises(RuntimeError, match="checkpoint_root"):
        make_service(prob, grads_fn).recover()


# ------------------------------------------------- response store (bounded)

def test_response_store_is_bounded_lru(prob, grads_fn):
    """Responses no longer accumulate forever: the store is a bounded
    LRU; eviction forgets the request record too, and the policy shows
    up in stats()."""
    svc = make_service(prob, grads_fn, response_cache_size=2)
    rids = [svc.submit(make_study(f"s{i}", n).to_json())
            for i, n in enumerate((3, 5, 8))]
    svc.flush()
    store = svc.stats()["response_store"]
    assert store["maxsize"] == 2 and store["size"] == 2
    assert store["evictions"] == 1
    with pytest.raises(KeyError, match="no response"):
        svc.result(rids[0])  # evicted (oldest)
    with pytest.raises(KeyError, match="unknown request id"):
        svc.wait(rids[0])  # request record evicted with it
    assert svc.result(rids[1]).error is None
    assert svc.result(rids[2]).error is None


# --------------------------------------------------------- shutdown & races

def test_stop_drains_queue_verifiably_empty(prob, grads_fn):
    """Requests sitting in the queue when stop() is called are served by
    the drain loop — stop() never walks away from a non-empty queue."""
    svc = make_service(prob, grads_fn)
    server = BackgroundServer(svc, window_s=0.05)
    server.start()
    rids = [svc.submit(make_study(f"s{i}", n).to_json())
            for i, n in enumerate(POPULATIONS)]
    server.stop()  # immediately: worker may not have flushed yet
    assert svc.pending == 0
    for rid in rids:
        assert svc.result(rid).error is None


def test_submit_while_draining_is_refused_not_stranded(prob, grads_fn):
    """During the stop() drain admissions are closed: a racing submit
    raises instead of landing in a queue with no flusher. Admissions
    reopen afterwards (the post-shutdown manual-flush pattern)."""
    svc = make_service(prob, grads_fn)
    svc._begin_drain()
    with pytest.raises(RuntimeError, match="draining"):
        svc.submit(make_study("s", 4).to_json())
    svc._end_drain()
    rid = svc.submit(make_study("s", 4).to_json())
    svc.flush()
    assert svc.result(rid).error is None


def test_concurrent_submitters_with_competing_flushers(prob, grads_fn):
    """The concurrent-serve stress test: many threads submit mixed-
    population manifests through one BackgroundServer while another
    thread hammers flush(); every waiter releases, every response is
    bitwise equal to its solo Study.run, and the cache counters stay
    consistent (each miss inserted exactly one entry — no lost
    updates)."""
    svc = make_service(prob, grads_fn, cache_size=8,
                       response_cache_size=256)
    pops = POPULATIONS
    solo = {n: make_study(f"ref{n}", n).run(
                grads_fn=grads_fn, p=prob.p, optimizer=sgd(0.05),
                params0=jnp.zeros(DIM))
            for n in sorted(set(pops))}
    n_threads, per_thread = 6, len(pops)
    errors, results = [], {}
    lock = threading.Lock()
    barrier = threading.Barrier(n_threads + 1)

    def submitter(tid):
        try:
            barrier.wait()
            for i, n in enumerate(pops):
                name = f"t{tid}_{i}"
                rid = svc.submit(make_study(name, n).to_json())
                resp = svc.wait(rid, timeout=300)
                with lock:
                    results[(tid, i, n)] = resp
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    def flusher():
        barrier.wait()
        for _ in range(200):
            svc.flush()
            time.sleep(0.001)

    with BackgroundServer(svc):
        threads = [threading.Thread(target=submitter, args=(t,))
                   for t in range(n_threads)]
        threads.append(threading.Thread(target=flusher))
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    assert not errors
    assert len(results) == n_threads * per_thread  # every waiter released
    for (tid, i, n), resp in results.items():
        assert resp.error is None
        served = resp.result
        ref = solo[n]
        (ref_cell,) = ref.cells.values()
        (served_cell,) = served.cells.values()
        for la, lb in zip(jax.tree_util.tree_leaves(ref_cell),
                          jax.tree_util.tree_leaves(served_cell)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    stats = svc.stats()
    assert stats["requests"] == n_threads * per_thread
    # no lost updates: every miss inserted exactly one cache entry
    assert stats["misses"] == stats["size"] + stats["evictions"]
    assert stats["compiles"] >= 1
    assert stats["response_store"]["size"] == n_threads * per_thread
