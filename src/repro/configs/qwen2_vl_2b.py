"""qwen2-vl-2b — VLM decoder with M-RoPE (transformer backbone only).

[arXiv:2409.12191] 28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960,
vocab=151936, M-RoPE sections (16, 24, 24) over head_dim=128, dynamic
resolution. The ViT vision encoder + projector is a STUB per the
assignment: ``input_specs`` provides precomputed patch embeddings
(B, 256, 1536) that the model scatters into the token stream.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    m_rope=True,
    mrope_sections=(16, 24, 24),
    n_vision_tokens=256,
    rope_theta=1000000.0,
    long_context_window=8192,
    norm="rmsnorm",
    act="silu",
    use_bias=True,  # qwen2 qkv biases
    dtype_name="bfloat16",
    remat=True,
    citation="[arXiv:2409.12191]",
)
