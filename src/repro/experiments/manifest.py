"""Serializable Study manifests: typed config-from-dict over the registries.

A **manifest** is the JSON form of a :class:`~repro.experiments.Study`
(and optionally an :class:`~repro.experiments.ExecutionConfig`) — the
wire format of the serve layer (DESIGN.md §11). Three schema-versioned
envelopes:

* ``study/v1`` — a Study: name, step budget, ordered sweep axes with
  their fixed/swept flags, seeds.
* ``execution-config/v1`` — the serializable subset of ExecutionConfig
  (``mesh`` / ``eval_fn`` carry live objects and are rejected with a
  named error; manifests run the vmap path).
* ``study-request/v1`` — the service request: a study envelope plus an
  optional execution envelope.

Decoding is *typed-config-from-dict* over the existing registries: every
axis name resolves through :func:`repro.experiments.axes.get_axis` (an
unknown axis names the axis registry and its keys) and every axis value
runs the axis's ``validate`` hook (an unknown scheduler / arrival family
/ fault family / taus profile names **its** registry and valid keys) —
so a malformed manifest fails loudly at ``from_json`` time, never deep
inside a compiled dispatch. Round-trip is exact:
``Study.from_json(study.to_json())`` reproduces axes, fixed-ness, seeds
and resolution (tuple values — ``("day_night", {"period": 50})`` pairs,
explicit taus vectors — are tagged in JSON so they decode back to
tuples).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import numpy as np

#: Schema tags — bump on incompatible layout changes.
STUDY_FORMAT = "study/v1"
EXEC_FORMAT = "execution-config/v1"
REQUEST_FORMAT = "study-request/v1"

_TUPLE_TAG = "__tuple__"


# ------------------------------------------------------------ value codec

def encode_value(v, *, where: str = "value"):
    """Encode one axis value into JSON-safe form.

    Tuples are tagged (``{"__tuple__": [...]}``) so round-trip restores
    them exactly — the axes layer distinguishes tuples (one
    hyperparameterized ``(kind, kwargs)`` value) from lists (a sweep).
    Unserializable values (callables, arbitrary objects) raise naming
    the offending location.
    """
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return [encode_value(x, where=where) for x in v.tolist()]
    if isinstance(v, tuple):
        return {_TUPLE_TAG: [encode_value(x, where=where) for x in v]}
    if isinstance(v, list):
        return [encode_value(x, where=where) for x in v]
    if isinstance(v, dict):
        bad = [k for k in v if not isinstance(k, str)]
        if bad:
            raise ValueError(
                f"{where}: dict keys must be strings, got {bad!r}")
        if _TUPLE_TAG in v:
            raise ValueError(
                f"{where}: dict key {_TUPLE_TAG!r} is reserved by the "
                f"manifest codec")
        return {k: encode_value(x, where=f"{where}[{k}]")
                for k, x in v.items()}
    raise ValueError(
        f"{where}: {type(v).__name__} value {v!r} is not manifest-"
        f"serializable (plain scalars, strings, lists, dicts and tuples "
        f"only)")


def decode_value(v):
    """Inverse of :func:`encode_value` (tagged tuples restored)."""
    if isinstance(v, dict):
        if set(v) == {_TUPLE_TAG}:
            return tuple(decode_value(x) for x in v[_TUPLE_TAG])
        return {k: decode_value(x) for k, x in v.items()}
    if isinstance(v, list):
        return [decode_value(x) for x in v]
    return v


# -------------------------------------------------------------- envelopes

def _require_dict(doc, what: str) -> dict:
    if not isinstance(doc, dict):
        raise ValueError(
            f"{what} manifest must be a JSON object, got "
            f"{type(doc).__name__}")
    return doc


def _check_format(doc: dict, want: str, what: str) -> None:
    got = doc.get("format")
    if got != want:
        raise ValueError(
            f"{what} manifest has unsupported format {got!r}; this "
            f"build reads {want!r}")


def _check_keys(doc: dict, allowed, what: str) -> None:
    unknown = sorted(set(doc) - set(allowed))
    if unknown:
        raise ValueError(
            f"{what} manifest has unknown key(s) {unknown}; valid keys: "
            f"{sorted(allowed)}")


def loads(text: str) -> dict:
    """``json.loads`` with a manifest-flavored error for bad payloads
    (truncated uploads are the common service failure mode)."""
    try:
        return json.loads(text)
    except json.JSONDecodeError as e:
        raise ValueError(
            f"manifest is not valid JSON (truncated or corrupt?): {e}"
        ) from None


# ---------------------------------------------------------------- study

def study_to_manifest(study) -> dict:
    """Encode a Study as a ``study/v1`` envelope (see module docstring)."""
    axes_doc = []
    for name, values in study.axes.items():
        if name == "seeds":
            continue
        axes_doc.append({
            "axis": name,
            "fixed": name in study._fixed,
            "values": [encode_value(v, where=f"axis {name!r}")
                       for v in values],
        })
    return {
        "format": STUDY_FORMAT,
        "name": study.name,
        "num_steps": int(study.num_steps),
        "axes": axes_doc,
        "seeds": encode_value(study.seeds(), where="seeds"),
    }


def study_from_manifest(doc: dict):
    """Decode a ``study/v1`` envelope into a Study.

    Every axis resolves through the axis registry and every value runs
    the axis's registry validator — errors name the registry and its
    valid keys (module docstring).
    """
    from repro.experiments.axes import get_axis
    from repro.experiments.study import Study

    doc = _require_dict(doc, "study")
    _check_format(doc, STUDY_FORMAT, "study")
    _check_keys(doc, ("format", "name", "num_steps", "axes", "seeds"),
                "study")
    for key in ("name", "num_steps", "axes"):
        if key not in doc:
            raise ValueError(f"study manifest missing required key {key!r}")

    study = Study(str(doc["name"]), num_steps=int(doc["num_steps"]))
    axes_doc = doc["axes"]
    if not isinstance(axes_doc, list):
        raise ValueError(
            f"study manifest 'axes' must be a list of axis entries, got "
            f"{type(axes_doc).__name__}")
    for entry in axes_doc:
        entry = _require_dict(entry, "axis entry")
        _check_keys(entry, ("axis", "fixed", "values"), "axis entry")
        for key in ("axis", "values"):
            if key not in entry:
                raise ValueError(
                    f"axis entry missing required key {key!r}: {entry}")
        name = entry["axis"]
        spec = get_axis(name)  # unknown axis -> names the axis registry
        values = [decode_value(v) for v in entry["values"]]
        if not values:
            raise ValueError(f"axis {name!r} has an empty values list")
        if spec.validate is not None:
            for v in values:
                try:
                    spec.validate(v)
                except ValueError as e:
                    raise ValueError(f"axis {name!r}: {e}") from None
        fixed = bool(entry.get("fixed", len(values) == 1))
        study.axis(name, values[0] if fixed else list(values))
    if "seeds" in doc:
        study.axis("seeds", decode_value(doc["seeds"]))
    return study


# ----------------------------------------------------- execution config

#: ExecutionConfig fields that carry live python objects — they cannot
#: cross a JSON boundary, so a manifest must leave them at their
#: defaults (None); the serve layer runs the vmap path.
_EXEC_LIVE_FIELDS = ("mesh", "eval_fn")


def _exec_fields():
    from repro.experiments.study import ExecutionConfig

    return [f.name for f in dataclasses.fields(ExecutionConfig)]


def execution_config_to_manifest(config) -> dict:
    """Encode an ExecutionConfig as an ``execution-config/v1`` envelope."""
    doc: dict[str, Any] = {"format": EXEC_FORMAT}
    for f in dataclasses.fields(config):
        value = getattr(config, f.name)
        if f.name in _EXEC_LIVE_FIELDS:
            if value is not None:
                raise ValueError(
                    f"ExecutionConfig.{f.name} holds a live object and is "
                    f"not manifest-serializable — manifests execute on the "
                    f"vmap path; leave {f.name}=None")
            continue
        doc[f.name] = encode_value(value, where=f"ExecutionConfig.{f.name}")
    return doc


def execution_config_from_manifest(doc: dict):
    """Decode an ``execution-config/v1`` envelope."""
    from repro.experiments.study import ExecutionConfig

    doc = _require_dict(doc, "execution-config")
    _check_format(doc, EXEC_FORMAT, "execution-config")
    valid = [f for f in _exec_fields() if f not in _EXEC_LIVE_FIELDS]
    _check_keys(doc, ["format", *valid], "execution-config")
    kw = {k: decode_value(v) for k, v in doc.items() if k != "format"}
    return ExecutionConfig(**kw)


# --------------------------------------------------------------- request

def request_to_manifest(study, config=None) -> dict:
    """Encode a service request: ``study-request/v1`` envelope wrapping a
    study (and optionally an execution-config) envelope."""
    doc = {"format": REQUEST_FORMAT, "study": study_to_manifest(study)}
    if config is not None:
        doc["execution"] = execution_config_to_manifest(config)
    return doc


def request_from_manifest(doc: dict):
    """Decode a service request to ``(study, config)``.

    Accepts either a ``study-request/v1`` envelope or a bare ``study/v1``
    envelope (config defaults to None).
    """
    doc = _require_dict(doc, "request")
    if doc.get("format") == STUDY_FORMAT:
        return study_from_manifest(doc), None
    _check_format(doc, REQUEST_FORMAT, "request")
    _check_keys(doc, ("format", "study", "execution"), "request")
    if "study" not in doc:
        raise ValueError("request manifest missing required key 'study'")
    study = study_from_manifest(doc["study"])
    config = None
    if doc.get("execution") is not None:
        config = execution_config_from_manifest(doc["execution"])
    return study, config
