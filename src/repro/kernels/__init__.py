"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU; Mosaic-compiled on TPU):

* ``aggregate`` — masked/scaled client-gradient aggregation (the paper's
  server update, eq. 11/12) and the fused reduce-and-update step
* ``flash_attention`` — blockwise causal/sliding-window GQA attention
* ``ssm_scan`` — chunked gated-linear-recurrence (Mamba2 SSD / mLSTM)

Each ships ``ops.py`` (jit'd wrapper) and ``ref.py`` (pure-jnp oracle).
"""

from jax.experimental.pallas import tpu as pltpu

#: The installed jax's TPU compiler-params dataclass: jax < 0.5 exposes
#: ``TPUCompilerParams``, newer releases renamed it ``CompilerParams``.
#: One shim for every kernel package (``tests/test_kernels.py`` pins
#: that this resolves to whichever symbol the installed jax exports).
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def tpu_compiler_params(**kwargs):
    """Build the installed jax's TPU compiler-params object — the shared
    seam for the ``CompilerParams`` / ``TPUCompilerParams`` rename."""
    return CompilerParams(**kwargs)
