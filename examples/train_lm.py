"""End-to-end driver: train a ~100M-parameter LM with energy-aware
distributed SGD for a few hundred steps.

The model is the stablelm-1.6b *family* scaled to ~100M parameters
(same blocks, GQA, norm). Default CPU budget uses ``--preset small``
(~20M params, minutes); ``--preset 100m`` is the full deliverable run
(~100M params, a few hours on 1 CPU core — exactly the same code path).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.configs.base import ArchConfig

PRESETS = {
    # name: (d_model, n_layers, n_heads, n_kv, d_ff, vocab)
    "small": (384, 6, 6, 6, 1024, 8192),      # ~20M params
    "100m": (640, 10, 10, 10, 1792, 50304),   # ~105M params
}


def make_cfg(preset: str) -> ArchConfig:
    d, l, h, kv, ff, vocab = PRESETS[preset]
    base = get_config("stablelm-1.6b")
    return base.replace(
        name=f"stablelm-family-{preset}", n_layers=l, d_model=d, n_heads=h,
        n_kv_heads=kv, head_dim=d // h, d_ff=ff, vocab=vocab,
        dtype_name="float32", remat=False)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--n-clients", type=int, default=8)
    ap.add_argument("--scheduler", default="alg1")
    ap.add_argument("--arrivals", default="periodic")
    args = ap.parse_args(argv)

    from repro.launch import train as train_mod
    # monkey-free: reuse the production driver with our config injected
    cfg = make_cfg(args.preset)
    if args.global_batch % args.n_clients:
        args.n_clients = max(1, args.global_batch // 2)  # keep divisible
    orig_get = train_mod.get_config
    train_mod.get_config = lambda name: cfg
    try:
        losses = train_mod.main([
            "--arch", cfg.name,
            "--steps", str(args.steps),
            "--global-batch", str(args.global_batch),
            "--seq-len", str(args.seq_len),
            "--n-clients", str(args.n_clients),
            "--scheduler", args.scheduler,
            "--arrivals", args.arrivals,
        ])
    finally:
        train_mod.get_config = orig_get
    assert np.mean(losses[-10:]) < losses[0], "loss must decrease"
    print("train_lm: loss decreased ✓")


if __name__ == "__main__":
    main()
