"""Energy-aware distributed LM training driver.

Runs any ``--arch`` (full or ``--reduced`` smoke variant) under any
scheduler (alg1 / alg2 / benchmark1 / benchmark2 / oracle) and any
registered arrival family (periodic / binary / uniform / the
non-stationary day_night profile). The energy scheduler runs as a tiny jitted state machine beside
the jitted SPMD train step; the (mask, scale) it emits each step is the
paper's eq. (11/12) weighting, applied inside the train step with zero
extra collective traffic.

CPU example (end-to-end, ~100M params):
    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --reduced --steps 200 --global-batch 16 --seq-len 128 \
        --scheduler alg1 --arrivals periodic
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step
from repro.configs import get_config
from repro.core.energy import arrival_family_names
from repro.data import GlobalBatcher, make_lm_tokens
from repro.experiments import build_components
from repro.launch.steps import make_train_step
from repro.models import count_params, init_lm
from repro.optim import adamw


def default_scheduler_for(arrivals: str, requested: str) -> str:
    if requested != "auto":
        return requested
    return "alg1" if arrivals == "periodic" else "alg2"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--n-clients", type=int, default=8)
    ap.add_argument("--scheduler", default="auto",
                    help="auto|alg1|alg2|benchmark1|benchmark2|oracle")
    ap.add_argument("--arrivals", default="periodic",
                    choices=arrival_family_names())
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="",
                    help="legacy params-only checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--checkpoint-dir", default="",
                    help="full-state resumable checkpoints (train state + "
                         "scheduler/energy state + data RNG), written "
                         "atomically every --ckpt-every steps")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint in "
                         "--checkpoint-dir; the resumed run is bitwise "
                         "identical to the uninterrupted one")
    ap.add_argument("--halt-at", type=int, default=0,
                    help="stop right after the full-state checkpoint at "
                         "this step (simulated preemption; components are "
                         "still built for the full --steps horizon)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    k_param, k_data, k_sched, k_energy, k_batch = jax.random.split(key, 5)

    params = init_lm(k_param, cfg)
    print(f"arch={cfg.name} reduced={args.reduced} "
          f"params={count_params(params):,}")

    lm = make_lm_tokens(args.seed, 512, args.seq_len, cfg.vocab)
    batcher = GlobalBatcher({"raw": lm.tokens}, n_clients=args.n_clients,
                            global_batch=args.global_batch)

    sched_name = default_scheduler_for(args.arrivals, args.scheduler)
    # Same axis registry the Study API sweeps over — a driver run is the
    # one-cell special case of a study.
    scheduler, energy = build_components(
        scheduler=sched_name, arrivals=args.arrivals,
        n_clients=args.n_clients, horizon=args.steps + 1)

    init_state, train_step = make_train_step(
        cfg, args.n_clients, optimizer=adamw(args.lr))
    state = init_state(params)
    train_step = jax.jit(train_step, donate_argnums=(0,))

    sched_state = scheduler.init(k_sched)
    energy_state = energy.init(k_energy)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    full_ckpt = (CheckpointManager(args.checkpoint_dir)
                 if args.checkpoint_dir else None)

    start_step = 0
    if args.resume:
        # The loop state is exactly (train state, scheduler state, energy
        # state, data RNG): restoring all four and re-entering the loop at
        # the saved step replays the identical step stream, so a resumed
        # run is bitwise equal to the uninterrupted one (DESIGN.md §10).
        if full_ckpt is None:
            raise SystemExit("--resume requires --checkpoint-dir")
        last = latest_step(args.checkpoint_dir)
        if last is not None:
            template = {"state": state, "sched_state": sched_state,
                        "energy_state": energy_state, "k_batch": k_batch}
            restored, start_step = full_ckpt.restore(template, last)
            state, sched_state = restored["state"], restored["sched_state"]
            energy_state, k_batch = (restored["energy_state"],
                                     restored["k_batch"])
            print(f"resumed from {full_ckpt.path(start_step)}")

    @jax.jit
    def sched_step(sched, en, sstate, estate, t, k):
        # Scheduler + energy process are pytrees: traced arguments, not
        # closed-over Python objects.
        k1, k2 = jax.random.split(k)
        estate, arr = en.arrivals(estate, t, k1)
        sstate, dec = sched.step(sstate, t, k2, arr)
        return sstate, estate, dec.mask, dec.scale

    t_start = time.time()
    losses = []
    for step in range(start_step, args.steps):
        k_batch, kb, ks = jax.random.split(k_batch, 3)
        batch_raw = batcher.sample(kb)
        batch = {
            "tokens": batch_raw["raw"][:, :-1],
            "labels": batch_raw["raw"][:, 1:],
            "client_ids": batch_raw["client_ids"],
        }
        if cfg.n_vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (args.global_batch, cfg.n_vision_tokens, cfg.d_model),
                cfg.dtype)
        if cfg.enc_dec:
            batch["audio_feats"] = jnp.zeros(
                (args.global_batch, cfg.enc_len, cfg.d_model), cfg.dtype)
        sched_state, energy_state, mask, scale = sched_step(
            scheduler, energy, sched_state, energy_state, jnp.asarray(step), ks)
        state, metrics = train_step(state, batch, mask, scale)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss={losses[-1]:.4f}  "
                  f"active={float(metrics['active_clients']):.0f}/"
                  f"{args.n_clients}  wsum={float(metrics['weight_sum']):.3f}")
        if ckpt and step and step % args.ckpt_every == 0:
            ckpt.save(step, state.params)
        if full_ckpt and (step + 1) % args.ckpt_every == 0:
            full_ckpt.save(step + 1, {
                "state": state, "sched_state": sched_state,
                "energy_state": energy_state, "k_batch": k_batch})
        if args.halt_at and step + 1 == args.halt_at:
            if full_ckpt is None:
                raise SystemExit("--halt-at requires --checkpoint-dir")
            if (step + 1) % args.ckpt_every != 0:
                full_ckpt.save(step + 1, {
                    "state": state, "sched_state": sched_state,
                    "energy_state": energy_state, "k_batch": k_batch})
            print(f"halted at step {step + 1} (simulated preemption)")
            return losses

    dt = time.time() - t_start
    done = args.steps - start_step
    tail = (f"loss {losses[0]:.4f} -> {np.mean(losses[-10:]):.4f}"
            if losses else "already complete")
    print(f"done: {done} steps in {dt:.1f}s "
          f"({max(done, 1) / dt:.2f} steps/s); {tail}")
    if ckpt:
        ckpt.save(args.steps, state.params)
    if full_ckpt:
        full_ckpt.save(args.steps, {
            "state": state, "sched_state": sched_state,
            "energy_state": energy_state, "k_batch": k_batch})
    return losses


if __name__ == "__main__":
    main()
