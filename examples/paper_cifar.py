"""Figure-1 reproduction (paper §V), CIFAR-10 replaced by the synthetic
class-prototype image task (offline container — DESIGN.md §2).

Setup exactly as the paper: N=40 clients in 4 equal groups A_k = {i : i mod
4 = k} with periodic energy E_i^t = 1 iff t ≡ 0 (mod τ_k), τ = (1,5,10,20)
(eq. 37); training via distributed SGD with the McMahan CIFAR CNN (~10⁶
params); compared: Algorithm 1, Benchmark 1 (energy-agnostic best-effort),
Benchmark 2 (wait-for-all), and full-participation oracle.

All four methods run through the scenario engine: the ``fig1`` study
(:func:`repro.experiments.get_study`) executes as one compiled
computation per scheduler type, with accuracy evaluated inside the
compiled loop every ``--eval-every`` steps (``ExecutionConfig``).
``--seeds K`` averages curves over K seeds.

Default is a CPU-sized variant (16×16 images, small CNN, 300 iterations);
``--full`` runs the paper-exact 32×32 / ~10⁶-param CNN / 1000 iterations
(hours on 1 CPU core). Writes a CSV of accuracy-vs-iteration per method to
``benchmarks/results/fig1.csv``.

    PYTHONPATH=src python examples/paper_cifar.py [--full] [--iters N]
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (
    ClientBatcher,
    group_label_skew_partition,
    make_confusable_image_classification,
)
from repro.experiments import ExecutionConfig, get_study
from repro.models.cnn import cnn_accuracy, cnn_forward, init_cnn
from repro.optim import sgd

N_CLIENTS, N_GROUPS = 40, 4
TAUS = (1, 5, 10, 20)
METHODS = ("alg1", "benchmark1", "benchmark2", "oracle")


def per_client_grads_fn(batcher, image_hw):
    """grads_fn for ClientSimulator: vmapped per-client CNN gradients."""

    def loss_one(params, images, labels):
        logits = cnn_forward(params, images).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)

    grad_one = jax.grad(loss_one)

    def grads_fn(params, key, t):
        batch = batcher.sample(key)
        return jax.vmap(lambda x, y: grad_one(params, x, y))(
            batch["x"], batch["y"])

    return grads_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-exact scale (32x32, ~1e6-param CNN, 1000 it)")
    ap.add_argument("--iters", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--seeds", type=int, default=1,
                    help="seeds per grid cell (curves averaged across seeds)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="benchmarks/results/fig1.csv")
    args = ap.parse_args(argv)

    if args.full:
        hw, batch, iters, n_train = 32, 16, args.iters or 1000, 8000
    else:
        hw, batch, iters, n_train = 16, 4, args.iters or 300, 2000
    lr = 0.05
    # Evaluation happens inside the compiled scan, once per chunk.
    eval_every = max(1, args.eval_every)
    iters = ((iters + eval_every - 1) // eval_every) * eval_every

    # Cross-group confusable classes: stands in for CIFAR's non-realizable
    # hardness — the weighting decides which class boundaries get resolved
    # (DESIGN.md §2; reproduces the paper's 80/64/52 ordering).
    ds = make_confusable_image_classification(
        args.seed, n_train + 800, image_shape=(hw, hw, 3),
        similarity=0.9, noise=0.8)
    train_x, train_y = ds.images[:n_train], ds.labels[:n_train]
    test_x = jnp.asarray(ds.images[n_train:])
    test_y = jnp.asarray(ds.labels[n_train:])

    # class partition aligned with energy groups (client i holds classes
    # ≡ i mod 4) -> benchmark-1's bias is visible
    parts = group_label_skew_partition(args.seed, train_y, N_CLIENTS,
                                       N_GROUPS, skew=1.0)
    per_client = [{"x": train_x[ix], "y": train_y[ix]} for ix in parts]
    batcher = ClientBatcher(per_client, batch_size=batch, seed=args.seed)

    params0 = init_cnn(jax.random.PRNGKey(args.seed), image_hw=hw)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params0))
    print(f"CNN params: {n_params:,}  clients: {N_CLIENTS}  "
          f"taus per group: {TAUS}  iters: {iters}  seeds: {args.seeds}")

    study = get_study("fig1", n_clients=N_CLIENTS, num_steps=iters,
                      taus_profile=list(TAUS),
                      seeds=[args.seed + 1 + s for s in range(args.seeds)])
    results = study.run(
        grads_fn=per_client_grads_fn(batcher, hw),
        p=batcher.p, optimizer=sgd(lr), params0=params0,
        config=ExecutionConfig(
            eval_fn=lambda p: cnn_accuracy(p, test_x, test_y),
            eval_every=eval_every))

    eval_steps = [(k + 1) * eval_every for k in range(iters // eval_every)]
    curves, stds = {}, {}
    for m in METHODS:
        evals = np.asarray(results[f"{m}_periodic"].evals)  # (seeds, E)
        curves[m] = evals.mean(axis=0)
        stds[m] = evals.std(axis=0)
        extra = f" ± {stds[m][-1]:.3f}" if args.seeds > 1 else ""
        print(f"{m:<12} final acc = {curves[m][-1]:.3f}{extra}")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write("method,iteration,test_accuracy,test_accuracy_std\n")
        for m in METHODS:
            for t, a, s in zip(eval_steps, curves[m], stds[m]):
                f.write(f"{m},{t},{a:.4f},{s:.4f}\n")
    print(f"wrote {args.out}")

    final = {m: float(curves[m][-1]) for m in METHODS}
    print("\npaper Fig-1 ordering check: "
          f"alg1={final['alg1']:.3f} ≥ benchmarks "
          f"(b1={final['benchmark1']:.3f}, b2={final['benchmark2']:.3f}); "
          f"oracle={final['oracle']:.3f}")
    return final


if __name__ == "__main__":
    main()
