"""Benchmark: the Study service under mixed-population request traffic.

Measures the serve path end to end (DESIGN.md §11): a burst of
mixed-population, single-structure manifests batched through
StudyService, then repeat traffic against the warm executable cache.

Series (all serve_*, validated by ``run.check_serve_series``):

  serve_throughput  warm-cache wall time per batched flush;
                    scenarios/sec in derived
  serve_latency     p50/p99 per-request latency (submit -> response)
                    over the warm rounds
  serve_cache       repeat-traffic executable-cache behavior (hit rate,
                    compiles — which must not grow after warmup)
  serve_collapse    the single-trace collapse: distinct population
                    sizes served per compile (us=0, derived-only)
"""

from __future__ import annotations

import time

import numpy as np


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


def run(fast: bool = False) -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.core.convergence import make_quadratic
    from repro.experiments import Study
    from repro.optim import sgd
    from repro.serve import StudyService

    num_steps = 40 if fast else 200
    rounds = 3 if fast else 8
    capacity, dim = 8, 8
    populations = [3, 4, 5, 6, 7, 8, 3, 5]

    prob = make_quadratic(jax.random.PRNGKey(0), capacity, dim=dim)
    service = StudyService(
        grads_fn=lambda w, k, t: prob.all_grads(w), p=prob.p,
        optimizer=sgd(0.05), loss_fn=prob.suboptimality,
        params0=jnp.zeros(dim), cache_size=16)

    manifests = []
    for i, n in enumerate(populations):
        study = (Study(f"b{i}", num_steps=num_steps)
                 .axis("scheduler", "alg2").axis("arrivals", "binary")
                 .axis("n_clients", n).axis("seeds", [0, 1]))
        manifests.append(study.to_json())

    # cold round: compiles happen here
    t0 = time.time()
    for m in manifests:
        service.submit(m)
    service.flush()
    cold_us = (time.time() - t0) * 1e6
    cold = service.stats()

    # warm rounds: repeat traffic, identical manifest set
    walls, latencies = [], []
    for _ in range(rounds):
        t0 = time.time()
        rids = [service.submit(m) for m in manifests]
        responses = service.flush()
        walls.append((time.time() - t0) * 1e6)
        latencies += [r.timings["latency_us"] for r in responses]
        del rids
    warm = service.stats()

    n_req = len(manifests)
    warm_us = float(np.mean(walls))
    scen_per_s = n_req / (warm_us / 1e6)
    hits = warm["hits"] - cold["hits"]
    misses = warm["misses"] - cold["misses"]
    hit_rate = hits / max(1, hits + misses)
    p50 = _percentile(latencies, 50)
    p99 = _percentile(latencies, 99)

    return [
        f"serve_throughput,{warm_us:.0f},scenarios_per_s={scen_per_s:.2f};"
        f"requests={n_req};cells={n_req};rounds={rounds};"
        f"cold_us={cold_us:.0f}",
        f"serve_latency,{p50:.0f},p50_us={p50:.0f};p99_us={p99:.0f};"
        f"n={len(latencies)}",
        f"serve_cache,0,hit_rate={hit_rate:.3f};hits={hits};misses={misses};"
        f"evictions={warm['evictions']};compiles={warm['compiles']};"
        f"warm_compiles={warm['compiles'] - cold['compiles']}",
        f"serve_collapse,0,populations={len(set(populations))};"
        f"compiles={cold['compiles']};"
        f"single_trace={cold['compiles'] == 1};"
        f"executable_entries={cold['executable_entries']}",
    ]
