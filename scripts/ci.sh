#!/usr/bin/env bash
# CI pipeline: tier-1 first (the gate every PR must keep green), then the
# marker suites as separate named stages so a sharding or ragged failure
# is attributable at a glance. Stages re-select subsets of tier-1 —
# cheap, since the jit caches are per-process and each stage is its own
# pytest process anyway.
#
#   scripts/ci.sh            # all stages
#   scripts/ci.sh tier1      # just the gate
#   scripts/ci.sh multidevice ragged clientshard faults
#   scripts/ci.sh kernels    # Pallas kernel suites + bench smoke
#   scripts/ci.sh serve      # manifest/service suites + serve-bench smoke
#   scripts/ci.sh serve-resume  # SIGKILL-and-recover + resume bench smoke
#   scripts/ci.sh multihost  # simulated 2-process jax.distributed suite
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

stage() {
    echo "=== stage: $1 ==="
    shift
    python -m pytest -q "$@"
}

run_stage() {
    case "$1" in
        tier1)       stage tier1 -x ;;
        multidevice) stage multidevice -m multidevice ;;
        ragged)      stage ragged -m ragged ;;
        clientshard) stage clientshard -m clientshard ;;
        faults)      stage faults -m faults ;;
        kernels)
            # Kernel correctness (interpret-mode vs oracles) plus a bench
            # harness smoke: the micro-bench suite must run end-to-end and
            # emit schema-valid JSON (timing-attribution guard included).
            stage kernels tests/test_kernels.py tests/test_kernels_properties.py \
                tests/test_fused_update.py
            python -m benchmarks.run --only kernels_bench --fast \
                --json /tmp/bench_kernels_smoke.json >/dev/null
            ;;
        serve)
            # Study-as-a-service: manifest round-trips + the batching
            # service suite, then a serve-bench smoke (the serve_* series
            # must emit and pass their schema validator end-to-end).
            stage serve -m serve
            python -m benchmarks.run --only serve_bench --fast \
                --json /tmp/bench_serve_smoke.json >/dev/null
            ;;
        serve-resume)
            # Preemption-safe serving (DESIGN.md §12): the SIGKILLed
            # serve subprocess must recover bitwise on a fresh service,
            # and the serve_resume_* bench series must emit and pass
            # the zero-recompile / bitwise validator end-to-end.
            stage serve-resume \
                tests/test_resumable.py::test_service_kill9_and_recover_bitwise \
                tests/test_service.py \
                -k "kill9 or resumable or recover or drain or response_store or concurrent or checkpoint"
            python -m benchmarks.run --only serve_bench --fast \
                --json /tmp/bench_serve_resume_smoke.json >/dev/null
            ;;
        multihost)
            # Multi-host execution (DESIGN.md §13): the simulated
            # 2-process jax.distributed run must stay bitwise (gather)
            # against the single-process vmap engine, and the
            # multihost_* bench series must emit and pass their
            # validator end-to-end.
            stage multihost -m multihost
            python -m benchmarks.run --only multihost --fast \
                --json /tmp/bench_multihost_smoke.json >/dev/null
            ;;
        *) echo "unknown stage: $1 (have tier1 multidevice ragged clientshard faults kernels serve serve-resume multihost)" >&2
           exit 2 ;;
    esac
}

if [ "$#" -eq 0 ]; then
    set -- tier1 multidevice ragged clientshard faults kernels serve serve-resume multihost
fi
for s in "$@"; do
    run_stage "$s"
done
echo "=== all stages green ==="
