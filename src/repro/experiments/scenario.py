"""Scenario specs: one declarative cell of an experiment grid.

A :class:`Scenario` names a (scheduler × energy-process) pair plus the
shape of the client population; :meth:`Scenario.build` materializes the
two pytree components. Scenarios are *host-side specs* (plain
dataclasses, not pytrees) — the pytrees they build are what crosses
``jit`` / ``vmap`` boundaries.

Scenarios are what :meth:`repro.experiments.Study.resolve` produces from
its sweep axes; writing them by hand remains supported for one-off
irregular cells. The module also keeps two **legacy shims**:

* :func:`make_energy_process` — now a thin delegate of
  :func:`repro.core.energy.make_arrivals` (the registry that owns
  arrival families, including the non-stationary ``day_night`` profile).
* :func:`get_grid` / :func:`register_grid` — the pre-Study named-grid
  registry. Built-in names (``fig1``, ``fig1_grid``, ``capacity_sweep``,
  …) live in the Study registry (:mod:`repro.experiments.study`);
  ``get_grid`` resolves them to a plain scenario list for callers that
  still drive :func:`repro.experiments.run_grid` directly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.energy import (
    PAPER_TAUS,
    default_taus,
    make_arrivals,
)
from repro.core.scheduling import make_scheduler

ARRIVAL_KINDS = ("periodic", "binary", "uniform")


def make_energy_process(kind: str, n_clients: int, horizon: int, taus=None,
                        **kw):
    """Deprecated alias of :func:`repro.core.energy.make_arrivals`.

    Kept so seed-era callers keep working; the registry (and the
    ``day_night`` non-stationary family) lives in ``repro.core.energy``.
    """
    return make_arrivals(kind, n_clients, horizon, taus=taus, **kw)


@dataclasses.dataclass
class Scenario:
    """One experiment-grid cell: scheduler × arrival process × population.

    ``scheduler`` / ``arrivals`` are registry names; ``taus`` is the
    per-client period vector shared across arrival kinds (None → the
    paper's cycling (1, 5, 10, 20) profile); ``scheduler_kwargs`` /
    ``arrival_kwargs`` feed extra hyperparameters (e.g. battery
    capacity, day/night cycle length) to the component factories.

    ``n_clients`` need not match other scenarios in a grid: the engine
    pads ragged populations to the simulator capacity under an active
    mask (DESIGN.md §7), so mixed-N scenario lists batch into one
    compiled computation per scheduler × arrival structure.

    ``faults`` optionally names a fault-injection family
    (:mod:`repro.core.faults` registry; ``fault_kwargs`` feeds its
    factory). ``None`` — the default — runs the fault-free program,
    bit-identical to pre-fault-layer builds.
    """

    name: str
    scheduler: str
    arrivals: str
    n_clients: int
    horizon: int
    taus: Sequence[int] | None = None
    scheduler_kwargs: dict = dataclasses.field(default_factory=dict)
    arrival_kwargs: dict = dataclasses.field(default_factory=dict)
    faults: str | None = None
    fault_kwargs: dict = dataclasses.field(default_factory=dict)

    def build(self):
        """Materialize the (scheduler, energy) pytree pair."""
        scheduler = make_scheduler(self.scheduler, self.n_clients,
                                   **self.scheduler_kwargs)
        energy = make_arrivals(self.arrivals, self.n_clients, self.horizon,
                               taus=self.taus, **self.arrival_kwargs)
        return scheduler, energy

    def build_faults(self):
        """Materialize the fault component (None when fault-free)."""
        if self.faults is None:
            return None
        from repro.core.faults import make_fault

        return make_fault(self.faults, self.n_clients, **self.fault_kwargs)


def scenario_grid(
    schedulers: Iterable[str],
    arrivals: Iterable[str],
    n_clients: int,
    horizon: int,
    taus=None,
    scheduler_kwargs: dict | None = None,
) -> list[Scenario]:
    """Cross product of scheduler × arrival-kind names as Scenario cells."""
    return [
        Scenario(name=f"{s}_{a}", scheduler=s, arrivals=a,
                 n_clients=n_clients, horizon=horizon, taus=taus,
                 scheduler_kwargs=dict(scheduler_kwargs or {}))
        for s in schedulers
        for a in arrivals
    ]


#: Paper Figure-1 methods, in presentation order.
FIG1_SCHEDULERS = ("alg1", "benchmark1", "benchmark2", "oracle")

_GRID_REGISTRY: dict[str, Callable[..., list[Scenario]]] = {}


def register_grid(name: str):
    """Decorator: register a named scenario-grid factory (legacy).

    New named experiments should be registered as Studies
    (:func:`repro.experiments.register_study`); this hook remains for
    factories that produce irregular scenario lists no axis
    cross-product expresses.
    """

    def deco(fn):
        _GRID_REGISTRY[name] = fn
        return fn

    return deco


def get_grid(name: str, **kw) -> list[Scenario]:
    """Resolve a named grid to a scenario list (legacy entry point).

    Dispatches to the legacy factory registry first, then to the Study
    registry (translating the old ``horizon=`` / ``taus=`` keywords), so
    seed-era callers see the registries as one namespace.
    """
    if name in _GRID_REGISTRY:
        return _GRID_REGISTRY[name](**kw)
    from repro.experiments.study import get_study, study_names

    if name not in study_names():
        raise ValueError(
            f"unknown scenario grid {name!r}; have {grid_names()}")
    if "horizon" in kw:
        kw["num_steps"] = kw.pop("horizon") - 1
    if "taus" in kw:
        taus = kw.pop("taus")
        if taus is not None:
            kw["taus_profile"] = taus
    return get_study(name, **kw).resolve()


def grid_names() -> list[str]:
    from repro.experiments.study import study_names

    return sorted(set(_GRID_REGISTRY) | set(study_names()))
