"""zamba2-2.7b — Mamba2 backbone with a shared attention block.

[arXiv:2411.15242] 54 blocks, d_model=2560, shared attention 32 heads
(MHA kv=32), shared-block d_ff=10240, vocab=32000, ssm_state=64.
Layout: 9 super-blocks × (5 Mamba2 blocks + 1 SHARED attn+MLP block) —
the shared block has ONE parameter set reused at every super-block
(Zamba2's parameter-sharing trick; we use one shared block instead of
Zamba2's two alternating ones — DESIGN.md notes the deviation). Decode
state: per-invocation KV caches for the 9 shared-block call sites +
Mamba2 conv/SSD states.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    superblock=(("mamba2", 5, False), ("attn_mlp", 1, True)),
    n_super=9,
    rope_theta=10000.0,
    long_context_window=4096,  # shared attn gets SWA under long_500k
    norm="rmsnorm",
    act="silu",
    gla_chunk=64,
    dtype_name="bfloat16",
    remat=True,
    citation="[arXiv:2411.15242]",
)
