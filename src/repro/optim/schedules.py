"""Learning-rate schedules as ``step -> lr`` callables."""

from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def fn(step):
        del step
        return jnp.asarray(lr, jnp.float32)

    return fn


def inverse_time_schedule(lr0: float, decay: float):
    """η_t = η₀ / (1 + decay·t) — the decreasing-step recipe of Remark 1
    (makes the Theorem-1 error floor vanish as T→∞)."""

    def fn(step):
        return jnp.asarray(lr0, jnp.float32) / (1.0 + decay * step.astype(jnp.float32))

    return fn


def cosine_schedule(lr0: float, total_steps: int, lr_min: float = 0.0):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        return lr_min + 0.5 * (lr0 - lr_min) * (1.0 + jnp.cos(jnp.pi * frac))

    return fn


def warmup_cosine_schedule(lr0: float, warmup_steps: int, total_steps: int,
                           lr_min: float = 0.0):
    cos = cosine_schedule(lr0, max(total_steps - warmup_steps, 1), lr_min)

    def fn(step):
        step_f = step.astype(jnp.float32)
        warm = lr0 * step_f / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return fn
