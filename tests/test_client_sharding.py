"""Within-cell client-axis sharding (DESIGN.md §8): differential suite.

The tentpole guarantee, locked in bit-for-bit: running one cell with its
client axis sharded across devices — per-client component rows,
arrivals/battery state, scheduler rows, ``active_mask`` and the
``(N, P)`` gradient buffer all device-local, the aggregation reduced
across the ``clients`` mesh axis, the server update replicated —
produces *exactly* the numbers of the single-device vmap path, across
all six schedulers × all four arrival families, including ragged
(masked) cells.

``reduction="gather"`` is the bitwise contract (the global gradient
buffer is reassembled in exact row order and every shard replays the
identical unsharded reduction) — the differential oracle every other
mode is held against. The *default* under an active clients axis is
``"psum"`` (DESIGN.md §9): bandwidth-optimal, float32-reassociation
tolerance; ``"fused[_bf16]"`` additionally folds the SGD update into
the local launch, and ``"psum_bf16"`` quantizes the wire. Combined
``(cells, clients)`` meshes must keep the one-trace-per-structure
guarantee of the cell-sharded path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClientSimulator, make_quadratic, scheduler_names
from repro.core.energy import make_arrivals
from repro.core.scheduling import make_scheduler
from repro.experiments import (
    ExecutionConfig,
    Study,
    make_cell_mesh,
    make_client_mesh,
    make_grid_mesh,
    run_client_sharded,
)
from repro.experiments import placement
from repro.optim import sgd

clientshard = pytest.mark.clientshard
multidevice = pytest.mark.multidevice

N_CAP, DIM = 8, 5

ARRIVALS = ("periodic", "binary", "uniform", "day_night")

SCHEDULER_ARRIVALS = [(s, a) for s in scheduler_names() for a in ARRIVALS]


@pytest.fixture(scope="module")
def master():
    return make_quadratic(jax.random.PRNGKey(2), n_clients=N_CAP, dim=DIM,
                          hetero=1.0)


@pytest.fixture(scope="module")
def loss_fn(master):
    # Elementwise + one sum: bit-stable under vmap (see test_ragged.py).
    w_star = master.w_star
    return lambda w: jnp.sum((w - w_star) ** 2)


@pytest.fixture(scope="module")
def sim(master, loss_fn):
    return ClientSimulator(grads_fn=lambda w, k, t: master.all_grads(w),
                           p=master.p, optimizer=sgd(0.02), loss_fn=loss_fn)


@pytest.fixture(scope="module")
def params0():
    return jnp.full((DIM,), 4.0)


def assert_cells_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.history.loss),
                                  np.asarray(b.history.loss))
    np.testing.assert_array_equal(np.asarray(a.history.participation),
                                  np.asarray(b.history.participation))
    np.testing.assert_array_equal(np.asarray(a.history.weight_sum),
                                  np.asarray(b.history.weight_sum))
    np.testing.assert_array_equal(np.asarray(a.params), np.asarray(b.params))


# --------------------------------------------------------- mesh factories

def test_make_client_mesh_axis_name():
    mesh = make_client_mesh()
    assert mesh.axis_names == (placement.CLIENT_AXIS,)
    assert mesh.size == jax.device_count()


@multidevice
def test_make_grid_mesh_shape():
    mesh = make_grid_mesh(2, jax.device_count() // 2)
    assert mesh.axis_names == (placement.CELL_AXIS, placement.CLIENT_AXIS)
    assert mesh.shape[placement.CELL_AXIS] == 2


def test_mesh_axes_resolution():
    assert placement._mesh_axes(make_cell_mesh(1)) == ("cells", None)
    assert placement._mesh_axes(make_client_mesh(1)) == (None, "clients")
    assert placement._mesh_axes(make_grid_mesh(1, 1)) == ("cells", "clients")
    bad = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("clients", "cells"))
    with pytest.raises(ValueError, match="clients"):
        placement._mesh_axes(bad)


def test_client_leaf_specs_shape_rule():
    from jax.sharding import PartitionSpec as P

    tree = {"rows": jnp.zeros((N_CAP, 3)), "scalar": jnp.zeros(()),
            "vec": jnp.zeros((3,))}
    specs = placement.client_leaf_specs(tree, N_CAP, client_axis="clients")
    by_leaf = dict(zip(sorted(tree), specs))
    assert by_leaf["rows"] == P("clients")
    assert by_leaf["scalar"] == P()
    assert by_leaf["vec"] == P()
    # grid layout: leading cell axis, client axis on dim 1
    specs = placement.client_leaf_specs(
        {"rows": jnp.zeros((4, N_CAP, 3)), "scalar": jnp.zeros((4,))},
        N_CAP, client_axis="clients", cell_axis="cells", lead=1)
    assert specs == [P("cells", "clients"), P("cells")]


# ----------------------------------------------- bitwise differential suite

@clientshard
@multidevice
@pytest.mark.parametrize("scheduler,arrivals", SCHEDULER_ARRIVALS)
def test_client_sharded_matches_vmap_bitwise(sim, params0, scheduler,
                                             arrivals):
    """Acceptance: the 8-device client-sharded run of every scheduler ×
    arrival-family cell — including a ragged (masked) population —
    equals the single-device vmap run bit-for-bit."""
    num_steps, seeds, pops = 15, 2, (5, 8)
    study = Study("cs", num_steps=num_steps, axes={
        "scheduler": scheduler, "arrivals": arrivals,
        "n_clients": list(pops), "seeds": seeds})
    plain = study.run(sim=sim, params0=params0)
    sharded = study.run(sim=sim, params0=params0,
                        config=ExecutionConfig(mesh=make_client_mesh(),
                                               client_reduction="gather"))
    for n in pops:
        name = f"{scheduler}_{arrivals}_n{n}"
        assert sharded[name].history.participation.shape == \
            (seeds, num_steps, n)
        assert_cells_equal(plain[name], sharded[name])


@clientshard
def test_all_six_schedulers_are_covered():
    assert sorted({s for s, _ in SCHEDULER_ARRIVALS}) == scheduler_names()
    assert sorted({a for _, a in SCHEDULER_ARRIVALS}) == sorted(ARRIVALS)


@clientshard
@multidevice
def test_single_cell_run_client_sharded_bitwise(sim, params0):
    """run_client_sharded (the single-population entry point) ==
    ClientSimulator.run, bit-for-bit, history and final params."""
    scheduler = make_scheduler("alg2", N_CAP)
    energy = make_arrivals("binary", N_CAP, 21)
    key = jax.random.PRNGKey(0)
    pu, hu = sim.run(key, params0, 20, scheduler=scheduler, energy=energy)
    ps, hs = run_client_sharded(sim, key, params0, 20, scheduler=scheduler,
                                energy=energy, mesh=make_client_mesh(),
                                reduction="gather")
    np.testing.assert_array_equal(np.asarray(pu), np.asarray(ps))
    np.testing.assert_array_equal(np.asarray(hu.loss), np.asarray(hs.loss))
    np.testing.assert_array_equal(np.asarray(hu.participation),
                                  np.asarray(hs.participation))
    np.testing.assert_array_equal(np.asarray(hu.weight_sum),
                                  np.asarray(hs.weight_sum))


@clientshard
@multidevice
def test_large_population_cell_bitwise():
    """Acceptance criterion: a single N=4096-client cell client-sharded
    on 8 host devices is bit-for-bit the unsharded vmap run."""
    if jax.device_count() < 8 or 4096 % jax.device_count() != 0:
        pytest.skip("needs a device count dividing 4096 (CI forces 8)")
    n, dim, steps = 4096, 8, 6
    prob = make_quadratic(jax.random.PRNGKey(7), n_clients=n, dim=dim,
                          hetero=1.0)
    w_star = prob.w_star
    sim = ClientSimulator(grads_fn=lambda w, k, t: prob.all_grads(w),
                          p=prob.p, optimizer=sgd(0.01),
                          loss_fn=lambda w: jnp.sum((w - w_star) ** 2))
    scheduler = make_scheduler("alg2", n)
    energy = make_arrivals("binary", n, steps + 1)
    key = jax.random.PRNGKey(1)
    params0 = jnp.full((dim,), 2.0)
    pu, hu = sim.run(key, params0, steps, scheduler=scheduler, energy=energy)
    ps, hs = run_client_sharded(sim, key, params0, steps, scheduler=scheduler,
                                energy=energy, mesh=make_client_mesh(),
                                reduction="gather")
    np.testing.assert_array_equal(np.asarray(pu), np.asarray(ps))
    np.testing.assert_array_equal(np.asarray(hu.loss), np.asarray(hs.loss))
    np.testing.assert_array_equal(np.asarray(hu.participation),
                                  np.asarray(hs.participation))


@clientshard
@multidevice
def test_eval_chunked_run_client_sharded(sim, params0, loss_fn):
    """The chunked in-loop eval path runs client-sharded too."""
    scheduler = make_scheduler("alg2", N_CAP)
    energy = make_arrivals("binary", N_CAP, 21)
    key = jax.random.PRNGKey(3)
    pu, hu, eu = sim.run(key, params0, 20, scheduler=scheduler, energy=energy,
                         eval_fn=loss_fn, eval_every=10)
    ps, hs, es = run_client_sharded(sim, key, params0, 20,
                                    scheduler=scheduler, energy=energy,
                                    mesh=make_client_mesh(),
                                    eval_fn=loss_fn, eval_every=10,
                                    reduction="gather")
    assert es.shape == (2,)
    np.testing.assert_array_equal(np.asarray(eu), np.asarray(es))
    np.testing.assert_array_equal(np.asarray(hu.loss), np.asarray(hs.loss))


# --------------------------------------------------- combined cells×clients

@clientshard
@multidevice
def test_combined_mesh_traces_once_per_structure(sim, params0):
    """cells×clients mesh: one _run_group_sharded trace per component
    structure, zero on repeat — exactly the cells-only guarantee."""
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices for a 2x2 grid mesh")
    mesh = make_grid_mesh(2, 2)
    study = Study("cs2", num_steps=13, axes={
        "scheduler": ["alg1", "alg2"], "arrivals": ["binary", "uniform"],
        "n_clients": N_CAP, "seeds": 3})
    cfg = ExecutionConfig(mesh=mesh)
    before = placement._run_group_sharded._cache_size()
    plain = study.run(sim=sim, params0=params0)
    sharded = study.run(sim=sim, params0=params0, config=cfg)
    assert placement._run_group_sharded._cache_size() - before == 4
    study.run(sim=sim, params0=params0, config=cfg)
    assert placement._run_group_sharded._cache_size() - before == 4
    for name in plain:
        np.testing.assert_allclose(np.asarray(plain[name].history.loss),
                                   np.asarray(sharded[name].history.loss),
                                   rtol=2e-4, atol=1e-5)
        np.testing.assert_array_equal(
            np.asarray(plain[name].history.participation),
            np.asarray(sharded[name].history.participation))


@clientshard
@multidevice
def test_combined_mesh_ragged_grid(sim, params0):
    """Ragged populations survive the combined mesh: masked cells over
    cells×clients sharding match the vmap path (exact participation)."""
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices for a 2x2 grid mesh")
    study = Study("cs3", num_steps=12, axes={
        "scheduler": "alg2", "arrivals": "binary",
        "n_clients": [4, 8], "seeds": 2})
    plain = study.run(sim=sim, params0=params0)
    sharded = study.run(sim=sim, params0=params0,
                        config=ExecutionConfig(mesh=make_grid_mesh(2, 2)))
    for name in plain:
        np.testing.assert_allclose(np.asarray(plain[name].history.loss),
                                   np.asarray(sharded[name].history.loss),
                                   rtol=2e-4, atol=1e-5)
        np.testing.assert_array_equal(
            np.asarray(plain[name].history.participation),
            np.asarray(sharded[name].history.participation))


# ----------------------------------------------------- psum / kernel modes

@clientshard
@multidevice
def test_psum_reduction_matches_gather(sim, params0):
    """reduction='psum' (local partial matvec + psum) agrees with the
    bitwise gather mode to f32 reassociation tolerance; participation
    (RNG + scheduling, no reduction involved) stays exact."""
    study = Study("cs", num_steps=15, axes={
        "scheduler": "alg2", "arrivals": "binary",
        "n_clients": [5, 8], "seeds": 2})
    gather = study.run(sim=sim, params0=params0,
                       config=ExecutionConfig(mesh=make_client_mesh(),
                                              client_reduction="gather"))
    psum = study.run(sim=sim, params0=params0,
                     config=ExecutionConfig(mesh=make_client_mesh(),
                                            client_reduction="psum"))
    for name in gather:
        np.testing.assert_allclose(np.asarray(gather[name].history.loss),
                                   np.asarray(psum[name].history.loss),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(
            np.asarray(gather[name].history.participation),
            np.asarray(psum[name].history.participation))
        np.testing.assert_allclose(np.asarray(gather[name].history.weight_sum),
                                   np.asarray(psum[name].history.weight_sum),
                                   rtol=1e-6, atol=1e-6)


@clientshard
@multidevice
def test_kernel_path_client_sharded(master, params0, loss_fn):
    """use_kernel=True routes the sharded-operand Pallas path (local
    tiled kernel + psum) — agrees with the jnp path."""
    kw = dict(grads_fn=lambda w, k, t: master.all_grads(w), p=master.p,
              optimizer=sgd(0.02), loss_fn=loss_fn)
    study = Study("cs", num_steps=10, axes={
        "scheduler": "alg2", "arrivals": "binary",
        "n_clients": [5, 8], "seeds": 2})
    cfg = ExecutionConfig(mesh=make_client_mesh(), client_reduction="psum")
    plain = study.run(sim=ClientSimulator(**kw), params0=params0)
    kern = study.run(sim=ClientSimulator(use_kernel=True, **kw),
                     params0=params0, config=cfg)
    for name in plain:
        np.testing.assert_allclose(np.asarray(plain[name].history.loss),
                                   np.asarray(kern[name].history.loss),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(
            np.asarray(plain[name].history.participation),
            np.asarray(kern[name].history.participation))


@clientshard
def test_default_client_reduction_is_psum():
    """The production default under a clients axis is psum (DESIGN.md
    §9 decision table); gather remains opt-in as the bitwise oracle."""
    assert ExecutionConfig().client_reduction == "psum"
    import inspect

    sig = inspect.signature(run_client_sharded)
    assert sig.parameters["reduction"].default == "psum"


@clientshard
@multidevice
@pytest.mark.parametrize("reduction", ["fused", "fused_bf16", "psum_bf16"])
def test_fused_and_wire_modes_match_gather(sim, params0, reduction):
    """The fused reduce-and-update modes and the bf16-wire psum agree
    with the bitwise gather oracle to their documented tolerances
    (DESIGN.md §9); participation (RNG + scheduling, no reduction
    involved) stays exact."""
    study = Study("cs", num_steps=15, axes={
        "scheduler": "alg2", "arrivals": "binary",
        "n_clients": [5, 8], "seeds": 2})
    gather = study.run(sim=sim, params0=params0,
                       config=ExecutionConfig(mesh=make_client_mesh(),
                                              client_reduction="gather"))
    other = study.run(sim=sim, params0=params0,
                      config=ExecutionConfig(mesh=make_client_mesh(),
                                             client_reduction=reduction))
    # bf16 wire: one quantization of the (P,) partial per shard per
    # step, f32 accumulation on both sides — bf16-relative tolerance.
    rtol, atol = (1e-5, 1e-6) if "bf16" not in reduction else (2e-2, 1e-2)
    for name in gather:
        np.testing.assert_allclose(np.asarray(gather[name].history.loss),
                                   np.asarray(other[name].history.loss),
                                   rtol=rtol, atol=atol)
        np.testing.assert_array_equal(
            np.asarray(gather[name].history.participation),
            np.asarray(other[name].history.participation))


@clientshard
@multidevice
def test_fused_requires_sgd(master, params0, loss_fn):
    """reduction='fused' with a stateful optimizer is a clear
    trace-time error, never silently-wrong numerics."""
    from repro.optim import adam

    sim = ClientSimulator(grads_fn=lambda w, k, t: master.all_grads(w),
                          p=master.p, optimizer=adam(1e-2), loss_fn=loss_fn)
    scheduler = make_scheduler("alg2", N_CAP)
    energy = make_arrivals("binary", N_CAP, 6)
    with pytest.raises(ValueError, match="sgd"):
        run_client_sharded(sim, jax.random.PRNGKey(0), params0, 5,
                           scheduler=scheduler, energy=energy,
                           mesh=make_client_mesh(), reduction="fused")


# --------------------------------------------------- client-aware grads_fn

@clientshard
@multidevice
def test_client_aware_grads_fn_shards_compute(master, params0, loss_fn):
    """A grads_fn accepting ``clients=`` computes only its shard's rows
    (the compute-sharding protocol) and agrees with the full-compute
    fallback to f32 tolerance; scheduling/participation stays exact."""
    def grads_cs(w, k, t, clients=None):
        if clients is None:
            return master.all_grads(w)
        return jnp.einsum("nij,j->ni", master.a[clients], w) \
            - master.b[clients]

    sim_full = ClientSimulator(grads_fn=lambda w, k, t: master.all_grads(w),
                               p=master.p, optimizer=sgd(0.02),
                               loss_fn=loss_fn)
    sim_aware = ClientSimulator(grads_fn=grads_cs, p=master.p,
                                optimizer=sgd(0.02), loss_fn=loss_fn)
    scheduler = make_scheduler("alg2", N_CAP)
    energy = make_arrivals("binary", N_CAP, 16)
    key = jax.random.PRNGKey(5)
    pu, hu = sim_full.run(key, params0, 15, scheduler=scheduler,
                          energy=energy)
    ps, hs = run_client_sharded(sim_aware, key, params0, 15,
                                scheduler=scheduler, energy=energy,
                                mesh=make_client_mesh())
    np.testing.assert_allclose(np.asarray(pu), np.asarray(ps),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(hu.participation),
                                  np.asarray(hs.participation))


# ------------------------------------------------------------- validation

@clientshard
@multidevice
def test_capacity_must_divide_client_shards(sim, params0):
    if jax.device_count() < 3:
        pytest.skip("needs >= 3 devices")
    scheduler = make_scheduler("alg2", N_CAP)
    energy = make_arrivals("binary", N_CAP, 6)
    with pytest.raises(ValueError, match="divide"):
        run_client_sharded(sim, jax.random.PRNGKey(0), params0, 5,
                           scheduler=scheduler, energy=energy,
                           mesh=make_client_mesh(3))


@clientshard
def test_run_client_sharded_rejects_cells_mesh(sim, params0):
    scheduler = make_scheduler("alg2", N_CAP)
    energy = make_arrivals("binary", N_CAP, 6)
    with pytest.raises(ValueError, match="clients"):
        run_client_sharded(sim, jax.random.PRNGKey(0), params0, 5,
                           scheduler=scheduler, energy=energy,
                           mesh=make_cell_mesh(1))


@clientshard
@multidevice
def test_legacy_per_leaf_path_rejected_under_sharding(master, params0,
                                                     loss_fn):
    """flat=False (per-leaf carry) cannot run client-sharded — a clear
    trace-time error, not silent wrong numerics."""
    sim = ClientSimulator(grads_fn=lambda w, k, t: master.all_grads(w),
                          p=master.p, optimizer=sgd(0.02), loss_fn=loss_fn,
                          flat=False)
    scheduler = make_scheduler("alg2", N_CAP)
    energy = make_arrivals("binary", N_CAP, 6)
    with pytest.raises(ValueError, match="flat"):
        run_client_sharded(sim, jax.random.PRNGKey(0), params0, 5,
                           scheduler=scheduler, energy=energy,
                           mesh=make_client_mesh())
