"""Benchmark: the Study service under mixed-population request traffic.

Measures the serve path end to end (DESIGN.md §11): a burst of
mixed-population, single-structure manifests batched through
StudyService, then repeat traffic against the warm executable cache.

Series (all serve_*, validated by ``run.check_serve_series``):

  serve_throughput  warm-cache wall time per batched flush;
                    scenarios/sec in derived
  serve_latency     p50/p99 per-request latency (submit -> response)
                    over the warm rounds
  serve_cache       repeat-traffic executable-cache behavior (hit rate,
                    compiles — which must not grow after warmup)
  serve_collapse    the single-trace collapse: distinct population
                    sizes served per compile (us=0, derived-only)

Resumable serving (DESIGN.md §12) — kill-and-resume vs uninterrupted:

  serve_resume_uninterrupted  checkpointed dispatch served end to end
                              (fresh checkpoint dir each round)
  serve_resume_latency        the resume leg after a simulated
                              preemption at half the chunks; the warm
                              resume must add ZERO new compiles, and
                              overhead_pct is (partial + resume) vs the
                              uninterrupted wall
  serve_resume_bitwise        resumed responses bitwise equal to the
                              uninterrupted dispatch (us=0)
"""

from __future__ import annotations

import os
import time

import numpy as np


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


def run(fast: bool = False) -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.core.convergence import make_quadratic
    from repro.experiments import Study
    from repro.optim import sgd
    from repro.serve import StudyService

    num_steps = 40 if fast else 200
    rounds = 3 if fast else 8
    capacity, dim = 8, 8
    populations = [3, 4, 5, 6, 7, 8, 3, 5]

    prob = make_quadratic(jax.random.PRNGKey(0), capacity, dim=dim)
    service = StudyService(
        grads_fn=lambda w, k, t: prob.all_grads(w), p=prob.p,
        optimizer=sgd(0.05), loss_fn=prob.suboptimality,
        params0=jnp.zeros(dim), cache_size=16)

    manifests = []
    for i, n in enumerate(populations):
        study = (Study(f"b{i}", num_steps=num_steps)
                 .axis("scheduler", "alg2").axis("arrivals", "binary")
                 .axis("n_clients", n).axis("seeds", [0, 1]))
        manifests.append(study.to_json())

    # cold round: compiles happen here
    t0 = time.time()
    for m in manifests:
        service.submit(m)
    service.flush()
    cold_us = (time.time() - t0) * 1e6
    cold = service.stats()

    # warm rounds: repeat traffic, identical manifest set
    walls, latencies = [], []
    for _ in range(rounds):
        t0 = time.time()
        rids = [service.submit(m) for m in manifests]
        responses = service.flush()
        walls.append((time.time() - t0) * 1e6)
        latencies += [r.timings["latency_us"] for r in responses]
        del rids
    warm = service.stats()

    n_req = len(manifests)
    warm_us = float(np.mean(walls))
    scen_per_s = n_req / (warm_us / 1e6)
    hits = warm["hits"] - cold["hits"]
    misses = warm["misses"] - cold["misses"]
    hit_rate = hits / max(1, hits + misses)
    p50 = _percentile(latencies, 50)
    p99 = _percentile(latencies, 99)

    rows = [
        f"serve_throughput,{warm_us:.0f},scenarios_per_s={scen_per_s:.2f};"
        f"requests={n_req};cells={n_req};rounds={rounds};"
        f"cold_us={cold_us:.0f}",
        f"serve_latency,{p50:.0f},p50_us={p50:.0f};p99_us={p99:.0f};"
        f"n={len(latencies)}",
        f"serve_cache,0,hit_rate={hit_rate:.3f};hits={hits};misses={misses};"
        f"evictions={warm['evictions']};compiles={warm['compiles']};"
        f"warm_compiles={warm['compiles'] - cold['compiles']}",
        f"serve_collapse,0,populations={len(set(populations))};"
        f"compiles={cold['compiles']};"
        f"single_trace={cold['compiles'] == 1};"
        f"executable_entries={cold['executable_entries']}",
    ]
    rows += _resume_rows(service, manifests, num_steps, fast)
    return rows


def _resume_rows(service, manifests, num_steps, fast):
    """Kill-and-resume overhead of the checkpointed serve path.

    Uninterrupted: the manifest set served with checkpointing against a
    fresh fingerprint dir each round (re-serving an intact dir would
    measure a pure restore, not checkpointed execution). Interrupted:
    CheckpointManager.save raises after half the chunks (the same
    injection the kill tests use — the service sees a dead dispatch and
    keeps the partial dir), then the resubmitted set resumes the tail.
    """
    import shutil
    import tempfile

    import jax

    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.experiments import ExecutionConfig

    n_chunks = 4
    every = max(1, num_steps // n_chunks)
    rounds = 2 if fast else 4

    with tempfile.TemporaryDirectory() as root:
        cfg = ExecutionConfig(checkpoint_dir=root, checkpoint_every=every)

        def clear():
            for d in os.listdir(root):
                shutil.rmtree(os.path.join(root, d))

        def serve_all():
            for m in manifests:
                service.submit(m, cfg)
            return service.flush()

        serve_all()  # warmup: compile the chunk runner
        un_walls = []
        for _ in range(rounds):
            clear()
            t0 = time.time()
            reference = serve_all()
            un_walls.append((time.time() - t0) * 1e6)
        uninterrupted_us = float(np.mean(un_walls))

        # preempt at half the chunks: save raises, the dispatch dies,
        # the partial checkpoint dir survives
        clear()
        real_save, saves = CheckpointManager.save, [0]

        def dying_save(self, step, state):
            if saves[0] >= n_chunks // 2:
                raise RuntimeError("bench-injected preemption")
            saves[0] += 1
            return real_save(self, step, state)

        CheckpointManager.save = dying_save
        try:
            t0 = time.time()
            serve_all()  # dies mid-dispatch
            partial_us = (time.time() - t0) * 1e6
        finally:
            CheckpointManager.save = real_save

        before = service.stats()["compiles"]
        t0 = time.time()
        resumed = serve_all()  # resumes the tail from the partial dir
        resume_us = (time.time() - t0) * 1e6
        new_compiles = service.stats()["compiles"] - before

        overhead_pct = 100.0 * (partial_us + resume_us - uninterrupted_us) \
            / uninterrupted_us
        resumed_steps = resumed[0].batch["resumed_steps"]

        by_name = {r.study: r for r in reference}
        bitwise = all(
            np.array_equal(np.asarray(la), np.asarray(lb), equal_nan=True)
            for r in resumed if r.error is None
            for cell in r.result.cells
            for la, lb in zip(
                jax.tree_util.tree_leaves(by_name[r.study].result.cells[cell]),
                jax.tree_util.tree_leaves(r.result.cells[cell])))
        bitwise = bitwise and all(r.error is None for r in resumed)

    return [
        f"serve_resume_uninterrupted,{uninterrupted_us:.0f},"
        f"chunks={n_chunks};checkpoint_every={every};rounds={rounds}",
        f"serve_resume_latency,{resume_us:.0f},resume_us={resume_us:.0f};"
        f"partial_us={partial_us:.0f};"
        f"uninterrupted_us={uninterrupted_us:.0f};"
        f"overhead_pct={overhead_pct:.1f};resumed_steps={resumed_steps};"
        f"new_compiles={new_compiles}",
        f"serve_resume_bitwise,0,bitwise={bitwise};"
        f"requests={len(manifests)}",
    ]
