"""Regression tests pinning the BENCH_*.json series schema and the
--bench-out non-clobbering rule (benchmarks/run.py).

The perf-trajectory files are compared across PRs, so their shape is a
contract: every emitted series must carry ``name`` / ``values`` /
``units`` keys, and same-date files must uniquify with ``.N`` suffixes
that keep counting past ``.2``.
"""

import json
import os
import sys

import pytest

# benchmarks/ is a repo-root package (like run.py's own `sys.path.insert`);
# derive the root from this file so collection works from any cwd.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks import run as bench_run  # noqa: E402


ROWS = [
    ("fig1", "fig1_alg1_periodic,123,acc_mean=0.5;acc_std=0.01;n_nan=0"),
    ("fig1", "quadgrid_sharded_speedup,4567,speedup=3.82;devices=8;"
             "sharded_faster=True"),
    ("theory", "bound_floor,0,floor=1.733"),
    ("fig1", "largeN_sharded_N10240,99,devices=8;iters=10"),
]


def test_every_series_has_name_values_units_keys():
    for suite, row in ROWS:
        rec = bench_run._parse_row(suite, row)
        for key in ("name", "values", "units"):
            assert key in rec, f"series missing {key!r}: {rec}"
        assert isinstance(rec["values"], dict) and rec["values"]
        assert rec["units"]["us_per_call"] == "us"
        # us_per_call is a value like any other, so downstream tooling
        # can read one flat dict per series.
        assert rec["values"]["us_per_call"] == rec["us_per_call"]


def test_parse_row_values_are_typed():
    rec = bench_run._parse_row(
        "fig1", "x,10,speedup=2.5;devices=8;ok=True;label=warm")
    assert rec["values"]["speedup"] == 2.5
    assert rec["values"]["devices"] == 8.0
    assert rec["values"]["ok"] is True
    assert rec["values"]["label"] == "warm"
    assert rec["values"]["us_per_call"] == 10.0


def test_build_doc_schema_and_roundtrip():
    records = [bench_run._parse_row(s, r) for s, r in ROWS]
    doc = bench_run.build_doc(["fig1", "theory"], True, 8, records, [])
    assert doc["schema"] == bench_run.SCHEMA
    assert doc["device_count"] == 8
    loaded = json.loads(json.dumps(doc))
    for rec in loaded["results"]:
        assert {"name", "values", "units"} <= set(rec)


def test_bench_out_keeps_counting_suffixes(tmp_path):
    """Non-clobbering must keep appending .N past .2 — a PR landing
    fourth on one date writes BENCH_d.4.json, overwriting nothing."""
    d, date = str(tmp_path), "2026-07-27"
    paths = []
    for expected in ("BENCH_2026-07-27.json", "BENCH_2026-07-27.2.json",
                     "BENCH_2026-07-27.3.json", "BENCH_2026-07-27.4.json"):
        path = bench_run.bench_out_path(d, date)
        assert path == str(tmp_path / expected)
        (tmp_path / expected).write_text("{}")
        paths.append(path)
    assert len(set(paths)) == 4


def test_bench_out_is_gap_tolerant(tmp_path):
    """A hole in the sequence (say .2 was deleted) is refilled without
    touching later files."""
    (tmp_path / "BENCH_2026-07-27.json").write_text("{}")
    (tmp_path / "BENCH_2026-07-27.3.json").write_text("{}")
    path = bench_run.bench_out_path(str(tmp_path), "2026-07-27")
    assert path.endswith("BENCH_2026-07-27.2.json")
