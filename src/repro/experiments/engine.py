"""Grid-batched scenario execution: one compiled computation per group.

The paper's headline evidence is a *grid* of runs — schedulers × arrival
processes × seeds. Because schedulers and energy processes are
registered pytrees (see :mod:`repro.core.energy` /
:mod:`repro.core.scheduling`), a whole grid collapses into a handful of
compiled computations:

1. Scenarios are grouped by the **pytree structure** of their built
   (scheduler, energy) pair — same dataclass types, same static
   metadata, same leaf shapes/dtypes.
2. Each group's component leaves are stacked along a new scenario axis.
3. One jitted function (:data:`_run_group`) runs
   ``vmap(scenarios) ∘ vmap(seeds)`` over :meth:`ClientSimulator.run`'s
   ``lax.scan`` — so XLA traces and compiles **once per group**, not
   once per (scenario, seed) cell.

:func:`run_grid_sequential` executes the identical cells one traced scan
at a time — the pre-refactor execution model — and exists for numerical
cross-checks and wall-clock comparison (``benchmarks/fig1.py`` times
both).
"""

from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.trainer import ClientSimulator, SimHistory
from repro.experiments.scenario import Scenario


class CellResult(NamedTuple):
    """Per-scenario result; every leaf carries a leading seed axis R.

    params  : final model parameters, leaves (R, ...)
    history : SimHistory with leaves (R, T, ...)
    evals   : eval_fn outputs with leaves (R, num_evals, ...), or None
    """

    params: Any
    history: SimHistory
    evals: Any = None


def _group_key(scheduler, energy):
    """Hashable trace signature: pytree structure + leaf shapes/dtypes."""
    leaves, treedef = jax.tree_util.tree_flatten((scheduler, energy))
    return treedef, tuple((l.shape, str(l.dtype)) for l in leaves)


def _stack(components):
    """Leaf-wise stack of same-structure pytrees along a new scenario axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *components)


@partial(jax.jit, static_argnames=("sim", "num_steps", "eval_fn", "eval_every"))
def _run_group(scheduler, energy, params0, keys, *, sim: ClientSimulator,
               num_steps: int, eval_fn=None, eval_every: int = 0):
    """vmap(scenario axis) ∘ vmap(seed axis) over one simulator scan.

    ``scheduler`` / ``energy`` leaves carry a leading scenario axis S;
    ``keys`` is (R, 2). Compiled once per (sim, group structure) — probe
    ``_run_group._cache_size()`` to assert trace counts.

    The static ``sim`` / ``eval_fn`` are hashed by identity, so each
    distinct closure (and the datasets it captures) stays referenced by
    the jit cache for process lifetime. Benchmarks and tests are short
    lived; a long-running service issuing many distinct grids should
    call :func:`clear_cache` between sweeps.
    """

    def one(sch, en, key):
        out = sim.run(key, params0, num_steps, scheduler=sch, energy=en,
                      eval_fn=eval_fn, eval_every=eval_every)
        return CellResult(*out) if eval_fn is not None else CellResult(*out, None)

    over_seeds = jax.vmap(one, in_axes=(None, None, 0))
    over_scenarios = jax.vmap(over_seeds, in_axes=(0, 0, None))
    return over_scenarios(scheduler, energy, keys)


def clear_cache() -> None:
    """Drop compiled grid executables (and the sim/eval_fn closures —
    with their captured datasets — that the jit cache keeps alive),
    for both the vmap and shard_map execution paths."""
    _run_group.clear_cache()
    from repro.experiments import placement

    placement.clear_cache()


def _seed_keys(seeds):
    if isinstance(seeds, int):
        seeds = range(seeds)
    seeds = list(seeds)
    if not seeds:
        raise ValueError("need at least one seed")
    return seeds, jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])


def check_unique_names(scenarios: Sequence[Scenario]) -> list[str]:
    """Scenario names key the result mapping — duplicates would silently
    overwrite cells. Shared by every execution path (batched, sequential,
    Study.resolve)."""
    names = [sc.name for sc in scenarios]
    if len(set(names)) != len(names):
        dups = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(
            f"scenario names must be unique, got duplicates {dups} in {names}")
    return names


def _resolve_sim(sim, grads_fn, p, optimizer, loss_fn, use_kernel):
    if sim is not None:
        return sim
    if grads_fn is None or p is None or optimizer is None:
        raise ValueError(
            "either pass a prebuilt sim= or all of grads_fn/p/optimizer")
    return ClientSimulator(grads_fn=grads_fn, p=p, optimizer=optimizer,
                           loss_fn=loss_fn, use_kernel=use_kernel)


def execute_cells(
    scenarios: Sequence[Scenario],
    *,
    sim: ClientSimulator,
    params0,
    num_steps: int,
    seeds: int | Sequence[int] = 8,
    eval_fn=None,
    eval_every: int = 0,
    mesh=None,
    sequential: bool = False,
) -> dict[str, CellResult]:
    """Execute scenario × seed cells with a prebuilt simulator.

    The single execution core behind :meth:`Study.run` and the legacy
    :func:`run_grid` / :func:`run_grid_sequential` shims. Batched mode
    groups cells by component structure and runs one compiled
    vmap(scenarios)∘vmap(seeds) computation per group (sharded across
    ``mesh`` when given); ``sequential=True`` runs one traced scan per
    cell — the pre-refactor model kept for cross-checks and timing.
    """
    scenarios = list(scenarios)
    names = check_unique_names(scenarios)
    seed_list, keys = _seed_keys(seeds)

    if sequential:
        if mesh is not None:
            raise ValueError("sequential execution does not take a mesh")
        results = {}
        for sc in scenarios:
            scheduler, energy = sc.build()
            per_seed = []
            for s in seed_list:
                out = sim.run(jax.random.PRNGKey(int(s)), params0, num_steps,
                              scheduler=scheduler, energy=energy,
                              eval_fn=eval_fn, eval_every=eval_every)
                cell = CellResult(*out) if eval_fn is not None \
                    else CellResult(*out, None)
                per_seed.append(cell)
            results[sc.name] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *per_seed)
        return results

    sharded = mesh is not None and mesh.size > 1
    if sharded:
        from repro.experiments import placement

    built = [sc.build() for sc in scenarios]
    groups: dict[Any, list[int]] = {}
    for idx, (sch, en) in enumerate(built):
        groups.setdefault(_group_key(sch, en), []).append(idx)

    results: list[CellResult | None] = [None] * len(scenarios)
    for members in groups.values():
        sch_batch = _stack([built[i][0] for i in members])
        en_batch = _stack([built[i][1] for i in members])
        if sharded:
            out = placement.run_group_sharded(
                sch_batch, en_batch, params0, keys, sim=sim,
                num_steps=num_steps, n_scenarios=len(members), mesh=mesh,
                eval_fn=eval_fn, eval_every=eval_every)
        else:
            out = _run_group(sch_batch, en_batch, params0, keys, sim=sim,
                             num_steps=num_steps, eval_fn=eval_fn,
                             eval_every=eval_every)
        for j, idx in enumerate(members):
            results[idx] = jax.tree_util.tree_map(lambda x: x[j], out)
    return dict(zip(names, results))


def run_grid(
    scenarios: Sequence[Scenario],
    *,
    grads_fn=None,
    p=None,
    optimizer=None,
    params0,
    num_steps: int,
    seeds: int | Sequence[int] = 8,
    loss_fn=None,
    use_kernel: bool = False,
    eval_fn=None,
    eval_every: int = 0,
    sim: ClientSimulator | None = None,
    mesh=None,
) -> dict[str, CellResult]:
    """Execute every scenario × seed cell, batched per component structure.

    .. deprecated:: prefer :meth:`repro.experiments.Study.run`, which
       owns simulator construction and returns a labeled
       :class:`~repro.experiments.GridResult`. This shim remains for
       hand-built irregular scenario lists.

    ``seeds`` is either a count (seeds 0..R−1) or an explicit list; seed
    ``s`` runs under ``jax.random.PRNGKey(s)``, bit-identical to a
    standalone ``ClientSimulator.run(PRNGKey(s), ...)`` of the same cell
    (up to float reassociation introduced by batching).

    ``mesh`` (a 1-D ``jax.sharding.Mesh``, e.g.
    :func:`repro.experiments.placement.make_cell_mesh`) shards each
    group's flattened (scenario × seed) cell axis across devices
    (DESIGN.md §5). Without a mesh — or with a 1-device mesh — execution
    takes the single-device vmap path, bit-for-bit as before.

    The jit cache is keyed on ``sim`` by identity, so repeated calls
    with a fresh simulator (or fresh grads_fn/eval_fn lambdas) re-trace
    every group. A driver issuing the same grid many times should build
    the simulator once and pass it via ``sim`` (then grads_fn/p/
    optimizer/loss_fn/use_kernel are taken from it and the keyword
    values are ignored).

    Returns ``{scenario.name: CellResult}`` in input order.
    """
    sim = _resolve_sim(sim, grads_fn, p, optimizer, loss_fn, use_kernel)
    return execute_cells(scenarios, sim=sim, params0=params0,
                         num_steps=num_steps, seeds=seeds, eval_fn=eval_fn,
                         eval_every=eval_every, mesh=mesh)


def run_grid_sequential(
    scenarios: Sequence[Scenario],
    *,
    grads_fn=None,
    p=None,
    optimizer=None,
    params0,
    num_steps: int,
    seeds: int | Sequence[int] = 8,
    loss_fn=None,
    use_kernel: bool = False,
    eval_fn=None,
    eval_every: int = 0,
    sim: ClientSimulator | None = None,
) -> dict[str, CellResult]:
    """The pre-refactor execution model: one traced scan per cell.

    .. deprecated:: prefer ``Study.run(config=ExecutionConfig(
       sequential=True))``. Numerically equivalent to :func:`run_grid`
       (same per-seed keys); kept as the baseline for correctness
       cross-checks and for the batched-vs-sequential wall-clock
       comparison in ``benchmarks/fig1.py``.
    """
    sim = _resolve_sim(sim, grads_fn, p, optimizer, loss_fn, use_kernel)
    return execute_cells(scenarios, sim=sim, params0=params0,
                         num_steps=num_steps, seeds=seeds, eval_fn=eval_fn,
                         eval_every=eval_every, sequential=True)


def grid_summary(results: dict[str, CellResult], reducer=None) -> dict[str, dict]:
    """Per-scenario NaN-aware mean±std over the seed axis of a metric.

    ``reducer(cell) -> (R,)`` extracts one scalar per seed; default is
    the mean loss over the final 10% of steps. Diverged seeds (NaN/inf)
    are excluded from mean/std and counted in ``n_nan``
    (:func:`repro.experiments.results.seed_stats` — the same reduction
    backing :meth:`GridResult.reduce`).
    """
    from repro.experiments import results as results_mod

    reducer = results_mod.default_metric if reducer is None else reducer
    return {name: results_mod.seed_stats(reducer(cell))
            for name, cell in results.items()}
