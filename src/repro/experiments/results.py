"""Labeled grid results: one table type for every reduction loop.

:class:`GridResult` is what :meth:`repro.experiments.Study.run` returns —
a mapping from cell name to :class:`~repro.experiments.engine.CellResult`
that *also* carries the named sweep axes each cell was resolved from, so
selection and reduction are declarative:

    result.sel(scheduler="alg1")                    # sub-grid
    result.reduce(metric, over="seed")              # mean±std per cell
    result.reduce(metric, over="capacity")          # pool an axis
    result.to_records() / result.to_json()          # export

Reductions are NaN-aware (:func:`seed_stats`): one diverged seed shows
up as ``n_nan`` instead of poisoning the scenario's mean/std. The same
helper backs the legacy :func:`repro.experiments.grid_summary`, so there
is exactly one reduction implementation.
"""

from __future__ import annotations

import json
from typing import Callable, Mapping

import numpy as np


def seed_stats(vals) -> dict:
    """NaN-aware mean/std over a (R,) per-seed metric vector.

    Returns ``{"mean", "std", "n_seeds", "n_nan"}`` where mean/std are
    computed over the finite entries only (NaN if none survive) and
    ``n_nan`` counts the discarded seeds — a diverged run is *reported*,
    not silently averaged in and not able to poison the stat.
    """
    vals = np.asarray(vals, np.float64).reshape(-1)
    nan = ~np.isfinite(vals)
    n_nan = int(nan.sum())
    kept = vals[~nan]
    if kept.size:
        mean, std = float(kept.mean()), float(kept.std())
    else:
        mean, std = float("nan"), float("nan")
    return {"mean": mean, "std": std, "n_seeds": int(vals.size),
            "n_nan": n_nan}


def default_metric(cell) -> np.ndarray:
    """Mean loss over the final 10% of steps, one scalar per seed."""
    tail = max(1, cell.history.loss.shape[-1] // 10)
    return np.asarray(cell.history.loss)[..., -tail:].mean(axis=-1)


class GridResult(Mapping):
    """Structure-of-results with named axes.

    Mapping protocol gives dict-compatible access by cell name
    (``result["alg1_periodic"].history`` works exactly like the legacy
    ``run_grid`` dict), while ``axes`` / ``labels`` carry the sweep
    coordinates each cell came from.

    Parameters
    ----------
    cells : ordered ``{name: CellResult}`` (every leaf's leading axis is
        the seed axis R).
    labels : ``{name: {axis: value}}`` — the sweep coordinates of each
        cell (excluding the seed axis).
    axes : ordered ``{axis: tuple(values)}`` for the sweep axes, in
        canonical resolution order; includes ``"seed"`` last.
    name : study name, carried into exports.
    """

    def __init__(self, cells: dict, labels: dict, axes: dict,
                 name: str = "grid", downgrades: tuple = ()):
        self._cells = dict(cells)
        self._labels = {k: dict(v) for k, v in labels.items()}
        self.axes = {k: tuple(v) for k, v in axes.items()}
        self.name = name
        #: graceful-degradation events recorded while executing this grid
        #: (:class:`repro.experiments.engine.DowngradeRecord`); empty when
        #: every group ran at its requested placement/reduction.
        self.downgrades = tuple(downgrades)

    # ------------------------------------------------------------ mapping

    def __getitem__(self, key):
        return self._cells[key]

    def __iter__(self):
        return iter(self._cells)

    def __len__(self):
        return len(self._cells)

    def __repr__(self):
        ax = ", ".join(f"{k}={len(v)}" for k, v in self.axes.items())
        return f"GridResult({self.name!r}: {len(self)} cells; {ax})"

    @property
    def cells(self) -> dict:
        return dict(self._cells)

    def labels(self, name: str) -> dict:
        """Sweep coordinates of one cell."""
        return dict(self._labels[name])

    # ---------------------------------------------------------- selection

    def sel(self, **selectors) -> "GridResult":
        """Filter cells by axis value(s): ``sel(scheduler="alg1")`` or
        ``sel(arrivals=["binary", "uniform"])``. Scalar selections drop
        the axis from ``axes`` (it no longer varies).

        Membership is by equality, never hashing — axis values may be
        unhashable (an explicit taus list, a ``(kind, kwargs)`` arrival
        pair). A selector that equals one axis value verbatim is a
        scalar selection even if it is itself a list/tuple.

        A selector value absent from its axis raises ``KeyError`` naming
        the axis and its valid values; an unknown axis *name* raises
        ``ValueError`` naming the selectable axes.
        """
        for axis in selectors:
            if axis not in self.axes or axis == "seed":
                selectable = [a for a in self.axes if a != "seed"]
                raise ValueError(
                    f"unknown axis {axis!r}; selectable axes: {selectable}")

        def is_scalar(axis, v):
            if any(v == av for av in self.axes[axis]):
                return True
            return not isinstance(v, (list, tuple, set))

        scalar = {a for a, v in selectors.items() if is_scalar(a, v)}
        wanted = {a: ([v] if a in scalar else list(v))
                  for a, v in selectors.items()}
        for axis, vs in wanted.items():
            missing = [v for v in vs
                       if not any(v == av for av in self.axes[axis])]
            if missing:
                raise KeyError(
                    f"axis {axis!r} has no value {missing[0]!r}; valid "
                    f"values: {list(self.axes[axis])}")
        names = [n for n, lab in self._labels.items()
                 if all(any(lab[a] == w for w in vs)
                        for a, vs in wanted.items())]
        if not names:
            # Every selector value exists on its axis, but the joint
            # combination has no cell (possible on irregular grids).
            raise KeyError(f"no cells match {selectors!r}")
        cells = {n: self._cells[n] for n in names}
        labels = {n: self._labels[n] for n in names}

        def surviving(axis, vals):
            if axis == "seed":
                return vals
            kept = [labels[n][axis] for n in names]
            return [v for v in vals if any(v == k for k in kept)]

        axes = {a: tuple(surviving(a, vals))
                for a, vals in self.axes.items() if a not in scalar}
        return GridResult(cells, labels, axes, name=self.name,
                          downgrades=self.downgrades)

    def only(self):
        """The single CellResult of a fully-selected grid."""
        if len(self._cells) != 1:
            raise ValueError(
                f"expected exactly one cell, have {len(self)}: "
                f"{list(self._cells)}")
        return next(iter(self._cells.values()))

    # ---------------------------------------------------------- reduction

    def reduce(self, metric: Callable | None = None,
               over: str = "seed") -> dict[str, dict]:
        """NaN-aware mean±std of a per-seed scalar metric.

        ``metric(cell) -> (R,)`` extracts one scalar per seed (default:
        mean loss over the final 10% of steps). ``over="seed"`` returns
        ``{cell_name: seed_stats}``; ``over=<axis>`` pools the metric
        across that axis's cells (seeds included), keyed by the joined
        remaining labels.
        """
        metric = default_metric if metric is None else metric
        if over == "seed":
            return {name: seed_stats(metric(cell))
                    for name, cell in self._cells.items()}
        if over not in self.axes:
            raise ValueError(
                f"unknown axis {over!r}; have {list(self.axes)}")
        keep = [a for a in self.axes
                if a not in (over, "seed") and len(self.axes[a]) > 1]
        groups: dict[str, list] = {}
        for name, cell in self._cells.items():
            lab = self._labels[name]
            key = "_".join(str(lab[a]) for a in keep) or "all"
            groups.setdefault(key, []).append(np.asarray(metric(cell)))
        return {key: seed_stats(np.concatenate(vs))
                for key, vs in groups.items()}

    def divergence(self) -> dict[str, dict]:
        """Per-cell quarantine report (DESIGN.md §10):
        ``{name: {"n_diverged", "first_bad_step"}}`` — how many seeds
        went non-finite and the earliest first-bad-step (−1: none).
        Delegates to :func:`repro.experiments.engine.divergence_summary`.
        """
        from repro.experiments.engine import divergence_summary

        return divergence_summary(self._cells)

    # ------------------------------------------------------------- export

    def to_records(self, metric: Callable | None = None) -> list[dict]:
        """One flat record per cell: name + axis labels + seed stats +
        the quarantine fields (``n_diverged`` / ``first_bad_step``)."""
        metric = default_metric if metric is None else metric
        div = self.divergence()
        return [
            {"name": name, **self._labels[name],
             **seed_stats(metric(cell)), **div[name]}
            for name, cell in self._cells.items()
        ]

    def to_json(self, path: str | None = None,
                metric: Callable | None = None) -> str:
        """Records + axes as a JSON document (optionally written to
        ``path``); values are reduced to plain python scalars."""
        doc = {
            "study": self.name,
            "axes": {a: [_jsonable(v) for v in vals]
                     for a, vals in self.axes.items()},
            "records": [{k: _jsonable(v) for k, v in rec.items()}
                        for rec in self.to_records(metric)],
        }
        text = json.dumps(doc, indent=2)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text


def _jsonable(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return [_jsonable(x) for x in v.tolist()]
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return v
