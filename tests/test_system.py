"""End-to-end system tests: drivers, simulator, aggregation kernel path."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ClientSimulator, make_quadratic, make_scheduler
from repro.core.energy import DeterministicArrivals
from repro.optim import sgd


def test_train_driver_end_to_end_loss_decreases(tmp_path):
    from repro.launch.train import main
    losses = main([
        "--arch", "stablelm-1.6b", "--reduced", "--steps", "25",
        "--global-batch", "8", "--seq-len", "32", "--n-clients", "4",
        "--scheduler", "alg1", "--arrivals", "periodic",
        "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "10",
    ])
    assert np.mean(losses[-5:]) < losses[0]
    assert any(f.startswith("step_") for f in os.listdir(tmp_path / "ck"))


def test_serve_driver_end_to_end(tmp_path):
    """The serve CLI batches a manifest file plus the demo burst through
    one StudyService: every request answered, demo structure collapsed
    onto a single compile."""
    from repro.experiments import Study
    from repro.launch.serve import main

    manifest = tmp_path / "req.json"
    study = (Study("filed", num_steps=20).axis("scheduler", "alg2")
             .axis("arrivals", "binary").axis("n_clients", 4)
             .axis("seeds", [0, 1]))
    manifest.write_text(study.to_json())

    responses = main([str(manifest), "--demo", "--demo-requests", "4",
                      "--demo-steps", "20"])
    assert len(responses) == 5
    assert all(r.error is None for r in responses)
    by_name = {r.study: r for r in responses}
    assert by_name["filed"].records[0]["scheduler"] == "alg2"
    # All 5 requests ride one dispatch (same steps/seeds/config); the 4
    # demo requests share one structure and the filed study is a second
    # -> exactly two compiles for the whole batch.
    assert responses[0].batch["requests"] == 5
    assert responses[0].cache["compiles"] == 2


def test_simulator_kernel_aggregation_matches_jnp():
    """ClientSimulator with use_kernel=True (Pallas aggregation) must give
    the same trajectory as the pure-jnp path."""
    prob = make_quadratic(jax.random.PRNGKey(0), n_clients=4, dim=8)
    det = DeterministicArrivals.periodic([1, 2, 4, 8], horizon=80)

    def grads_fn(params, key, t):
        return prob.all_grads(params)

    runs = {}
    for use_kernel in (False, True):
        sim = ClientSimulator(
            grads_fn=grads_fn, scheduler=make_scheduler("alg1", 4),
            energy=det, p=prob.p, optimizer=sgd(0.02),
            loss_fn=prob.suboptimality, use_kernel=use_kernel)
        w, hist = sim.run(jax.random.PRNGKey(5), jnp.zeros(8), 60)
        runs[use_kernel] = np.asarray(w)
    np.testing.assert_allclose(runs[False], runs[True], rtol=1e-4, atol=1e-5)


def test_dryrun_machinery_on_tiny_mesh():
    """The dry-run lowering path itself (specs → jit → lower → compile),
    exercised on a 1×1 mesh with a reduced config so it runs in-process."""
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.configs import get_config
    from repro.configs.shapes import InputShape, train_input_specs
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_train_step
    from repro.models import init_lm
    from repro.sharding import batch_specs, param_specs

    cfg = get_config("qwen2-vl-2b").reduced()
    mesh = make_host_mesh()
    shape = InputShape("tiny", 8, 4, "train")
    with mesh:
        params_s = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
        init_state, train_step = make_train_step(cfg, 2)
        state_s = jax.eval_shape(init_state, params_s)
        st_specs = param_specs(state_s, mesh)
        batch_s, sched_s = train_input_specs(cfg, shape, n_clients=2)
        b_specs = batch_specs(batch_s, mesh)
        ns = lambda t: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, PartitionSpec))
        jitted = jax.jit(train_step,
                         in_shardings=(ns(st_specs), ns(b_specs),
                                       None, None))
        lowered = jitted.lower(state_s, batch_s, sched_s["mask"],
                               sched_s["scale"])
        compiled = lowered.compile()
        assert compiled.as_text()  # HLO exists

    from repro.launch.roofline import parse_collective_bytes
    coll = parse_collective_bytes(compiled.as_text())
    assert "total" in coll


def test_roofline_collective_parser():
    from repro.launch.roofline import parse_collective_bytes
    hlo = """
  %ag = bf16[2,1024,512]{2,1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%add
  %rs = f32[128,64]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = u8[4]{0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    got = parse_collective_bytes(hlo)
    assert got["per_kind"]["all-gather"] == 2 * 1024 * 512 * 2
    assert got["per_kind"]["all-reduce"] == 256 * 4
    assert got["per_kind"]["reduce-scatter"] == 128 * 64 * 4
    assert got["per_kind"]["collective-permute"] == 4
    assert got["counts"]["all-gather"] == 1


def test_roofline_model_flops():
    from repro.configs import get_config
    from repro.launch.roofline import active_params, model_flops
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    n_act = active_params(cfg)
    assert 5e9 < n_act < 9e9  # "a6.6b"
    mf = model_flops(cfg, "train_4k")
    np.testing.assert_allclose(mf, 6 * n_act * 256 * 4096, rtol=1e-6)
