"""Benchmark: kernel-adjacent micro-benchmarks on CPU.

Pallas kernels execute in interpret mode here (the container has no TPU),
so their wall-time is NOT meaningful — instead we benchmark the XLA
implementations the kernels are validated against, plus the algorithmic
win of the chunked GLA over a naive sequential scan (a real, CPU-visible
effect of the TPU-oriented chunking).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)

    # server aggregation: weighted reduce over 40 clients x 1M params
    g = jax.random.normal(key, (40, 1_000_000))
    w = jax.random.uniform(key, (40,))
    from repro.kernels.aggregate.ref import masked_scaled_aggregate_ref
    us = _time(jax.jit(masked_scaled_aggregate_ref), g, w)
    rows.append(f"aggregate_ref_40x1M,{us:.0f},bytes={g.nbytes}")

    # attention reference at a serving-ish shape
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q = jax.random.normal(key, (1, 8, 512, 64))
    kv = jax.random.normal(key, (1, 2, 512, 64))
    us = _time(jax.jit(lambda a, b, c: flash_attention_ref(a, b, c)), q, kv,
               kv)
    rows.append(f"attention_ref_gqa_512,{us:.0f},S=512;H=8;Hkv=2")

    # chunked GLA vs naive sequential scan (the SSD chunking win)
    from repro.kernels.ssm_scan.ref import gla_scan_ref
    from repro.models.ssm import chunked_gla
    b, s, h, dk, dv = 2, 1024, 4, 32, 32
    ks = jax.random.split(key, 4)
    a = jax.random.uniform(ks[0], (b, s, h), minval=0.8, maxval=1.0)
    k = jax.random.normal(ks[1], (b, s, h, dk)) * 0.2
    v = jax.random.normal(ks[2], (b, s, h, dv))
    q2 = jax.random.normal(ks[3], (b, s, h, dk)) * 0.2
    us_chunk = _time(jax.jit(lambda *t: chunked_gla(*t, chunk=64)[0]),
                     a, k, v, q2)
    fold = lambda x: x.swapaxes(1, 2).reshape((b * h, s) + x.shape[3:])
    us_seq = _time(jax.jit(gla_scan_ref), fold(a), fold(k), fold(v),
                   fold(q2))
    rows.append(f"gla_chunked_1k,{us_chunk:.0f},speedup_vs_seq="
                f"{us_seq / us_chunk:.1f}x")
    rows.append(f"gla_sequential_1k,{us_seq:.0f},baseline")
    return rows
