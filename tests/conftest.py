"""Test-session configuration: multi-device CPU for the sharded grid path.

The placement layer (DESIGN.md §5) is only exercised with ≥ 2 devices,
so CI gives the CPU backend 8 placeholder devices
(``repro._env.ensure_host_device_count``, shared with
``benchmarks/run.py``). The flag must be set before the *first* jax
import — pytest imports conftest before any test module, which is the
one reliable hook for that.

Tests that genuinely need multiple devices carry
``@pytest.mark.multidevice`` and are skipped when the session ends up
single-device anyway (e.g. a user overriding XLA_FLAGS).
"""

import pytest

from repro._env import ensure_host_device_count

ensure_host_device_count(8)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: requires >= 2 jax devices (sharded grid placement)")
    config.addinivalue_line(
        "markers",
        "ragged: ragged client populations (mask-aware padded grids, "
        "DESIGN.md §7) — select with `-m ragged`")
    config.addinivalue_line(
        "markers",
        "clientshard: within-cell client-axis sharding (DESIGN.md §8) — "
        "select with `-m clientshard`")
    config.addinivalue_line(
        "markers",
        "faults: fault injection, non-finite quarantine and "
        "preemption-safe resumable execution (DESIGN.md §10) — select "
        "with `-m faults`")
    config.addinivalue_line(
        "markers",
        "serve: Study manifests, the batching StudyService and the "
        "keyed executable cache (DESIGN.md §11) — select with `-m serve`")
    config.addinivalue_line(
        "markers",
        "multihost: simulated multi-process `jax.distributed` execution "
        "(subprocess workers, DESIGN.md §13) — select with `-m multihost`")


def pytest_collection_modifyitems(config, items):
    import jax

    if jax.device_count() >= 2:
        return
    skip = pytest.mark.skip(reason="requires >= 2 jax devices")
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)
