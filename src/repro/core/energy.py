"""Energy-arrival processes (paper §II-B), as registered JAX pytrees.

Each process models ``E_i^t`` — whether client ``i`` harvests a unit of
energy at step ``t`` — for ``n_clients`` clients, vectorized and
scan-friendly so the whole training loop can live under ``jax.jit`` /
``jax.lax.scan``.

Every process is a ``jax.tree_util.register_dataclass`` pytree: its
array-valued hyperparameters (the schedule/gap tables, β_i, T_i) are
*leaves*, so a process can cross ``jit`` / ``vmap`` boundaries as an
ordinary argument, and a whole family of processes (e.g. one per
scenario in a sweep) can be stacked leaf-wise and executed by a single
compiled computation (see :mod:`repro.experiments`). Shapes are static
metadata by construction — ``n_clients`` / ``horizon`` derive from leaf
shapes, which jax specializes on. Registration rules are documented in
DESIGN.md §3.

Protocol (structural; all methods pure):

    init(key)              -> state                     (pytree)
    arrivals(state, t, key)-> (state, Arrivals)
    expected_participation() -> (N,) long-run participation probability

``Arrivals`` carries:
    energy : (N,) float32 in {0,1}   -- E_i^t
    gap    : (N,) float32            -- T_i^t for deterministic arrivals
                                        (gap between the arrival at/most
                                        recently before t and the next one);
                                        for stochastic processes, the
                                        *nominal* scaling constant γ_i
                                        (1/β_i binary, T_i uniform).

Three concrete processes, mirroring the paper exactly:

* ``DeterministicArrivals`` — arrival times known in advance (paper
  §II-B-1). Built from an explicit (N, horizon) 0/1 schedule or from
  per-client periods via :meth:`DeterministicArrivals.periodic`.
* ``BinaryArrivals`` — E_i^t ~ Bern(β_i) iid per step (paper eq. 9).
* ``UniformArrivals`` — exactly one arrival per window of length T_i,
  uniformly placed within the window (paper §II-B-2, "Uniform Arrivals").
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Arrivals(NamedTuple):
    """Per-step arrival information for all clients."""

    energy: jax.Array  # (N,) float32 in {0, 1}
    gap: jax.Array     # (N,) float32 — T_i^t (det.) or γ_i (stochastic)


def _concrete(x):
    """``x`` as a host ndarray if it holds concrete values, else None.

    Pytree unflattening re-invokes the dataclass constructor — sometimes
    with tracers (under jit/vmap) or opaque placeholder objects (during
    tree-structure manipulation) — so ``__post_init__`` validation must
    only fire on concrete inputs (DESIGN.md §3).
    """
    if isinstance(x, jax.core.Tracer):
        return None
    try:
        return np.asarray(x, np.float64)
    except (TypeError, ValueError):
        return None


def _gap_table(schedule: np.ndarray) -> np.ndarray:
    """Vectorized T[i, t] = Ī_i^t − I_i^t over an (N, H) 0/1 schedule.

    For each arrival at t0 with next arrival t1 (horizon if none),
    T[i, t] = t1 − t0 on t ∈ [t0, t1); 0 before the first arrival.
    """
    n, h = schedule.shape
    arr = schedule > 0
    idx = np.arange(h)[None, :]
    # I_i^t: most recent arrival at or before t (−1: none yet).
    last = np.maximum.accumulate(np.where(arr, idx, -1), axis=1)
    # First arrival at or after t (h: none); padded at index h so the
    # lookup below stays in-bounds for the final interval.
    next_ge = np.minimum.accumulate(np.where(arr, idx, h)[:, ::-1], axis=1)[:, ::-1]
    next_ge = np.concatenate([next_ge, np.full((n, 1), h)], axis=1)
    ibar = np.take_along_axis(next_ge, np.clip(last + 1, 0, h), axis=1)
    return np.where(last >= 0, ibar - last, 0).astype(np.float32)


@dataclasses.dataclass(eq=False)
class DeterministicArrivals:
    """Deterministic energy arrivals known in advance (paper §II-B-1).

    Parameters
    ----------
    schedule : (N, horizon) 0/1 array of arrival indicators. Arrival times
        for client i are ``I_i = {t : schedule[i, t] == 1}``.
    gaps : precomputed gap table; leave as None (the default) and it is
        derived from ``schedule`` on the host — the schedule is known in
        advance by assumption. Pytree unflattening supplies both leaves,
        so no recomputation happens across jit/vmap boundaries.

    The gap table ``T[i, t] = Ī_i^t − I_i^t`` is what Algorithm 1 uses. At
    an arrival time ``t`` this is the distance to the next arrival; the
    final interval is truncated at the horizon so the run stays
    self-contained (and the scheme stays unbiased within the run). Steps
    before a client's first arrival have gap 0 (the client cannot
    participate yet).
    """

    schedule: jax.Array        # (N, horizon) float32 in {0, 1} — leaf
    gaps: jax.Array = None     # (N, horizon) float32 — leaf

    def __post_init__(self):
        if self.gaps is None:
            schedule = np.asarray(self.schedule)
            if schedule.ndim != 2:
                raise ValueError(
                    f"schedule must be (N, horizon), got {schedule.shape}")
            sched01 = (schedule != 0).astype(np.float32)
            self.gaps = jnp.asarray(_gap_table(sched01))
            self.schedule = jnp.asarray(sched01)

    @property
    def n_clients(self) -> int:
        return self.schedule.shape[-2]

    @property
    def horizon(self) -> int:
        return self.schedule.shape[-1]

    @classmethod
    def periodic(cls, taus, horizon: int, offsets=None) -> "DeterministicArrivals":
        """Paper's experimental profile (eq. 37): arrivals at ``t ≡ off (mod τ_i)``."""
        taus = np.asarray(taus, dtype=np.int64)
        if offsets is None:
            offsets = np.zeros_like(taus)
        offsets = np.asarray(offsets, dtype=np.int64)
        t = np.arange(horizon)[None, :]
        sched = ((t - offsets[:, None]) % taus[:, None] == 0) & (t >= offsets[:, None])
        return cls(sched.astype(np.float32))

    def init(self, key):
        del key
        return ()

    def arrivals(self, state, t, key):
        del key
        t = jnp.asarray(t, jnp.int32)
        # Past the precomputed horizon there are no further arrivals.
        tc = jnp.clip(t, 0, self.horizon - 1)
        valid = (t < self.horizon).astype(jnp.float32)
        energy = self.schedule[:, tc] * valid
        gap = self.gaps[:, tc] * valid
        return state, Arrivals(energy=energy, gap=gap)

    def expected_participation(self) -> jax.Array:
        # Trailing (horizon) axis so stacked (S, N, H) instances batch too.
        return jnp.mean(self.schedule, axis=-1)


@dataclasses.dataclass(eq=False)
class BinaryArrivals:
    """E_i^t ~ Bern(β_i), iid across steps and clients (paper eq. 9).

    Requires β_i ∈ (0, 1]: the unbiased scaling γ_i = 1/β_i (Alg. 2 /
    Corollary 1) is infinite for β_i = 0 — a client that never harvests
    cannot be scheduled — so zero/negative rates are rejected at
    construction rather than silently producing ``inf`` scales.
    """

    betas: jax.Array  # (N,) float32 — leaf

    def __post_init__(self):
        betas = _concrete(self.betas)
        if betas is not None:
            if betas.ndim < 1:
                raise ValueError(f"betas must be (N,), got {betas.shape}")
            if betas.size and not (np.all(np.isfinite(betas))
                                   and np.all(betas > 0.0)
                                   and np.all(betas <= 1.0)):
                raise ValueError(
                    "BinaryArrivals requires finite betas in (0, 1]; got "
                    f"min={betas.min():g}, max={betas.max():g} "
                    "(β_i = 0 would make the 1/β_i scaling infinite)")
            self.betas = jnp.asarray(betas, jnp.float32)

    @property
    def n_clients(self) -> int:
        # Trailing axis so stacked (scenario-batched) instances resolve too.
        return self.betas.shape[-1]

    def init(self, key):
        del key
        return ()

    def arrivals(self, state, t, key):
        del t
        u = jax.random.uniform(key, (self.n_clients,))
        energy = (u < self.betas).astype(jnp.float32)
        gap = 1.0 / self.betas  # γ_i = 1/β_i (Alg. 2 / Corollary 1)
        return state, Arrivals(energy=energy, gap=gap)

    def expected_participation(self) -> jax.Array:
        return self.betas


class UniformArrivalsState(NamedTuple):
    offset: jax.Array  # (N,) int32 — arrival position inside current window


@dataclasses.dataclass(eq=False)
class UniformArrivals:
    """One arrival per window of length T_i, uniformly placed (paper §II-B-2).

    For every t with ``t mod T_i == 0`` a fresh offset ``U{0,…,T_i−1}`` is
    drawn; the client receives energy when ``t mod T_i == offset``. Windows
    are per-client, so clients with different ``T_i`` roll over at
    different times.
    """

    periods: jax.Array  # (N,) int32 — leaf

    def __post_init__(self):
        periods = _concrete(self.periods)
        if periods is not None:
            if periods.ndim < 1:
                raise ValueError(f"periods must be (N,), got {periods.shape}")
            if periods.size and not (np.all(np.isfinite(periods))
                                     and np.all(periods >= 1)):
                raise ValueError(
                    "UniformArrivals requires finite periods >= 1; "
                    f"got min={periods.min():g}")
            self.periods = jnp.asarray(periods, jnp.int32)

    @property
    def n_clients(self) -> int:
        return self.periods.shape[-1]

    def init(self, key):
        # Offsets for the first window (the t=0 step rolls them anyway if
        # t mod T == 0, which it is; keep a valid placeholder).
        offset = jax.random.randint(key, (self.n_clients,), 0, jnp.asarray(2**30)) % self.periods
        return UniformArrivalsState(offset=offset.astype(jnp.int32))

    def arrivals(self, state, t, key):
        t = jnp.asarray(t, jnp.int32)
        pos = t % self.periods
        fresh = jax.random.randint(key, (self.n_clients,), 0, jnp.asarray(2**30)) % self.periods
        offset = jnp.where(pos == 0, fresh.astype(jnp.int32), state.offset)
        energy = (pos == offset).astype(jnp.float32)
        gap = self.periods.astype(jnp.float32)  # γ_i = T_i (Corollary 1)
        return UniformArrivalsState(offset=offset), Arrivals(energy=energy, gap=gap)

    def expected_participation(self) -> jax.Array:
        return 1.0 / self.periods.astype(jnp.float32)


jax.tree_util.register_dataclass(
    DeterministicArrivals, data_fields=["schedule", "gaps"], meta_fields=[])
jax.tree_util.register_dataclass(
    BinaryArrivals, data_fields=["betas"], meta_fields=[])
jax.tree_util.register_dataclass(
    UniformArrivals, data_fields=["periods"], meta_fields=[])


def expected_participation(process) -> jax.Array:
    """Long-run participation probability per client under best-effort.

    Delegates to the process's protocol method — any object implementing
    ``expected_participation()`` works; no type dispatch.

    Used by tests and by the theory module (Corollary 1 constants).
    """
    try:
        method = process.expected_participation
    except AttributeError:
        raise TypeError(
            f"{type(process)!r} does not implement the energy-process "
            "protocol (missing expected_participation())") from None
    return method()
