"""Simulated multi-process `jax.distributed` execution (DESIGN.md §13).

One 2-process harness launch (subprocess workers, 4 CPU placeholder
devices each, gloo collectives over localhost) runs the canonical
differential job — a ragged Fig-1 sub-grid, 2 scheduler structures ×
ragged populations — through the unchanged ``Study.run`` dispatch on
process-spanning meshes. The module-scoped fixture launches once; the
tests then hold the workers' combined output to the repo's equivalence
contract: gather mode bitwise against the single-process vmap engine,
psum and the cells-spanning mesh to float32 reassociation tolerance,
one compile per structure group per process.

Plus the satellite device/env-flag fixes: the late
``ensure_host_device_count`` warning, the ``REPRO_DIST_*`` env
contract, and global-vs-local device counts in placement errors.
"""

import json
import os

import numpy as np
import pytest

from repro._env import (
    DIST_COORDINATOR,
    DIST_LOCAL_DEVICES,
    DIST_NUM_PROCESSES,
    DIST_PROCESS_ID,
    distributed_env,
    ensure_host_device_count,
)
from repro.launch import distributed as dist

pytestmark = pytest.mark.multihost

STEPS, SEEDS = 25, 2
LOSS_TOL = dict(rtol=2e-4, atol=1e-5)


@pytest.fixture(scope="module")
def simulated_run(tmp_path_factory):
    """One 2-process run covering both meshes and both reductions."""
    out = str(tmp_path_factory.mktemp("mh"))
    dist.launch_simulated(2, 4, argv=[
        "--mesh", "clients,multihost", "--reduction", "gather,psum",
        "--steps", str(STEPS), "--seeds", str(SEEDS), "--out", out])
    results = dict(np.load(os.path.join(out, "results.npz")))
    reports = []
    for pid in range(2):
        with open(os.path.join(out, f"report_p{pid}.json")) as f:
            reports.append(json.load(f))
    return results, reports


@pytest.fixture(scope="module")
def reference():
    """Single-process vmap-engine oracle, flattened like the npz."""
    return dist.flatten_results("ref", dist.reference_results(STEPS, SEEDS))


def _cells(results, tag):
    cells = {k.split("|")[1] for k in results if k.startswith(tag + "|")}
    assert cells, f"no {tag} results in the worker npz"
    return cells


def test_job_is_a_ragged_multischeduler_grid():
    # The differential job must keep covering what the contract names:
    # >= 2 scheduler structures and genuinely ragged populations.
    scenarios = dist.make_job_study(STEPS, SEEDS).resolve()
    assert len({sc.scheduler for sc in scenarios}) >= 2
    assert len({sc.n_clients for sc in scenarios}) >= 2
    assert min(sc.n_clients for sc in scenarios) < dist.JOB_N_CAP


def test_gather_bitwise_vs_single_process_vmap(simulated_run, reference):
    results, _ = simulated_run
    for cell in _cells(results, "clients-gather"):
        for field in ("params", "loss", "participation", "weight_sum",
                      "finite", "diverged"):
            got = results[f"clients-gather|{cell}|{field}"]
            ref = reference[f"ref|{cell}|{field}"]
            np.testing.assert_array_equal(got, ref, err_msg=(
                f"2-process gather drifted from the vmap engine: "
                f"{cell}/{field}"))


def test_psum_within_tolerance(simulated_run, reference):
    results, _ = simulated_run
    for cell in _cells(results, "clients-psum"):
        np.testing.assert_allclose(
            results[f"clients-psum|{cell}|loss"],
            reference[f"ref|{cell}|loss"], **LOSS_TOL)
        np.testing.assert_allclose(
            results[f"clients-psum|{cell}|params"],
            reference[f"ref|{cell}|params"], **LOSS_TOL)
        for field in ("participation", "finite", "diverged"):
            np.testing.assert_array_equal(
                results[f"clients-psum|{cell}|{field}"],
                reference[f"ref|{cell}|{field}"])


def test_cells_spanning_mesh_within_tolerance(simulated_run, reference):
    results, _ = simulated_run
    for cell in _cells(results, "multihost-gather"):
        np.testing.assert_allclose(
            results[f"multihost-gather|{cell}|loss"],
            reference[f"ref|{cell}|loss"], **LOSS_TOL)
        for field in ("participation", "finite", "diverged"):
            np.testing.assert_array_equal(
                results[f"multihost-gather|{cell}|{field}"],
                reference[f"ref|{cell}|{field}"])


def test_one_compile_per_structure_group_per_process(simulated_run):
    _, reports = simulated_run
    assert [r["process_id"] for r in reports] == [0, 1]
    for rep in reports:
        assert rep["process_count"] == 2
        assert rep["global_devices"] == 8
        assert rep["local_devices"] == 4
        for tag, combo in rep["combos"].items():
            assert combo["compiles"] == 2, (tag, combo)
            assert combo["warm_new_compiles"] == 0, (tag, combo)
            assert combo["mesh_process_span"] == 2, (tag, combo)


def test_mesh_topologies(simulated_run):
    _, reports = simulated_run
    combos = reports[0]["combos"]
    # clients mesh: the ROADMAP mapping — client axis across hosts.
    assert combos["clients-gather"]["mesh_shape"] == {"clients": 8}
    # multihost mesh: cells across processes, clients process-local.
    assert combos["multihost-gather"]["mesh_shape"] == {
        "cells": 2, "clients": 4}


# ----------------------------------------- satellite device/env fixes

def test_late_ensure_host_device_count_warns():
    import jax  # long imported by this suite

    with pytest.warns(UserWarning, match=r"jax\.device_count\(\)=%d"
                      % jax.device_count()):
        assert ensure_host_device_count(512) is False


def test_distributed_env_roundtrip_and_partial(monkeypatch):
    monkeypatch.delenv(DIST_COORDINATOR, raising=False)
    monkeypatch.delenv(DIST_NUM_PROCESSES, raising=False)
    monkeypatch.delenv(DIST_PROCESS_ID, raising=False)
    monkeypatch.delenv(DIST_LOCAL_DEVICES, raising=False)
    assert distributed_env() is None

    monkeypatch.setenv(DIST_COORDINATOR, "127.0.0.1:1234")
    with pytest.raises(ValueError, match="partial REPRO_DIST_"):
        distributed_env()

    monkeypatch.setenv(DIST_NUM_PROCESSES, "2")
    monkeypatch.setenv(DIST_PROCESS_ID, "1")
    monkeypatch.setenv(DIST_LOCAL_DEVICES, "4")
    assert distributed_env() == {
        "coordinator": "127.0.0.1:1234", "num_processes": 2,
        "process_id": 1, "local_devices": 4}

    monkeypatch.delenv(DIST_COORDINATOR)
    with pytest.raises(ValueError, match="partial REPRO_DIST_"):
        distributed_env()


def test_placement_errors_name_global_topology():
    from repro.experiments import placement

    with pytest.raises(ValueError, match=r"global device\(s\) across "
                                         r"\d+ process\(es\)"):
        placement.make_grid_mesh(cells=7, clients=5)
    with pytest.raises(ValueError, match="needs 35 global devices"):
        placement.make_grid_mesh(cells=7, clients=5)


def test_device_topology_string():
    import jax

    from repro.experiments import placement

    s = placement.device_topology()
    assert f"{jax.device_count()} global device(s)" in s
    assert "across 1 process(es)" in s
