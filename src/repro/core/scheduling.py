"""User-scheduling policies (paper §III + §V benchmarks), as JAX pytrees.

Every scheduler is a pure-jax state machine:

    init(key)               -> state
    step(state, t, key, arrivals, active=None) -> (state, Decision)

``active`` is an optional (N,) 0/1 mask of *existing* clients — the
ragged-population mechanism (DESIGN.md §7): padded rows (``active=0``)
must receive zero participation probability mass from every scheduler,
and population-global decisions (Benchmark 2's all-batteries-full
barrier, the oracle's full participation) are taken over active clients
only. ``active=None`` means all clients exist and is bit-for-bit the
pre-ragged behavior.

with ``Decision(mask, scale)``:

    mask  : (N,) float32 in {0,1} — α_i^t, does client i participate at t
    scale : (N,) float32          — the gradient scaling the client applies
                                    (T_i^t, γ_i, or 1 for benchmarks)

The server-side weight for client i at step t is then
``p_i · mask_i · scale_i`` (paper eq. 11/12), assembled by
:mod:`repro.core.aggregation`.

Like the energy processes, every scheduler is a registered pytree
dataclass (``jax.tree_util.register_dataclass``): array-valued
hyperparameters (battery capacity, EMA rate, warmup) are leaves, while
shape-determining fields (``n_clients``) and python-level branches
(``scaled``) are static metadata. A scheduler therefore passes through
``jit`` / ``vmap`` / ``lax.scan`` as a plain argument, and a family of
schedulers (e.g. a capacity sweep) stacks leaf-wise into one batched
computation. See DESIGN.md §3 for the registration rules.

Schedulers
----------
* ``EHAppointmentScheduler`` — **Algorithm 1** (deterministic arrivals):
  on arrival at t, draw J ~ U{0,…,T_i^t−1}, book an appointment at t+J,
  participate then with scale T_i^t. P[participate at any step] = 1/T_i^t.
* ``BestEffortScheduler`` — **Algorithm 2** (stochastic arrivals):
  participate immediately on arrival, scale γ_i (=1/β_i or T_i).
  With ``scaled=False`` it degrades into the paper's **Benchmark 1**
  (energy-agnostic best-effort).
* ``WaitForAllScheduler`` — **Benchmark 2**: clients bank energy in a unit
  battery; a global synchronous step fires only when *all* batteries are
  full.
* ``AlwaysOnScheduler`` — the full-participation oracle (conventional
  distributed SGD with all users available, paper §V "target").
* ``BatteryAdaptiveScheduler`` — beyond-paper energy accumulation with
  adaptive inverse-rate scaling (paper §VI future work).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.energy import (
    Arrivals,
    _concrete,
    client_randint,
    population_min,
)


class Decision(NamedTuple):
    mask: jax.Array   # (N,) float32 in {0,1}
    scale: jax.Array  # (N,) float32


def mask_arrivals(arrivals: Arrivals, active) -> Arrivals:
    """Zero the energy of inactive rows (identity when ``active`` is None).

    Multiplication by 1.0 is exact on active rows, so a padded run stays
    bit-identical to the natural-N run for every existing client.
    """
    if active is None:
        return arrivals
    return Arrivals(energy=arrivals.energy * active, gap=arrivals.gap)


def _mask_decision(mask: jax.Array, active) -> jax.Array:
    return mask if active is None else mask * active


class AppointmentState(NamedTuple):
    appt_time: jax.Array   # (N,) int32 — booked participation step (-1: none)
    appt_scale: jax.Array  # (N,) float32 — T_i^t captured at booking time


@dataclasses.dataclass(eq=False)
class EHAppointmentScheduler:
    """Algorithm 1 — unbiased scheduling for deterministic arrivals."""

    n_clients: int  # static

    def init(self, key):
        del key
        return AppointmentState(
            appt_time=jnp.full((self.n_clients,), -1, jnp.int32),
            appt_scale=jnp.zeros((self.n_clients,), jnp.float32),
        )

    def step(self, state, t, key, arrivals: Arrivals, active=None):
        arrivals = mask_arrivals(arrivals, active)
        t = jnp.asarray(t, jnp.int32)
        gap = jnp.maximum(arrivals.gap, 1.0)
        # J ~ Uniform{0, …, T_i^t − 1}, per-client bound, drawn
        # shape-independently (fold_in per client — padding the
        # population never changes client i's draw).
        j = client_randint(key, self.n_clients, gap)
        arrived = arrivals.energy > 0
        appt_time = jnp.where(arrived, t + j, state.appt_time)
        appt_scale = jnp.where(arrived, gap, state.appt_scale)
        mask = _mask_decision((appt_time == t).astype(jnp.float32), active)
        new_state = AppointmentState(appt_time=appt_time, appt_scale=appt_scale)
        return new_state, Decision(mask=mask, scale=appt_scale)


@dataclasses.dataclass(eq=False)
class BestEffortScheduler:
    """Algorithm 2 (scaled=True) / paper Benchmark 1 (scaled=False)."""

    n_clients: int       # static
    scaled: bool = True  # static — selects which algorithm is traced

    def init(self, key):
        del key
        return ()

    def step(self, state, t, key, arrivals: Arrivals, active=None):
        del t, key
        mask = mask_arrivals(arrivals, active).energy
        if self.scaled:
            scale = jnp.maximum(arrivals.gap, 1.0)
        else:
            scale = jnp.ones_like(mask)
        return state, Decision(mask=mask, scale=scale)


class WaitForAllState(NamedTuple):
    battery: jax.Array  # (N,) float32 in {0,1} — unit battery


@dataclasses.dataclass(eq=False)
class WaitForAllScheduler:
    """Benchmark 2 — synchronous step only when every battery is full."""

    n_clients: int  # static

    def init(self, key):
        del key
        return WaitForAllState(battery=jnp.zeros((self.n_clients,), jnp.float32))

    def step(self, state, t, key, arrivals: Arrivals, active=None):
        del t, key
        arrivals = mask_arrivals(arrivals, active)
        battery = jnp.minimum(state.battery + arrivals.energy, 1.0)
        # The all-full barrier is over *active* clients only: a padded
        # row (which never harvests) must not block the whole population.
        # population_min is a pmin across shards when the client axis is
        # device-sharded (DESIGN.md §8) — min is exact, so the sharded
        # barrier fires on bitwise the same step as the unsharded one.
        ready = battery if active is None else jnp.where(active > 0,
                                                         battery, 1.0)
        fire = population_min(ready) >= 1.0
        mask = jnp.where(fire, jnp.ones_like(battery), jnp.zeros_like(battery))
        mask = _mask_decision(mask, active)
        battery = battery - mask
        return WaitForAllState(battery=battery), Decision(
            mask=mask, scale=jnp.ones_like(battery)
        )


@dataclasses.dataclass(eq=False)
class AlwaysOnScheduler:
    """Full-participation oracle (conventional distributed SGD)."""

    n_clients: int  # static

    def init(self, key):
        del key
        return ()

    def step(self, state, t, key, arrivals: Arrivals, active=None):
        del t, key, arrivals
        ones = jnp.ones((self.n_clients,), jnp.float32)
        return state, Decision(mask=_mask_decision(ones, active), scale=ones)


class BatteryState(NamedTuple):
    battery: jax.Array  # (N,) float32 in [0, capacity]
    rate: jax.Array     # (N,) float32 — EMA participation-rate estimate
    steps: jax.Array    # () int32


@dataclasses.dataclass(eq=False)
class BatteryAdaptiveScheduler:
    """Beyond-paper: energy ACCUMULATION (the paper's §VI future work).

    Devices bank harvested energy in a battery of ``capacity`` units
    (paper assumes capacity 1) and participate whenever ≥1 unit is
    stored. Unbiasedness is restored *adaptively*: each client scales its
    gradient by the inverse of its own EMA participation-rate estimate —
    "requires only local estimation of the energy statistics" (abstract).
    With capacity 1 and Bernoulli arrivals this converges to Algorithm 2's
    1/β_i scaling without knowing β_i.

    ``capacity`` / ``ema`` / ``warmup`` are array leaves, so a sweep over
    battery capacities is a leaf-stacked batch of schedulers — one
    compiled computation for the whole sweep.
    """

    n_clients: int            # static
    capacity: jax.Array = 2.0  # () float32 — leaf
    ema: jax.Array = 0.05      # () float32 — leaf
    warmup: jax.Array = 20     # () int32 — leaf

    def __post_init__(self):
        for name, dtype in (("capacity", jnp.float32), ("ema", jnp.float32),
                            ("warmup", jnp.int32)):
            val = _concrete(getattr(self, name))
            if val is not None:
                setattr(self, name, jnp.asarray(val, dtype))

    def init(self, key):
        del key
        return BatteryState(
            battery=jnp.zeros((self.n_clients,), jnp.float32),
            rate=jnp.ones((self.n_clients,), jnp.float32),
            steps=jnp.zeros((), jnp.int32),
        )

    def step(self, state, t, key, arrivals: Arrivals, active=None):
        del t, key
        arrivals = mask_arrivals(arrivals, active)
        battery = jnp.minimum(state.battery + arrivals.energy, self.capacity)
        mask = _mask_decision((battery >= 1.0).astype(jnp.float32), active)
        battery = battery - mask
        rate = (1 - self.ema) * state.rate + self.ema * mask
        # During warmup the estimate is unusable -> scale 1 (biased but
        # bounded); afterwards scale by 1/r̂ clipped for stability.
        scale = jnp.where(state.steps >= self.warmup,
                          1.0 / jnp.clip(rate, 0.02, 1.0),
                          jnp.ones_like(rate))
        new = BatteryState(battery=battery, rate=rate, steps=state.steps + 1)
        return new, Decision(mask=mask, scale=scale)


jax.tree_util.register_dataclass(
    EHAppointmentScheduler, data_fields=[], meta_fields=["n_clients"])
jax.tree_util.register_dataclass(
    BestEffortScheduler, data_fields=[], meta_fields=["n_clients", "scaled"])
jax.tree_util.register_dataclass(
    WaitForAllScheduler, data_fields=[], meta_fields=["n_clients"])
jax.tree_util.register_dataclass(
    AlwaysOnScheduler, data_fields=[], meta_fields=["n_clients"])
jax.tree_util.register_dataclass(
    BatteryAdaptiveScheduler,
    data_fields=["capacity", "ema", "warmup"], meta_fields=["n_clients"])


def pad_scheduler(scheduler, n_total: int):
    """Widen a scheduler to ``n_total`` client rows (ragged padding).

    Schedulers defining ``pad_clients(n)`` own their padding rule (needed
    when a custom scheduler carries per-client leaves); the built-ins
    have only the static ``n_clients`` plus scalar leaves, so
    ``dataclasses.replace`` widens them — per-client *state* is sized by
    ``init`` at the padded width automatically.
    """
    method = getattr(scheduler, "pad_clients", None)
    if method is not None:
        return method(n_total)
    if int(n_total) < int(scheduler.n_clients):
        raise ValueError(
            f"cannot pad {scheduler.n_clients} clients down to {n_total}")
    return dataclasses.replace(scheduler, n_clients=int(n_total))


def shard_scheduler(scheduler, n_local: int):
    """Scheduler view over one client-axis shard of ``n_local`` rows.

    The client-sharded execution path (DESIGN.md §8) runs every scheduler
    with shard-local per-client *state* — ``init`` sizes its arrays from
    ``n_clients``, so narrowing the static width is all the built-ins
    need (their leaves are scalar hyperparameters, replicated across the
    client axis). A custom scheduler carrying per-client leaves must
    define ``shard_clients(n_local)`` returning its local view; the
    placement layer shards the leaves themselves via the leaf-shape rule
    (:func:`repro.experiments.placement.client_leaf_specs`).
    """
    method = getattr(scheduler, "shard_clients", None)
    if method is not None:
        return method(n_local)
    return dataclasses.replace(scheduler, n_clients=int(n_local))


def _strict(ctor, name, n, kw, **fixed):
    """Registry entries whose identity admits no extra hyperparameters
    must reject them — silently swallowing `scaled=False` (or a typo'd
    kwarg) would run a different algorithm than requested."""
    if kw:
        raise TypeError(f"scheduler {name!r} takes no extra kwargs; "
                        f"got {sorted(kw)}")
    return ctor(n, **fixed)


_REGISTRY = {
    "alg1": lambda n, **kw: _strict(EHAppointmentScheduler, "alg1", n, kw),
    "alg2": lambda n, **kw: _strict(BestEffortScheduler, "alg2", n, kw,
                                    scaled=True),
    "benchmark1": lambda n, **kw: _strict(BestEffortScheduler, "benchmark1",
                                          n, kw, scaled=False),
    "benchmark2": lambda n, **kw: _strict(WaitForAllScheduler, "benchmark2",
                                          n, kw),
    "oracle": lambda n, **kw: _strict(AlwaysOnScheduler, "oracle", n, kw),
    "battery_adaptive": lambda n, **kw: BatteryAdaptiveScheduler(n, **kw),
}


def register_scheduler(name: str, factory=None):
    """Register a named scheduler factory ``(n_clients, **kw) -> scheduler``.

    Usable directly or as a decorator; the experiment layer's
    ``scheduler`` sweep axis is built from this registry.
    """
    if factory is None:
        def deco(fn):
            _REGISTRY[name] = fn
            return fn

        return deco
    _REGISTRY[name] = factory
    return factory


def make_scheduler(name: str, n_clients: int, **kw):
    """Scheduler factory — names used across configs/CLI/benchmarks."""
    try:
        return _REGISTRY[name](int(n_clients), **kw)
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}; have {sorted(_REGISTRY)}") from None


def scheduler_names():
    return sorted(_REGISTRY)
