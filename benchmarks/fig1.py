"""Benchmark: paper Figure 1 as a full scenario grid.

Runs the 4 paper schedulers × 3 arrival families × ``seeds`` seeds on a
reduced-scale CNN image task through :func:`repro.experiments.run_grid`
(one compiled computation per scheduler × arrival structure), then runs
the *identical* cells through the sequential per-cell baseline
(:func:`run_grid_sequential`, one traced scan per cell — the
pre-scenario-engine execution model) and reports both wall-clocks.

Emits ``name,us_per_call,derived`` CSV rows: per-cell mean±std final
test accuracy across seeds, the two grid wall-clocks, the batched
speedup, and the paper's Fig-1 ordering check (periodic arrivals).
``examples/paper_cifar.py --full`` remains the paper-exact variant.
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp


def _setup(n_clients: int, hw: int, batch: int, seed: int = 0):
    from repro.data import (
        ClientBatcher,
        group_label_skew_partition,
        make_confusable_image_classification,
    )
    from repro.models.cnn import cnn_accuracy, init_cnn

    n_train, n_test = 96 * n_clients, 512
    ds = make_confusable_image_classification(
        seed, n_train + n_test, image_shape=(hw, hw, 3),
        similarity=0.9, noise=0.8)
    train_x, train_y = ds.images[:n_train], ds.labels[:n_train]
    test_x = jnp.asarray(ds.images[n_train:])
    test_y = jnp.asarray(ds.labels[n_train:])
    parts = group_label_skew_partition(seed, train_y, n_clients, 4, skew=1.0)
    per_client = [{"x": train_x[ix], "y": train_y[ix]} for ix in parts]
    batcher = ClientBatcher(per_client, batch_size=batch, seed=seed)
    params0 = init_cnn(jax.random.PRNGKey(seed), image_hw=hw)

    from examples.paper_cifar import per_client_grads_fn
    grads_fn = per_client_grads_fn(batcher, hw)
    eval_fn = lambda p: cnn_accuracy(p, test_x, test_y)
    return grads_fn, eval_fn, batcher.p, params0


def run(iters: int = 100, seeds: int = 8, n_clients: int = 8) -> list[str]:
    from repro.core import ClientSimulator
    from repro.experiments import (
        ARRIVAL_KINDS,
        FIG1_SCHEDULERS,
        clear_cache,
        get_grid,
        grid_summary,
        run_grid,
        run_grid_sequential,
    )
    from repro.optim import sgd

    hw, batch, lr = 8, 2, 0.05
    grads_fn, eval_fn, p, params0 = _setup(n_clients, hw, batch)
    scenarios = get_grid("fig1_grid", n_clients=n_clients, horizon=iters + 1)
    # One simulator for both execution paths: repeat run_grid calls with
    # the same sim hit the jit cache instead of re-tracing.
    sim = ClientSimulator(grads_fn=grads_fn, p=p, optimizer=sgd(lr))
    kw = dict(sim=sim, params0=params0, num_steps=iters, seeds=seeds,
              eval_fn=eval_fn, eval_every=iters)
    n_cells = len(scenarios) * seeds

    t0 = time.time()
    results = run_grid(scenarios, **kw)
    jax.block_until_ready([c.evals for c in results.values()])
    dt_batched = time.time() - t0

    t0 = time.time()
    seq_results = run_grid_sequential(scenarios, **kw)
    jax.block_until_ready([c.evals for c in seq_results.values()])
    dt_seq = time.time() - t0

    # Final test accuracy per seed = the single end-of-run eval.
    acc = grid_summary(results, reducer=lambda c: c.evals[:, -1])
    rows = []
    for sc in scenarios:
        s = acc[sc.name]
        rows.append(f"fig1_{sc.name},{dt_batched * 1e6 / n_cells:.0f},"
                    f"acc_mean={s['mean']:.3f};acc_std={s['std']:.3f};"
                    f"seeds={s['n_seeds']}")

    speedup = dt_seq / dt_batched
    # Meta output goes to stderr — stdout is the harness's CSV stream.
    print(f"fig1 grid: {n_cells} cells "
          f"({len(FIG1_SCHEDULERS)}x{len(ARRIVAL_KINDS)}x{seeds} seeds), "
          f"{iters} iters; "
          f"batched {dt_batched:.1f}s vs sequential {dt_seq:.1f}s "
          f"-> {speedup:.1f}x", file=sys.stderr)
    rows.append(f"fig1_grid_batched,{dt_batched * 1e6:.0f},"
                f"cells={n_cells};iters={iters}")
    rows.append(f"fig1_grid_sequential,{dt_seq * 1e6:.0f},"
                f"cells={n_cells};iters={iters}")
    rows.append(f"fig1_grid_speedup,{dt_batched * 1e6:.0f},"
                f"speedup={speedup:.2f};batched_faster={dt_batched < dt_seq}")

    # Paper ordering on the paper's (periodic) arrivals, seed-averaged.
    a = {m: acc[f"{m}_periodic"]["mean"] for m in FIG1_SCHEDULERS}
    ok = a["alg1"] > a["benchmark1"] > 0 and a["alg1"] > a["benchmark2"]
    rows.append(f"fig1_ordering,{dt_batched * 1e6:.0f},alg1>benchmarks={ok}")
    # Release the compiled grid + the dataset-capturing closures it pins
    # (the harness process may go on to run other suites).
    clear_cache()
    return rows
