"""ArchConfig — the single config dataclass every architecture instantiates.

A config fully determines: parameter shapes/init, the block stack
(``superblock`` × ``n_super``), attention flavour, decode-cache layout and
the dry-run input specs. ``reduced()`` produces the CPU smoke-test variant
(≤2 layers, d_model ≤ 512, ≤4 experts) of the *same family*.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

Superblock = Tuple[Tuple[str, int, bool], ...]  # (kind, count, shared)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    citation: str = ""

    head_dim: int = 0              # 0 → d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    moe_capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_head_dim: int = 64
    slstm_heads: int = 4
    slstm_ff: int = 0
    gla_chunk: int = 64
    # Stack layout; () → derived from arch_type in __post_init__-ish helper
    superblock: Superblock = ()
    n_super: int = 1
    # Attention details
    sliding_window: int = 0        # 0 = full causal attention
    long_context_window: int = 8192  # SWA window used only for long_500k
    rope_theta: float = 1e4
    m_rope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    pos_embed: str = "rope"        # rope | sinusoidal | none
    # Encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_len: int = 1500
    # VLM
    n_vision_tokens: int = 0
    # Misc
    use_bias: bool = False
    norm: str = "rmsnorm"
    act: str = "silu"
    gated_mlp: bool = True
    tie_embeddings: bool = False
    dtype_name: str = "float32"
    remat: bool = False
    # Sequence-chunked cross entropy: the (B,S,vocab) logits tensor never
    # fully materializes — live logits are (B,loss_chunk,vocab). 0 = off.
    # §Perf hillclimb 3.3 lever for huge-vocab trains (command-r 256k).
    loss_chunk: int = 0
    # remat granularity: "full" recomputes everything in backward;
    # "dots" saves matmul outputs (jax dots_with_no_batch_dims_saveable)
    # trading HBM for ~1/3 less recompute — a §Perf lever.
    remat_policy: str = "full"
    use_flash: bool = False
    # Dry-run fidelity: unroll layer scans so cost_analysis counts every
    # layer (XLA HloCostAnalysis counts a while body ONCE — measured).
    unroll_layers: bool = False

    # ------------------------------------------------------------- derived

    @property
    def dtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                "float16": jnp.float16}[self.dtype_name]

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_superblock(self) -> Superblock:
        if self.superblock:
            return self.superblock
        kind = "attn_moe" if self.arch_type == "moe" else "attn_mlp"
        return ((kind, self.n_layers, False),)

    @property
    def total_layers(self) -> int:
        return self.n_super * sum(c for _, c, _ in self.resolved_superblock)

    @property
    def is_subquadratic(self) -> bool:
        """True if long_500k decodes in O(1)/O(window) state per token."""
        kinds = {k for k, _, _ in self.resolved_superblock}
        ssm_only = kinds <= {"mamba2", "mlstm", "slstm"}
        return ssm_only or self.sliding_window > 0

    def supports_shape(self, shape_name: str) -> bool:
        if self.enc_dec and shape_name == "long_500k":
            return False  # whisper: 524k-token decoder is meaningless (DESIGN.md)
        return True

    # ------------------------------------------------------------ variants

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family, tiny dims (CPU-runnable)."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        head_dim = max(d_model // n_heads, 8)
        # Shrink each superblock segment to ≤1 block, ≤2 supers.
        sb = tuple((k, 1, sh) for k, _, sh in self.resolved_superblock)
        # M-RoPE sections must sum to head_dim/2 — re-derive for tiny dims.
        half = head_dim // 2
        t_sec = max(half // 4, 1)
        h_sec = (half - t_sec) // 2
        mrope = (t_sec, h_sec, half - t_sec - h_sec)
        return self.replace(
            n_layers=min(self.n_layers, 2),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_head_dim=min(self.ssm_head_dim, 32),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            slstm_heads=min(self.slstm_heads, 2),
            superblock=sb,
            n_super=min(self.n_super, 2),
            enc_len=min(self.enc_len, 16),
            n_enc_layers=min(self.n_enc_layers, 2),
            n_vision_tokens=min(self.n_vision_tokens, 4),
            mrope_sections=mrope,
            dtype_name="float32",
            gla_chunk=8,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            long_context_window=16,
            remat=False,
        )
