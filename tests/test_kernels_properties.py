"""Property-based kernel tests (randomized shapes via hypothesis).

The deterministic shape/dtype sweeps live in ``test_kernels.py`` and run
under plain pytest; this module is skipped as a whole when ``hypothesis``
is not installed in the container.
"""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.aggregate import masked_scaled_aggregate_ref  # noqa: E402
from repro.kernels.aggregate.aggregate import (  # noqa: E402
    masked_scaled_aggregate_kernel,
)
from repro.kernels.ssm_scan.ops import gla_scan  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 33), p=st.integers(1, 300),
       seed=st.integers(0, 2**30))
def test_aggregate_property(n, p, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    g = jax.random.normal(k1, (n, p))
    w = jax.random.normal(k2, (n,))
    out = masked_scaled_aggregate_kernel(g, w, block_p=64, interpret=True)
    np.testing.assert_allclose(out, masked_scaled_aggregate_ref(g, w),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 40), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2**30))
def test_gla_scan_property_chunk_invariance(s, chunk, seed):
    """Output must be independent of the chunk size (exact algorithm)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    b, h, dk, dv = 1, 2, 4, 4
    a = jax.random.uniform(ks[0], (b, s, h), minval=0.5, maxval=1.0)
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    q = jax.random.normal(ks[3], (b, s, h, dk))
    y1 = gla_scan(a, k, v, q, chunk=chunk)
    y2 = gla_scan(a, k, v, q, chunk=s)  # single chunk
    np.testing.assert_allclose(y1, y2, rtol=5e-4, atol=5e-4)
