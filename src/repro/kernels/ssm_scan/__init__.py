from repro.kernels.ssm_scan.ops import gla_scan
from repro.kernels.ssm_scan.ref import gla_scan_ref

__all__ = ["gla_scan", "gla_scan_ref"]
