"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU; Mosaic-compiled on TPU):

* ``aggregate`` — masked/scaled client-gradient aggregation (the paper's
  server update, eq. 11/12)
* ``flash_attention`` — blockwise causal/sliding-window GQA attention
* ``ssm_scan`` — chunked gated-linear-recurrence (Mamba2 SSD / mLSTM)

Each ships ``ops.py`` (jit'd wrapper) and ``ref.py`` (pure-jnp oracle).
"""
