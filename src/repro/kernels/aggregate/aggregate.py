"""Pallas TPU kernel: masked/scaled client-gradient aggregation.

The server update (paper eq. 11/12) reduces N client gradients with
weights ω_i = p_i·mask_i·scale_i:

    out[p] = Σ_n ω[n] · g[n, p]

i.e. a (1,N)×(N,P) matvec — tall-skinny, memory-bound. The TPU-native
layout: tile the parameter axis into lane-aligned blocks resident in
VMEM; the client axis (N ≤ a few thousand) rides the sublane dimension in
full so each grid step is a single MXU matvec over an (N, bp) tile. The
weight vector is tiny and replicated to every grid step.

Grid: (P // bp,). VMEM per step: N·bp·itemsize + bp·4 — with N=1024,
bp=2048, f32: 8 MB, comfortably inside VMEM; ops.py shrinks bp for larger
N. FLOPs 2·N·P, bytes ≈ N·P·itemsize ⇒ arithmetic intensity ~2/itemsize:
firmly memory-bound, so the win vs. a naive XLA reduce chain is avoiding
the (N,P)→(P,) reduction materializing intermediates in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(w_ref, g_ref, o_ref):
    # w: (1, N) f32; g: (N, bp); o: (1, bp)
    g = g_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(w_ref[...], g,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _agg_kernel_masked(w_ref, m_ref, g_ref, o_ref):
    # w, m: (1, N) f32; g: (N, bp); o: (1, bp).  The mask is a row
    # *select*, not a multiplicand: masked rows are replaced by zeros
    # before the matvec, so a padded client contributes exactly 0 even
    # when its gradient row is inf/NaN garbage (0·inf would be NaN).
    g = g_ref[...].astype(jnp.float32)
    g = jnp.where(m_ref[...].T > 0, g, 0.0)
    o_ref[...] = jnp.dot(w_ref[...], g,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _agg_update_kernel(eta_ref, w_ref, m_ref, g_ref, p_ref, o_ref):
    # eta: (1, 1) f32; w, m: (1, N) f32; g: (N, bp); p, o: (1, bp).
    # The fused server step (DESIGN.md §9): mask-select, weighted
    # reduction, and the SGD update in one tile visit — the gradient
    # block is read from HBM exactly once and no (P,)-sized aggregate
    # ever materializes outside VMEM. Accumulation is f32 (MXU
    # contract); the parameter tile is upcast, updated in f32, and cast
    # back only on the way out.
    g = g_ref[...].astype(jnp.float32)
    g = jnp.where(m_ref[...].T > 0, g, 0.0)
    acc = jnp.dot(w_ref[...], g, preferred_element_type=jnp.float32)
    o_ref[...] = (p_ref[...].astype(jnp.float32)
                  - eta_ref[0, 0] * acc).astype(o_ref.dtype)


def _agg_delta_kernel(eta_ref, w_ref, m_ref, g_ref, o_ref):
    # Same fused tile minus the parameter operand: emits the local
    # update *delta* −eta·(w @ g_sel). The client-sharded step psums
    # this (P,)-sized delta across shards and adds it to the replicated
    # parameters — SGD is linear in the gradient, so the sum of local
    # deltas equals the delta of the global reduction.
    g = g_ref[...].astype(jnp.float32)
    g = jnp.where(m_ref[...].T > 0, g, 0.0)
    acc = jnp.dot(w_ref[...], g, preferred_element_type=jnp.float32)
    o_ref[...] = (-eta_ref[0, 0] * acc).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_p", "interpret", "out_dtype"))
def masked_scaled_aggregate_kernel(g, w, mask=None, *, block_p: int = 2048,
                                   interpret: bool = False, out_dtype=None):
    """g: (N, P); w: (N,) -> (P,) = w @ g.

    P is padded to a multiple of ``block_p`` internally — one padding of
    the whole flat buffer, which is why the flat aggregation path
    (DESIGN.md §5) ravels the gradient pytree *before* calling in rather
    than launching per leaf. ``out_dtype`` overrides the output dtype
    (the in-kernel accumulation is f32 regardless), e.g. f32 server
    aggregates from bf16 client gradients. ``mask`` is an optional (N,)
    0/1 active-row operand (ragged populations, DESIGN.md §7): masked
    rows are zero-selected inside the tile before the MXU matvec, so
    they contribute exact zeros regardless of their contents; without a
    mask the two-operand program is unchanged.
    """
    n, p = g.shape
    bp = min(block_p, p)
    pad = (-p) % bp
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
    pp = p + pad
    out_shape = jax.ShapeDtypeStruct(
        (1, pp), jnp.dtype(out_dtype) if out_dtype is not None else g.dtype)
    w_op = w.reshape(1, n).astype(jnp.float32)
    vec_spec = pl.BlockSpec((1, n), lambda i: (0, 0))
    g_spec = pl.BlockSpec((n, bp), lambda i: (0, i))
    o_spec = pl.BlockSpec((1, bp), lambda i: (0, i))
    if mask is None:
        out = pl.pallas_call(
            _agg_kernel,
            grid=(pp // bp,),
            in_specs=[vec_spec, g_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(w_op, g)
    else:
        m_op = mask.reshape(1, n).astype(jnp.float32)
        out = pl.pallas_call(
            _agg_kernel_masked,
            grid=(pp // bp,),
            in_specs=[vec_spec, vec_spec, g_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(w_op, m_op, g)
    return out[0, :p]


@functools.partial(jax.jit,
                   static_argnames=("block_p", "interpret", "out_dtype"))
def masked_scaled_aggregate_update_kernel(g, w, eta, params=None, mask=None,
                                          *, block_p: int = 2048,
                                          interpret: bool = False,
                                          out_dtype=None):
    """Fused reduce-and-update: one tiled launch over the parameter axis.

    g: (N, P); w: (N,); eta: scalar learning rate.

    * ``params`` given ((P,)): returns ``params − eta·(w_sel @ g)`` —
      the whole flat SGD server step (mask-select, per-client scaling,
      client-axis reduction, parameter update) as a single Pallas
      program. Output dtype is ``params.dtype`` unless ``out_dtype``
      overrides it.
    * ``params`` None: returns the update *delta* ``−eta·(w_sel @ g)``
      — the client-sharded form, where the (P,)-sized delta psums
      across shards before the replicated parameters absorb it
      (``out_dtype`` then defaults to f32 so partials travel in the
      accumulation dtype).

    ``mask`` is the (N,) 0/1 active-row operand; masked rows are
    zero-*selected* inside the tile before the MXU matvec (exact zeros
    even for inf/NaN garbage rows). In-kernel accumulation is f32
    regardless of input dtypes; ``eta`` rides a (1, 1) operand
    replicated to every grid step.
    """
    n, p = g.shape
    bp = min(block_p, p)
    pad = (-p) % bp
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
    pp = p + pad
    if out_dtype is None:
        out_dtype = jnp.float32 if params is None else params.dtype
    out_shape = jax.ShapeDtypeStruct((1, pp), jnp.dtype(out_dtype))
    w_op = w.reshape(1, n).astype(jnp.float32)
    # mask=None runs the same program under an all-ones select — a
    # bit-exact identity on every row, unlike a ×mask multiplicand.
    m_op = (jnp.ones((1, n), jnp.float32) if mask is None
            else mask.reshape(1, n).astype(jnp.float32))
    eta_op = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    vec_spec = pl.BlockSpec((1, n), lambda i: (0, 0))
    g_spec = pl.BlockSpec((n, bp), lambda i: (0, i))
    tile_spec = pl.BlockSpec((1, bp), lambda i: (0, i))
    if params is None:
        out = pl.pallas_call(
            _agg_delta_kernel,
            grid=(pp // bp,),
            in_specs=[scalar_spec, vec_spec, vec_spec, g_spec],
            out_specs=tile_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(eta_op, w_op, m_op, g)
    else:
        p_op = params.reshape(1, p)
        if pad:
            p_op = jnp.pad(p_op, ((0, 0), (0, pad)))
        out = pl.pallas_call(
            _agg_update_kernel,
            grid=(pp // bp,),
            in_specs=[scalar_spec, vec_spec, vec_spec, g_spec, tile_spec],
            out_specs=tile_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(eta_op, w_op, m_op, g, p_op)
    return out[0, :p]
