"""Checkpoint roundtrip / retention / validation tests — including the
flat ``SimCarry`` save/restore/resume contract (DESIGN.md §5/§8): a
simulator run interrupted mid-scan and resumed from an npz checkpoint
must be bitwise the uninterrupted run."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
)


def tree():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3),
                   "b": jnp.ones((3,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    p = str(tmp_path / "ck.npz")
    t = tree()
    save_pytree(p, t)
    got = restore_pytree(p, jax.tree_util.tree_map(jnp.zeros_like, t))
    np.testing.assert_allclose(got["params"]["w"], t["params"]["w"])
    assert got["params"]["b"].dtype == np.dtype(jnp.bfloat16)
    assert int(got["step"]) == 7


def test_restore_shape_mismatch_raises(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_pytree(p, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore_pytree(p, {"w": jnp.ones((3, 2))})


def test_restore_missing_leaf_raises(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_pytree(p, {"w": jnp.ones((2,))})
    with pytest.raises(KeyError):
        restore_pytree(p, {"w": jnp.ones((2,)), "extra": jnp.ones((1,))})


def test_manager_retention_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        cm.save(s, tree())
    assert latest_step(str(tmp_path)) == 30
    files = sorted(os.listdir(str(tmp_path)))
    assert files == ["step_20.npz", "step_30.npz"]
    got, step = cm.restore(tree())
    assert step == 30
    got20, step20 = cm.restore(tree(), step=20)
    assert step20 == 20


def test_manager_empty_raises(tmp_path):
    cm = CheckpointManager(str(tmp_path / "none"))
    with pytest.raises(FileNotFoundError):
        cm.restore(tree())


# ------------------------------------------- crash consistency (DESIGN.md §10)

def _no_stray_tmps(directory):
    return [f for f in os.listdir(directory) if ".tmp." in f] == []


@pytest.mark.faults
def test_crash_mid_write_leaves_previous_intact(tmp_path, monkeypatch):
    """A writer that dies mid-``np.savez`` must leave the previous
    checkpoint byte-identical and no stray temp file — the atomic
    protocol only publishes a fully-written, fsynced npz."""
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(10, tree())
    before = open(cm.path(10), "rb").read()

    real_savez = np.savez

    def torn_savez(f, **arrays):
        real_savez(f, **{k: v for k, v in list(arrays.items())[:1]})
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez", torn_savez)
    with pytest.raises(OSError, match="disk full"):
        cm.save(20, tree())
    monkeypatch.undo()

    assert open(cm.path(10), "rb").read() == before
    assert not os.path.exists(cm.path(20))
    assert _no_stray_tmps(str(tmp_path))
    got, step = cm.restore(tree())
    assert step == 10


@pytest.mark.faults
def test_failed_replace_removes_temp(tmp_path, monkeypatch):
    """If the final ``os.replace`` itself fails, the temp file is cleaned
    up and the target path is untouched."""
    p = str(tmp_path / "ck.npz")
    save_pytree(p, tree())
    before = open(p, "rb").read()
    monkeypatch.setattr(os, "replace",
                        lambda a, b: (_ for _ in ()).throw(OSError("EXDEV")))
    with pytest.raises(OSError, match="EXDEV"):
        save_pytree(p, tree())
    monkeypatch.undo()
    assert open(p, "rb").read() == before
    assert _no_stray_tmps(str(tmp_path))


@pytest.mark.faults
def test_truncated_npz_raises_unreadable(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_pytree(p, tree())
    blob = open(p, "rb").read()
    open(p, "wb").write(blob[: len(blob) // 2])
    with pytest.raises(ValueError, match="unreadable"):
        restore_pytree(p, tree())


@pytest.mark.faults
def test_corrupt_member_names_offending_key(tmp_path):
    """Bit-rot inside one npz member fails restore with an error naming
    that leaf key, not a generic zip traceback."""
    import zipfile

    big = {"params": {"w": jnp.arange(4096.0)},
           "step": jnp.asarray(7, jnp.int32)}
    p = str(tmp_path / "ck.npz")
    save_pytree(p, big)
    with zipfile.ZipFile(p) as z:
        info = z.getinfo("params/w.npy")
    # Flip bytes well inside the stored member's data region (past the
    # 30-byte local header + name + npy header).
    offset = info.header_offset + 30 + len(info.filename) + 512
    with open(p, "r+b") as f:
        f.seek(offset)
        f.write(b"\xff" * 64)
    with pytest.raises(ValueError, match="params/w"):
        restore_pytree(p, big)


# ------------------------------------------------- flat SimCarry round-trip

def _sim_setup(optimizer):
    from repro.core import ClientSimulator, make_quadratic
    from repro.core.energy import make_arrivals
    from repro.core.scheduling import make_scheduler

    n, dim, steps = 6, 4, 30
    prob = make_quadratic(jax.random.PRNGKey(3), n_clients=n, dim=dim,
                          hetero=1.0)
    w_star = prob.w_star
    sim = ClientSimulator(
        grads_fn=lambda w, k, t: {"w": prob.all_grads(w["w"])},
        p=prob.p, optimizer=optimizer,
        loss_fn=lambda w: jnp.sum((w["w"] - w_star) ** 2))
    scheduler = make_scheduler("battery_adaptive", n)
    energy = make_arrivals("binary", n, steps + 1)
    params0 = {"w": jnp.full((dim,), 4.0)}
    return sim, scheduler, energy, params0, steps


def _cat_history(a, b):
    return jax.tree_util.tree_map(
        lambda x, y: jnp.concatenate([x, y]), a, b)


@pytest.mark.parametrize("opt_name", ["sgd", "adam"])
def test_flat_simcarry_checkpoint_resume_bitwise(tmp_path, opt_name):
    """Save the flat SimCarry mid-run, restore it from disk into a
    zeroed template, resume — history and final params bitwise equal to
    the uninterrupted scan. Covers stateless (sgd) and stateful (adam —
    flat (P,) moment buffers in the carry) optimizers, plus the
    scheduler/energy state and the PRNG key surviving the npz trip."""
    from repro import optim

    optimizer = optim.sgd(0.02) if opt_name == "sgd" else optim.adam(0.01)
    sim, scheduler, energy, params0, steps = _sim_setup(optimizer)
    key = jax.random.PRNGKey(9)
    spec = sim.flat_spec(params0)
    assert spec is not None  # uniform-dtype params → flat carry

    # Uninterrupted reference.
    ref_params, ref_hist = sim.run(key, params0, steps, scheduler=scheduler,
                                   energy=energy)

    # First leg, checkpoint, restore into a zeroed same-structure
    # template, second leg.
    cut = 12
    carry = sim.init(key, params0, scheduler=scheduler, energy=energy,
                     spec=spec)
    carry, hist1 = sim.run_carry(carry, cut, scheduler=scheduler,
                                 energy=energy, spec=spec)
    path = str(tmp_path / "carry.npz")
    save_pytree(path, carry)
    template = jax.tree_util.tree_map(jnp.zeros_like, carry)
    restored = restore_pytree(path, template)
    for a, b in zip(jax.tree_util.tree_leaves(carry),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    restored = jax.tree_util.tree_map(jnp.asarray, restored)
    carry2, hist2 = sim.run_carry(restored, steps - cut, scheduler=scheduler,
                                  energy=energy, spec=spec)

    from repro.core import aggregation
    final = aggregation.unravel_pytree(carry2.params, spec)
    np.testing.assert_array_equal(np.asarray(final["w"]),
                                  np.asarray(ref_params["w"]))
    hist = _cat_history(hist1, hist2)
    np.testing.assert_array_equal(np.asarray(hist.loss),
                                  np.asarray(ref_hist.loss))
    np.testing.assert_array_equal(np.asarray(hist.participation),
                                  np.asarray(ref_hist.participation))
    np.testing.assert_array_equal(np.asarray(hist.weight_sum),
                                  np.asarray(ref_hist.weight_sum))


def test_run_carry_matches_run_single_leg():
    """run() is init + run_carry: one uncut run_carry leg reproduces
    run() bitwise (the refactor guarantee)."""
    from repro import optim
    from repro.core import aggregation

    sim, scheduler, energy, params0, steps = _sim_setup(optim.sgd(0.02))
    key = jax.random.PRNGKey(4)
    spec = sim.flat_spec(params0)
    ref_params, ref_hist = sim.run(key, params0, steps, scheduler=scheduler,
                                   energy=energy)
    carry = sim.init(key, params0, scheduler=scheduler, energy=energy,
                     spec=spec)
    carry, hist = sim.run_carry(carry, steps, scheduler=scheduler,
                                energy=energy, spec=spec)
    final = aggregation.unravel_pytree(carry.params, spec)
    np.testing.assert_array_equal(np.asarray(final["w"]),
                                  np.asarray(ref_params["w"]))
    np.testing.assert_array_equal(np.asarray(hist.loss),
                                  np.asarray(ref_hist.loss))
