"""SPMD energy-weighted train step: the per-example-coefficient path must
realize the paper's eq. (11/12) exactly."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import per_example_coefficients
from repro.core.trainer import build_energy_train_step
from repro.optim import sgd


def quadratic_loss(params, batch):
    # per-example loss ||w - x_j||^2 — gradient is linear, so the paper's
    # client aggregation has a closed form to compare against.
    diff = params["w"][None, :] - batch["x"]
    return jnp.sum(diff * diff, axis=-1)


def make(n_clients=4, per_client=3, dim=5, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n_clients * per_client, dim)).astype(np.float32)
    batch = {
        "x": jnp.asarray(x),
        "client_ids": jnp.repeat(jnp.arange(n_clients), per_client),
    }
    params = {"w": jnp.zeros((dim,))}
    return params, batch, x


def test_masked_scaled_update_matches_paper_formula():
    n, b, dim = 4, 3, 5
    params, batch, x = make(n, b, dim)
    lr = 0.1
    init_state, step = build_energy_train_step(
        per_example_loss_fn=quadratic_loss, optimizer=sgd(lr), n_clients=n)
    state = init_state(params)
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    scale = jnp.asarray([2.0, 2.0, 4.0, 4.0])
    state2, metrics = jax.jit(step)(state, batch, mask, scale)

    # paper: w' = w − η Σ_i p_i·mask_i·scale_i·g_i,  g_i = mean_j ∇l_ij
    p = np.full(n, 1.0 / n)
    g = np.zeros(dim)
    for i in range(n):
        gi = np.mean(2 * (0.0 - x[i * b:(i + 1) * b]), axis=0)
        g += p[i] * float(mask[i] * scale[i]) * gi
    expected = -lr * g
    np.testing.assert_allclose(np.asarray(state2.params["w"]), expected,
                               rtol=1e-5, atol=1e-6)


def test_masked_client_contributes_nothing():
    n, b, dim = 4, 3, 5
    params, batch, x = make(n, b, dim)
    init_state, step = build_energy_train_step(
        per_example_loss_fn=quadratic_loss, optimizer=sgd(0.1), n_clients=n)
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    scale = jnp.ones((n,))
    s1, _ = jax.jit(step)(init_state(params), batch, mask, scale)
    # perturb ONLY client 1's data — update must not change
    x2 = x.copy()
    x2[3:6] += 100.0
    batch2 = dict(batch, x=jnp.asarray(x2))
    s2, _ = jax.jit(step)(init_state(params), batch2, mask, scale)
    np.testing.assert_allclose(s1.params["w"], s2.params["w"], atol=1e-6)


def test_full_participation_equals_plain_sgd():
    n, b, dim = 4, 3, 5
    params, batch, x = make(n, b, dim)
    init_state, step = build_energy_train_step(
        per_example_loss_fn=quadratic_loss, optimizer=sgd(0.1), n_clients=n)
    ones = jnp.ones((n,))
    s1, _ = jax.jit(step)(init_state(params), batch, ones, ones)
    # plain SGD on mean loss over the batch
    grad = jax.grad(lambda p: jnp.mean(quadratic_loss(p, batch)))(params)
    expected = params["w"] - 0.1 * grad["w"]
    np.testing.assert_allclose(s1.params["w"], expected, rtol=1e-5)


def test_flat_loss_path_matches_per_leaf():
    """build_energy_train_step(flat=True) — gradient raveled to one (P,)
    buffer, flat optimizer state — is bitwise the per-leaf update for
    elementwise optimizers (the ravel is a pure relayout)."""
    from repro.optim import adam

    n, b, dim = 4, 3, 5
    params, batch, x = make(n, b, dim)
    params = {"w": params["w"], "v": jnp.ones((dim,))}

    def loss2(p, bt):
        diff = p["w"][None, :] - bt["x"] * p["v"][None, :]
        return jnp.sum(diff * diff, axis=-1)

    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    scale = jnp.asarray([2.0, 1.0, 1.0, 3.0])
    outs = {}
    for flat in (False, True):
        init_state, step = build_energy_train_step(
            per_example_loss_fn=loss2, optimizer=adam(0.05), n_clients=n,
            flat=flat)
        state = init_state(params)
        for _ in range(3):
            state, metrics = jax.jit(step)(state, batch, mask, scale)
        outs[flat] = (state, metrics)
    for leaf in ("w", "v"):
        np.testing.assert_array_equal(
            np.asarray(outs[False][0].params[leaf]),
            np.asarray(outs[True][0].params[leaf]))
    np.testing.assert_array_equal(
        np.asarray(outs[False][1]["weighted_loss"]),
        np.asarray(outs[True][1]["weighted_loss"]))
    # flat=True carries its optimizer moments as single (P,) buffers
    flat_state = outs[True][0]
    assert flat_state.opt_state.mu.shape == (2 * dim,)


def test_per_example_coefficients():
    w = jnp.asarray([0.4, 0.0, 0.6])
    ids = jnp.asarray([0, 0, 1, 1, 2, 2])
    c = per_example_coefficients(ids, w, 2)
    np.testing.assert_allclose(c, [0.2, 0.2, 0.0, 0.0, 0.3, 0.3])
