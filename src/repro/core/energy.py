"""Energy-arrival processes (paper §II-B).

Each process models ``E_i^t`` — whether client ``i`` harvests a unit of
energy at step ``t`` — for ``n_clients`` clients, vectorized and
scan-friendly so the whole training loop can live under ``jax.jit`` /
``jax.lax.scan``.

Protocol (duck-typed; all methods pure):

    init(key)              -> state                     (pytree)
    arrivals(state, t, key)-> (state, Arrivals)

``Arrivals`` carries:
    energy : (N,) float32 in {0,1}   -- E_i^t
    gap    : (N,) float32            -- T_i^t for deterministic arrivals
                                        (gap between the arrival at/most
                                        recently before t and the next one);
                                        for stochastic processes, the
                                        *nominal* scaling constant γ_i
                                        (1/β_i binary, T_i uniform).

Three concrete processes, mirroring the paper exactly:

* ``DeterministicArrivals`` — arrival times known in advance (paper
  §II-B-1). Built from an explicit (N, horizon) 0/1 schedule or from
  per-client periods via :meth:`DeterministicArrivals.periodic`.
* ``BinaryArrivals`` — E_i^t ~ Bern(β_i) iid per step (paper eq. 9).
* ``UniformArrivals`` — exactly one arrival per window of length T_i,
  uniformly placed within the window (paper §II-B-2, "Uniform Arrivals").
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Arrivals(NamedTuple):
    """Per-step arrival information for all clients."""

    energy: jax.Array  # (N,) float32 in {0, 1}
    gap: jax.Array     # (N,) float32 — T_i^t (det.) or γ_i (stochastic)


class DeterministicArrivals:
    """Deterministic energy arrivals known in advance (paper §II-B-1).

    Parameters
    ----------
    schedule : (N, horizon) 0/1 array of arrival indicators. Arrival times
        for client i are ``I_i = {t : schedule[i, t] == 1}``.

    Precomputes, on the host (the schedule is known in advance by
    assumption), the gap table ``T[i, t] = Ī_i^t − I_i^t`` used by
    Algorithm 1. At an arrival time ``t`` this is the distance to the next
    arrival; the final interval is truncated at the horizon so the run
    stays self-contained (and the scheme stays unbiased within the run).
    Steps before a client's first arrival have gap 0 (the client cannot
    participate yet).
    """

    def __init__(self, schedule):
        schedule = np.asarray(schedule)
        if schedule.ndim != 2:
            raise ValueError(f"schedule must be (N, horizon), got {schedule.shape}")
        self.n_clients, self.horizon = schedule.shape
        self._np_schedule = (schedule != 0).astype(np.float32)

        gaps = np.zeros_like(self._np_schedule)
        for i in range(self.n_clients):
            ts = np.flatnonzero(self._np_schedule[i])
            for k, t0 in enumerate(ts):
                t1 = ts[k + 1] if k + 1 < len(ts) else self.horizon
                gaps[i, t0:t1] = t1 - t0  # T_i^t constant over [I, Ī)
        self.schedule = jnp.asarray(self._np_schedule)
        self.gaps = jnp.asarray(gaps)

    @classmethod
    def periodic(cls, taus, horizon: int, offsets=None) -> "DeterministicArrivals":
        """Paper's experimental profile (eq. 37): arrivals at ``t ≡ off (mod τ_i)``."""
        taus = np.asarray(taus, dtype=np.int64)
        if offsets is None:
            offsets = np.zeros_like(taus)
        offsets = np.asarray(offsets, dtype=np.int64)
        t = np.arange(horizon)[None, :]
        sched = ((t - offsets[:, None]) % taus[:, None] == 0) & (t >= offsets[:, None])
        return cls(sched.astype(np.float32))

    def init(self, key):
        del key
        return ()

    def arrivals(self, state, t, key):
        del key
        t = jnp.asarray(t, jnp.int32)
        # Past the precomputed horizon there are no further arrivals.
        tc = jnp.clip(t, 0, self.horizon - 1)
        valid = (t < self.horizon).astype(jnp.float32)
        energy = self.schedule[:, tc] * valid
        gap = self.gaps[:, tc] * valid
        return state, Arrivals(energy=energy, gap=gap)


class BinaryArrivals:
    """E_i^t ~ Bern(β_i), iid across steps and clients (paper eq. 9)."""

    def __init__(self, betas):
        self.betas = jnp.asarray(betas, jnp.float32)
        self.n_clients = self.betas.shape[0]

    def init(self, key):
        del key
        return ()

    def arrivals(self, state, t, key):
        del t
        u = jax.random.uniform(key, (self.n_clients,))
        energy = (u < self.betas).astype(jnp.float32)
        gap = 1.0 / self.betas  # γ_i = 1/β_i (Alg. 2 / Corollary 1)
        return state, Arrivals(energy=energy, gap=gap)


class UniformArrivalsState(NamedTuple):
    offset: jax.Array  # (N,) int32 — arrival position inside current window


class UniformArrivals:
    """One arrival per window of length T_i, uniformly placed (paper §II-B-2).

    For every t with ``t mod T_i == 0`` a fresh offset ``U{0,…,T_i−1}`` is
    drawn; the client receives energy when ``t mod T_i == offset``. Windows
    are per-client, so clients with different ``T_i`` roll over at
    different times.
    """

    def __init__(self, periods):
        self.periods = jnp.asarray(periods, jnp.int32)
        self.n_clients = self.periods.shape[0]

    def init(self, key):
        # Offsets for the first window (the t=0 step rolls them anyway if
        # t mod T == 0, which it is; keep a valid placeholder).
        offset = jax.random.randint(key, (self.n_clients,), 0, jnp.asarray(2**30)) % self.periods
        return UniformArrivalsState(offset=offset.astype(jnp.int32))

    def arrivals(self, state, t, key):
        t = jnp.asarray(t, jnp.int32)
        pos = t % self.periods
        fresh = jax.random.randint(key, (self.n_clients,), 0, jnp.asarray(2**30)) % self.periods
        offset = jnp.where(pos == 0, fresh.astype(jnp.int32), state.offset)
        energy = (pos == offset).astype(jnp.float32)
        gap = self.periods.astype(jnp.float32)  # γ_i = T_i (Corollary 1)
        return UniformArrivalsState(offset=offset), Arrivals(energy=energy, gap=gap)


def expected_participation(process) -> jax.Array:
    """Long-run participation probability per client under best-effort.

    Used by tests and by the theory module (Corollary 1 constants).
    """
    if isinstance(process, BinaryArrivals):
        return process.betas
    if isinstance(process, UniformArrivals):
        return 1.0 / process.periods.astype(jnp.float32)
    if isinstance(process, DeterministicArrivals):
        return jnp.mean(process.schedule, axis=1)
    raise TypeError(f"unknown process {type(process)!r}")
