"""Production mesh definitions.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count locks on first jax init).

  single pod : (16, 16)    axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips
  cell mesh  : (D,)        axis ("cells",)   — scenario-grid sharding
  client mesh: (D,)        axis ("clients",) — within-cell client sharding
  grid mesh  : (Dc, Dn)    axes ("cells", "clients") — both, composed

The dry-run launcher sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import so 512 placeholder CPU devices exist.
"""

from __future__ import annotations

import jax

# Grid sharding wants a flat 1-D mesh over the (scenario × seed) cell
# axis regardless of how training meshes are shaped; within-cell client
# sharding (DESIGN.md §8) adds the composable "clients" axis. The
# factories live with the placement layer (DESIGN.md §5) and are
# re-exported here so drivers import every mesh from one module.
from repro.experiments.placement import (  # noqa: F401
    CELL_AXIS,
    CLIENT_AXIS,
    make_cell_mesh,
    make_client_mesh,
    make_grid_mesh,
)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke paths (tests, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
