"""Pytree checkpointing on npz — no external deps, structure-checked.

Leaves are flattened with ``jax.tree_util.tree_flatten_with_path`` so the
npz carries stable, human-readable keys; restore verifies the target
structure matches and re-dtypes leaves to the template.

``CheckpointManager`` adds step-indexed directories, atomic writes and
retention. Writes are **crash-consistent** (DESIGN.md §10): the npz is
written to a same-directory temp file, fsynced, renamed over the target
with ``os.replace`` (atomic on POSIX), and the directory entry is
fsynced — so at every instant the target path either holds the complete
previous checkpoint or the complete new one, never a torn write. A
checkpoint that *does* end up unreadable (torn by a pre-fix writer,
bit-rot, truncated copy) fails restore with an error naming the file —
and, when one npz member is bad, the offending leaf key.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import zipfile
import zlib
from typing import Any

import jax
import numpy as np

#: Exceptions that mean "this npz is not a readable checkpoint" —
#: truncation (BadZipFile/EOFError), torn members (zlib.error), OS-level
#: read failures, and numpy's own format complaints (ValueError).
_CORRUPT_ERRORS = (OSError, EOFError, ValueError, zipfile.BadZipFile,
                   zlib.error)


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _fsync_dir(directory: str) -> None:
    """fsync a directory entry so a just-renamed file survives power loss."""
    fd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _replace_atomic(tmp: str, path: str, directory: str) -> None:
    """``os.replace`` + directory fsync, removing ``tmp`` on any failure."""
    try:
        os.replace(tmp, path)
        _fsync_dir(directory)
    finally:
        # os.replace consumed tmp on success; on failure (target is a
        # directory, cross-device link, ...) remove it so an aborted save
        # leaves no stray temp file next to the intact previous file.
        try:
            os.remove(tmp)
        except FileNotFoundError:
            pass


def save_pytree(path: str, tree: Any) -> None:
    """Atomically write ``tree`` to ``path`` as a flat npz.

    Durable write protocol: temp file in the destination directory →
    ``np.savez`` into the open descriptor → ``fsync`` the data →
    ``os.replace`` over the target → ``fsync`` the directory. A crash at
    any point leaves the previous ``path`` contents intact.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    for p, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype.name not in ("float64", "float32", "float16", "int64",
                                  "int32", "int16", "int8", "uint64",
                                  "uint32", "uint16", "uint8", "bool"):
            # bfloat16 / fp8 etc. don't survive npz — store as float32;
            # restore re-casts to the template dtype.
            arr = arr.astype(np.float32)
        arrays[_key_str(p)] = arr
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.npz")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
    except BaseException:
        os.remove(tmp)
        raise
    _replace_atomic(tmp, path, directory)


def write_json_atomic(path: str, obj: Any) -> None:
    """Atomic, durable JSON write — same protocol as :func:`save_pytree`.

    Backs the resumable-Study manifest (DESIGN.md §10): readers see
    either the previous manifest or the new one, never a torn file.
    """
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, indent=2, sort_keys=True)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
    except BaseException:
        os.remove(tmp)
        raise
    _replace_atomic(tmp, path, directory)


def _leaf_spec(leaf) -> tuple[tuple, np.dtype]:
    """(shape, dtype) of a template leaf — concrete arrays, scalars, and
    abstract ``jax.ShapeDtypeStruct``-likes all work, so templates can be
    built with ``jax.eval_shape`` without materializing buffers."""
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
        return tuple(leaf.shape), np.dtype(leaf.dtype)
    arr = np.asarray(leaf)
    return tuple(arr.shape), arr.dtype


def restore_pytree(path: str, template: Any) -> Any:
    """Load ``path`` into the structure (and dtypes) of ``template``.

    Raises ``ValueError`` naming the file when the npz is unreadable
    (truncated/corrupt), and naming the offending leaf key when one
    member is torn or its shape disagrees with the template; ``KeyError``
    when the checkpoint is missing a template leaf.
    """
    try:
        data = np.load(path)
    except _CORRUPT_ERRORS as e:
        raise ValueError(
            f"checkpoint {path} is unreadable (truncated or corrupt "
            f"npz): {e}") from e
    with data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat:
            key = _key_str(p)
            if key not in data:
                raise KeyError(f"checkpoint {path} missing leaf {key!r}")
            try:
                arr = data[key]
            except _CORRUPT_ERRORS as e:
                raise ValueError(
                    f"checkpoint {path}: leaf {key!r} is corrupt "
                    f"(truncated member?): {e}") from e
            shape, dtype = _leaf_spec(leaf)
            if tuple(arr.shape) != shape:
                raise ValueError(
                    f"checkpoint {path}: shape mismatch for {key!r}: "
                    f"ckpt {tuple(arr.shape)} vs template {shape}")
            leaves.append(arr.astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


_STEP_RE = re.compile(r"^step_(\d+)\.npz$")


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := _STEP_RE.match(f))]
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step}.npz")

    def save(self, step: int, tree: Any) -> str:
        p = self.path(step)
        save_pytree(p, tree)
        self._retain()
        return p

    def restore(self, template: Any, step: int | None = None) -> tuple[Any, int]:
        if step is None:
            step = latest_step(self.directory)
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return restore_pytree(self.path(step), template), step

    def _retain(self) -> None:
        steps = sorted(
            int(m.group(1)) for f in os.listdir(self.directory)
            if (m := _STEP_RE.match(f)))
        for s in steps[:-self.keep] if self.keep else []:
            os.remove(self.path(s))

    def delete(self) -> None:
        shutil.rmtree(self.directory, ignore_errors=True)
