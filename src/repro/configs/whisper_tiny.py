"""whisper-tiny — encoder-decoder speech model (transformer backbone only).

[arXiv:2212.04356] 4L enc + 4L dec, d_model=384, 6 heads (MHA, kv=6),
d_ff=1536, vocab=51865. The mel-spectrogram + conv frontend is a STUB per
the assignment: ``input_specs`` provides precomputed frame embeddings
(B, 1500, 384). long_500k is skipped (DESIGN.md §4 — a 524k-token decoder
against a 1500-frame encoder is architecturally meaningless).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    arch_type="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51865,
    enc_dec=True,
    n_enc_layers=4,
    enc_len=1500,
    pos_embed="sinusoidal",
    superblock=(("xattn", 4, False),),
    norm="layernorm",
    act="gelu",
    use_bias=True,
    gated_mlp=False,
    dtype_name="bfloat16",
    remat=True,
    citation="[arXiv:2212.04356]",
)
