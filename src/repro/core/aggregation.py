"""Server-side aggregation (paper eq. 11 / 12) — flat single-pass hot path.

The update the paper's server performs is

    w ← w − η · Σ_{i∈S_t} p_i · scale_i^t · g_i(w, ξ_i)

which we express as a *weighted sum over the client axis* with weights
``ω_i = p_i · mask_i · scale_i``. Execution paths, all algebraically
identical:

1. ``aggregate_client_grads`` — per-leaf weighted sum over the leading
   client axis, pure jnp. The *reference* path: no raveling, preserves
   every leaf dtype independently. Property tests compare everything
   else against it.
2. ``aggregate_client_grads_flat`` / ``aggregate_client_grads_kernel``
   — the hot path (DESIGN.md §5): the whole gradient pytree is raveled
   into **one** ``(N, P)`` buffer (a cached :class:`RavelSpec` records
   treedef/shapes/offsets), reduced by **one** tiled Pallas kernel or
   jnp matvec per step — instead of one kernel launch (each with its
   own lane padding) per parameter leaf — and unraveled by offset
   slicing. Mixed-dtype pytrees fall back to the per-leaf path.
3. ``per_example_coefficients`` — the *SPMD path* for framework-scale
   training: instead of materializing N per-client gradients, each example
   in the global batch carries the coefficient of its owning client, and
   the ordinary gradient of the weighted loss equals the paper's update.
   This is what the pjit train step uses; it adds **zero** collective
   traffic over plain data-parallel SGD.

The raveler is shared infrastructure: :class:`repro.core.trainer.
ClientSimulator` keeps its whole scan carry (params + optimizer state)
in the flat space, so the per-step loop never round-trips the pytree
leaf-by-leaf. The ravel boundary itself sits at the gradient source —
:func:`make_flat_grads_fn` wraps any ``grads_fn`` into a flat ``(N, P)``
emitter (and shards it along a client mesh axis, DESIGN.md §8);
:func:`reduce_flat_client_sharded` is the cross-shard reduction with the
server update left replicated.
"""

from __future__ import annotations

import inspect
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.scheduling import Decision


def client_weights(p: jax.Array, decision: Decision) -> jax.Array:
    """ω_i = p_i · mask_i · scale_i — the per-client aggregation weight."""
    return p * decision.mask * decision.scale


# ----------------------------------------------------- reduction grammar

_REDUCTION_MODES = ("gather", "psum", "fused")


def parse_reduction(reduction: str) -> tuple[str, Any]:
    """Parse a cross-shard reduction string → ``(mode, wire_dtype)``.

    Grammar (DESIGN.md §9): ``gather | psum | psum_bf16 | fused |
    fused_bf16``. The optional ``_bf16`` suffix quantizes the ``(P,)``
    partial sums *on the wire only* — each shard's partial is cast to
    bf16, gathered, and accumulated locally in f32 (quantize-then-
    exact-accumulate; a plain psum of bf16 operands would accumulate in
    bf16 and compound rounding with shard count). ``gather`` admits no
    wire dtype: it is the bit-for-bit differential oracle, and rounding
    the wire would contradict that contract.
    """
    mode, _, wire = reduction.partition("_")
    if mode not in _REDUCTION_MODES or wire not in ("", "bf16"):
        raise ValueError(
            f"reduction must be one of gather, psum[_bf16], fused[_bf16]; "
            f"got {reduction!r}")
    if wire and mode == "gather":
        raise ValueError(
            "gather is the bitwise oracle and takes no wire dtype; "
            f"got {reduction!r}")
    return mode, (jnp.bfloat16 if wire else None)


def _mask_rows(leaf: jax.Array, mask: jax.Array | None) -> jax.Array:
    """Zero the masked-out client rows of an (N, ...) buffer.

    A ``where`` select, not a multiply: padded rows contribute *exact*
    zeros to every reduction even when a grads_fn emits garbage
    (inf/NaN) for clients that don't exist (DESIGN.md §7). Identity on
    active rows, so the masked reduction stays bit-identical to the
    unpadded one.
    """
    if mask is None:
        return leaf
    m = mask.reshape((-1,) + (1,) * (leaf.ndim - 1))
    return jnp.where(m > 0, leaf, jnp.zeros((), leaf.dtype))


def compose_masks(*masks):
    """Product of (N,) 0/1 row masks, None-transparent.

    ``None`` means "no constraint" and drops out; all-None composes to
    None (the unmasked fast path). With 0/1 operands the product is
    exact — a row survives iff every mask keeps it — so composing the
    ragged ``active_mask`` with a fault-delivery ``keep`` mask preserves
    the DESIGN.md §7 guarantee: a row dropped by *either* contributes an
    exact zero through :func:`_mask_rows` / the masked Pallas kernels.
    """
    out = None
    for m in masks:
        if m is None:
            continue
        out = m if out is None else out * m
    return out


# --------------------------------------------------------------- raveler

class RavelSpec(NamedTuple):
    """Static flat-space layout of a pytree: where each leaf lives in P.

    ``shapes`` exclude any leading batch axes (``lead_axes`` at build
    time), so one spec describes both the stacked ``(N, P)`` gradient
    buffer and the unbatched ``(P,)`` parameter vector of the same tree.
    """

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    offsets: tuple[int, ...]
    sizes: tuple[int, ...]
    dtype: Any
    total: int


_SPEC_CACHE: dict = {}


def ravel_spec(tree, *, lead_axes: int = 0) -> RavelSpec:
    """Cached flat-space spec for ``tree``.

    ``lead_axes`` axes are stripped from every leaf shape (1 for
    client-stacked gradients). Raises ``ValueError`` on mixed leaf
    dtypes — the flat buffer is a single concatenation, so callers fall
    back to the per-leaf path (see :func:`aggregate_client_grads_flat`).
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot ravel an empty pytree")
    shapes = tuple(tuple(l.shape[lead_axes:]) for l in leaves)
    dtypes = {jnp.dtype(l.dtype) for l in leaves}
    if len(dtypes) != 1:
        raise ValueError(
            f"flat path needs a single leaf dtype, got {sorted(map(str, dtypes))}")
    dtype = dtypes.pop()
    key = (treedef, shapes, dtype)
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        sizes = tuple(math.prod(s) for s in shapes)
        offsets, off = [], 0
        for sz in sizes:
            offsets.append(off)
            off += sz
        spec = RavelSpec(treedef=treedef, shapes=shapes, offsets=tuple(offsets),
                         sizes=sizes, dtype=dtype, total=off)
        _SPEC_CACHE[key] = spec
    return spec


def ravel_pytree(tree, spec: RavelSpec | None = None) -> jax.Array:
    """Concatenate every leaf of ``tree`` into one ``(P,)`` vector."""
    if spec is None:
        spec = ravel_spec(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    if len(leaves) == 1:
        return leaves[0].reshape(-1)
    return jnp.concatenate([l.reshape(-1) for l in leaves])


def ravel_stacked(tree, spec: RavelSpec | None = None) -> jax.Array:
    """Client-stacked pytree (leaves ``(N, ...)``) → one ``(N, P)`` buffer."""
    if spec is None:
        spec = ravel_spec(tree, lead_axes=1)
    leaves = jax.tree_util.tree_leaves(tree)
    n = leaves[0].shape[0]
    if len(leaves) == 1:
        return leaves[0].reshape(n, -1)
    return jnp.concatenate([l.reshape(n, -1) for l in leaves], axis=1)


def unravel_pytree(vec: jax.Array, spec: RavelSpec):
    """``(..., P)`` flat vector → pytree with leaves ``(..., *shape)``."""
    lead = vec.shape[:-1]
    parts = [
        vec[..., o:o + sz].reshape(lead + shp)
        for o, sz, shp in zip(spec.offsets, spec.sizes, spec.shapes)
    ]
    return jax.tree_util.tree_unflatten(spec.treedef, parts)


# ------------------------------------------------ flat grads_fn boundary

def accepts_clients_kwarg(grads_fn) -> bool:
    """True if ``grads_fn`` takes a ``clients`` keyword — the client-axis
    sharding protocol (DESIGN.md §8): a client-aware grads_fn is called
    with ``clients=(n_local,) int32`` global client indices and computes
    only those rows, so per-client gradient work shards across devices.
    A plain ``(params, key, t)`` grads_fn still works sharded — each
    device computes the full stack and slices its rows (correct and
    bitwise-identical, but the gradient compute is replicated). Only an
    explicitly *named* ``clients`` parameter opts in — a bare
    ``**kwargs`` does not, since a kwargs-tolerant grads_fn that ignores
    ``clients`` would silently return full-population rows."""
    try:
        sig = inspect.signature(grads_fn)
    except (TypeError, ValueError):
        return False
    return "clients" in sig.parameters


def make_flat_grads_fn(grads_fn, spec: RavelSpec, n_clients: int):
    """RavelSpec-aware wrapper: ``grads_fn`` → flat ``(N, P)`` emitter.

    The ravel boundary lives *here*, at the gradient source, so the scan
    body carries no per-leaf concat: the wrapped function returns the
    flat client-stacked buffer directly, whether ``grads_fn`` emits

    * a client-stacked pytree mirroring the parameter tree (raveled via
      a cached spec; uniform-dtype gradient trees that differ from the
      params dtype stay in their own dtype, mixed-dtype trees are cast
      to the params dtype — accumulation in the reduce is f32-or-better
      either way), or
    * a single ``(N, ...)`` array — already flat up to a reshape (the
      natively-flat fast path; single-leaf parameter trees land here).

    Under an active client-sharding context (DESIGN.md §8) the wrapper
    returns this shard's ``(n_local, P)`` rows: a client-aware grads_fn
    (:func:`accepts_clients_kwarg`) is called with the shard's global
    client indices; a plain grads_fn is called in full — row-sliced
    (bitwise the rows of the unsharded call) under ``psum``, but handed
    over *whole* under ``gather``, where every shard already holds the
    identical replicated buffer and slicing it apart only for
    ``all_gather`` to reassemble it would be a pure N·P round trip
    (:func:`reduce_flat_client_sharded` skips the gradient gather on
    full-width input).
    """
    from repro.core.energy import client_shard

    accepts = accepts_clients_kwarg(grads_fn)

    def flatten(stacked, n_rows):
        if isinstance(stacked, jax.Array):
            g = stacked.reshape(n_rows, -1)
            if g.shape[1] != spec.total:
                raise ValueError(
                    f"flat grads_fn output has {g.shape[1]} parameters per "
                    f"client; the parameter pytree has {spec.total}")
            return g
        try:
            gspec = ravel_spec(stacked, lead_axes=1)
        except ValueError:
            # Mixed-dtype gradients (e.g. one layer computed in bf16)
            # against uniform-dtype params: aggregate in the params
            # dtype — accumulation inside reduce_flat is f32-or-better
            # either way.
            stacked = jax.tree_util.tree_map(
                lambda x: x.astype(spec.dtype), stacked)
            gspec = ravel_spec(stacked, lead_axes=1)
        if gspec.shapes != spec.shapes or gspec.treedef != spec.treedef:
            raise ValueError(
                "grads_fn output does not mirror the parameter pytree; "
                "flat-carry execution needs matching structure+shapes "
                f"(params {spec.shapes}, grads {gspec.shapes})")
        return ravel_stacked(stacked, gspec)

    def flat_grads(params, key, t):
        shard = client_shard()
        if shard is None:
            return flatten(grads_fn(params, key, t), n_clients)
        n_local = n_clients // shard.shards
        if accepts:
            idx = (jax.lax.axis_index(shard.axis_name) * n_local
                   + jnp.arange(n_local, dtype=jnp.int32))
            return flatten(grads_fn(params, key, t, clients=idx), n_local)
        full = flatten(grads_fn(params, key, t), n_clients)
        if parse_reduction(shard.reduction)[0] == "gather":
            return full
        off = jax.lax.axis_index(shard.axis_name) * n_local
        return jax.lax.dynamic_slice_in_dim(full, off, n_local, axis=0)

    return flat_grads


# ----------------------------------------------------- aggregation paths

def aggregate_client_grads(stacked_grads, weights: jax.Array,
                           mask: jax.Array | None = None):
    """Per-leaf weighted sum over the leading (client) axis — the
    reference path (one reduction per leaf, leaf dtypes preserved).

    stacked_grads: pytree whose leaves have shape (N, ...).
    weights: (N,) float32 — ω_i.
    mask: optional (N,) 0/1 active-client mask; masked rows are
        ``where``-selected to exact zero before the reduction.
    """

    def _one(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(w * _mask_rows(leaf, mask), axis=0)

    return jax.tree_util.tree_map(_one, stacked_grads)


def reduce_flat(g: jax.Array, weights: jax.Array, *,
                use_kernel: bool = False, out_dtype=None,
                mask: jax.Array | None = None) -> jax.Array:
    """``(N, P)`` flat gradient buffer → ``(P,)`` = ω @ g, in one pass.

    Accumulation is at least f32 (low-precision inputs are upcast; f64
    under ``jax_enable_x64`` stays f64). ``out_dtype`` overrides the
    result dtype — e.g. bf16 client gradients aggregated into an f32
    server update without a round-trip through bf16. ``mask`` is the
    (N,) active-client mask of a ragged population: masked rows are
    excluded from the reduction *exactly* (a row select, not a ×0 — the
    kernel takes the mask as an operand on the tiled reduction). The
    Pallas path is one tiled kernel launch over the whole parameter
    space (imported lazily so the pure-jnp path has no kernel
    dependency); in-kernel accumulation is f32 — the MXU contract.
    """
    od = jnp.dtype(out_dtype) if out_dtype is not None else g.dtype
    if use_kernel:
        from repro.kernels.aggregate import ops as agg_ops

        return agg_ops.masked_scaled_aggregate(
            g, weights.astype(jnp.float32), out_dtype=od, mask=mask)
    acc = jnp.promote_types(g.dtype, jnp.float32)
    out = weights.astype(acc) @ _mask_rows(g, mask).astype(acc)
    return out.astype(od)


def _cross_shard_sum(partial: jax.Array, axis_name: str,
                     wire_dtype=None) -> jax.Array:
    """Sum ``(P,)`` partials across ``axis_name`` shards.

    ``wire_dtype=None`` is a plain psum. With a wire dtype (bf16), each
    shard's partial is *quantized once* for the collective, then the
    gathered partials are accumulated locally in f32-or-better — so the
    rounding error is one cast per shard, independent of shard count. A
    psum of bf16 operands would instead accumulate in bf16, compounding
    rounding with every add in the reduction tree.
    """
    if wire_dtype is None:
        return jax.lax.psum(partial, axis_name)
    acc = jnp.promote_types(partial.dtype, jnp.float32)
    wired = jax.lax.all_gather(partial.astype(wire_dtype), axis_name, axis=0)
    return jnp.sum(wired.astype(acc), axis=0)


def reduce_flat_client_sharded(g: jax.Array, weights: jax.Array, *,
                               axis_name: str, reduction: str = "gather",
                               use_kernel: bool = False, out_dtype=None,
                               mask: jax.Array | None = None,
                               wire_dtype=None
                               ) -> tuple[jax.Array, jax.Array]:
    """Client-sharded flat reduction: local ``(n_local, P)`` shard →
    replicated ``((P,), weight_sum)`` across the ``axis_name`` devices.

    Two reductions, both leaving the server update replicated
    (DESIGN.md §8):

    * ``"gather"`` — all_gather the gradient rows (tiled, so the global
      ``(N, P)`` buffer is reassembled in exact row order) and replicate
      the *identical* unsharded reduction on every device. Bit-for-bit
      the single-device result; costs N_local·P per device per step of
      interconnect. A ``g`` already at full population width (a plain
      grads_fn computes it replicated on every shard —
      :func:`make_flat_grads_fn`) skips the gradient gather; only the
      (N,)-sized weights/mask cross the axis.
    * ``"psum"`` — one local matvec/kernel launch over this shard's rows
      followed by a ``(P,)`` cross-shard sum. Bandwidth-optimal (the
      collective moves P floats, not N·P) but reassociates the client
      sum across shards — float32-tolerance, not bitwise. Partial sums
      travel in the f32-or-better accumulation dtype and are cast to
      ``out_dtype`` only after the collective. ``"psum_bf16"`` (or an
      explicit ``wire_dtype``) additionally quantizes the partials to
      bf16 *on the wire only* — local accumulation stays f32 on both
      sides of the collective (:func:`_cross_shard_sum`), halving
      collective bytes for one rounding per shard.

    ``"fused"`` is rejected here: the fused reduce-and-update owns the
    parameter step as well and lives in :func:`fused_flat_sgd_update`.
    """
    mode, parsed_wire = parse_reduction(reduction)
    if wire_dtype is None:
        wire_dtype = parsed_wire
    if mode == "fused":
        raise ValueError(
            "reduction 'fused' bundles the parameter update; use "
            "fused_flat_sgd_update (trainer routes it automatically)")
    if mode == "gather":
        if wire_dtype is not None:
            raise ValueError("gather is bitwise; wire_dtype is not allowed")
        weights = jax.lax.all_gather(weights, axis_name, axis=0, tiled=True)
        if mask is not None:
            mask = jax.lax.all_gather(mask, axis_name, axis=0, tiled=True)
        if g.shape[0] != weights.shape[0]:
            g = jax.lax.all_gather(g, axis_name, axis=0, tiled=True)
        out = reduce_flat(g, weights, use_kernel=use_kernel,
                          out_dtype=out_dtype, mask=mask)
        return out, jnp.sum(weights)
    od = jnp.dtype(out_dtype) if out_dtype is not None else g.dtype
    acc = jnp.promote_types(g.dtype, jnp.float32)
    partial = reduce_flat(g, weights, use_kernel=use_kernel,
                          out_dtype=acc, mask=mask)
    out = _cross_shard_sum(partial, axis_name, wire_dtype).astype(od)
    return out, jax.lax.psum(jnp.sum(weights), axis_name)


def fused_flat_sgd_update(g: jax.Array, weights: jax.Array,
                          params: jax.Array, opt_state, optimizer, *,
                          mask: jax.Array | None = None,
                          use_kernel: bool = False, shard=None,
                          wire_dtype=None):
    """Fused reduce-and-update (DESIGN.md §9): mask-select, per-client
    scaling, ``(N, P) → (P,)`` reduction, and the flat SGD parameter
    step in **one** pass — a single Pallas launch when ``use_kernel``
    (``masked_scaled_aggregate_update``), a single XLA-fusable matvec +
    axpy otherwise. Returns ``(new_params, new_opt_state, weight_sum)``.

    Only engages for a tagged plain-SGD optimizer (``kind == "sgd"``) —
    the kernel reproduces ``w − η·(ω_sel @ g)`` exactly; anything
    stateful (momentum, Adam) or nonlinear in the gradient (clipping)
    must keep the unfused reduce → update split.

    Sharded (``shard`` a ``ClientShard``): each device's kernel emits
    its local update *delta* ``−η·(ω_sel @ g_local)``; SGD is linear in
    the gradient, so ``params + Σ_shards delta`` equals the update of
    the global reduction. The collective stays ``(P,)``-sized
    (:func:`_cross_shard_sum`; ``wire_dtype`` quantizes it bf16-on-the-
    wire with f32 accumulation), and the replicated parameters absorb
    the summed delta in f32 before casting back.
    """
    from repro.optim.optimizers import SGDState, resolve_lr

    if getattr(optimizer, "kind", "") != "sgd":
        raise ValueError(
            "fused_flat_sgd_update requires a plain sgd() optimizer "
            f"(kind='sgd'); got kind={getattr(optimizer, 'kind', '')!r}")
    eta = resolve_lr(optimizer.hyper, opt_state.step)
    new_state = SGDState(step=opt_state.step + 1)
    w32 = weights.astype(jnp.float32)
    if shard is None:
        if use_kernel:
            from repro.kernels.aggregate import ops as agg_ops

            new_params = agg_ops.masked_scaled_aggregate_update(
                g, w32, eta, params, mask)
        else:
            agg = reduce_flat(g, weights, out_dtype=jnp.float32, mask=mask)
            new_params = (params.astype(jnp.float32)
                          - eta * agg).astype(params.dtype)
        return new_params, new_state, jnp.sum(weights)
    if use_kernel:
        from repro.kernels.aggregate import ops as agg_ops

        delta = agg_ops.masked_scaled_aggregate_update(g, w32, eta, None, mask)
    else:
        agg = reduce_flat(g, weights, out_dtype=jnp.float32, mask=mask)
        delta = -eta * agg
    delta = _cross_shard_sum(delta, shard.axis_name, wire_dtype)
    new_params = (params.astype(jnp.float32) + delta).astype(params.dtype)
    wsum = jax.lax.psum(jnp.sum(weights), shard.axis_name)
    return new_params, new_state, wsum


def aggregate_client_grads_flat(stacked_grads, weights: jax.Array, *,
                                use_kernel: bool = False,
                                mask: jax.Array | None = None):
    """Single-pass aggregation: ravel → one kernel/matvec → unravel.

    Same contract as :func:`aggregate_client_grads` (float32-accumulation
    tolerance); issues exactly **one** reduction regardless of the number
    of parameter leaves. Mixed-dtype pytrees fall back to the per-leaf
    path.
    """
    try:
        spec = ravel_spec(stacked_grads, lead_axes=1)
    except ValueError:
        if use_kernel:
            return aggregate_client_grads_kernel_per_leaf(
                stacked_grads, weights, mask)
        return aggregate_client_grads(stacked_grads, weights, mask)
    g = ravel_stacked(stacked_grads, spec)
    return unravel_pytree(
        reduce_flat(g, weights, use_kernel=use_kernel, mask=mask), spec)


def aggregate_client_grads_kernel(stacked_grads, weights: jax.Array,
                                  mask: jax.Array | None = None):
    """Kernel-path aggregation: one Pallas launch for the whole pytree.

    Previously one ``masked_scaled_aggregate`` call (with its own lane
    padding) *per leaf*; now the tree is raveled once into ``(N, P)``
    and reduced by a single tiled kernel (DESIGN.md §5).
    """
    return aggregate_client_grads_flat(stacked_grads, weights,
                                       use_kernel=True, mask=mask)


def aggregate_client_grads_kernel_per_leaf(stacked_grads, weights: jax.Array,
                                           mask: jax.Array | None = None):
    """One kernel launch per leaf — the pre-flat kernel path, kept as
    the mixed-dtype fallback and the ``ClientSimulator(flat=False)``
    legacy behavior."""
    from repro.kernels.aggregate import ops as agg_ops

    def _one(leaf):
        n = leaf.shape[0]
        flat = leaf.reshape(n, -1)
        out = agg_ops.masked_scaled_aggregate(
            flat, weights.astype(leaf.dtype), mask=mask)
        return out.reshape(leaf.shape[1:])

    return jax.tree_util.tree_map(_one, stacked_grads)


def per_example_coefficients(
    client_ids: jax.Array,
    weights: jax.Array,
    examples_per_client: jax.Array | int,
) -> jax.Array:
    """Per-example loss coefficients realizing the paper's update in SPMD.

    If client i owns b_i examples of the batch and g_i is the *mean*
    gradient over its examples, then

        Σ_i ω_i g_i = Σ_i Σ_{j∈i} (ω_i / b_i) · ∇l_ij

    so example j of client i gets coefficient ω_i / b_i. Gradient of
    ``sum(coeff * per_example_loss)`` == paper's aggregated update.

    client_ids : (B,) int32 — owning client of each example.
    weights    : (N,) float32 — ω_i.
    examples_per_client : scalar or (N,) — b_i.
    """
    b = jnp.asarray(examples_per_client, jnp.float32)
    if b.ndim == 0:
        per_client = weights / b
    else:
        per_client = weights / jnp.maximum(b, 1.0)
    return per_client[client_ids]


def server_update(params, aggregated_grads, lr):
    """Plain SGD server update, w ← w − η · aggregate (paper eq. 11)."""
    return jax.tree_util.tree_map(
        lambda w, g: w - lr * g.astype(w.dtype), params, aggregated_grads
    )
