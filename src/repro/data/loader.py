"""Batching: per-client samplers (simulator path) and global batchers (SPMD).

Everything is device-resident jnp + PRNG-indexed gather so batch sampling
can live *inside* the jitted/scan'd training loop (the paper's eq. (4)
ξ_i^t sampling) with no host round-trips.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class ClientBatcher:
    """Per-client uniform sampling ξ_i^t from equal-size client shards.

    Stores client data as stacked arrays (N, D_i, ...) (shards padded to a
    common size by resampling, recorded in ``true_sizes`` so p_i can still
    reflect the real D_i). ``sample(key)`` returns a pytree of
    (N, batch, ...) minibatches, one per client — vmap-ready.
    """

    def __init__(self, arrays_per_client: list[dict], batch_size: int, seed: int = 0):
        if not arrays_per_client:
            raise ValueError("need at least one client")
        self.n_clients = len(arrays_per_client)
        self.batch_size = batch_size
        sizes = [len(next(iter(d.values()))) for d in arrays_per_client]
        self.true_sizes = np.asarray(sizes, dtype=np.int64)
        cap = max(sizes)
        rng = np.random.default_rng(seed)
        stacked: dict[str, np.ndarray] = {}
        for name in arrays_per_client[0]:
            per = []
            for d, size in zip(arrays_per_client, sizes):
                arr = np.asarray(d[name])
                if size < cap:  # pad by resampling with replacement
                    extra = arr[rng.integers(0, size, cap - size)]
                    arr = np.concatenate([arr, extra], axis=0)
                per.append(arr)
            stacked[name] = np.stack(per, axis=0)
        self.data = {k: jnp.asarray(v) for k, v in stacked.items()}
        self.shard_size = cap

    @property
    def p(self) -> jnp.ndarray:
        """p_i = D_i / D from the true (pre-padding) shard sizes."""
        return jnp.asarray(self.true_sizes / self.true_sizes.sum(), jnp.float32)

    def sample(self, key) -> dict:
        idx = jax.random.randint(
            key, (self.n_clients, self.batch_size), 0, self.shard_size)

        def gather(arr):
            return jax.vmap(lambda a, ix: a[ix])(arr, idx)

        return {k: gather(v) for k, v in self.data.items()}


class GlobalBatcher:
    """Global-batch sampler for the SPMD path.

    The global batch of size B is laid out as ``n_clients`` contiguous
    slots of B/N examples; ``client_ids`` marks ownership so the train
    step can apply per-example energy coefficients. Sampling is
    jnp-resident like ClientBatcher.
    """

    def __init__(self, data: dict, n_clients: int, global_batch: int,
                 client_index: list[np.ndarray] | None = None):
        if global_batch % n_clients != 0:
            raise ValueError(f"global_batch {global_batch} % n_clients {n_clients} != 0")
        self.n_clients = n_clients
        self.global_batch = global_batch
        self.per_client = global_batch // n_clients
        n = len(next(iter(data.values())))
        if client_index is None:
            # IID: every client samples from the full dataset.
            self._index = None
            self.data = {k: jnp.asarray(v) for k, v in data.items()}
            self._n = n
        else:
            cap = max(len(ix) for ix in client_index)
            rng = np.random.default_rng(0)
            padded = []
            for ix in client_index:
                if len(ix) < cap:
                    ix = np.concatenate([ix, rng.choice(ix, cap - len(ix))])
                padded.append(ix)
            self._index = jnp.asarray(np.stack(padded))  # (N, cap)
            self.data = {k: jnp.asarray(v) for k, v in data.items()}
            self._n = cap
        self.client_ids = jnp.repeat(jnp.arange(n_clients, dtype=jnp.int32),
                                     self.per_client)

    def sample(self, key) -> dict:
        idx = jax.random.randint(key, (self.n_clients, self.per_client), 0, self._n)
        if self._index is not None:
            idx = jax.vmap(lambda row, ix: row[ix])(self._index, idx)
        flat = idx.reshape(-1)
        batch = {k: v[flat] for k, v in self.data.items()}
        batch["client_ids"] = self.client_ids
        return batch
