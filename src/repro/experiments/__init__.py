"""Scenario engine: declarative experiment grids, batched execution.

* :mod:`repro.experiments.scenario` — :class:`Scenario` specs, the
  energy-profile factory, and the named-grid registry.
* :mod:`repro.experiments.engine` — :func:`run_grid`, which executes a
  whole scheduler × arrival × seed grid as one compiled computation per
  component structure (vmap over stacked pytree leaves), plus the
  sequential per-cell baseline for cross-checks and benchmarking.
"""

from repro.experiments.engine import (
    CellResult,
    clear_cache,
    grid_summary,
    run_grid,
    run_grid_sequential,
)
from repro.experiments.scenario import (
    ARRIVAL_KINDS,
    FIG1_SCHEDULERS,
    PAPER_TAUS,
    Scenario,
    default_taus,
    get_grid,
    grid_names,
    make_energy_process,
    register_grid,
    scenario_grid,
)

__all__ = [
    "ARRIVAL_KINDS", "FIG1_SCHEDULERS", "PAPER_TAUS",
    "CellResult", "Scenario", "clear_cache", "default_taus", "get_grid",
    "grid_names",
    "grid_summary", "make_energy_process", "register_grid", "run_grid",
    "run_grid_sequential", "scenario_grid",
]
