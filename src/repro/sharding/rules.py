"""Parameter / activation / state PartitionSpec rules.

Strategy (MaxText-style FSDP + TP):
  * ``data``  — batch dimension of activations; FSDP dimension of weights
  * ``model`` — tensor parallel: attention heads & FFN columns & experts
  * ``pod``   — pure data parallel across pods (weights replicated
                pod-wise; gradients all-reduce over pod)

Rules are *suffix-matched* on the parameter tree path so the same table
covers stacked (scan) parameters — leading (n_super, count) axes are
padded with None. Every named axis is divisibility-checked against the
actual mesh and dropped when it doesn't divide (e.g. whisper's odd 51865
vocab stays replicated; xlstm's 4 heads skip TP).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path-suffix, spec for the TRAILING dims of the leaf)
# Suffixes are matched against the end of the '/'-joined leaf path.
SUFFIX_RULES: list[tuple[str, tuple]] = [
    # attention
    ("attn/wq/w", ("data", "model")),
    ("attn/wk/w", ("data", "model")),
    ("attn/wv/w", ("data", "model")),
    ("attn/wo/w", ("model", "data")),
    ("self/wq/w", ("data", "model")),
    ("self/wk/w", ("data", "model")),
    ("self/wv/w", ("data", "model")),
    ("self/wo/w", ("model", "data")),
    ("cross/wq/w", ("data", "model")),
    ("cross/wk/w", ("data", "model")),
    ("cross/wv/w", ("data", "model")),
    ("cross/wo/w", ("model", "data")),
    # dense FFN
    ("mlp/gate/w", ("data", "model")),
    ("mlp/up/w", ("data", "model")),
    ("mlp/down/w", ("model", "data")),
    # MoE: experts on the model axis (expert parallelism)
    ("moe/router/w", (None, None)),
    ("moe/w_gate", ("model", "data", None)),
    ("moe/w_up", ("model", "data", None)),
    ("moe/w_down", ("model", None, "data")),
    ("moe/shared/gate/w", ("data", "model")),
    ("moe/shared/up/w", ("data", "model")),
    ("moe/shared/down/w", ("model", "data")),
    # SSM mixers
    ("mixer/in_proj/w", ("data", "model")),
    ("mixer/out_proj/w", ("model", "data")),
    ("mixer/wq", ("model", None, None)),
    ("mixer/wk", ("model", None, None)),
    ("mixer/wv", ("model", None, None)),
    ("mixer/w_in/w", ("data", "model")),
    ("mixer/r", ("model", None, None)),
    # embeddings / head
    ("embed/w", ("model", "data")),
    ("lm_head/w", ("data", "model")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_size(mesh_shape: dict, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh_shape.get(a, 1)
        return n
    return mesh_shape.get(entry, 1)


def _fit_spec(shape, trailing_spec, mesh_shape) -> P:
    """Pad leading Nones and divisibility-check every named axis."""
    ndim = len(shape)
    k = len(trailing_spec)
    lead = (None,) * (ndim - k)
    fitted = []
    for dim, entry in zip(shape[ndim - k:], trailing_spec):
        size = _axis_size(mesh_shape, entry)
        present = entry is not None and all(
            a in mesh_shape for a in (entry if isinstance(entry, tuple) else (entry,)))
        fitted.append(entry if (present and size > 1 and dim % size == 0) else None)
    return P(*(lead + tuple(fitted)))


def param_specs(params: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params`` (suffix rules + checks)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        ps = _path_str(path)
        for suffix, spec in SUFFIX_RULES:
            if ps.endswith(suffix):
                return _fit_spec(leaf.shape, spec, mesh_shape)
        return P()  # norms, biases, gates, scalars: replicated

    return jax.tree_util.tree_map_with_path(one, params)


def auto_spec(shape, mesh: Mesh, batch_axis: int | None = 0) -> P:
    """Heuristic spec for activations / decode state leaves.

    Axis ``batch_axis`` shards over ("pod","data") (with fallbacks to
    whichever divides); the first later axis divisible by the model-axis
    size gets "model" (for KV caches this lands on the sequence axis —
    context-parallel cache — or the head axis, whichever divides first).
    """
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    ndim = len(shape)
    entries: list = [None] * ndim
    if batch_axis is not None and ndim > 0:
        b = shape[batch_axis]
        for cand in (("pod", "data"), ("data",), ("pod",)):
            if all(a in mesh_shape for a in cand):
                size = _axis_size(mesh_shape, tuple(cand))
                if size > 1 and b % size == 0:
                    entries[batch_axis] = cand if len(cand) > 1 else cand[0]
                    break
    msize = mesh_shape.get("model", 1)
    if msize > 1:
        for ax in range(ndim):
            if ax == batch_axis or entries[ax] is not None:
                continue
            if shape[ax] % msize == 0 and shape[ax] >= msize:
                entries[ax] = "model"
                break
    return P(*entries)


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Specs for a training/prefill batch: leading axis = global batch."""

    def one(leaf):
        return auto_spec(leaf.shape, mesh, batch_axis=0)

    return jax.tree_util.tree_map(one, batch)


import os

# Perf-iteration toggle (EXPERIMENTS.md §Perf): which axis of a decode
# state leaf gets the "model" mesh axis. "trailing" (default) walks axes
# from the END — landing on head_dim/feature axes; "leading" walks from
# the batch axis forward — landing on the KV-cache *sequence* axis, which
# forces GSPMD to re-materialize the cache at every dynamic-index write
# (measured: the phi3.5 decode_32k collective term).
_STATE_AXIS_ORDER = os.environ.get("REPRO_STATE_SPEC_ORDER", "trailing")


def state_specs(states: Any, mesh: Mesh) -> Any:
    """Specs for decode state pytrees.

    Leaves carry leading (n_super[, count]) stacking axes before the batch
    axis; the first axis divisible by the (pod×data) size is treated as
    batch, and one later axis (order per _STATE_AXIS_ORDER) divisible by
    the model-axis size gets "model".
    """
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = _axis_size(mesh_shape, ("pod", "data")) if "pod" in mesh_shape \
        else _axis_size(mesh_shape, ("data",))
    msize = mesh_shape.get("model", 1)
    dp_axes = ("pod", "data") if "pod" in mesh_shape else "data"

    def one(leaf):
        shape = leaf.shape
        entries: list = [None] * len(shape)
        batch_axis = None
        for ax, dim in enumerate(shape):
            if dim % dp == 0 and dim >= dp:
                batch_axis = ax
                entries[ax] = dp_axes
                break
        if msize > 1 and _STATE_AXIS_ORDER != "none":
            start = (batch_axis + 1) if batch_axis is not None else 0
            order = range(len(shape) - 1, start - 1, -1) \
                if _STATE_AXIS_ORDER == "trailing" else range(start, len(shape))
            for ax in order:
                if entries[ax] is None and shape[ax] % msize == 0 \
                        and shape[ax] >= msize:
                    entries[ax] = "model"
                    break
        return P(*entries)

    return jax.tree_util.tree_map(one, states)


def tree_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
