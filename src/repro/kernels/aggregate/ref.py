"""Pure-jnp oracle for the masked/scaled aggregation kernel."""

import jax.numpy as jnp


def masked_scaled_aggregate_ref(g, w, mask=None):
    """g: (N, P); w: (N,) -> (P,). ``mask``: optional (N,) active rows —
    masked rows are dropped (selected to zero) before the reduction."""
    g32 = g.astype(jnp.float32)
    if mask is not None:
        g32 = jnp.where(mask.reshape(-1, 1) > 0, g32, 0.0)
    return jnp.einsum("n,np->p", w.astype(jnp.float32), g32).astype(g.dtype)
