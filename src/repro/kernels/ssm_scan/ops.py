"""Jit'd public wrapper for the GLA/SSD scan kernel.

Model layout (B, S, H, D*) is folded to the kernel's (B·H, S, D*);
sequence is padded to the chunk size with a=1, k=v=0 (identity steps).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.ssm_scan import gla_scan_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def gla_scan(a, k, v, q, chunk: int = 64):
    """a: (B,S,H); k,q: (B,S,H,dk); v: (B,S,H,dv) -> y (B,S,H,dv) f32."""
    b, s, h = a.shape
    dk, dv = k.shape[-1], v.shape[-1]
    fold = lambda x: x.swapaxes(1, 2).reshape((b * h, s) + x.shape[3:])
    af, kf, vf, qf = fold(a), fold(k), fold(v), fold(q)
    pad = (-s) % chunk
    if pad:
        af = jnp.pad(af, ((0, 0), (0, pad)), constant_values=1.0)
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
    y = gla_scan_kernel(af, kf, vf, qf, chunk=min(chunk, af.shape[1]),
                        interpret=_interpret())
    y = y[:, :s]
    return y.reshape(b, h, s, dv).swapaxes(1, 2)
