"""Quickstart: energy-harvesting distributed SGD in ~60 lines.

Builds the paper's setting on a closed-form quadratic: 8 clients with
heterogeneous periodic energy (τ cycling through 1/5/10/20), and compares
Algorithm 1 against the paper's two benchmarks and the full-participation
oracle. Run:

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ClientSimulator, make_quadratic, make_scheduler
from repro.core.energy import DeterministicArrivals
from repro.optim import sgd

N_CLIENTS, STEPS, ETA = 8, 1000, 0.01  # t=1000 as in the paper's Fig. 1
TAUS = [(1, 5, 10, 20)[i % 4] for i in range(N_CLIENTS)]


def main():
    problem = make_quadratic(jax.random.PRNGKey(0), N_CLIENTS, dim=10,
                             hetero=1.0)
    energy = DeterministicArrivals.periodic(TAUS, horizon=STEPS + 1)

    def grads_fn(params, key, t):
        return problem.all_grads(params, key=key, noise=0.05)

    print(f"{N_CLIENTS} clients, energy periods {TAUS}")
    print(f"{'scheduler':<12} {'final subopt':>14} {'mean weight Σω':>16}")
    results = {}
    for name in ("alg1", "benchmark1", "benchmark2", "oracle"):
        sim = ClientSimulator(
            grads_fn=grads_fn,
            scheduler=make_scheduler(name, N_CLIENTS),
            energy=energy,
            p=problem.p,
            optimizer=sgd(ETA),
            loss_fn=problem.suboptimality,
        )
        w0 = jnp.full((10,), 5.0)
        _, hist = sim.run(jax.random.PRNGKey(1), w0, STEPS)
        final = float(np.asarray(hist.loss[-100:]).mean())
        results[name] = final
        print(f"{name:<12} {final:>14.5f} "
              f"{float(hist.weight_sum.mean()):>16.3f}")

    assert results["alg1"] < results["benchmark1"], "Alg1 must beat B1"
    assert results["alg1"] < results["benchmark2"], "Alg1 must beat B2"
    print("\nAlgorithm 1 (unbiased energy-aware) beats both benchmarks ✓")


if __name__ == "__main__":
    main()
