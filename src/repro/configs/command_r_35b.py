"""command-r-35b — large dense decoder, GQA, no biases.

[hf:CohereForAI/c4ai-command-r-v01] 40L, d_model=8192, 64 heads (GQA
kv=8), d_ff=22528, vocab=256000, no-bias.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    arch_type="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab=256000,
    rope_theta=8000000.0,
    long_context_window=8192,
    norm="layernorm",  # command-r uses LayerNorm (no bias)
    act="silu",
    use_bias=False,
    dtype_name="bfloat16",
    remat=True,
    citation="[hf:CohereForAI/c4ai-command-r-v01]",
)
