"""xlstm-1.3b — sLSTM + mLSTM block stack (xLSTM[7:1]).

[arXiv:2405.04517] 48 blocks, d_model=2048, 4 heads (kv=4), d_ff=0 (the
blocks carry their own up/down projections), vocab=50304. Layout: 6
super-blocks × (7 mLSTM + 1 sLSTM), the paper's 7:1 ratio. mLSTM runs on
the chunked GLA engine (matrix memory = gated linear recurrence); sLSTM
is a true sequential scan (hidden-to-hidden recurrence).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_heads=4,
    superblock=(("mlstm", 7, False), ("slstm", 1, False)),
    n_super=6,
    norm="rmsnorm",
    act="gelu",
    gla_chunk=64,
    dtype_name="bfloat16",
    remat=True,
    citation="[arXiv:2405.04517]",
)
