"""render_md bench-series renderer: every family renders, nothing drops."""

import pytest

from benchmarks.render_md import FAMILIES, family_title, render_bench

pytestmark = pytest.mark.serve


def _rec(name, us=100.0, derived=None):
    return {"suite": "x", "name": name, "us_per_call": us,
            "derived": derived or {}, "values": {"us_per_call": us},
            "units": {"us_per_call": "us"}}


def _doc(records):
    return {"schema": "bench-series/v1", "suites": ["x"], "fast": True,
            "device_count": 8, "failed": [], "results": records}


def test_known_families_have_sections():
    md = render_bench(_doc([
        _rec("largeN_sharded_N1024", derived={"devices": 8}),
        _rec("faultpath_inject_warm"),
        _rec("serve_throughput", derived={"scenarios_per_s": 356.0}),
        _rec("fig1_alg1_periodic"),
    ]))
    assert "## Large-N client sharding" in md
    assert "## Fault-injection path" in md
    assert "## Study service" in md
    assert "## Figure 1 grid" in md
    assert "| serve_throughput | 100 | scenarios_per_s=356 |" in md


def test_unknown_series_render_under_other_never_dropped():
    md = render_bench(_doc([
        _rec("fig1_alg1_periodic"),
        _rec("mystery_series_42", derived={"k": True}),
        _rec("another_new_family"),
    ]))
    assert "## other" in md
    assert "mystery_series_42" in md
    assert "another_new_family" in md


def test_every_series_renders_exactly_once():
    names = [f"{p}x{i}" for i, (p, _) in enumerate(FAMILIES)] \
        + ["unaffiliated_1", "unaffiliated_2"]
    md = render_bench(_doc([_rec(n) for n in names]))
    for n in names:
        assert md.count(f"| {n} |") == 1


def test_family_title_prefix_matching():
    assert family_title("serve_latency") == "Study service"
    assert family_title("largeN_speedup_N4096") == "Large-N client sharding"
    assert family_title("faultpath_overhead") == "Fault-injection path"
    assert family_title("gla_chunked_1k") == "Kernel micro-benchmarks"
    assert family_title("brand_new_thing") == "other"


def test_zero_and_none_us_render_as_dash():
    md = render_bench(_doc([_rec("serve_collapse", us=0,
                                 derived={"compiles": 1}),
                            _rec("serve_cache", us=None)]))
    assert "| serve_collapse | — | compiles=1 |" in md
    assert "| serve_cache | — |" in md


def test_failed_suites_surface_in_header():
    doc = _doc([_rec("fig1_x")])
    doc["failed"] = ["serve_bench"]
    assert "**FAILED**: ['serve_bench']" in render_bench(doc)
