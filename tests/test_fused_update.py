"""Fused reduce-and-update path (DESIGN.md §9).

Locks the tentpole contracts of the fused Pallas server step:

* kernel ≡ interpret-mode reference (``masked_scaled_aggregate_update_ref``)
  across shapes, with and without mask/params, f32 and bf16 inputs
  (f32 in-kernel accumulation);
* mask-poisoned rows (inf/NaN) contribute **exact zeros**;
* the reduction grammar ``gather | psum[_bf16] | fused[_bf16]``;
* bf16-on-the-wire partial sums accumulate in f32 (quantize once per
  shard, never accumulate in bf16);
* the sharded fused step is a **single Pallas launch** per step
  (jaxpr-walk launch count);
* ``run_carry`` donates the flat carry: no warnings, the input buffers
  are consumed, and the donated chunked run resumes bitwise.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClientSimulator, make_quadratic, make_scheduler
from repro.core.aggregation import (
    _cross_shard_sum,
    fused_flat_sgd_update,
    parse_reduction,
)
from repro.core.energy import BinaryArrivals, make_arrivals
from repro.experiments import make_client_mesh, run_client_sharded
from repro.kernels.aggregate import ops as agg_ops
from repro.kernels.aggregate.ref import masked_scaled_aggregate_update_ref
from repro.optim import adam, sgd

multidevice = pytest.mark.multidevice


# ------------------------------------------------------- kernel vs oracle

SHAPES = [(1, 1), (3, 129), (8, 300), (17, 2048), (64, 2049)]


@pytest.mark.parametrize("n,p", SHAPES)
@pytest.mark.parametrize("with_params", [False, True], ids=["delta", "update"])
@pytest.mark.parametrize("with_mask", [False, True], ids=["dense", "masked"])
def test_fused_kernel_matches_ref_f32(n, p, with_params, with_mask):
    rng = np.random.default_rng(n * 1000 + p)
    g = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    w = jnp.asarray(rng.uniform(size=(n,)), jnp.float32)
    params = jnp.asarray(rng.normal(size=(p,)), jnp.float32) \
        if with_params else None
    mask = jnp.asarray(rng.integers(0, 2, size=(n,)), jnp.float32) \
        if with_mask else None
    eta = 0.07
    out = agg_ops.masked_scaled_aggregate_update(g, w, eta, params, mask)
    ref = masked_scaled_aggregate_update_ref(g, w, eta, params, mask)
    assert out.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_fused_kernel_bf16_inputs_f32_accumulation():
    """bf16 gradient rows, f32 params: the kernel upcasts per tile and
    accumulates f32 — the result matches the f32 oracle of the *same
    bf16-rounded inputs* to f32 tolerance, far tighter than any bf16
    accumulation could achieve at N=512."""
    rng = np.random.default_rng(0)
    n, p = 512, 700
    g = jnp.asarray(rng.normal(size=(n, p)), jnp.bfloat16)
    w = jnp.asarray(rng.uniform(size=(n,)), jnp.float32)
    params = jnp.asarray(rng.normal(size=(p,)), jnp.float32)
    out = agg_ops.masked_scaled_aggregate_update(g, w, 0.01, params)
    assert out.dtype == jnp.float32
    ref = masked_scaled_aggregate_update_ref(g, w, 0.01, params)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # delta mode defaults to the f32 accumulation dtype, not bf16
    delta = agg_ops.masked_scaled_aggregate_update(g, w, 0.01, None)
    assert delta.dtype == jnp.float32


@pytest.mark.parametrize("use_kernel", [False, True], ids=["jnp", "kernel"])
def test_fused_update_poisoned_masked_rows_exact_zero(use_kernel):
    """Acceptance: inf/NaN gradient rows behind mask=0 contribute exact
    zeros through the fused update — bitwise equal to zeroing the rows
    by hand, all the way through fused_flat_sgd_update."""
    rng = np.random.default_rng(3)
    n, p = 16, 260
    g = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    poisoned = g.at[2].set(jnp.inf).at[9].set(jnp.nan).at[11].set(-jnp.inf)
    mask = jnp.ones((n,), jnp.float32).at[2].set(0).at[9].set(0).at[11].set(0)
    w = jnp.asarray(rng.uniform(size=(n,)), jnp.float32)
    params = jnp.asarray(rng.normal(size=(p,)), jnp.float32)
    opt = sgd(0.05)
    st = opt.init(params)
    clean = g * mask[:, None]
    out_p, _, _ = fused_flat_sgd_update(poisoned, w, params, st, opt,
                                        mask=mask, use_kernel=use_kernel)
    out_c, _, _ = fused_flat_sgd_update(clean, w, params, st, opt,
                                        mask=mask, use_kernel=use_kernel)
    assert bool(jnp.all(jnp.isfinite(out_p)))
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_c))


def test_fused_update_rejects_untagged_optimizer():
    g = jnp.ones((2, 4))
    w = jnp.ones((2,))
    params = jnp.zeros((4,))
    opt = adam(0.1)
    with pytest.raises(ValueError, match="sgd"):
        fused_flat_sgd_update(g, w, params, opt.init(params), opt)


def test_sgd_is_tagged_for_fusion_and_wrappers_are_not():
    from repro.optim import chain_clip, momentum

    assert sgd(0.1).kind == "sgd"
    assert sgd(0.1).hyper == 0.1
    assert momentum(0.1).kind == ""
    assert adam(0.1).kind == ""
    assert chain_clip(sgd(0.1), 1.0).kind == ""


def test_fused_update_schedule_lr():
    """A schedule lr is resolved at the carried step, matching the
    unfused sgd().update numerics exactly."""
    sched = lambda step: 0.1 / (1.0 + step.astype(jnp.float32))
    opt = sgd(sched)
    rng = np.random.default_rng(5)
    g = jnp.asarray(rng.normal(size=(3, 20)), jnp.float32)
    w = jnp.asarray(rng.uniform(size=(3,)), jnp.float32)
    params = jnp.asarray(rng.normal(size=(20,)), jnp.float32)
    st = opt.init(params)
    st = st._replace(step=jnp.asarray(7, jnp.int32))
    fused_p, fused_st, _ = fused_flat_sgd_update(g, w, params, st, opt)
    agg = w @ g
    updates, ref_st = opt.update(agg, st)
    np.testing.assert_array_equal(np.asarray(fused_p),
                                  np.asarray(params + updates))
    assert int(fused_st.step) == int(ref_st.step) == 8


# ------------------------------------------------------ reduction grammar

def test_parse_reduction_grammar():
    assert parse_reduction("gather") == ("gather", None)
    assert parse_reduction("psum") == ("psum", None)
    assert parse_reduction("fused") == ("fused", None)
    assert parse_reduction("psum_bf16") == ("psum", jnp.bfloat16)
    assert parse_reduction("fused_bf16") == ("fused", jnp.bfloat16)


@pytest.mark.parametrize("bad", ["gather_bf16", "psum_f16", "allgather",
                                 "fused_f32", ""])
def test_parse_reduction_rejects(bad):
    with pytest.raises(ValueError):
        parse_reduction(bad)


def test_client_sharding_context_validates_reduction():
    from repro.core.energy import client_sharding

    with pytest.raises(ValueError):
        with client_sharding("clients", 2, "gather_bf16"):
            pass
    with client_sharding("clients", 2, "fused_bf16"):
        pass


# ------------------------------------------------------- bf16 wire semantics

@multidevice
def test_cross_shard_sum_bf16_wire_f32_accumulation():
    """The bf16 wire quantizes each shard's partial ONCE and accumulates
    the gathered partials in f32 — bitwise equal to the explicit
    quantize-then-f32-sum, not to a bf16-accumulated psum."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_client_mesh()
    shards = mesh.size
    rng = np.random.default_rng(11)
    partials = jnp.asarray(rng.normal(size=(shards, 64)), jnp.float32)

    fn = shard_map(
        lambda x: _cross_shard_sum(x[0], "clients", jnp.bfloat16)[None],
        mesh=mesh, in_specs=P("clients"), out_specs=P("clients"),
        check_rep=False)
    out = np.asarray(fn(partials)[0])
    expected = np.sum(np.asarray(partials.astype(jnp.bfloat16)
                                 .astype(jnp.float32)), axis=0)
    np.testing.assert_array_equal(out, expected)
    exact = np.sum(np.asarray(partials), axis=0)
    np.testing.assert_allclose(out, exact, rtol=2e-2, atol=1e-2)


# --------------------------------------------------- single-launch contract

def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(
                    v, is_leaf=lambda x: isinstance(
                        x, (jax.core.Jaxpr, jax.core.ClosedJaxpr))):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    n += _count_pallas_calls(sub.jaxpr)
                elif isinstance(sub, jax.core.Jaxpr):
                    n += _count_pallas_calls(sub)
    return n


@multidevice
def test_sharded_fused_step_is_single_pallas_launch():
    """Acceptance: the client-sharded fused hot loop contains exactly
    ONE pallas_call in its step program — the fused reduce-and-update
    launch; the parameter update is not a second kernel."""
    n, dim, steps = 8, 5, 4
    prob = make_quadratic(jax.random.PRNGKey(0), n_clients=n, dim=dim)
    sim = ClientSimulator(grads_fn=lambda w, k, t: prob.all_grads(w),
                          p=prob.p, optimizer=sgd(0.02), use_kernel=True)
    scheduler = make_scheduler("alg2", n)
    energy = make_arrivals("binary", n, steps + 1)
    params0 = jnp.full((dim,), 2.0)

    jaxpr = jax.make_jaxpr(
        lambda k, p0: run_client_sharded(
            sim, k, p0, steps, scheduler=scheduler, energy=energy,
            mesh=make_client_mesh(), reduction="fused"))(
        jax.random.PRNGKey(1), params0)
    # The scan body traces once, so the whole program holds exactly the
    # per-step launch count.
    assert _count_pallas_calls(jaxpr.jaxpr) == 1


# ------------------------------------------------------------- donation

def _donation_sim(n):
    prob = make_quadratic(jax.random.PRNGKey(4), n_clients=n, dim=6)
    sim = ClientSimulator(grads_fn=lambda w, k, t: prob.all_grads(w),
                          p=prob.p, optimizer=sgd(0.03),
                          scheduler=make_scheduler("alg1", n),
                          energy=BinaryArrivals([0.6] * n))
    return sim, jnp.full((6,), 3.0)


def test_run_carry_donates_flat_buffers_silently():
    """Top-level run_carry consumes the input carry's buffers (donation
    took effect) without emitting any donation warnings."""
    n = 4
    sim, params0 = _donation_sim(n)
    spec = sim.flat_spec(params0)
    carry = sim.init(jax.random.PRNGKey(0), params0, spec=spec)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        carry2, hist = sim.run_carry(carry, 5, spec=spec)
    assert carry.params.is_deleted()
    assert not carry2.params.is_deleted()
    assert hist.loss.shape == (5,)
    # the caller's params0 was copied at init, never donated
    assert not params0.is_deleted()
    np.asarray(params0)


def test_run_carry_donation_opt_out():
    n = 4
    sim, params0 = _donation_sim(n)
    spec = sim.flat_spec(params0)
    carry = sim.init(jax.random.PRNGKey(0), params0, spec=spec)
    carry2, _ = sim.run_carry(carry, 5, spec=spec, donate=False)
    assert not carry.params.is_deleted()
    np.asarray(carry.params)


def test_donated_chunked_run_carry_resumes_bitwise():
    """Two donated 10-step run_carry chunks == one 20-step run, bitwise
    — donation aliases buffers without perturbing the step stream."""
    n = 4
    sim, params0 = _donation_sim(n)
    spec = sim.flat_spec(params0)
    carry = sim.init(jax.random.PRNGKey(7), params0, spec=spec)
    c1, h1 = sim.run_carry(carry, 10, spec=spec)
    c2, h2 = sim.run_carry(c1, 10, spec=spec)

    whole = sim.init(jax.random.PRNGKey(7), params0, spec=spec)
    cw, hw = sim.run_carry(whole, 20, spec=spec)
    np.testing.assert_array_equal(np.asarray(cw.params),
                                  np.asarray(c2.params))
    np.testing.assert_array_equal(
        np.asarray(hw.weight_sum),
        np.concatenate([np.asarray(h1.weight_sum),
                        np.asarray(h2.weight_sum)]))


# ----------------------------------------------- SPMD flat train step fused

def test_build_energy_train_step_flat_sgd_routes_fused(monkeypatch):
    """flat=True + tagged sgd() routes through fused_flat_sgd_update and
    matches the unfused flat step bitwise."""
    from repro.core import aggregation as agg_mod
    from repro.core.trainer import build_energy_train_step

    n_clients, dim, bsz = 4, 6, 8
    rng = np.random.default_rng(9)
    w_true = jnp.asarray(rng.normal(size=(dim,)), jnp.float32)

    def per_example_loss(params, batch):
        pred = batch["x"] @ params["w"]
        return (pred - batch["y"]) ** 2

    batch = {
        "x": jnp.asarray(rng.normal(size=(bsz, dim)), jnp.float32),
        "y": jnp.asarray(rng.normal(size=(bsz,)), jnp.float32),
        "client_ids": jnp.repeat(jnp.arange(n_clients, dtype=jnp.int32),
                                 bsz // n_clients),
    }
    params = {"w": jnp.zeros((dim,), jnp.float32) + w_true * 0.1}
    mask = jnp.ones((n_clients,), jnp.float32)
    scale = jnp.ones((n_clients,), jnp.float32)

    calls = []
    real = agg_mod.fused_flat_sgd_update

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(agg_mod, "fused_flat_sgd_update", counting)
    init, step = build_energy_train_step(
        per_example_loss_fn=per_example_loss, optimizer=sgd(0.1),
        n_clients=n_clients, flat=True)
    st1, m1 = step(init(params), batch, mask, scale)
    assert calls, "flat sgd step did not route through the fused update"

    init_a, step_a = build_energy_train_step(
        per_example_loss_fn=per_example_loss, optimizer=adam(0.1),
        n_clients=n_clients, flat=True)
    st2, m2 = step_a(init_a(params), batch, mask, scale)
    np.testing.assert_array_equal(np.asarray(m1["weight_sum"]),
                                  np.asarray(m2["weight_sum"]))
    assert st1.params["w"].shape == (dim,)
