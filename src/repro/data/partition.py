"""Federated partitioning of a dataset across N clients.

The paper distributes CIFAR-10 "over 40 users uniformly at random" (IID).
We provide that, plus two heterogeneous partitioners used by the
benchmarks to make Benchmark-1's bias *visible* (with IID data, biased
client sampling still converges near the optimum because every client's
local loss has the same minimizer — the bias shows up in p_i weighting
only; with label skew aligned to energy groups, the bias is large).
"""

from __future__ import annotations

import numpy as np


def iid_partition(seed: int, n_examples: int, n_clients: int) -> list[np.ndarray]:
    """Uniformly-at-random equal split (paper §V)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_examples)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def dirichlet_partition(
    seed: int, labels: np.ndarray, n_clients: int, alpha: float = 0.3
) -> list[np.ndarray]:
    """Label-Dirichlet split (standard non-IID federated benchmark)."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.flatnonzero(labels == k) for k in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    client_idx: list[list[int]] = [[] for _ in range(n_clients)]
    for k in range(n_classes):
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props)[:-1] * len(idx_by_class[k])).astype(int)
        for c, shard in enumerate(np.split(idx_by_class[k], cuts)):
            client_idx[c].extend(shard.tolist())
    return [np.sort(np.asarray(ix, dtype=np.int64)) for ix in client_idx]


def group_label_skew_partition(
    seed: int,
    labels: np.ndarray,
    n_clients: int,
    n_groups: int,
    skew: float = 0.8,
) -> list[np.ndarray]:
    """Label skew aligned with energy groups (client i ∈ group i mod G).

    Group g's clients draw a fraction ``skew`` of their data from classes
    ≡ g (mod G) and the rest uniformly. With energy periods also assigned
    per group (paper eq. 37), energy-agnostic participation then biases
    the model toward the energy-rich group's classes — the exact failure
    mode the paper's Benchmark 1 exhibits.
    """
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [list(np.flatnonzero(labels == k)) for k in range(n_classes)]
    for lst in idx_by_class:
        rng.shuffle(lst)
    per_client = len(labels) // n_clients
    out = []
    for i in range(n_clients):
        g = i % n_groups
        fav = [k for k in range(n_classes) if k % n_groups == g]
        take = []
        n_fav = int(skew * per_client)
        for j in range(n_fav):
            k = fav[j % len(fav)]
            if idx_by_class[k]:
                take.append(idx_by_class[k].pop())
        while len(take) < per_client:
            k = int(rng.integers(0, n_classes))
            if idx_by_class[k]:
                take.append(idx_by_class[k].pop())
        out.append(np.sort(np.asarray(take, dtype=np.int64)))
    return out
