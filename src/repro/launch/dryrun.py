from repro._env import ensure_host_device_count

ensure_host_device_count(512)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh).

Proves the distribution config is coherent without hardware: for each
assigned architecture and input shape, the train/prefill/serve step is
``jax.jit(...).lower(**ShapeDtypeStructs).compile()``'d against the
production mesh — (16,16)=(data,model) single pod AND (2,16,16)=
(pod,data,model) two pods — and the compiled artifact's
memory_analysis / cost_analysis / collective schedule are recorded to
``benchmarks/results/dryrun.json`` for the §Roofline tables.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
    PYTHONPATH=src python -m repro.launch.dryrun --arch command-r-35b \
        --shape train_4k --mesh multi_pod
Incremental: existing (arch, shape, mesh) entries are skipped unless
--force.
"""

import argparse      # noqa: E402
import functools     # noqa: E402
import json          # noqa: E402
import os            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (  # noqa: E402
    DEFAULT_N_CLIENTS,
    INPUT_SHAPES,
    arch_names,
    effective_window,
    get_config,
    input_specs,
)
from repro.launch import roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models import init_lm  # noqa: E402
from repro.sharding import batch_specs, param_specs, state_specs  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "dryrun.json")


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _memory_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float))}


def _coerce(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return v == "True"
    return v


def lower_pair(arch: str, shape_name: str, multi_pod: bool,
               unroll: bool | None = None, overrides: dict | None = None):
    """Lower + compile one (arch, shape, mesh). Returns the record dict.

    ``unroll`` (default: single-pod yes, multi-pod no): unrolled layers
    make cost_analysis / collective parsing count every layer (XLA counts
    a while body ONCE — measured); the scan version is the faster
    production artifact and is what the multi-pod coherence proof uses.
    Roofline tables read the single-pod (unrolled) records.
    """
    if unroll is None:
        unroll = not multi_pod
    cfg = get_config(arch).replace(unroll_layers=unroll, **(overrides or {}))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    window = effective_window(cfg, shape) or None
    t0 = time.time()

    with mesh:
        params_s = jax.eval_shape(
            lambda: init_lm(jax.random.PRNGKey(0), cfg))
        p_specs = param_specs(params_s, mesh)

        if shape.mode == "train":
            (batch_s, sched_s), _ = input_specs(cfg, shape_name)
            init_state, train_step = make_train_step(
                cfg, DEFAULT_N_CLIENTS, window=window)
            state_s = jax.eval_shape(init_state, params_s)
            # optimizer state mirrors the param tree → same suffix rules
            st_specs = param_specs(state_s, mesh)
            b_specs = batch_specs(batch_s, mesh)
            repl = P()
            jitted = jax.jit(
                train_step,
                in_shardings=(_ns(mesh, st_specs), _ns(mesh, b_specs),
                              _ns(mesh, repl), _ns(mesh, repl)),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_s, batch_s, sched_s["mask"],
                                   sched_s["scale"])
        elif shape.mode == "prefill":
            specs, _ = input_specs(cfg, shape_name)
            prefill = make_prefill_step(cfg, window=window)
            b_specs = batch_specs(specs, mesh)
            jitted = jax.jit(prefill,
                             in_shardings=(_ns(mesh, p_specs),
                                           _ns(mesh, b_specs)))
            lowered = jitted.lower(params_s, specs)
        else:  # decode
            specs, _ = input_specs(cfg, shape_name)
            serve = make_serve_step(cfg, window=window)
            tok_specs = batch_specs({"tokens": specs["tokens"]}, mesh)["tokens"]
            s_specs = state_specs(specs["states"], mesh)
            in_sh = [_ns(mesh, p_specs), _ns(mesh, tok_specs),
                     _ns(mesh, s_specs), _ns(mesh, P())]
            args = [params_s, specs["tokens"], specs["states"], specs["pos"]]
            if cfg.enc_dec:
                mem_spec = batch_specs({"m": specs["memory"]}, mesh)["m"]
                in_sh.append(_ns(mesh, mem_spec))
                args.append(specs["memory"])
                serve_fn = lambda p, t, s, pos, mem: serve(p, t, s, pos,
                                                           memory=mem)
            else:
                serve_fn = serve
            # Pin output states to the INPUT cache sharding — leaving it
            # to the compiler makes GSPMD all-gather the entire KV cache
            # at step exit (measured: 69.6 GB/step on minitron decode_32k,
            # EXPERIMENTS.md §Perf hillclimb 2).
            b = specs["tokens"].shape[0]
            tok_out = batch_specs(
                {"t": jax.ShapeDtypeStruct((b,), jnp.int32)}, mesh)["t"]
            logit_out = batch_specs(
                {"l": jax.ShapeDtypeStruct((b, cfg.vocab), cfg.dtype)},
                mesh)["l"]
            jitted = jax.jit(
                serve_fn, in_shardings=tuple(in_sh),
                out_shardings=(_ns(mesh, tok_out), _ns(mesh, logit_out),
                               _ns(mesh, s_specs)),
                donate_argnums=(2,))
            lowered = jitted.lower(*args)

        compiled = lowered.compile()

    compile_s = time.time() - t0
    hlo = compiled.as_text()
    coll = roofline.parse_collective_bytes(hlo)
    cost = _cost_analysis(compiled)
    mem = _memory_analysis(compiled)
    mf = roofline.model_flops(cfg, shape_name)
    flops = cost.get("flops", 0.0)
    # Decide scope: GSPMD-partitioned modules are per-device programs.
    per_device = flops < 0.6 * mf  # heuristic recorded for transparency
    terms = roofline.roofline_terms(
        flops, cost.get("bytes accessed", 0.0), coll["total"], chips,
        per_device=per_device)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "mode": shape.mode,
        "layer_accounting": "unrolled" if unroll else "scan_body_once",
        "compile_seconds": round(compile_s, 1),
        "flops": flops,
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collectives": coll,
        "memory_analysis": mem,
        "model_flops": mf,
        "flops_scope": "per_device" if per_device else "whole_module",
        "roofline": terms,
        "useful_flops_ratio": (mf / (flops * (chips if per_device else 1))
                               if flops else None),
    }
    return record


def load_results(path=RESULTS):
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(results, path=RESULTS):
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(results, f, indent=1)


def key_of(arch, shape, mesh_name):
    return f"{arch}|{shape}|{mesh_name}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi_pod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--unroll", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--out", default=RESULTS)
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override, e.g. --set remat_policy=dots")
    args = ap.parse_args()
    unroll = {"auto": None, "on": True, "off": False}[args.unroll]
    overrides = dict(kv.split("=", 1) for kv in args.set)
    overrides = {k: _coerce(v) for k, v in overrides.items()}

    archs = arch_names() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi_pod": [True],
              "both": [False, True]}[args.mesh]

    results = load_results(args.out)
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            if not cfg.supports_shape(shape):
                print(f"SKIP  {arch} × {shape} (see DESIGN.md §4)")
                continue
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                k = key_of(arch, shape, mesh_name)
                if k in results and not args.force:
                    print(f"CACHED {k}")
                    continue
                print(f"LOWER {k} ...", flush=True)
                try:
                    rec = lower_pair(arch, shape, mp, unroll=unroll,
                                     overrides=overrides)
                    results[k] = rec
                    save_results(results, args.out)
                    r = rec["roofline"]
                    print(f"  ok in {rec['compile_seconds']}s  "
                          f"compute={r['compute_s']:.3e}s "
                          f"memory={r['memory_s']:.3e}s "
                          f"coll={r['collective_s']:.3e}s "
                          f"bottleneck={r['bottleneck']}")
                except Exception as e:  # noqa: BLE001
                    failures.append((k, repr(e)))
                    traceback.print_exc()
    if failures:
        print("\nFAILURES:")
        for k, e in failures:
            print(f"  {k}: {e}")
        raise SystemExit(1)
    print("\nall requested dry-runs compiled OK")


if __name__ == "__main__":
    main()
