"""Batched serving driver: greedy decode with KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-vl-2b \
        --reduced --batch 4 --prompt-len 16 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models import encode, init_decode_state, init_lm
from repro.models.transformer import decode_cache_len


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    k_param, k_prompt = jax.random.split(key)
    params = init_lm(k_param, cfg)

    cache_len = decode_cache_len(cfg, args.max_len)
    states = init_decode_state(cfg, args.batch, cache_len)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(2,))

    memory = None
    if cfg.enc_dec:
        memory = encode(params, cfg,
                        jnp.zeros((args.batch, cfg.enc_len, cfg.d_model),
                                  cfg.dtype))

    prompt = jax.random.randint(
        k_prompt, (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32)

    def step(tok, states, pos):
        if cfg.enc_dec:
            return serve(params, tok, states, jnp.asarray(pos), memory)
        return serve(params, tok, states, jnp.asarray(pos))

    # Prefill by sequential cache writes (teacher-forced prompt tokens).
    t0 = time.time()
    tok = prompt[:, :1]
    for pos in range(args.prompt_len):
        tok_in = prompt[:, pos:pos + 1]
        next_tok, logits, states = step(tok_in, states, pos)
    prefill_s = time.time() - t0

    out_tokens = []
    t0 = time.time()
    tok = next_tok[:, None]
    for i in range(args.new_tokens):
        next_tok, logits, states = step(tok, states, args.prompt_len + i)
        out_tokens.append(next_tok)
        tok = next_tok[:, None]
    jax.block_until_ready(next_tok)
    decode_s = time.time() - t0

    toks = jnp.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill {args.prompt_len} tok in {prefill_s:.2f}s; "
          f"decode {args.new_tokens} tok in {decode_s:.2f}s "
          f"({args.batch * args.new_tokens / decode_s:.1f} tok/s)")
    print("sample tokens:", toks[0, :16].tolist())
    return toks


if __name__ == "__main__":
    main()
