"""Data substrate: synthetic datasets, federated partitioning, batching."""

from repro.data.synthetic import (
    SyntheticImageDataset,
    SyntheticLMDataset,
    make_confusable_image_classification,
    make_image_classification,
    make_lm_tokens,
)
from repro.data.partition import (
    dirichlet_partition,
    group_label_skew_partition,
    iid_partition,
)
from repro.data.loader import ClientBatcher, GlobalBatcher

__all__ = [
    "SyntheticImageDataset", "SyntheticLMDataset",
    "make_image_classification", "make_lm_tokens",
    "iid_partition", "dirichlet_partition", "group_label_skew_partition",
    "ClientBatcher", "GlobalBatcher",
]
