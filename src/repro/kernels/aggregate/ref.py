"""Pure-jnp oracle for the masked/scaled aggregation kernel."""

import jax.numpy as jnp


def masked_scaled_aggregate_ref(g, w):
    """g: (N, P); w: (N,) -> (P,)."""
    return jnp.einsum("n,np->p", w.astype(jnp.float32),
                      g.astype(jnp.float32)).astype(g.dtype)
