"""Energy-arrival processes (paper §II-B), as registered JAX pytrees.

Each process models ``E_i^t`` — whether client ``i`` harvests a unit of
energy at step ``t`` — for ``n_clients`` clients, vectorized and
scan-friendly so the whole training loop can live under ``jax.jit`` /
``jax.lax.scan``.

Every process is a ``jax.tree_util.register_dataclass`` pytree: its
array-valued hyperparameters (the schedule/gap tables, β_i, T_i) are
*leaves*, so a process can cross ``jit`` / ``vmap`` boundaries as an
ordinary argument, and a whole family of processes (e.g. one per
scenario in a sweep) can be stacked leaf-wise and executed by a single
compiled computation (see :mod:`repro.experiments`). Shapes are static
metadata by construction — ``n_clients`` / ``horizon`` derive from leaf
shapes, which jax specializes on. Registration rules are documented in
DESIGN.md §3.

Protocol (structural; all methods pure):

    init(key)              -> state                     (pytree)
    arrivals(state, t, key)-> (state, Arrivals)
    expected_participation() -> (N,) long-run participation probability

``Arrivals`` carries:
    energy : (N,) float32 in {0,1}   -- E_i^t
    gap    : (N,) float32            -- T_i^t for deterministic arrivals
                                        (gap between the arrival at/most
                                        recently before t and the next one);
                                        for stochastic processes, the
                                        *nominal* scaling constant γ_i
                                        (1/β_i binary, T_i uniform).

Four concrete processes — three mirroring the paper exactly, one
beyond-paper non-stationary family:

* ``DeterministicArrivals`` — arrival times known in advance (paper
  §II-B-1). Built from an explicit (N, horizon) 0/1 schedule or from
  per-client periods via :meth:`DeterministicArrivals.periodic`.
* ``BinaryArrivals`` — E_i^t ~ Bern(β_i) iid per step (paper eq. 9).
* ``UniformArrivals`` — exactly one arrival per window of length T_i,
  uniformly placed within the window (paper §II-B-2, "Uniform Arrivals").
* ``DayNightArrivals`` — non-stationary Bernoulli with a periodic
  day/night rate profile β_i(t) (cf. Sustainable Federated Learning,
  arXiv:2102.11274): solar-harvesting devices cycle between a high
  daytime rate and a low nighttime rate.

The module also owns the **arrival-family registry**
(:func:`register_arrival_family` / :func:`make_arrivals`): every family
is constructible by name from the paper-§V per-client period vector τ,
so sweeps over arrival statistics hold the mean energy rate fixed.
:mod:`repro.experiments` builds its ``arrivals`` sweep axis from this
registry.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Arrivals(NamedTuple):
    """Per-step arrival information for all clients."""

    energy: jax.Array  # (N,) float32 in {0, 1}
    gap: jax.Array     # (N,) float32 — T_i^t (det.) or γ_i (stochastic)


#: Paper §V experimental profile: 4 client groups with periods (1, 5, 10, 20).
PAPER_TAUS = (1, 5, 10, 20)


def default_taus(n_clients: int) -> np.ndarray:
    """Paper §V grouping generalized to N clients: client i ∈ group i mod 4."""
    return np.array([PAPER_TAUS[i % len(PAPER_TAUS)] for i in range(n_clients)])


def _concrete(x):
    """``x`` as a host ndarray if it holds concrete values, else None.

    Pytree unflattening re-invokes the dataclass constructor — sometimes
    with tracers (under jit/vmap) or opaque placeholder objects (during
    tree-structure manipulation) — so ``__post_init__`` validation must
    only fire on concrete inputs (DESIGN.md §3).
    """
    if isinstance(x, jax.core.Tracer):
        return None
    try:
        return np.asarray(x, np.float64)
    except (TypeError, ValueError):
        return None


# ------------------------------------------------- client-axis sharding

class ClientShard(NamedTuple):
    """Trace-time description of an active client-axis sharding context.

    axis_name : the mesh axis the client dimension is sharded over.
    shards    : number of devices along that axis (static).
    reduction : "gather" (all_gather rows, replicate the exact unsharded
                reduction — bit-for-bit), "psum[_bf16]" (local partial
                reduction + (P,) collective — bandwidth-optimal, float32
                reassociation tolerance), or "fused[_bf16]" (psum wiring
                plus the SGD parameter update fused into the local
                kernel launch). ``_bf16`` quantizes the collective's
                payload to bf16-on-the-wire with f32 accumulation.
                DESIGN.md §8–9.
    """

    axis_name: str
    shards: int
    reduction: str = "gather"


_CLIENT_SHARD: list[ClientShard] = []


@contextlib.contextmanager
def client_sharding(axis_name: str, shards: int, reduction: str = "gather"):
    """Activate a client-axis sharding context for the enclosed trace.

    Inside the context every per-client draw (:func:`client_keys` and
    friends) folds in the *global* client index — shard-local row ``i``
    becomes ``axis_index(axis_name)·n_local + i`` — and population-global
    reductions (:func:`population_min`; the aggregation reduce +
    weight_sum via :func:`repro.core.aggregation.
    reduce_flat_client_sharded`) become collectives over ``axis_name``.
    The context is consulted at trace time only; compiled executables
    bake the collectives in.
    """
    # Validate against the shared grammar (lazy import: aggregation
    # imports this module back for client_shard()).
    from repro.core.aggregation import parse_reduction

    parse_reduction(reduction)
    _CLIENT_SHARD.append(ClientShard(axis_name, int(shards), reduction))
    try:
        yield
    finally:
        _CLIENT_SHARD.pop()


def client_shard() -> ClientShard | None:
    """The innermost active client-sharding context, or None."""
    return _CLIENT_SHARD[-1] if _CLIENT_SHARD else None


def _client_offset(n_local: int):
    """Global index of this shard's row 0 (0 when unsharded)."""
    shard = client_shard()
    if shard is None:
        return 0
    return jax.lax.axis_index(shard.axis_name) * n_local


def population_min(x: jax.Array) -> jax.Array:
    """min over the client axis — exact (min is associative), so the
    sharded value is bitwise the unsharded one."""
    m = jnp.min(x)
    shard = client_shard()
    if shard is None:
        return m
    return jax.lax.pmin(m, shard.axis_name)


def client_keys(key, n_clients: int) -> jax.Array:
    """(N, key) array of per-client keys via ``fold_in`` on the client index.

    The derived keys depend only on ``(key, i)`` — *not* on ``n_clients`` —
    unlike ``jax.random.split(key, n)`` or a single shaped draw
    ``jax.random.uniform(key, (n,))``, whose bits change with ``n``
    (threefry pairs counters by half-length). This shape independence is
    what makes ragged-population padding bit-exact: client ``i`` of a
    padded N_max-wide run draws the same randomness as client ``i`` of
    the natural-N run (DESIGN.md §7).

    Under an active :func:`client_sharding` context the folded index is
    the *global* one (shard offset + local row), so shard-local row
    ``i`` of a client-sharded run draws exactly the bits global client
    ``offset + i`` draws in the unsharded run (DESIGN.md §8).
    """
    idx = _client_offset(n_clients) + jnp.arange(n_clients)
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)


def client_uniform(key, n_clients: int) -> jax.Array:
    """(N,) iid U[0,1) draws, one per client, shape-independent per row."""
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(
        client_keys(key, n_clients))


def client_randint(key, n_clients: int, maxval) -> jax.Array:
    """(N,) iid U{0,…,maxval_i−1} draws, shape-independent per row.

    ``maxval`` may be a scalar or an (N,) per-client bound (≥ 1).
    Implemented as ``floor(u · maxval)`` — exact for integer bounds well
    below 2^24 (the paper's periods are tiny) and uniform per client.
    """
    maxval = jnp.asarray(maxval)
    u = client_uniform(key, n_clients)
    draw = jnp.floor(u * maxval.astype(jnp.float32)).astype(jnp.int32)
    return jnp.minimum(draw, maxval.astype(jnp.int32) - 1)


def _pad_leaf(x, pad: int, value, axis: int = 0):
    """Append ``pad`` rows of ``value`` along ``axis``."""
    if pad == 0:
        return x
    shape = list(x.shape)
    shape[axis] = pad
    return jnp.concatenate(
        [jnp.asarray(x), jnp.full(shape, value, x.dtype)], axis=axis)


def _check_pad(n_clients: int, n_total: int) -> int:
    pad = int(n_total) - int(n_clients)
    if pad < 0:
        raise ValueError(
            f"cannot pad {n_clients} clients down to {n_total}")
    return pad


def _gap_table(schedule: np.ndarray) -> np.ndarray:
    """Vectorized T[i, t] = Ī_i^t − I_i^t over an (N, H) 0/1 schedule.

    For each arrival at t0 with next arrival t1 (horizon if none),
    T[i, t] = t1 − t0 on t ∈ [t0, t1); 0 before the first arrival.
    """
    n, h = schedule.shape
    arr = schedule > 0
    idx = np.arange(h)[None, :]
    # I_i^t: most recent arrival at or before t (−1: none yet).
    last = np.maximum.accumulate(np.where(arr, idx, -1), axis=1)
    # First arrival at or after t (h: none); padded at index h so the
    # lookup below stays in-bounds for the final interval.
    next_ge = np.minimum.accumulate(np.where(arr, idx, h)[:, ::-1], axis=1)[:, ::-1]
    next_ge = np.concatenate([next_ge, np.full((n, 1), h)], axis=1)
    ibar = np.take_along_axis(next_ge, np.clip(last + 1, 0, h), axis=1)
    return np.where(last >= 0, ibar - last, 0).astype(np.float32)


@dataclasses.dataclass(eq=False)
class DeterministicArrivals:
    """Deterministic energy arrivals known in advance (paper §II-B-1).

    Parameters
    ----------
    schedule : (N, horizon) 0/1 array of arrival indicators. Arrival times
        for client i are ``I_i = {t : schedule[i, t] == 1}``.
    gaps : precomputed gap table; leave as None (the default) and it is
        derived from ``schedule`` on the host — the schedule is known in
        advance by assumption. Pytree unflattening supplies both leaves,
        so no recomputation happens across jit/vmap boundaries.

    The gap table ``T[i, t] = Ī_i^t − I_i^t`` is what Algorithm 1 uses. At
    an arrival time ``t`` this is the distance to the next arrival; the
    final interval is truncated at the horizon so the run stays
    self-contained (and the scheme stays unbiased within the run). Steps
    before a client's first arrival have gap 0 (the client cannot
    participate yet).
    """

    schedule: jax.Array        # (N, horizon) float32 in {0, 1} — leaf
    gaps: jax.Array = None     # (N, horizon) float32 — leaf

    def __post_init__(self):
        if self.gaps is None:
            schedule = np.asarray(self.schedule)
            if schedule.ndim != 2:
                raise ValueError(
                    f"schedule must be (N, horizon), got {schedule.shape}")
            sched01 = (schedule != 0).astype(np.float32)
            self.gaps = jnp.asarray(_gap_table(sched01))
            self.schedule = jnp.asarray(sched01)

    @property
    def n_clients(self) -> int:
        return self.schedule.shape[-2]

    @property
    def horizon(self) -> int:
        return self.schedule.shape[-1]

    @classmethod
    def periodic(cls, taus, horizon: int, offsets=None) -> "DeterministicArrivals":
        """Paper's experimental profile (eq. 37): arrivals at ``t ≡ off (mod τ_i)``."""
        taus = np.asarray(taus, dtype=np.int64)
        if offsets is None:
            offsets = np.zeros_like(taus)
        offsets = np.asarray(offsets, dtype=np.int64)
        t = np.arange(horizon)[None, :]
        sched = ((t - offsets[:, None]) % taus[:, None] == 0) & (t >= offsets[:, None])
        return cls(sched.astype(np.float32))

    def init(self, key):
        del key
        return ()

    def arrivals(self, state, t, key):
        del key
        t = jnp.asarray(t, jnp.int32)
        # Past the precomputed horizon there are no further arrivals.
        tc = jnp.clip(t, 0, self.horizon - 1)
        valid = (t < self.horizon).astype(jnp.float32)
        energy = self.schedule[:, tc] * valid
        gap = self.gaps[:, tc] * valid
        return state, Arrivals(energy=energy, gap=gap)

    def expected_participation(self) -> jax.Array:
        # Trailing (horizon) axis so stacked (S, N, H) instances batch too.
        return jnp.mean(self.schedule, axis=-1)

    def pad_clients(self, n_total: int) -> "DeterministicArrivals":
        """Same process over ``n_total`` client rows; padded rows never
        harvest (all-zero schedule ⇒ gap 0 ⇒ cannot participate)."""
        pad = _check_pad(self.n_clients, n_total)
        return DeterministicArrivals(
            schedule=_pad_leaf(self.schedule, pad, 0.0),
            gaps=_pad_leaf(self.gaps, pad, 0.0))


@dataclasses.dataclass(eq=False)
class BinaryArrivals:
    """E_i^t ~ Bern(β_i), iid across steps and clients (paper eq. 9).

    Requires β_i ∈ (0, 1]: the unbiased scaling γ_i = 1/β_i (Alg. 2 /
    Corollary 1) is infinite for β_i = 0 — a client that never harvests
    cannot be scheduled — so zero/negative rates are rejected at
    construction rather than silently producing ``inf`` scales.
    """

    betas: jax.Array  # (N,) float32 — leaf

    def __post_init__(self):
        betas = _concrete(self.betas)
        if betas is not None:
            if betas.ndim < 1:
                raise ValueError(f"betas must be (N,), got {betas.shape}")
            if betas.size and not (np.all(np.isfinite(betas))
                                   and np.all(betas > 0.0)
                                   and np.all(betas <= 1.0)):
                raise ValueError(
                    "BinaryArrivals requires finite betas in (0, 1]; got "
                    f"min={betas.min():g}, max={betas.max():g} "
                    "(β_i = 0 would make the 1/β_i scaling infinite)")
            self.betas = jnp.asarray(betas, jnp.float32)

    @property
    def n_clients(self) -> int:
        # Trailing axis so stacked (scenario-batched) instances resolve too.
        return self.betas.shape[-1]

    def init(self, key):
        del key
        return ()

    def arrivals(self, state, t, key):
        del t
        u = client_uniform(key, self.n_clients)
        energy = (u < self.betas).astype(jnp.float32)
        gap = 1.0 / self.betas  # γ_i = 1/β_i (Alg. 2 / Corollary 1)
        return state, Arrivals(energy=energy, gap=gap)

    def expected_participation(self) -> jax.Array:
        return self.betas

    def pad_clients(self, n_total: int) -> "BinaryArrivals":
        """Padded rows get β = 1 (a *valid* rate — no inf scales); their
        draws are masked out by the scheduler/aggregation layers."""
        pad = _check_pad(self.n_clients, n_total)
        return BinaryArrivals(betas=_pad_leaf(self.betas, pad, 1.0))


class UniformArrivalsState(NamedTuple):
    offset: jax.Array  # (N,) int32 — arrival position inside current window


@dataclasses.dataclass(eq=False)
class UniformArrivals:
    """One arrival per window of length T_i, uniformly placed (paper §II-B-2).

    For every t with ``t mod T_i == 0`` a fresh offset ``U{0,…,T_i−1}`` is
    drawn; the client receives energy when ``t mod T_i == offset``. Windows
    are per-client, so clients with different ``T_i`` roll over at
    different times.
    """

    periods: jax.Array  # (N,) int32 — leaf

    def __post_init__(self):
        periods = _concrete(self.periods)
        if periods is not None:
            if periods.ndim < 1:
                raise ValueError(f"periods must be (N,), got {periods.shape}")
            if periods.size and not (np.all(np.isfinite(periods))
                                     and np.all(periods >= 1)):
                raise ValueError(
                    "UniformArrivals requires finite periods >= 1; "
                    f"got min={periods.min():g}")
            self.periods = jnp.asarray(periods, jnp.int32)

    @property
    def n_clients(self) -> int:
        return self.periods.shape[-1]

    def init(self, key):
        # Offsets for the first window (the t=0 step rolls them anyway if
        # t mod T == 0, which it is; keep a valid placeholder).
        offset = client_randint(key, self.n_clients, self.periods)
        return UniformArrivalsState(offset=offset.astype(jnp.int32))

    def arrivals(self, state, t, key):
        t = jnp.asarray(t, jnp.int32)
        pos = t % self.periods
        fresh = client_randint(key, self.n_clients, self.periods)
        offset = jnp.where(pos == 0, fresh.astype(jnp.int32), state.offset)
        energy = (pos == offset).astype(jnp.float32)
        gap = self.periods.astype(jnp.float32)  # γ_i = T_i (Corollary 1)
        return UniformArrivalsState(offset=offset), Arrivals(energy=energy, gap=gap)

    def expected_participation(self) -> jax.Array:
        return 1.0 / self.periods.astype(jnp.float32)

    def pad_clients(self, n_total: int) -> "UniformArrivals":
        """Padded rows get period 1 (valid; arrives every step) — masked
        out downstream."""
        pad = _check_pad(self.n_clients, n_total)
        return UniformArrivals(periods=_pad_leaf(self.periods, pad, 1))


@dataclasses.dataclass(eq=False)
class DayNightArrivals:
    """Non-stationary Bernoulli arrivals with a periodic day/night β_t.

    E_i^t ~ Bern(β_i(t)) where β_i(t) = ``betas_day[i]`` for the first
    ``day_steps`` steps of every ``period``-step cycle and
    ``betas_night[i]`` for the remainder — the solar-harvesting regime
    (cf. arXiv:2102.11274) where devices charge fast in daylight and
    slowly (but not zero: a device may still scavenge) at night.

    The unbiasedness scale is the *instantaneous* inverse rate
    γ_i(t) = 1/β_i(t): a best-effort scheduler that scales by it stays
    unbiased step-by-step even though the process is non-stationary.

    All four hyperparameters are pytree leaves, so a sweep over periods,
    phases of the day, or rate contrasts is one leaf-stacked batch of
    processes (a single compiled computation per scheduler structure).
    """

    betas_day: jax.Array    # (N,) float32 in (0, 1] — leaf
    betas_night: jax.Array  # (N,) float32 in (0, 1] — leaf
    period: jax.Array       # () int32, full day/night cycle length — leaf
    day_steps: jax.Array = None  # () int32, day length; None → period // 2

    def __post_init__(self):
        period = _concrete(self.period)
        if self.day_steps is None:
            if period is None:
                raise ValueError(
                    "day_steps=None needs a concrete period to derive from")
            self.day_steps = jnp.asarray(int(period) // 2, jnp.int32)
        day_steps = _concrete(self.day_steps)
        if period is not None and day_steps is not None:
            if not (np.all(period >= 1) and np.all(day_steps >= 0)
                    and np.all(day_steps <= period)):
                raise ValueError(
                    f"need 0 <= day_steps <= period and period >= 1; got "
                    f"period={period}, day_steps={day_steps}")
            self.period = jnp.asarray(period, jnp.int32)
            self.day_steps = jnp.asarray(day_steps, jnp.int32)
        for name in ("betas_day", "betas_night"):
            betas = _concrete(getattr(self, name))
            if betas is None:
                continue
            if betas.ndim < 1:
                raise ValueError(f"{name} must be (N,), got {betas.shape}")
            if betas.size and not (np.all(np.isfinite(betas))
                                   and np.all(betas > 0.0)
                                   and np.all(betas <= 1.0)):
                raise ValueError(
                    f"DayNightArrivals requires finite {name} in (0, 1]; got "
                    f"min={betas.min():g}, max={betas.max():g}")
            setattr(self, name, jnp.asarray(betas, jnp.float32))

    @property
    def n_clients(self) -> int:
        return self.betas_day.shape[-1]

    @classmethod
    def from_taus(cls, taus, period: int = 50, day_frac: float = 0.5,
                  contrast: float = 3.0) -> "DayNightArrivals":
        """Day/night profile with the paper's mean rate held at 1/τ_i.

        ``contrast`` is the day:night rate ratio. Solving
        f·β_day + (1−f)·β_night = 1/τ with β_day = contrast·β_night
        (f = the realized day fraction after rounding to whole steps);
        when that puts β_day above 1 it is clamped and β_night re-solved
        so the mean rate stays exactly 1/τ (the τ=1 always-on client
        degenerates to β_day = β_night = 1).
        """
        taus = np.asarray(taus, np.float64)
        if period < 2:
            raise ValueError(f"period must be >= 2, got {period}")
        if not 0.0 < day_frac < 1.0:
            raise ValueError(f"day_frac must be in (0, 1), got {day_frac}")
        if contrast < 1.0:
            raise ValueError(f"contrast must be >= 1, got {contrast}")
        day_steps = int(np.clip(round(day_frac * period), 1, period - 1))
        f = day_steps / period
        night = 1.0 / (taus * (f * contrast + (1.0 - f)))
        day = contrast * night
        clamped = day > 1.0
        day = np.where(clamped, 1.0, day)
        night = np.where(clamped, (1.0 / taus - f) / (1.0 - f), night)
        if np.any(night <= 0.0):
            raise ValueError(
                f"mean rate 1/τ below day fraction {f:g} for τ="
                f"{taus[np.asarray(night) <= 0]}; lower day_frac or contrast")
        return cls(betas_day=day.astype(np.float32),
                   betas_night=night.astype(np.float32),
                   period=period, day_steps=day_steps)

    def _beta_t(self, t) -> jax.Array:
        pos = jnp.asarray(t, jnp.int32) % self.period
        is_day = pos < self.day_steps
        return jnp.where(is_day, self.betas_day, self.betas_night)

    def init(self, key):
        del key
        return ()

    def arrivals(self, state, t, key):
        beta = self._beta_t(t)
        u = client_uniform(key, self.n_clients)
        energy = (u < beta).astype(jnp.float32)
        gap = 1.0 / beta  # γ_i(t) = 1/β_i(t), the instantaneous scale
        return state, Arrivals(energy=energy, gap=gap)

    def expected_participation(self) -> jax.Array:
        p = self.period.astype(jnp.float32)[..., None]
        d = self.day_steps.astype(jnp.float32)[..., None]
        return (d * self.betas_day + (p - d) * self.betas_night) / p

    def pad_clients(self, n_total: int) -> "DayNightArrivals":
        pad = _check_pad(self.n_clients, n_total)
        return DayNightArrivals(
            betas_day=_pad_leaf(self.betas_day, pad, 1.0),
            betas_night=_pad_leaf(self.betas_night, pad, 1.0),
            period=self.period, day_steps=self.day_steps)


jax.tree_util.register_dataclass(
    DeterministicArrivals, data_fields=["schedule", "gaps"], meta_fields=[])
jax.tree_util.register_dataclass(
    BinaryArrivals, data_fields=["betas"], meta_fields=[])
jax.tree_util.register_dataclass(
    UniformArrivals, data_fields=["periods"], meta_fields=[])
jax.tree_util.register_dataclass(
    DayNightArrivals,
    data_fields=["betas_day", "betas_night", "period", "day_steps"],
    meta_fields=[])


_ARRIVAL_FAMILIES: dict = {}


def register_arrival_family(name: str):
    """Decorator: register a named arrival-family factory.

    A factory has signature ``(n_clients, horizon, taus, **kw) ->
    process`` where ``taus`` is the per-client period vector that every
    family interprets so a kind-sweep holds the mean energy rate 1/τ_i
    fixed. :func:`make_arrivals` dispatches by name; the experiment
    layer's ``arrivals`` sweep axis is built from this registry.
    """

    def deco(fn):
        _ARRIVAL_FAMILIES[name] = fn
        return fn

    return deco


def arrival_family_names() -> list[str]:
    return sorted(_ARRIVAL_FAMILIES)


def make_arrivals(kind: str, n_clients: int, horizon: int, taus=None, **kw):
    """Arrival-process factory: paper §V profile, generalized to N clients
    by cycling the group periods (client i ∈ group i mod 4) unless an
    explicit per-client ``taus`` vector is given.

    The same τ parameterizes every family so sweeps hold the mean energy
    rate fixed: ``periodic`` arrivals every τ_i steps, ``binary``
    Bern(1/τ_i), ``uniform`` one arrival per τ_i-window, and
    ``day_night`` a periodic β_i(t) profile averaging 1/τ_i.
    """
    try:
        factory = _ARRIVAL_FAMILIES[kind]
    except KeyError:
        raise ValueError(
            f"unknown arrival kind {kind!r}; have {arrival_family_names()}"
        ) from None
    taus = default_taus(n_clients) if taus is None else np.asarray(taus)
    return factory(n_clients, horizon, taus, **kw)


@register_arrival_family("periodic")
def _periodic(n_clients, horizon, taus, **kw):
    return DeterministicArrivals.periodic(taus, horizon, **kw)


@register_arrival_family("binary")
def _binary(n_clients, horizon, taus, **kw):
    del horizon
    if kw:
        raise TypeError(f"binary arrivals take no extra kwargs; got {sorted(kw)}")
    return BinaryArrivals(1.0 / taus)


@register_arrival_family("uniform")
def _uniform(n_clients, horizon, taus, **kw):
    del horizon
    if kw:
        raise TypeError(f"uniform arrivals take no extra kwargs; got {sorted(kw)}")
    return UniformArrivals(taus)


@register_arrival_family("day_night")
def _day_night(n_clients, horizon, taus, **kw):
    del horizon
    return DayNightArrivals.from_taus(taus, **kw)


def pad_arrivals(process, n_total: int):
    """Pad a process's per-client leaves to ``n_total`` rows (protocol
    dispatch to ``pad_clients``). Padded rows carry *valid* neutral
    hyperparameters (β=1, period=1, empty schedule) so no inf/NaN ever
    enters the compiled computation; the scheduler/aggregation layers
    mask them out of participation and gradient mass (DESIGN.md §7)."""
    try:
        method = process.pad_clients
    except AttributeError:
        raise TypeError(
            f"{type(process)!r} does not implement pad_clients(); ragged "
            "client populations need every arrival family to define its "
            "padding rule") from None
    return method(n_total)


def expected_participation(process) -> jax.Array:
    """Long-run participation probability per client under best-effort.

    Delegates to the process's protocol method — any object implementing
    ``expected_participation()`` works; no type dispatch.

    Used by tests and by the theory module (Corollary 1 constants).
    """
    try:
        method = process.expected_participation
    except AttributeError:
        raise TypeError(
            f"{type(process)!r} does not implement the energy-process "
            "protocol (missing expected_participation())") from None
    return method()
