"""Pallas TPU kernel: masked/scaled client-gradient aggregation.

The server update (paper eq. 11/12) reduces N client gradients with
weights ω_i = p_i·mask_i·scale_i:

    out[p] = Σ_n ω[n] · g[n, p]

i.e. a (1,N)×(N,P) matvec — tall-skinny, memory-bound. The TPU-native
layout: tile the parameter axis into lane-aligned blocks resident in
VMEM; the client axis (N ≤ a few thousand) rides the sublane dimension in
full so each grid step is a single MXU matvec over an (N, bp) tile. The
weight vector is tiny and replicated to every grid step.

Grid: (P // bp,). VMEM per step: N·bp·itemsize + bp·4 — with N=1024,
bp=2048, f32: 8 MB, comfortably inside VMEM; ops.py shrinks bp for larger
N. FLOPs 2·N·P, bytes ≈ N·P·itemsize ⇒ arithmetic intensity ~2/itemsize:
firmly memory-bound, so the win vs. a naive XLA reduce chain is avoiding
the (N,P)→(P,) reduction materializing intermediates in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(w_ref, g_ref, o_ref):
    # w: (1, N) f32; g: (N, bp); o: (1, bp)
    g = g_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(w_ref[...], g,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _agg_kernel_masked(w_ref, m_ref, g_ref, o_ref):
    # w, m: (1, N) f32; g: (N, bp); o: (1, bp).  The mask is a row
    # *select*, not a multiplicand: masked rows are replaced by zeros
    # before the matvec, so a padded client contributes exactly 0 even
    # when its gradient row is inf/NaN garbage (0·inf would be NaN).
    g = g_ref[...].astype(jnp.float32)
    g = jnp.where(m_ref[...].T > 0, g, 0.0)
    o_ref[...] = jnp.dot(w_ref[...], g,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_p", "interpret", "out_dtype"))
def masked_scaled_aggregate_kernel(g, w, mask=None, *, block_p: int = 2048,
                                   interpret: bool = False, out_dtype=None):
    """g: (N, P); w: (N,) -> (P,) = w @ g.

    P is padded to a multiple of ``block_p`` internally — one padding of
    the whole flat buffer, which is why the flat aggregation path
    (DESIGN.md §5) ravels the gradient pytree *before* calling in rather
    than launching per leaf. ``out_dtype`` overrides the output dtype
    (the in-kernel accumulation is f32 regardless), e.g. f32 server
    aggregates from bf16 client gradients. ``mask`` is an optional (N,)
    0/1 active-row operand (ragged populations, DESIGN.md §7): masked
    rows are zero-selected inside the tile before the MXU matvec, so
    they contribute exact zeros regardless of their contents; without a
    mask the two-operand program is unchanged.
    """
    n, p = g.shape
    bp = min(block_p, p)
    pad = (-p) % bp
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
    pp = p + pad
    out_shape = jax.ShapeDtypeStruct(
        (1, pp), jnp.dtype(out_dtype) if out_dtype is not None else g.dtype)
    w_op = w.reshape(1, n).astype(jnp.float32)
    vec_spec = pl.BlockSpec((1, n), lambda i: (0, 0))
    g_spec = pl.BlockSpec((n, bp), lambda i: (0, i))
    o_spec = pl.BlockSpec((1, bp), lambda i: (0, i))
    if mask is None:
        out = pl.pallas_call(
            _agg_kernel,
            grid=(pp // bp,),
            in_specs=[vec_spec, g_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(w_op, g)
    else:
        m_op = mask.reshape(1, n).astype(jnp.float32)
        out = pl.pallas_call(
            _agg_kernel_masked,
            grid=(pp // bp,),
            in_specs=[vec_spec, vec_spec, g_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(w_op, m_op, g)
    return out[0, :p]
