"""Energy-arrival process tests (paper §II-B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.energy import (
    BinaryArrivals,
    DayNightArrivals,
    DeterministicArrivals,
    UniformArrivals,
    arrival_family_names,
    expected_participation,
    make_arrivals,
)


def collect(process, horizon, seed=0):
    key = jax.random.PRNGKey(seed)
    state = process.init(key)

    def body(carry, t):
        state, key = carry
        key, k = jax.random.split(key)
        state, arr = process.arrivals(state, t, k)
        return (state, key), (arr.energy, arr.gap)

    (_, _), (energy, gap) = jax.lax.scan(
        body, (state, key), jnp.arange(horizon))
    return np.asarray(energy), np.asarray(gap)  # (T, N)


def test_periodic_schedule_matches_eq37():
    taus = [1, 5, 10, 20]
    det = DeterministicArrivals.periodic(taus, horizon=100)
    energy, gap = collect(det, 100)
    for i, tau in enumerate(taus):
        expect = (np.arange(100) % tau == 0).astype(np.float32)
        np.testing.assert_array_equal(energy[:, i], expect)
        # T_i^t equals tau everywhere inside the horizon interior
        assert np.all(gap[: 100 - tau, i] == tau)


def test_gap_table_irregular_schedule():
    sched = np.zeros((1, 12))
    sched[0, [2, 5, 11]] = 1  # gaps: 3 (t∈[2,5)), 6 (t∈[5,11)), 1 (t=11)
    det = DeterministicArrivals(sched)
    _, gap = collect(det, 12)
    assert gap[2, 0] == 3 and gap[4, 0] == 3
    assert gap[5, 0] == 6 and gap[10, 0] == 6
    assert gap[11, 0] == 1  # truncated at horizon
    assert np.all(gap[:2, 0] == 0)  # before first arrival


def test_binary_arrival_rate():
    betas = jnp.asarray([0.1, 0.5, 0.9])
    proc = BinaryArrivals(betas)
    energy, gap = collect(proc, 4000)
    np.testing.assert_allclose(energy.mean(0), betas, atol=0.03)
    np.testing.assert_allclose(gap[0], 1.0 / np.asarray(betas), rtol=1e-6)


def test_uniform_exactly_one_arrival_per_window():
    periods = np.array([4, 7])
    proc = UniformArrivals(periods)
    energy, gap = collect(proc, 28 * 10)
    for i, t in enumerate(periods):
        per_window = energy[: (280 // t) * t, i].reshape(-1, t).sum(1)
        np.testing.assert_array_equal(per_window, 1.0)
        assert np.all(gap[:, i] == t)


def test_uniform_offset_is_uniform():
    proc = UniformArrivals(np.array([8]))
    energy, _ = collect(proc, 8 * 500, seed=3)
    hist = energy[:, 0].reshape(-1, 8).sum(0)
    # each in-window slot hit ~500/8 = 62.5 times
    assert hist.sum() == 500
    assert hist.min() > 30 and hist.max() < 95


def test_expected_participation():
    det = DeterministicArrivals.periodic([2, 4], horizon=100)
    np.testing.assert_allclose(expected_participation(det), [0.5, 0.25])
    np.testing.assert_allclose(
        expected_participation(BinaryArrivals([0.3])), [0.3])
    np.testing.assert_allclose(
        expected_participation(UniformArrivals([5])), [0.2])


def test_past_horizon_no_arrivals():
    det = DeterministicArrivals.periodic([2], horizon=10)
    _, arr = det.arrivals((), jnp.asarray(50), None)
    assert float(arr.energy[0]) == 0.0 and float(arr.gap[0]) == 0.0


def test_binary_rejects_nonpositive_beta():
    """Regression: β_i = 0 used to silently produce gap = 1/β = inf."""
    with pytest.raises(ValueError, match="0, 1"):
        BinaryArrivals([0.5, 0.0])
    with pytest.raises(ValueError):
        BinaryArrivals([-0.1])
    with pytest.raises(ValueError):
        BinaryArrivals([1.5])
    with pytest.raises(ValueError):
        BinaryArrivals(np.zeros((3,)))
    with pytest.raises(ValueError):  # NaN must not slip through the range check
        BinaryArrivals([0.5, np.nan])


def test_uniform_rejects_nonpositive_period():
    with pytest.raises(ValueError):
        UniformArrivals([4, 0])
    with pytest.raises(ValueError):
        UniformArrivals([4.0, np.nan])


def test_day_night_rate_profile():
    """β_i(t) follows the day/night square wave; realized day and night
    rates bracket the mean, which stays at the paper's 1/τ_i."""
    taus = [1, 5, 10, 20]
    dn = DayNightArrivals.from_taus(taus, period=50, day_frac=0.5,
                                    contrast=3.0)
    np.testing.assert_allclose(expected_participation(dn),
                               [1.0, 0.2, 0.1, 0.05], rtol=1e-6)
    energy, gap = collect(dn, 50 * 120, seed=1)
    e = energy.reshape(-1, 50, len(taus))
    day, night = e[:, :25].mean((0, 1)), e[:, 25:].mean((0, 1))
    np.testing.assert_allclose(energy.mean(0), 1.0 / np.asarray(taus),
                               atol=0.02)
    np.testing.assert_allclose(day, np.asarray(dn.betas_day), atol=0.03)
    np.testing.assert_allclose(night, np.asarray(dn.betas_night), atol=0.03)
    assert np.all(np.asarray(dn.betas_day)[1:]
                  > np.asarray(dn.betas_night)[1:])
    # γ(t) is the instantaneous inverse rate, switching with the phase
    np.testing.assert_allclose(gap[0], 1.0 / np.asarray(dn.betas_day),
                               rtol=1e-6)
    np.testing.assert_allclose(gap[25], 1.0 / np.asarray(dn.betas_night),
                               rtol=1e-6)


def test_day_night_validation():
    with pytest.raises(ValueError, match="0, 1"):
        DayNightArrivals([0.5, 0.0], [0.1, 0.1], period=10)
    with pytest.raises(ValueError, match="day_steps"):
        DayNightArrivals([0.5], [0.1], period=10, day_steps=11)
    with pytest.raises(ValueError, match="period"):
        DayNightArrivals.from_taus([2], period=1)
    with pytest.raises(ValueError, match="day_frac"):
        DayNightArrivals.from_taus([2], day_frac=1.5)
    with pytest.raises(ValueError, match="contrast"):
        DayNightArrivals.from_taus([2], contrast=0.5)


def test_arrival_family_registry():
    assert {"periodic", "binary", "uniform", "day_night"} \
        <= set(arrival_family_names())
    dn = make_arrivals("day_night", 4, 100, period=20)
    assert type(dn) is DayNightArrivals
    assert int(dn.period) == 20
    np.testing.assert_allclose(expected_participation(dn),
                               [1.0, 0.2, 0.1, 0.05], rtol=1e-6)
    with pytest.raises(ValueError, match="unknown arrival kind"):
        make_arrivals("lunar", 4, 100)
    with pytest.raises(TypeError, match="no extra kwargs"):
        make_arrivals("binary", 4, 100, period=20)


def test_gap_table_vectorized_matches_reference():
    """The vectorized gap-table builder vs the obvious double loop."""
    rng = np.random.default_rng(0)
    sched = (rng.random((7, 50)) < 0.2).astype(np.float32)
    sched[3] = 0.0  # a client with no arrivals at all
    det = DeterministicArrivals(sched)

    ref = np.zeros_like(sched)
    for i in range(sched.shape[0]):
        ts = np.flatnonzero(sched[i])
        for k, t0 in enumerate(ts):
            t1 = ts[k + 1] if k + 1 < len(ts) else sched.shape[1]
            ref[i, t0:t1] = t1 - t0
    np.testing.assert_array_equal(np.asarray(det.gaps), ref)
