"""Language-model stacks: init / forward / loss / decode for every arch.

The stack is declared by ``cfg.resolved_superblock`` — an ordered tuple of
``(block_kind, count, shared)`` segments repeated ``cfg.n_super`` times —
and executed with ``jax.lax.scan`` over both the super-block axis and the
per-segment layer axis, so the lowered HLO is O(1) in depth (critical for
compiling 62-layer configs on the dry-run host). Shared segments (zamba2's
shared attention block) keep ONE parameter set reused every super-block,
while their decode state (KV cache) is still per-invocation.

Public entry points:
  init_lm / forward / per_example_loss      — training & prefill
  init_decode_state / decode_step           — serving (1 token, KV cache)
  encode                                    — whisper encoder
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.blocks import BLOCKS
from repro.models.common import (
    apply_norm,
    dense_init,
    maybe_shard,
    norm_init,
    normal_init,
)


# ---------------------------------------------------------------- helpers

def sinusoidal(positions, d_model):
    """positions: (...,) int -> (..., d_model) float32 sinusoidal embeds."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _default_positions(cfg: ArchConfig, b, s):
    if cfg.pos_embed != "rope":
        return None
    pos = jnp.arange(s)[None, :]
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.m_rope:
        return jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def _seg_key(idx: int) -> str:
    return f"seg{idx}"


# ------------------------------------------------------------------- init

def _init_segments(key, cfg: ArchConfig, superblock, n_super):
    params = {}
    keys = jax.random.split(key, len(superblock))
    for idx, (kind, count, shared) in enumerate(superblock):
        bdef = BLOCKS[kind]
        init_one = functools.partial(bdef.init, cfg=cfg)
        if shared:
            params[_seg_key(idx)] = init_one(keys[idx])
        elif n_super > 1:
            ks = jax.random.split(keys[idx], (n_super, count))
            params[_seg_key(idx)] = jax.vmap(jax.vmap(init_one))(ks)
        else:
            ks = jax.random.split(keys[idx], count)
            params[_seg_key(idx)] = jax.vmap(init_one)(ks)
    return params


def init_lm(key, cfg: ArchConfig):
    k_embed, k_stack, k_head, k_enc = jax.random.split(key, 4)
    params = {
        "embed": {"w": normal_init(k_embed, (cfg.vocab, cfg.d_model),
                                   cfg.dtype, cfg.d_model ** -0.5)},
        "stack": _init_segments(k_stack, cfg, cfg.resolved_superblock,
                                cfg.n_super),
        "final_norm": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab,
                                       cfg.dtype)
    if cfg.enc_dec:
        params["encoder"] = {
            "stack": _init_segments(
                k_enc, cfg, (("enc_attn_mlp", cfg.n_enc_layers, False),), 1),
            "final_norm": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        }
    return params


# ------------------------------------------------------------------ apply

def _tree_index(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _remat(cfg, fn):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _apply_segment_scan(bdef, cfg, stacked_params, x, aux, ctx):
    """Run one non-shared segment's layers (scan, or unrolled for the
    dry-run so cost_analysis counts every layer)."""

    def layer(p, x):
        return bdef.apply(p, x, ctx, cfg)

    layer = _remat(cfg, layer)

    if cfg.unroll_layers:
        count = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
        for i in range(count):
            x, a = layer(_tree_index(stacked_params, i), x)
            aux = aux + a
        return x, aux

    def body(carry, p):
        x, aux = carry
        x, a = layer(p, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, aux), stacked_params)
    return x, aux


def apply_stack(params, cfg: ArchConfig, x, ctx, superblock=None,
                n_super=None):
    superblock = superblock or cfg.resolved_superblock
    n_super = n_super or cfg.n_super
    aux = jnp.zeros((), jnp.float32)

    if n_super == 1:
        for idx, (kind, count, shared) in enumerate(superblock):
            bdef = BLOCKS[kind]
            p = params[_seg_key(idx)]
            if shared:
                x, a = bdef.apply(p, x, ctx, cfg)
                aux = aux + a
            else:
                x, aux = _apply_segment_scan(bdef, cfg, p, x, aux, ctx)
        return x, aux

    shared_params = {_seg_key(i): params[_seg_key(i)]
                     for i, (_, _, sh) in enumerate(superblock) if sh}
    scanned_params = {_seg_key(i): params[_seg_key(i)]
                      for i, (_, _, sh) in enumerate(superblock) if not sh}

    def super_body(carry, xs):
        x, aux = carry
        for idx, (kind, count, shared) in enumerate(superblock):
            bdef = BLOCKS[kind]
            if shared:
                fn = _remat(cfg, functools.partial(bdef.apply, ctx=ctx,
                                                   cfg=cfg))
                x, a = fn(shared_params[_seg_key(idx)], x)
                aux = aux + a
            else:
                x, aux = _apply_segment_scan(bdef, cfg, xs[_seg_key(idx)],
                                             x, aux, ctx)
        return (x, aux), None

    if cfg.unroll_layers:
        carry = (x, aux)
        for i in range(n_super):
            carry, _ = super_body(carry, _tree_index(scanned_params, i))
        return carry

    (x, aux), _ = jax.lax.scan(super_body, (x, aux), scanned_params)
    return x, aux


def _make_ctx(cfg: ArchConfig, positions, memory=None, window=None):
    return {
        "positions": positions,
        "memory": memory,
        "window": cfg.sliding_window if window is None else window,
        "use_flash": cfg.use_flash,
    }


def encode(params, cfg: ArchConfig, audio_feats):
    """Whisper encoder over stub frontend features (B, enc_len, d_model)."""
    x = audio_feats.astype(cfg.dtype)
    pos = sinusoidal(jnp.arange(x.shape[1]), cfg.d_model).astype(cfg.dtype)
    x = x + pos[None]
    x, _ = apply_stack(params["encoder"]["stack"], cfg, x,
                       _make_ctx(cfg, None),
                       superblock=(("enc_attn_mlp", cfg.n_enc_layers, False),),
                       n_super=1)
    return apply_norm(params["encoder"]["final_norm"], x, cfg.norm)


def _embed(params, cfg, tokens):
    x = jnp.take(params["embed"]["w"], tokens, axis=0)
    return maybe_shard(x, ("pod", "data"), None, None)


def _head(params, cfg, x):
    x = apply_norm(params["final_norm"], x, cfg.norm)
    w = (params["embed"]["w"].T if cfg.tie_embeddings
         else params["lm_head"]["w"])
    logits = x @ w
    return maybe_shard(logits, ("pod", "data"), None, "model")


def hidden_states(params, cfg: ArchConfig, tokens, *, vision_embeds=None,
                  audio_feats=None, positions=None, window=None):
    """tokens: (B, S) -> (hidden (B,S,D), aux) — stack output, pre-head."""
    b, s = tokens.shape
    x = _embed(params, cfg, tokens)
    if cfg.n_vision_tokens and vision_embeds is not None:
        nv = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, nv:]], axis=1)
    if cfg.pos_embed == "sinusoidal":
        x = x + sinusoidal(jnp.arange(s), cfg.d_model).astype(x.dtype)[None]
    memory = None
    if cfg.enc_dec:
        memory = encode(params, cfg, audio_feats)
    if positions is None:
        positions = _default_positions(cfg, b, s)
    ctx = _make_ctx(cfg, positions, memory=memory, window=window)
    x, aux = apply_stack(params["stack"], cfg, x, ctx)
    return x, aux


def forward(params, cfg: ArchConfig, tokens, *, vision_embeds=None,
            audio_feats=None, positions=None, window=None):
    """tokens: (B, S) -> (logits (B,S,V), aux)."""
    x, aux = hidden_states(params, cfg, tokens, vision_embeds=vision_embeds,
                           audio_feats=audio_feats, positions=positions,
                           window=window)
    return _head(params, cfg, x), aux


def _ce_from_logits(logits, labels):
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def _chunked_ce(params, cfg, hidden, labels, chunk):
    """CE by scanning sequence chunks of the LM head: live logits are
    (B, chunk, V) instead of (B, S, V) — the §Perf 3.3 memory lever."""
    b, s, d = hidden.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xs = (hidden.reshape(b, nc, chunk, d).swapaxes(0, 1),
          labels.reshape(b, nc, chunk).swapaxes(0, 1))

    def body(_, xs):
        xc, lc = xs
        ce = _ce_from_logits(_head(params, cfg, xc), lc)  # (B, chunk)
        return None, jnp.sum(ce, axis=-1)

    _, sums = jax.lax.scan(body, None, xs)  # (nc, B)
    return jnp.sum(sums, axis=0) / s        # (B,) mean over positions


def per_example_loss(params, cfg: ArchConfig, batch, window=None):
    """Causal-LM cross entropy -> ((B,) per-example losses, aux)."""
    labels = batch["labels"]
    if cfg.loss_chunk and labels.shape[1] % cfg.loss_chunk == 0 \
            and "loss_mask" not in batch:
        hidden, aux = hidden_states(
            params, cfg, batch["tokens"],
            vision_embeds=batch.get("vision_embeds"),
            audio_feats=batch.get("audio_feats"),
            window=window)
        return _chunked_ce(params, cfg, hidden, labels, cfg.loss_chunk), aux
    logits, aux = forward(
        params, cfg, batch["tokens"],
        vision_embeds=batch.get("vision_embeds"),
        audio_feats=batch.get("audio_feats"),
        window=window)
    ce = _ce_from_logits(logits, labels)  # (B, S)
    if "loss_mask" in batch:
        m = batch["loss_mask"].astype(jnp.float32)
        return jnp.sum(ce * m, axis=-1) / jnp.maximum(jnp.sum(m, -1), 1.0), aux
    return jnp.mean(ce, axis=-1), aux


# ----------------------------------------------------------------- decode

def _state_lead_dims(superblock, n_super, idx):
    kind, count, shared = superblock[idx]
    if n_super > 1:
        return (n_super,) if shared else (n_super, count)
    return () if shared else (count,)


def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int, dtype=None):
    """Zero-initialized decode state mirroring the stack layout."""
    dtype = dtype or cfg.dtype
    superblock = cfg.resolved_superblock
    states = {}
    for idx, (kind, count, shared) in enumerate(superblock):
        bdef = BLOCKS[kind]
        if bdef.state is None:
            continue
        base = bdef.state(cfg, batch, cache_len, dtype)
        lead = _state_lead_dims(superblock, cfg.n_super, idx)
        states[_seg_key(idx)] = jax.tree_util.tree_map(
            lambda l: jnp.zeros(lead + l.shape, l.dtype), base)
    return states


def _tree_stack(trees):
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *trees)


def decode_stack(params, cfg: ArchConfig, x, states, pos, ctx):
    superblock = cfg.resolved_superblock

    def seg_scan(bdef, p_stacked, s_stacked, x):
        if cfg.unroll_layers:
            count = jax.tree_util.tree_leaves(p_stacked)[0].shape[0]
            news = []
            for i in range(count):
                x, s = bdef.decode(_tree_index(p_stacked, i), x,
                                   _tree_index(s_stacked, i), pos, ctx, cfg)
                news.append(s)
            return x, _tree_stack(news)

        def body(x, ps):
            p, s = ps
            x, s = bdef.decode(p, x, s, pos, ctx, cfg)
            return x, s

        return jax.lax.scan(body, x, (p_stacked, s_stacked))

    if cfg.n_super == 1:
        new_states = {}
        for idx, (kind, count, shared) in enumerate(superblock):
            bdef = BLOCKS[kind]
            key = _seg_key(idx)
            if shared:
                x, s = bdef.decode(params[key], x, states[key], pos, ctx, cfg)
                new_states[key] = s
            else:
                x, s = seg_scan(bdef, params[key], states[key], x)
                new_states[key] = s
        return x, new_states

    shared_params = {_seg_key(i): params[_seg_key(i)]
                     for i, (_, _, sh) in enumerate(superblock) if sh}
    scanned_params = {_seg_key(i): params[_seg_key(i)]
                      for i, (_, _, sh) in enumerate(superblock) if not sh}

    def super_body(x, xs):
        seg_ps, seg_ss = xs
        new_ss = {}
        for idx, (kind, count, shared) in enumerate(superblock):
            bdef = BLOCKS[kind]
            key = _seg_key(idx)
            if shared:
                x, s = bdef.decode(shared_params[key], x, seg_ss[key], pos,
                                   ctx, cfg)
            else:
                x, s = seg_scan(bdef, seg_ps[key], seg_ss[key], x)
            new_ss[key] = s
        return x, new_ss

    if cfg.unroll_layers:
        outs = []
        for i in range(cfg.n_super):
            x, ns = super_body(x, (_tree_index(scanned_params, i),
                                   _tree_index(states, i)))
            outs.append(ns)
        return x, _tree_stack(outs)

    x, new_states = jax.lax.scan(super_body, x, (scanned_params, states))
    return x, new_states


def decode_step(params, cfg: ArchConfig, tokens, states, pos, *,
                memory=None, window=None):
    """One serving step. tokens: (B, 1); pos: scalar absolute position.
    Returns (logits (B, vocab), new states)."""
    x = _embed(params, cfg, tokens)
    if cfg.pos_embed == "sinusoidal":
        x = x + sinusoidal(jnp.asarray(pos)[None], cfg.d_model).astype(x.dtype)[None]
    ctx = _make_ctx(cfg, None, memory=memory, window=window)
    x, new_states = decode_stack(params["stack"], cfg, x, states, pos, ctx)
    logits = _head(params, cfg, x)
    return logits[:, 0], new_states


def decode_cache_len(cfg: ArchConfig, seq_len: int, window=None) -> int:
    """Cache length: ring-buffer window for SWA, else the full context."""
    w = cfg.sliding_window if window is None else window
    return min(seq_len, w) if w and w > 0 else seq_len
