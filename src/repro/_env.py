"""Pre-jax-import environment helpers.

This module must stay free of jax (and jax-importing repro) imports:
its callers run *before* the first jax import, which is the only moment
XLA client flags can still take effect. (``repro`` is a namespace
package — no ``__init__.py`` — so ``from repro._env import ...`` pulls
in nothing else.)
"""

from __future__ import annotations

import os
import sys
import warnings


def ensure_host_device_count(n: int = 8) -> bool:
    """Merge ``--xla_force_host_platform_device_count=n`` into XLA_FLAGS.

    Gives the CPU backend ``n`` placeholder devices so the sharded grid
    path (DESIGN.md §5) can run on hosts without accelerators. Existing
    ``XLA_FLAGS`` content is preserved; an explicit device-count flag
    from the environment wins; real TPU/GPU backends ignore the flag.

    Returns True if the flag was added, False if it was too late (jax
    already imported — a :class:`UserWarning` names the device count the
    session is actually stuck with) or a device-count flag was already
    present (the environment's explicit choice wins, silently — that is
    the documented contract, not a failure).
    """
    if "jax" in sys.modules:
        warnings.warn(
            "ensure_host_device_count(%d) called after jax was imported — "
            "XLA client flags no longer take effect; this process keeps "
            "jax.device_count()=%s. Sharded suites will silently run on "
            "whatever that is (1 means no sharding at all); call this "
            "before anything imports jax." % (n, _imported_device_count()),
            stacklevel=2)
        return False
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return False
    os.environ["XLA_FLAGS"] = \
        f"{flags} --xla_force_host_platform_device_count={n}".strip()
    return True


def _imported_device_count():
    """Device count of the already-imported jax, via ``sys.modules`` —
    never imports jax itself (this module's contract). Returns the
    string ``"?"`` when the backend cannot be asked (mid-import, broken
    install), so warning paths stay exception-free."""
    try:
        return sys.modules["jax"].device_count()
    except Exception:  # noqa: BLE001 — diagnostics must never raise
        return "?"


#: Environment contract of the multi-process launcher (DESIGN.md §13).
#: ``repro.launch.distributed.init_from_env`` reads these; the simulated
#: harness sets them on each worker it spawns. They live here so the
#: names have one jax-free home both sides import.
DIST_COORDINATOR = "REPRO_DIST_COORDINATOR"
DIST_NUM_PROCESSES = "REPRO_DIST_NUM_PROCESSES"
DIST_PROCESS_ID = "REPRO_DIST_PROCESS_ID"
DIST_LOCAL_DEVICES = "REPRO_DIST_LOCAL_DEVICES"


def distributed_env() -> dict | None:
    """Parse the ``REPRO_DIST_*`` worker environment, or None when unset.

    Returns ``{"coordinator": str, "num_processes": int,
    "process_id": int, "local_devices": int | None}``. Partial
    configuration raises — a worker with a coordinator but no process id
    would hang the whole barrier, so refusing early is the kind option.
    """
    coord = os.environ.get(DIST_COORDINATOR)
    if coord is None:
        if any(v in os.environ for v in (DIST_NUM_PROCESSES,
                                         DIST_PROCESS_ID)):
            raise ValueError(
                f"partial REPRO_DIST_* environment: {DIST_COORDINATOR} is "
                f"unset but process-topology variables are present")
        return None
    try:
        nproc = int(os.environ[DIST_NUM_PROCESSES])
        pid = int(os.environ[DIST_PROCESS_ID])
    except KeyError as e:
        raise ValueError(
            f"partial REPRO_DIST_* environment: {DIST_COORDINATOR} is set "
            f"but {e.args[0]} is missing") from None
    local = os.environ.get(DIST_LOCAL_DEVICES)
    return {"coordinator": coord, "num_processes": nproc, "process_id": pid,
            "local_devices": int(local) if local is not None else None}
