"""Ragged-population padding invariance — hypothesis property tests.

Property (DESIGN.md §7): padding a scenario with k inactive clients
never changes the loss trajectory, the scheduler participation counts,
or the aggregate output of the clients that exist — for random
population sizes, pad amounts, β rates, battery capacities, data
weights, and per-client gradient noise (drawn with the
shape-independent fold_in scheme so the property is exact, not just
statistical).

The deterministic bit-for-bit suite lives in ``test_ragged.py``; this
module is skipped as a whole when ``hypothesis`` is not installed in
the container.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ClientSimulator, make_quadratic  # noqa: E402
from repro.core.aggregation import (  # noqa: E402
    aggregate_client_grads,
    reduce_flat,
)
from repro.core.energy import (  # noqa: E402
    BinaryArrivals,
    client_keys,
    pad_arrivals,
)
from repro.core.scheduling import make_scheduler, pad_scheduler  # noqa: E402
from repro.experiments import subpopulation_p  # noqa: E402
from repro.optim import sgd  # noqa: E402

N_MAX, DIM = 12, 4


def noisy_grads_fn(problem, n, noise):
    """Deterministic per-client gradients + fold_in per-client noise —
    client i's noise depends only on (key, t, i), never on n, so padded
    and natural runs see identical randomness for existing clients."""

    def grads(w, key, t):
        g = problem.all_grads(w)[:n]
        eps = jax.vmap(lambda k: noise * jax.random.normal(k, (DIM,)))(
            client_keys(key, n))
        return g + eps

    return grads


def run_once(problem, *, n, n_pad, betas, capacity, noise, num_steps=15,
             seed=0):
    """One simulator run of the first-n subpopulation, padded to n_pad
    rows (n_pad == n → natural, unmasked run). Returns (loss,
    participation-of-existing, weight_sum, params)."""
    scheduler = make_scheduler("battery_adaptive", n, capacity=capacity)
    energy = BinaryArrivals(jnp.asarray(betas[:n], jnp.float32))
    active = None
    if n_pad > n:
        scheduler = pad_scheduler(scheduler, n_pad)
        energy = pad_arrivals(energy, n_pad)
        active = (jnp.arange(n_pad) < n).astype(jnp.float32)
    p_cell = subpopulation_p(problem.p, n, n_pad)
    sim = ClientSimulator(
        grads_fn=noisy_grads_fn(problem, n_pad, noise),
        p=p_cell, optimizer=sgd(0.05),
        loss_fn=lambda w: jnp.sum(w * w))
    params, hist = sim.run(jax.random.PRNGKey(seed), jnp.ones((DIM,)),
                           num_steps, scheduler=scheduler, energy=energy,
                           active_mask=active)
    return (np.asarray(hist.loss), np.asarray(hist.participation)[..., :n],
            np.asarray(hist.weight_sum), np.asarray(params))


@pytest.fixture(scope="module")
def problem():
    return make_quadratic(jax.random.PRNGKey(0), n_clients=N_MAX, dim=DIM,
                          hetero=1.0)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, N_MAX - 1),
    k=st.integers(1, 6),
    beta_seed=st.integers(0, 2**20),
    capacity=st.floats(1.0, 4.0),
    noise=st.floats(0.0, 0.3),
    seed=st.integers(0, 2**20),
)
def test_padding_never_changes_existing_clients(problem, n, k, beta_seed,
                                                capacity, noise, seed):
    """loss / participation / Σω / final params are identical between the
    natural n-client run and the same run padded with k dead rows."""
    k = min(k, N_MAX - n)
    rng = np.random.default_rng(beta_seed)
    betas = rng.uniform(0.1, 1.0, size=N_MAX)
    nat = run_once(problem, n=n, n_pad=n, betas=betas, capacity=capacity,
                   noise=noise, seed=seed)
    pad = run_once(problem, n=n, n_pad=n + k, betas=betas, capacity=capacity,
                   noise=noise, seed=seed)
    for a, b in zip(nat, pad):
        np.testing.assert_array_equal(a, b)


@settings(max_examples=15, deadline=None)
@given(
    mask_bits=st.lists(st.booleans(), min_size=2, max_size=10),
    seed=st.integers(0, 2**20),
)
def test_arbitrary_masks_zero_inactive_rows(mask_bits, seed):
    """For an arbitrary (not necessarily prefix) 0/1 mask, masked rows
    contribute nothing: the aggregate equals the reference over the
    active subset, and garbage (NaN) in masked rows never leaks."""
    n = len(mask_bits)
    if not any(mask_bits):
        mask_bits[0] = True
    mask = jnp.asarray(mask_bits, jnp.float32)
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (n, 33))
    g = jnp.where(mask[:, None] > 0, g, jnp.nan)  # poison dead rows
    w = jax.random.uniform(jax.random.fold_in(key, 1), (n,)) * mask
    out = reduce_flat(g, w, mask=mask)
    active = np.flatnonzero(np.asarray(mask))
    ref = np.asarray(w, np.float64)[active] @ np.asarray(
        jnp.where(mask[:, None] > 0, g, 0.0), np.float64)[active]
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               rtol=1e-5, atol=1e-6)
    # per-leaf reference path agrees
    tree_out = aggregate_client_grads({"g": g}, w, mask)
    np.testing.assert_allclose(np.asarray(tree_out["g"]), np.asarray(out),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, N_MAX - 1),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**20),
)
def test_participation_counts_invariant_under_padding(problem, n, k, seed):
    """Scheduler participation *counts* of existing clients are identical
    after padding — no probability mass moves to or from dead rows."""
    k = min(k, N_MAX - n)
    betas = np.full(N_MAX, 0.5)
    nat = run_once(problem, n=n, n_pad=n, betas=betas, capacity=2.0,
                   noise=0.0, seed=seed)
    pad = run_once(problem, n=n, n_pad=n + k, betas=betas, capacity=2.0,
                   noise=0.0, seed=seed)
    np.testing.assert_array_equal(nat[1].sum(axis=0), pad[1].sum(axis=0))
