"""Pure-jnp oracle: sequential evaluation of the gated linear recurrence."""

import jax
import jax.numpy as jnp


def gla_scan_ref(a, k, v, q):
    """a: (BH, S); k,q: (BH, S, dk); v: (BH, S, dv) -> (BH, S, dv) f32."""
    f32 = lambda x: x.astype(jnp.float32)
    a, k, v, q = f32(a), f32(k), f32(v), f32(q)
    bh = a.shape[0]
    dk, dv = k.shape[-1], v.shape[-1]

    def body(h, xs):
        a_t, k_t, v_t, q_t = xs
        h = a_t[:, None, None] * h + k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bd,bdv->bv", q_t, h)
        return h, y

    h0 = jnp.zeros((bh, dk, dv), jnp.float32)
    _, ys = jax.lax.scan(
        body, h0,
        (a.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
         q.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)
