"""Synthetic datasets standing in for CIFAR-10 / LM corpora.

The container is offline (repro band ≤ 2 — data gate), so the Fig-1
reproduction uses a *class-structured* synthetic image task with the same
tensor shapes as CIFAR-10 (32×32×3, 10 classes): each class k has a random
smooth prototype image; samples are prototype + noise, so the task is
learnable but non-trivial, and per-client label skew creates the client
heterogeneity that makes Benchmark 1's bias visible.

For LM-scale runs, a Zipf-distributed Markov token stream gives
non-uniform unigram/bigram statistics (so losses actually decrease) at any
vocab size without external corpora.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class SyntheticImageDataset(NamedTuple):
    images: np.ndarray  # (D, H, W, C) float32
    labels: np.ndarray  # (D,) int32
    n_classes: int


def make_image_classification(
    seed: int,
    n_examples: int,
    *,
    n_classes: int = 10,
    image_shape: tuple[int, int, int] = (32, 32, 3),
    noise: float = 0.35,
    prototype_smoothness: int = 4,
) -> SyntheticImageDataset:
    """Gaussian-prototype image classification (CIFAR-shaped)."""
    rng = np.random.default_rng(seed)
    h, w, c = image_shape
    # Smooth prototypes: low-res random fields upsampled — closer to natural
    # image statistics than white noise, keeps the CNN's conv stack honest.
    lo = max(h // prototype_smoothness, 1)
    protos_lo = rng.normal(size=(n_classes, lo, lo, c)).astype(np.float32)
    reps = (h + lo - 1) // lo
    protos = np.repeat(np.repeat(protos_lo, reps, axis=1), reps, axis=2)[:, :h, :w, :]
    labels = rng.integers(0, n_classes, size=n_examples).astype(np.int32)
    images = protos[labels] + noise * rng.normal(size=(n_examples, h, w, c)).astype(np.float32)
    return SyntheticImageDataset(images=images.astype(np.float32), labels=labels,
                                 n_classes=n_classes)


def make_confusable_image_classification(
    seed: int,
    n_examples: int,
    *,
    n_classes: int = 10,
    n_groups: int = 4,
    image_shape: tuple[int, int, int] = (32, 32, 3),
    similarity: float = 0.9,
    noise: float = 0.8,
) -> SyntheticImageDataset:
    """Cross-group *confusable* class task — the Fig-1 reproduction dataset.

    Class ``c``'s prototype = ``similarity``·(shared confuser of group
    c mod n_groups) + (1−similarity)·(unique part). Classes living in
    different energy groups share most of their signal, so the decision
    boundary between them is capacity/weight-limited: a model trained with
    biased client weighting (paper's Benchmark 1) resolves the energy-rich
    group's boundaries and *confuses* the rest — reproducing the paper's
    accuracy ordering (alg1 ≈ oracle ≫ B1 ≫ B2) without CIFAR.
    """
    rng = np.random.default_rng(seed)
    h, w, c = image_shape
    lo = 4
    shared = rng.normal(size=(n_groups, lo, lo, c)).astype(np.float32)
    unique = rng.normal(size=(n_classes, lo, lo, c)).astype(np.float32)

    def up(a):
        reps_h, reps_w = (h + lo - 1) // lo, (w + lo - 1) // lo
        return np.repeat(np.repeat(a, reps_h, 1), reps_w, 2)[:, :h, :w, :]

    protos = up(similarity * shared[np.arange(n_classes) % n_groups]
                + (1 - similarity) * unique)
    labels = rng.integers(0, n_classes, n_examples).astype(np.int32)
    images = protos[labels] + noise * rng.normal(
        size=(n_examples, h, w, c)).astype(np.float32)
    return SyntheticImageDataset(images=images.astype(np.float32),
                                 labels=labels, n_classes=n_classes)


class SyntheticLMDataset(NamedTuple):
    tokens: np.ndarray  # (D, seq_len+1) int32 — shifted inside the model
    vocab: int


def make_lm_tokens(
    seed: int,
    n_sequences: int,
    seq_len: int,
    vocab: int,
    *,
    zipf_a: float = 1.2,
    markov_order: bool = True,
) -> SyntheticLMDataset:
    """Zipf-Markov synthetic token stream.

    Unigram distribution ~ Zipf(a); with ``markov_order`` each token's
    distribution is additionally shifted by the previous token (a cheap
    bigram structure), so a model can reduce loss below the unigram
    entropy by learning context.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    base = 1.0 / ranks**zipf_a
    base /= base.sum()
    toks = np.empty((n_sequences, seq_len + 1), dtype=np.int32)
    # Vectorized: sample unigram stream, then mix in a deterministic bigram
    # shift tok_{t} = (tok_t + f(tok_{t-1})) % vocab with prob 0.5.
    uni = rng.choice(vocab, size=(n_sequences, seq_len + 1), p=base).astype(np.int32)
    if markov_order:
        shift = (uni[:, :-1] * 31 + 7) % vocab
        use = rng.random((n_sequences, seq_len)) < 0.5
        uni[:, 1:] = np.where(use, shift, uni[:, 1:])
    toks[:] = uni
    return SyntheticLMDataset(tokens=toks, vocab=vocab)
