"""SGD / momentum / Adam(W) as pure pytree gradient transformations.

The learning rate may be a float or a ``schedule(step) -> lr`` callable
(see :mod:`repro.optim.schedules`). All states are pytrees, so optimizer
state shards with the parameters under pjit (same PartitionSpec as the
corresponding parameter leaf — see ``repro.sharding.rules``).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]  # (grads, state, params) -> (updates, state)
    # Fusion tag (DESIGN.md §9): ``kind`` names the update rule when it is
    # simple enough for a fused kernel to reproduce ("sgd"), and ``hyper``
    # carries the hyperparameters the kernel needs (for sgd: the lr /
    # schedule). Wrappers like chain_clip stay untagged — their update is
    # not linear in the gradient, so fusion must not engage.
    kind: str = ""
    hyper: Any = None


def resolve_lr(lr, step):
    """Evaluate a float-or-schedule learning rate at ``step`` (f32)."""
    if callable(lr):
        return lr(step)
    return jnp.asarray(lr, jnp.float32)


_resolve_lr = resolve_lr


class SGDState(NamedTuple):
    step: jax.Array


def sgd(lr) -> Optimizer:
    def init(params):
        del params
        return SGDState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        del params
        eta = _resolve_lr(lr, state.step)
        updates = jax.tree_util.tree_map(lambda g: -eta * g, grads)
        return updates, SGDState(step=state.step + 1)

    return Optimizer(init, update, kind="sgd", hyper=lr)


class MomentumState(NamedTuple):
    step: jax.Array
    velocity: Any


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return MomentumState(
            step=jnp.zeros((), jnp.int32),
            velocity=jax.tree_util.tree_map(jnp.zeros_like, params),
        )

    def update(grads, state, params=None):
        del params
        eta = _resolve_lr(lr, state.step)
        vel = jax.tree_util.tree_map(lambda v, g: beta * v + g, state.velocity, grads)
        if nesterov:
            updates = jax.tree_util.tree_map(lambda v, g: -eta * (beta * v + g), vel, grads)
        else:
            updates = jax.tree_util.tree_map(lambda v: -eta * v, vel)
        return updates, MomentumState(step=state.step + 1, velocity=vel)

    return Optimizer(init, update)


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, decoupled: bool = True) -> Optimizer:
    """Adam; with ``weight_decay > 0`` and ``decoupled=True`` this is AdamW.

    Moments are kept in float32 regardless of param dtype (the standard
    mixed-precision recipe: bf16 params / f32 optimizer state).
    """

    def init(params):
        f32zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(f32zeros, params),
            nu=jax.tree_util.tree_map(f32zeros, params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        eta = _resolve_lr(lr, state.step)
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def _upd(m, v, p):
            u = -(eta * (m / bc1) / (jnp.sqrt(v / bc2) + eps))
            if weight_decay > 0.0 and decoupled and p is not None:
                u = u - eta * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype if p is not None else u.dtype)

        if params is None:
            params = jax.tree_util.tree_map(lambda m: None, mu)
        updates = jax.tree_util.tree_map(_upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, decoupled=True, **kw)


def chain_clip(opt: Optimizer, max_norm: float) -> Optimizer:
    """Global-norm gradient clipping wrapped around another optimizer."""

    def init(params):
        return opt.init(params)

    def update(grads, state, params=None):
        leaves = jax.tree_util.tree_leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
        clipped = jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads)
        return opt.update(clipped, state, params)

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates
    )
