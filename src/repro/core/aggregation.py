"""Server-side aggregation (paper eq. 11 / 12), in three flavours.

The update the paper's server performs is

    w ← w − η · Σ_{i∈S_t} p_i · scale_i^t · g_i(w, ξ_i)

which we express as a *weighted sum over the client axis* with weights
``ω_i = p_i · mask_i · scale_i``. Three execution paths, all algebraically
identical:

1. ``aggregate_client_grads`` — client-stacked gradients (leading axis N),
   pure jnp. Used by the paper-scale simulator (vmap over clients).
2. ``aggregate_client_grads_kernel`` — same contract, but the flat
   parameter vector is reduced by the Pallas ``masked scaled aggregate``
   kernel (``repro.kernels.aggregate``) — the TPU hot path for the server.
3. ``per_example_coefficients`` — the *SPMD path* for framework-scale
   training: instead of materializing N per-client gradients, each example
   in the global batch carries the coefficient of its owning client, and
   the ordinary gradient of the weighted loss equals the paper's update.
   This is what the pjit train step uses; it adds **zero** collective
   traffic over plain data-parallel SGD.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scheduling import Decision


def client_weights(p: jax.Array, decision: Decision) -> jax.Array:
    """ω_i = p_i · mask_i · scale_i — the per-client aggregation weight."""
    return p * decision.mask * decision.scale


def aggregate_client_grads(stacked_grads, weights: jax.Array):
    """Weighted sum over the leading (client) axis of a gradient pytree.

    stacked_grads: pytree whose leaves have shape (N, ...).
    weights: (N,) float32 — ω_i.
    """

    def _one(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(w * leaf, axis=0)

    return jax.tree_util.tree_map(_one, stacked_grads)


def aggregate_client_grads_kernel(stacked_grads, weights: jax.Array):
    """Same contract as :func:`aggregate_client_grads` via the Pallas kernel.

    Flattens every leaf to (N, P), reduces with the kernel, reshapes back.
    Imported lazily so the pure-jnp path has no kernel dependency.
    """
    from repro.kernels.aggregate import ops as agg_ops

    def _one(leaf):
        n = leaf.shape[0]
        flat = leaf.reshape(n, -1)
        out = agg_ops.masked_scaled_aggregate(flat, weights.astype(leaf.dtype))
        return out.reshape(leaf.shape[1:])

    return jax.tree_util.tree_map(_one, stacked_grads)


def per_example_coefficients(
    client_ids: jax.Array,
    weights: jax.Array,
    examples_per_client: jax.Array | int,
) -> jax.Array:
    """Per-example loss coefficients realizing the paper's update in SPMD.

    If client i owns b_i examples of the batch and g_i is the *mean*
    gradient over its examples, then

        Σ_i ω_i g_i = Σ_i Σ_{j∈i} (ω_i / b_i) · ∇l_ij

    so example j of client i gets coefficient ω_i / b_i. Gradient of
    ``sum(coeff * per_example_loss)`` == paper's aggregated update.

    client_ids : (B,) int32 — owning client of each example.
    weights    : (N,) float32 — ω_i.
    examples_per_client : scalar or (N,) — b_i.
    """
    b = jnp.asarray(examples_per_client, jnp.float32)
    if b.ndim == 0:
        per_client = weights / b
    else:
        per_client = weights / jnp.maximum(b, 1.0)
    return per_client[client_ids]


def server_update(params, aggregated_grads, lr):
    """Plain SGD server update, w ← w − η · aggregate (paper eq. 11)."""
    return jax.tree_util.tree_map(
        lambda w, g: w - lr * g.astype(w.dtype), params, aggregated_grads
    )
