"""Render benchmark results to markdown.

Two modes:

* default — the §Roofline table from dryrun.json into EXPERIMENTS.md
  (replaces the <!-- ROOFLINE_TABLE --> marker block):

      PYTHONPATH=src python -m benchmarks.render_md

* ``--bench BENCH_<date>.json`` — a bench-series/v1 perf-trajectory
  file as grouped markdown tables, one section per series *family*
  (``fig1_*``, ``quadgrid_*``, ``popscale_*``, ``largeN_*``,
  ``faultpath_*``, ``serve_*``, ``theorem1_*``, kernels, roofline).
  Names outside every known family land in an "other" section — a
  series is never silently dropped, so a new family shows up (ugly but
  visible) the day it first lands:

      PYTHONPATH=src python -m benchmarks.render_md --bench \
          BENCH_2026-08-08.json [--out serving.md]
"""

from __future__ import annotations

import json
import os
import re

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "results", "dryrun.json")
EXPERIMENTS = os.path.join(HERE, "..", "EXPERIMENTS.md")

MARK = "<!-- ROOFLINE_TABLE -->"

#: Ordered (prefix, section title) — first match wins; names matching
#: no prefix go to "other" (never dropped).
FAMILIES = (
    ("fig1_", "Figure 1 grid"),
    ("quadgrid_", "Quadratic grid (batched vs sharded)"),
    ("popscale_", "Population scaling"),
    ("largeN_", "Large-N client sharding"),
    ("faultpath_", "Fault-injection path"),
    ("serve_", "Study service"),
    ("theorem1_", "Theorem 1 bound"),
    ("aggregate_", "Kernel micro-benchmarks"),
    ("attention_", "Kernel micro-benchmarks"),
    ("gla_", "Kernel micro-benchmarks"),
    ("roofline", "Roofline dry-run"),
)


def fmt(x):
    return f"{x:.2e}" if x else "0"


def render() -> str:
    with open(RESULTS) as f:
        results = json.load(f)
    singles = {k: v for k, v in results.items() if v["mesh"] == "16x16"}
    multis = {k: v for k, v in results.items() if v["mesh"] == "2x16x16"}

    out = ["## §Roofline — single-pod 16×16 (256 chips), unrolled accounting",
           "",
           "Terms in seconds/step (compute = HLO_FLOPs/(chip·197e12); "
           "memory = HLO_bytes/(chip·819e9); collective = coll_bytes/"
           "(chip·50e9)). `useful` = MODEL_FLOPS(6·N_act·D or 2·N_act·D) / "
           "total-HLO-FLOPs — the fraction of compiled compute that is "
           "model math (rest: remat recompute, attention O(S²), dispatch).",
           "",
           "| arch | shape | compute_s | memory_s | collective_s | "
           "bottleneck | useful | coll GB (AG/AR/RS/A2A/CP) |",
           "|---|---|---|---|---|---|---|---|"]
    for k in sorted(singles):
        r = singles[k]
        t = r["roofline"]
        pk = r["collectives"]["per_kind"]
        gb = "/".join(f"{pk[c] / 1e9:.1f}" for c in
                      ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        ratio = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(t['compute_s'])} | "
            f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
            f"**{t['bottleneck']}** | "
            f"{('%.2f' % ratio) if ratio else '—'} | {gb} |")

    out += ["",
            f"Multi-pod 2×16×16: **{len(multis)} pairs compiled** "
            "(scan artifacts — coherence proof; per-layer terms live in "
            "the single-pod table). Bottleneck distribution: "]
    from collections import Counter
    c = Counter(v["roofline"]["bottleneck"] for v in multis.values())
    out[-1] += ", ".join(f"{k}={v}" for k, v in sorted(c.items())) + "."
    return "\n".join(out)


def family_title(name: str) -> str:
    for prefix, title in FAMILIES:
        if str(name).startswith(prefix):
            return title
    return "other"


def _fmt_value(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def render_bench(doc: dict) -> str:
    """bench-series/v1 document -> grouped markdown (module docstring).

    Every record renders exactly once: known families under their
    section, everything else under "other"."""
    records = doc.get("results", [])
    sections: dict[str, list] = {}
    for rec in records:
        sections.setdefault(family_title(rec.get("name")), []).append(rec)

    header = [f"# Bench series ({doc.get('schema', '?')})",
              "",
              f"suites: {', '.join(doc.get('suites', []))} — "
              f"fast={doc.get('fast')} devices={doc.get('device_count')}"]
    if doc.get("failed"):
        header.append(f"**FAILED**: {doc['failed']}")

    titles = [t for _, t in FAMILIES] + ["other"]
    seen, ordered = set(), []
    for t in titles:
        if t in sections and t not in seen:
            ordered.append(t)
            seen.add(t)

    out = header
    rendered = 0
    for title in ordered:
        out += ["", f"## {title}", "",
                "| series | us/call | derived |", "|---|---|---|"]
        for rec in sections[title]:
            us = rec.get("us_per_call")
            derived = "; ".join(
                f"{k}={_fmt_value(v)}"
                for k, v in sorted((rec.get("derived") or {}).items()))
            out.append(f"| {rec.get('name')} | "
                       f"{'—' if not us else f'{us:.0f}'} | {derived} |")
            rendered += 1
    assert rendered == len(records), "every series must render"
    return "\n".join(out)


def main_bench(path: str, out_path: str | None) -> None:
    with open(path) as f:
        doc = json.load(f)
    text = render_bench(doc)
    if out_path:
        with open(out_path, "w") as f:
            f.write(text + "\n")
        print(f"rendered {len(doc.get('results', []))} series into {out_path}")
    else:
        print(text)


def main():
    table = render()
    with open(EXPERIMENTS) as f:
        text = f.read()
    block = f"{MARK}\n{table}\n{MARK}"
    if text.count(MARK) == 2:
        text = re.sub(f"{MARK}.*?{MARK}", block, text, flags=re.S)
    else:
        text = text.replace(MARK, block, 1)
    with open(EXPERIMENTS, "w") as f:
        f.write(text)
    print(f"rendered {len(table.splitlines())} lines into EXPERIMENTS.md")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="",
                    help="render a BENCH_*.json series file instead of the "
                         "roofline table")
    ap.add_argument("--out", default="",
                    help="with --bench: write markdown here instead of stdout")
    args = ap.parse_args()
    if args.bench:
        main_bench(args.bench, args.out or None)
    else:
        main()
