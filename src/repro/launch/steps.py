"""Step-function builders: train / prefill / serve per architecture.

These are the exact functions the dry-run lowers and the drivers run.
The energy-harvesting weighting (paper eq. 11/12) enters ``train_step``
through the (mask, scale) scheduler outputs — see
``repro.core.trainer.build_energy_train_step``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.trainer import build_energy_train_step
from repro.models import transformer
from repro.optim import adamw, sgd


def make_train_step(cfg: ArchConfig, n_clients: int, *, lr: float = 1e-4,
                    optimizer=None, window=None):
    """Returns (init_state, train_step(state, batch, mask, scale))."""
    if optimizer is None:
        optimizer = adamw(lr)

    def loss_fn(params, batch):
        return transformer.per_example_loss(params, cfg, batch, window=window)

    return build_energy_train_step(
        per_example_loss_fn=loss_fn,
        optimizer=optimizer,
        n_clients=n_clients,
        aux_loss_weight=(0.01 if cfg.n_experts else 0.0),
    )


def make_prefill_step(cfg: ArchConfig, *, window=None):
    """prefill(params, batch) -> last-position logits (B, vocab).

    The LM head is applied to the final position only — the (B, S, vocab)
    logits tensor never materializes (537 GB for command-r @ 32k×32).
    """

    def prefill(params, batch):
        x, _ = transformer.hidden_states(
            params, cfg, batch["tokens"],
            vision_embeds=batch.get("vision_embeds"),
            audio_feats=batch.get("audio_feats"),
            window=window)
        last = x[:, -1:]
        logits = transformer._head(params, cfg, last)
        return logits[:, 0]

    return prefill


def make_serve_step(cfg: ArchConfig, *, window=None, greedy: bool = True):
    """serve(params, tokens (B,1), states, pos[, memory]) ->
    (next_token (B,), logits (B,vocab), new_states)."""

    def serve(params, tokens, states, pos, memory=None):
        logits, new_states = transformer.decode_step(
            params, cfg, tokens, states, pos, memory=memory, window=window)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_states

    return serve


def make_sgd_train_step(cfg: ArchConfig, n_clients: int, lr: float = 0.05,
                        window=None):
    """Paper-exact variant: plain SGD server update (eq. 11)."""
    return make_train_step(cfg, n_clients, optimizer=sgd(lr), window=window)
