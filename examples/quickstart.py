"""Quickstart: energy-harvesting distributed SGD in ~60 lines.

Builds the paper's setting on a closed-form quadratic: 8 clients with
heterogeneous periodic energy (τ cycling through 1/5/10/20), and compares
Algorithm 1 against the paper's two benchmarks and the full-participation
oracle. The whole grid is one declarative :class:`repro.experiments.Study`
— named sweep axes, resolved and executed as a handful of compiled
computations, returning a labeled :class:`GridResult`. Run:

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_quadratic
from repro.experiments import get_study, resolve_taus_profile
from repro.optim import sgd

N_CLIENTS, STEPS, ETA, SEEDS = 8, 1000, 0.01, 8  # t=1000 as in paper Fig. 1


def main():
    problem = make_quadratic(jax.random.PRNGKey(0), N_CLIENTS, dim=10,
                             hetero=1.0)
    # The paper's 4 methods on periodic (eq. 37) arrivals: the registered
    # "fig1" study (scheduler axis × fixed arrivals × seeds).
    study = get_study("fig1", n_clients=N_CLIENTS, num_steps=STEPS,
                      seeds=SEEDS)
    taus = resolve_taus_profile("paper", N_CLIENTS)
    print(f"{N_CLIENTS} clients, energy periods {[int(t) for t in taus]}, "
          f"{SEEDS} seeds")

    def grads_fn(params, key, t):
        return problem.all_grads(params, key=key, noise=0.05)

    # Study.run owns simulator construction and jit-cache keying; it
    # returns a GridResult labeled by the study's sweep axes.
    results = study.run(grads_fn=grads_fn, p=problem.p, optimizer=sgd(ETA),
                        loss_fn=problem.suboptimality,
                        params0=jnp.full((10,), 5.0))

    # NaN-aware mean±std over the seed axis — one diverged seed would be
    # reported as n_nan, not averaged into the stats.
    summary = results.reduce(
        metric=lambda c: c.history.loss[:, -100:].mean(axis=-1))
    print(f"{'scenario':<22} {'final subopt':>22} {'mean weight Σω':>16}")
    finals = {}
    for name, cell in results.items():
        s = summary[name]
        finals[results.labels(name)["scheduler"]] = s["mean"]
        print(f"{name:<22} {s['mean']:>13.5f} ± {s['std']:<7.5f}"
              f"{float(np.asarray(cell.history.weight_sum).mean()):>16.3f}")

    assert finals["alg1"] < finals["benchmark1"], "Alg1 must beat B1"
    assert finals["alg1"] < finals["benchmark2"], "Alg1 must beat B2"
    print("\nAlgorithm 1 (unbiased energy-aware) beats both benchmarks ✓")

    # Axis selection: the alg1 row only, as plain records.
    for rec in results.sel(scheduler="alg1").to_records(
            metric=lambda c: c.history.loss[:, -100:].mean(axis=-1)):
        print(f"sel(scheduler='alg1') -> {rec['name']}: "
              f"{rec['mean']:.5f} ± {rec['std']:.5f} "
              f"({rec['n_seeds']} seeds, {rec['n_nan']} diverged)")


if __name__ == "__main__":
    main()
