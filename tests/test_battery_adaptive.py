"""Beyond-paper extension: energy accumulation + adaptive scaling
(paper §VI future work). See BatteryAdaptiveScheduler."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ClientSimulator, make_quadratic, make_scheduler
from repro.core.aggregation import client_weights
from repro.core.energy import BinaryArrivals, DeterministicArrivals
from repro.optim import sgd


def mean_weights(scheduler, process, p, horizon, skip=200, seed=0):
    key = jax.random.PRNGKey(seed)
    sstate, estate = scheduler.init(key), process.init(key)
    p = jnp.asarray(p, jnp.float32)

    def body(carry, t):
        sstate, estate, key = carry
        key, k1, k2 = jax.random.split(key, 3)
        estate, arr = process.arrivals(estate, t, k1)
        sstate, dec = scheduler.step(sstate, t, k2, arr)
        return (sstate, estate, key), client_weights(p, dec)

    _, w = jax.lax.scan(body, (sstate, estate, key), jnp.arange(horizon))
    return np.asarray(w)[skip:].mean(0)


def test_adaptive_scaling_is_asymptotically_unbiased():
    """Asymptotic in the EMA rate: the 1/r̂ scale is anti-correlated with
    the mask (r̂ jumps right when the client participates), a systematic
    O(ema) downward bias for low-β clients — so the unbiasedness claim
    is tested at a small EMA rate, where it is ~6% for β=0.15 (vs ~15%
    at the 0.05 default)."""
    p = np.array([0.3, 0.3, 0.4])
    proc = BinaryArrivals([0.15, 0.45, 0.9])
    sch = make_scheduler("battery_adaptive", 3, capacity=1.0, ema=0.02)
    w = mean_weights(sch, proc, p, horizon=12000, skip=2000)
    np.testing.assert_allclose(w, p, rtol=0.10)


def test_energy_conservation():
    """Physics invariant: with 0/1 arrivals, long-run participation rate
    equals the arrival rate for ANY capacity (you cannot spend energy you
    never harvested — banking shifts WHEN rounds happen, not how many)."""
    proc = BinaryArrivals([0.5])
    key = jax.random.PRNGKey(0)

    def run(capacity):
        sch = make_scheduler("battery_adaptive", 1, capacity=capacity)
        sstate, estate = sch.init(key), proc.init(key)

        def body(carry, t):
            sstate, estate, k = carry
            k, k1, k2 = jax.random.split(k, 3)
            estate, arr = proc.arrivals(estate, t, k1)
            sstate, dec = sch.step(sstate, t, k2, arr)
            return (sstate, estate, k), dec.mask

        _, m = jax.lax.scan(body, (sstate, estate, key), jnp.arange(3000))
        return float(np.asarray(m).mean())

    for cap in (1.0, 3.0):
        np.testing.assert_allclose(run(cap), 0.5, atol=0.03)


def test_adaptive_beats_benchmark1_on_heterogeneous_energy():
    prob = make_quadratic(jax.random.PRNGKey(3), n_clients=8, dim=6,
                          hetero=1.0)
    det = DeterministicArrivals.periodic(
        [(1, 4, 8, 16)[i % 4] for i in range(8)], horizon=4001)

    def final(name, **kw):
        sim = ClientSimulator(
            grads_fn=lambda pp, k, t: prob.all_grads(pp),
            scheduler=make_scheduler(name, 8, **kw), energy=det, p=prob.p,
            optimizer=sgd(0.02), loss_fn=prob.suboptimality)
        _, hist = sim.run(jax.random.PRNGKey(1), jnp.full((6,), 5.0), 4000)
        return float(np.asarray(hist.loss[-200:]).mean())

    adaptive = final("battery_adaptive", capacity=2.0)
    biased = final("benchmark1")
    assert adaptive < 0.5 * biased  # de-biasing works without knowing τ_i
