"""Placement-layer tests: sharded ≡ batched ≡ sequential, compile
counts on both execution paths, and padding-cell containment
(DESIGN.md §5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClientSimulator, make_quadratic
from repro.experiments import (
    Scenario,
    make_cell_mesh,
    run_grid,
    run_grid_sequential,
)
from repro.experiments import engine, placement
from repro.optim import sgd

multidevice = pytest.mark.multidevice


@pytest.fixture(scope="module")
def problem():
    return make_quadratic(jax.random.PRNGKey(2), n_clients=6, dim=5,
                          hetero=1.0)


def _grid_kwargs(problem, steps):
    return dict(
        grads_fn=lambda p, k, t: problem.all_grads(p, key=k, noise=0.05),
        p=problem.p, optimizer=sgd(0.02),
        params0=jnp.full((5,), 4.0), num_steps=steps,
        loss_fn=problem.suboptimality)


def _sim(problem, steps):
    kw = _grid_kwargs(problem, steps)
    return ClientSimulator(grads_fn=kw["grads_fn"], p=kw["p"],
                           optimizer=kw["optimizer"], loss_fn=kw["loss_fn"])


# --------------------------------------------------------- mesh factory

def test_make_cell_mesh_defaults_to_all_devices():
    mesh = make_cell_mesh()
    assert mesh.size == jax.device_count()
    assert mesh.axis_names == (placement.CELL_AXIS,)


def test_make_cell_mesh_validates_device_count():
    with pytest.raises(ValueError, match="n_devices"):
        make_cell_mesh(jax.device_count() + 1)
    with pytest.raises(ValueError, match="n_devices"):
        make_cell_mesh(0)


def test_multi_axis_mesh_rejected(problem):
    if jax.device_count() < 2:
        pytest.skip("requires >= 2 jax devices")
    devs = np.array(jax.devices()[:2]).reshape(2, 1)
    bad = jax.sharding.Mesh(devs, ("a", "b"))
    steps = 10
    scens = [Scenario("alg1_periodic", "alg1", "periodic", 6, steps + 1)]
    with pytest.raises(ValueError, match="1-D mesh"):
        run_grid(scens, seeds=2, mesh=bad, **_grid_kwargs(problem, steps))


# ---------------------------------------------------- cell-axis algebra

def test_flatten_cells_ordering():
    """Cell c = s·R + r must pair scenario s with seed r."""
    sch = {"x": jnp.arange(3.0)}          # S = 3 scenarios
    en = {"y": jnp.arange(30.0, 33.0)}
    keys = jnp.stack([jax.random.PRNGKey(s) for s in (7, 11)])  # R = 2
    sch_c, en_c, flt_c, active_c, p_c, keys_c = placement.flatten_cells(
        sch, en, keys, n_scenarios=3)
    assert flt_c is None and active_c is None and p_c is None
    np.testing.assert_array_equal(np.asarray(sch_c["x"]),
                                  [0, 0, 1, 1, 2, 2])
    np.testing.assert_array_equal(np.asarray(en_c["y"]),
                                  [30, 30, 31, 31, 32, 32])
    np.testing.assert_array_equal(np.asarray(keys_c),
                                  np.tile(np.asarray(keys), (3, 1)))
    # ragged operands (S, N_cap) repeat over seeds like the components
    active = jnp.asarray([[1.0, 0.0], [1.0, 1.0], [0.0, 1.0]])
    _, _, _, active_c, p_c, _ = placement.flatten_cells(
        sch, en, keys, n_scenarios=3, active=active, p=active)
    np.testing.assert_array_equal(np.asarray(active_c),
                                  np.repeat(np.asarray(active), 2, axis=0))
    np.testing.assert_array_equal(np.asarray(p_c), np.asarray(active_c))


def test_pad_cells_repeats_first_cell():
    tree = {"a": jnp.arange(6.0).reshape(3, 2)}
    padded, n = placement.pad_cells(tree, 3, 4)
    assert n == 4
    np.testing.assert_array_equal(np.asarray(padded["a"]),
                                  [[0, 1], [2, 3], [4, 5], [0, 1]])
    same, n = placement.pad_cells(tree, 3, 3)
    assert n == 3 and same is tree  # no copy when already divisible


# ------------------------------------------------- sharded grid results

@multidevice
def test_sharded_matches_batched_and_sequential(problem):
    """run_grid results are seed-reproducible and equal across the three
    execution modes for the same cells (float32 tolerance)."""
    steps = 80
    scenarios = [
        Scenario("alg1_periodic", "alg1", "periodic", 6, steps + 1),
        Scenario("alg2_binary", "alg2", "binary", 6, steps + 1),
        Scenario("b2_uniform", "benchmark2", "uniform", 6, steps + 1),
    ]
    kw = _grid_kwargs(problem, steps)
    mesh = make_cell_mesh()
    batched = run_grid(scenarios, seeds=3, **kw)
    sharded = run_grid(scenarios, seeds=3, mesh=mesh, **kw)
    sequential = run_grid_sequential(scenarios, seeds=3, **kw)
    assert set(batched) == set(sharded) == set(sequential)
    for name in batched:
        for other in (sharded, sequential):
            np.testing.assert_allclose(
                np.asarray(batched[name].history.loss),
                np.asarray(other[name].history.loss),
                rtol=2e-4, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(batched[name].params),
            np.asarray(sharded[name].params), rtol=2e-4, atol=1e-5)
        np.testing.assert_array_equal(
            np.asarray(batched[name].history.participation),
            np.asarray(sharded[name].history.participation))


@multidevice
def test_sharded_padding_cells_do_not_leak(problem):
    """A cell count with maximal padding (C % D == 1) must yield exactly
    (S, R)-shaped results that match the unsharded path — padded lanes
    are sliced off before CellResult assembly."""
    steps = 40
    seeds = 3  # 3 scenarios x 3 seeds = 9 cells -> pad 7 on 8 devices
    scenarios = [
        Scenario(f"alg2_binary_{i}", "alg2", "binary", 6, steps + 1,
                 taus=[1 + i, 2, 2, 4, 4, 8])
        for i in range(3)
    ]
    kw = _grid_kwargs(problem, steps)
    mesh = make_cell_mesh()
    assert (len(scenarios) * seeds) % mesh.size != 0  # really exercises padding
    sharded = run_grid(scenarios, seeds=seeds, mesh=mesh, **kw)
    batched = run_grid(scenarios, seeds=seeds, **kw)
    for name in sharded:
        assert sharded[name].history.loss.shape == (seeds, steps)
        assert sharded[name].params.shape == (seeds, 5)
        np.testing.assert_allclose(
            np.asarray(sharded[name].history.loss),
            np.asarray(batched[name].history.loss), rtol=2e-4, atol=1e-5)


@multidevice
def test_sharded_eval_chunking(problem):
    steps = 60
    scenarios = [Scenario("alg1_periodic", "alg1", "periodic", 6, steps + 1)]
    kw = _grid_kwargs(problem, steps)
    mesh = make_cell_mesh()
    sharded = run_grid(scenarios, seeds=2, mesh=mesh,
                       eval_fn=problem.suboptimality, eval_every=20, **kw)
    batched = run_grid(scenarios, seeds=2,
                       eval_fn=problem.suboptimality, eval_every=20, **kw)
    cell = sharded["alg1_periodic"]
    assert cell.evals.shape == (2, 3)
    np.testing.assert_allclose(np.asarray(cell.evals),
                               np.asarray(batched["alg1_periodic"].evals),
                               rtol=2e-4, atol=1e-5)


# -------------------------------------------------------- compile counts

@multidevice
def test_compile_once_per_group_on_both_paths(problem):
    """An S-scenario × R-seed grid traces once per component structure on
    the vmap path AND once per structure on the shard_map path; a repeat
    call with the same sim traces zero new computations on either."""
    steps = 30
    scenarios = [
        Scenario(f"{s}_{a}", s, a, 6, steps + 1)
        for s in ("alg1", "benchmark1")
        for a in ("periodic", "binary")
    ]  # 4 distinct component structures
    kw = _grid_kwargs(problem, steps)
    sim = _sim(problem, steps)
    mesh = make_cell_mesh()
    run_kw = dict(sim=sim, params0=kw["params0"], num_steps=steps, seeds=5)

    vmap_before = engine._run_group._cache_size()
    sh_before = placement._run_group_sharded._cache_size()

    run_grid(scenarios, **run_kw)
    assert engine._run_group._cache_size() - vmap_before == len(scenarios)
    assert placement._run_group_sharded._cache_size() == sh_before

    run_grid(scenarios, mesh=mesh, **run_kw)
    assert placement._run_group_sharded._cache_size() - sh_before \
        == len(scenarios)
    assert engine._run_group._cache_size() - vmap_before == len(scenarios)

    # Repeat calls with the same sim: zero new traces on either path.
    run_grid(scenarios, **run_kw)
    run_grid(scenarios, mesh=mesh, **run_kw)
    assert engine._run_group._cache_size() - vmap_before == len(scenarios)
    assert placement._run_group_sharded._cache_size() - sh_before \
        == len(scenarios)


@multidevice
def test_one_device_mesh_takes_vmap_path(problem):
    """mesh.size == 1 must fall back to the single-device vmap path —
    bit-for-bit the no-mesh behavior, no shard_map trace."""
    steps = 20
    scenarios = [Scenario("alg1_periodic", "alg1", "periodic", 6, steps + 1)]
    kw = _grid_kwargs(problem, steps)
    sim = _sim(problem, steps)
    run_kw = dict(sim=sim, params0=kw["params0"], num_steps=steps, seeds=2)
    sh_before = placement._run_group_sharded._cache_size()
    plain = run_grid(scenarios, **run_kw)
    one = run_grid(scenarios, mesh=make_cell_mesh(1), **run_kw)
    assert placement._run_group_sharded._cache_size() == sh_before
    np.testing.assert_array_equal(
        np.asarray(plain["alg1_periodic"].history.loss),
        np.asarray(one["alg1_periodic"].history.loss))
