"""Ragged client populations (DESIGN.md §7): equivalence + trace counts.

The tentpole guarantee, locked in bit-for-bit: padding a scenario's
per-client component leaves to the simulator capacity N_cap and running
it under an ``active_mask`` produces *exactly* the numbers of the
natural-N run — across all six schedulers — while every population size
of one scheduler × arrival structure shares a single compiled
computation.

The per-N baseline is an honest unpadded setup: its own simulator whose
``grads_fn``/``p`` are built at the natural N (via the same
:func:`repro.experiments.subpopulation_p` renormalization the engine
applies), executed through ``run_grid_sequential`` — one traced scan per
cell, no padding, no masks.

Loss functions here are chosen vmap-stable (elementwise + single
reduction): batching never reassociates them, so ``assert_array_equal``
is meaningful. Gradients are deterministic — per-client *stochastic*
noise is exercised by the hypothesis module
(``test_ragged_properties.py``) with shape-independent fold_in draws.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClientSimulator, make_quadratic, scheduler_names
from repro.core.energy import (
    BinaryArrivals,
    DayNightArrivals,
    DeterministicArrivals,
    UniformArrivals,
    client_randint,
    client_uniform,
    pad_arrivals,
)
from repro.core.scheduling import make_scheduler, pad_scheduler
from repro.experiments import (
    ExecutionConfig,
    Scenario,
    Study,
    engine,
    get_study,
    make_cell_mesh,
    run_grid_sequential,
    subpopulation_p,
)
from repro.optim import sgd

ragged = pytest.mark.ragged
multidevice = pytest.mark.multidevice

N_CAP, DIM = 8, 5

#: every (scheduler, arrival-family) pairing exercised bit-for-bit; all
#: six schedulers appear, each against a compatible arrival process.
SCHEDULER_ARRIVALS = [
    ("alg1", "periodic"),
    ("alg2", "binary"),
    ("benchmark1", "uniform"),
    ("benchmark2", "periodic"),
    ("oracle", "binary"),
    ("battery_adaptive", "day_night"),
]


@pytest.fixture(scope="module")
def master():
    return make_quadratic(jax.random.PRNGKey(2), n_clients=N_CAP, dim=DIM,
                          hetero=1.0)


@pytest.fixture(scope="module")
def loss_fn(master):
    # Elementwise + one sum: bit-stable under vmap (the 4-operand
    # suboptimality einsum is not — its contraction path changes when
    # batched).
    w_star = master.w_star
    return lambda w: jnp.sum((w - w_star) ** 2)


@pytest.fixture(scope="module")
def sim(master, loss_fn):
    """The capacity-wide simulator the padded engine path uses."""
    return ClientSimulator(grads_fn=lambda w, k, t: master.all_grads(w),
                           p=master.p, optimizer=sgd(0.02), loss_fn=loss_fn)


@pytest.fixture(scope="module")
def params0():
    return jnp.full((DIM,), 4.0)


def baseline_cell(master, loss_fn, params0, *, scheduler, arrivals, n,
                  num_steps, seeds):
    """Natural-N reference: a dedicated n-client sim, sequential scan.

    Weights follow the engine's rule: a true subpopulation renormalizes
    the master prefix over its n clients; the full population keeps the
    master p verbatim (capacity cells are never renormalized)."""
    name = f"{scheduler}_{arrivals}_n{n}"
    p_n = master.p if n == N_CAP else subpopulation_p(master.p, n, n)
    sub = ClientSimulator(
        grads_fn=lambda w, k, t: master.all_grads(w)[:n],
        p=p_n, optimizer=sgd(0.02), loss_fn=loss_fn)
    return run_grid_sequential(
        [Scenario(name, scheduler, arrivals, n, num_steps + 1)],
        sim=sub, params0=params0, num_steps=num_steps, seeds=seeds)[name]


def assert_cells_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.history.loss),
                                  np.asarray(b.history.loss))
    np.testing.assert_array_equal(np.asarray(a.history.participation),
                                  np.asarray(b.history.participation))
    np.testing.assert_array_equal(np.asarray(a.history.weight_sum),
                                  np.asarray(b.history.weight_sum))
    np.testing.assert_array_equal(np.asarray(a.params), np.asarray(b.params))


# ----------------------------------------------------- bit-for-bit equality

@ragged
@pytest.mark.parametrize("scheduler,arrivals", SCHEDULER_ARRIVALS)
def test_padded_matches_per_n_sequential_bitwise(master, loss_fn, sim,
                                                 params0, scheduler,
                                                 arrivals):
    """Acceptance: masked-padded batched execution == per-N sequential
    baseline, bit-for-bit, for every scheduler (all 6 covered across the
    parametrization) and every population size."""
    num_steps, seeds, pops = 25, 2, (3, 5, 8)
    study = Study("rag", num_steps=num_steps, axes={
        "scheduler": scheduler, "arrivals": arrivals,
        "n_clients": list(pops), "seeds": seeds})
    res = study.run(sim=sim, params0=params0)
    for n in pops:
        base = baseline_cell(master, loss_fn, params0, scheduler=scheduler,
                             arrivals=arrivals, n=n, num_steps=num_steps,
                             seeds=seeds)
        cell = res[f"{scheduler}_{arrivals}_n{n}"]
        assert cell.history.participation.shape == (seeds, num_steps, n)
        assert_cells_equal(cell, base)


@ragged
def test_all_six_schedulers_are_covered():
    assert sorted(s for s, _ in SCHEDULER_ARRIVALS) == scheduler_names()


@ragged
def test_padded_sequential_matches_per_n_sequential_bitwise(master, loss_fn,
                                                            sim, params0):
    """The sequential engine path pads ragged cells too (so batched and
    sequential run identical cell programs) — and stays bit-identical to
    the natural-N run."""
    num_steps, seeds = 20, 2
    study = Study("rag", num_steps=num_steps, axes={
        "scheduler": "alg2", "arrivals": "binary",
        "n_clients": [4, 8], "seeds": seeds})
    res = study.run(sim=sim, params0=params0,
                    config=ExecutionConfig(sequential=True))
    for n in (4, 8):
        base = baseline_cell(master, loss_fn, params0, scheduler="alg2",
                             arrivals="binary", n=n, num_steps=num_steps,
                             seeds=seeds)
        assert_cells_equal(res[f"alg2_binary_n{n}"], base)


@ragged
def test_kernel_path_matches_reference_on_ragged_grid(master, loss_fn,
                                                      params0):
    """The Pallas mask-operand path agrees with the jnp masked matvec."""
    kw = dict(grads_fn=lambda w, k, t: master.all_grads(w), p=master.p,
              optimizer=sgd(0.02), loss_fn=loss_fn)
    study = Study("rag", num_steps=10, axes={
        "scheduler": "alg2", "arrivals": "binary",
        "n_clients": [3, 8], "seeds": 2})
    plain = study.run(sim=ClientSimulator(**kw), params0=params0)
    kern = study.run(sim=ClientSimulator(use_kernel=True, **kw),
                     params0=params0)
    for name in plain:
        np.testing.assert_allclose(np.asarray(plain[name].history.loss),
                                   np.asarray(kern[name].history.loss),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(
            np.asarray(plain[name].history.participation),
            np.asarray(kern[name].history.participation))


# ----------------------------------------------------------- trace counts

@ragged
def test_three_population_grid_compiles_once_per_structure(sim, params0):
    """Acceptance: a 3-population grid over 2 schedulers × 1 arrival
    family compiles exactly one computation per scheduler × arrival
    structure — N is a data axis, not a shape axis."""
    study = Study("rag", num_steps=15, axes={
        "scheduler": ["alg2", "benchmark1"], "arrivals": "binary",
        "n_clients": [3, 5, 8], "seeds": 2})
    before = engine._run_group._cache_size()
    res = study.run(sim=sim, params0=params0)
    assert engine._run_group._cache_size() - before == 2  # not 6
    assert len(res) == 6


@ragged
def test_population_scaling_study_single_trace(sim, params0):
    """The registered population_scaling study over 3 N values is one
    compiled computation. (num_steps differs from every other test in
    this module so the delta measures a fresh trace, not a jit-cache
    hit from an earlier identically-shaped group.)"""
    study = get_study("population_scaling", n_clients=(3, 5, 8),
                      num_steps=17, seeds=2)
    before = engine._run_group._cache_size()
    res = study.run(sim=sim, params0=params0)
    assert engine._run_group._cache_size() - before == 1
    assert [res[n].history.participation.shape[-1] for n in res] == [3, 5, 8]


@ragged
def test_full_capacity_cell_unchanged_by_ragged_neighbors(sim, params0):
    """Regression: adding an unrelated smaller-N scenario to a grid must
    not change a full-capacity cell's numerics — bit-for-bit. (The
    capacity cell keeps the caller's ``sim.p`` verbatim and an all-ones
    mask; renormalizing p — which does not sum to exactly 1.0 in f32 —
    would have perturbed it.)"""
    from repro.experiments import run_grid

    num_steps, seeds = 20, 2
    cell8 = Scenario("alg2_binary_n8", "alg2", "binary", N_CAP, num_steps + 1)
    cell4 = Scenario("alg2_binary_n4", "alg2", "binary", 4, num_steps + 1)
    alone = run_grid([cell8], sim=sim, params0=params0,
                     num_steps=num_steps, seeds=seeds)
    mixed = run_grid([cell8, cell4], sim=sim, params0=params0,
                     num_steps=num_steps, seeds=seeds)
    assert_cells_equal(alone["alg2_binary_n8"], mixed["alg2_binary_n8"])


@ragged
def test_uniform_group_keeps_cache_in_mixed_grid(sim, params0):
    """Raggedness is per structure group: a group whose members are all
    at capacity runs the mask-free program and keeps its jit cache entry
    even when another group of the same grid mixes populations."""
    from repro.experiments import run_grid

    num_steps, seeds = 11, 2
    alg1_8 = Scenario("alg1_n8", "alg1", "binary", N_CAP, num_steps + 1)
    run_grid([alg1_8], sim=sim, params0=params0, num_steps=num_steps,
             seeds=seeds)
    before = engine._run_group._cache_size()
    mixed = run_grid(
        [alg1_8,
         Scenario("alg2_n4", "alg2", "binary", 4, num_steps + 1),
         Scenario("alg2_n8", "alg2", "binary", N_CAP, num_steps + 1)],
        sim=sim, params0=params0, num_steps=num_steps, seeds=seeds)
    # only the (ragged) alg2 group traces; the uniform alg1 group hits
    # its existing mask-free executable
    assert engine._run_group._cache_size() - before == 1
    assert len(mixed) == 3


# ------------------------------------------------------------- validation

@ragged
def test_population_above_capacity_is_a_clear_error(sim, params0):
    study = Study("rag", num_steps=5, axes={
        "scheduler": "alg2", "arrivals": "binary",
        "n_clients": [4, 16], "seeds": 1})
    with pytest.raises(ValueError, match=r"N_cap=8.*n16.*N=16"):
        study.run(sim=sim, params0=params0)


@ragged
def test_pad_clients_rejects_shrinking():
    with pytest.raises(ValueError, match="pad"):
        BinaryArrivals(jnp.full((4,), 0.5)).pad_clients(2)
    with pytest.raises(ValueError, match="pad"):
        pad_scheduler(make_scheduler("alg2", 4), 2)


@ragged
def test_padded_rows_are_valid_neutral_hyperparameters():
    """Padding must never manufacture inf/NaN: β=1, period=1, empty
    schedule rows."""
    bin8 = pad_arrivals(BinaryArrivals(jnp.full((3,), 0.25)), 8)
    assert bin8.n_clients == 8
    np.testing.assert_array_equal(np.asarray(bin8.betas[3:]), 1.0)
    uni8 = pad_arrivals(UniformArrivals(jnp.array([2, 3, 4])), 8)
    np.testing.assert_array_equal(np.asarray(uni8.periods[3:]), 1)
    det8 = pad_arrivals(DeterministicArrivals.periodic([1, 5], 20), 8)
    np.testing.assert_array_equal(np.asarray(det8.schedule[2:]), 0.0)
    np.testing.assert_array_equal(np.asarray(det8.gaps[2:]), 0.0)
    dn = DayNightArrivals.from_taus([1, 5, 10], period=10)
    dn8 = pad_arrivals(dn, 8)
    assert np.isfinite(np.asarray(1.0 / dn8.betas_night)).all()
    sch = pad_scheduler(make_scheduler("battery_adaptive", 3, capacity=4.0), 8)
    assert sch.n_clients == 8 and float(sch.capacity) == 4.0


# -------------------------------------------------- shape-independent RNG

@ragged
def test_client_draws_are_shape_independent():
    """The enabling property: client i's draw does not depend on how
    many other clients exist (unlike ``jax.random.uniform(key, (n,))``)."""
    key = jax.random.PRNGKey(7)
    u8, u3 = client_uniform(key, 8), client_uniform(key, 3)
    np.testing.assert_array_equal(np.asarray(u8[:3]), np.asarray(u3))
    periods = jnp.array([2, 5, 9, 4, 7, 3, 8, 6])
    r8 = client_randint(key, 8, periods)
    r3 = client_randint(key, 3, periods[:3])
    np.testing.assert_array_equal(np.asarray(r8[:3]), np.asarray(r3))
    assert (np.asarray(r8) >= 0).all()
    assert (np.asarray(r8) < np.asarray(periods)).all()


@ragged
def test_arrival_processes_are_shape_independent():
    """First-n rows of every stochastic process match the n-client run."""
    key = jax.random.PRNGKey(3)
    taus = np.array([1, 5, 10, 20, 1, 5, 10, 20])
    for big, small in [
        (BinaryArrivals(1.0 / taus), BinaryArrivals(1.0 / taus[:3])),
        (UniformArrivals(taus), UniformArrivals(taus[:3])),
        (DayNightArrivals.from_taus(taus, period=10),
         DayNightArrivals.from_taus(taus[:3], period=10)),
    ]:
        sb, ss = big.init(key), small.init(key)
        for t in range(7):
            kt = jax.random.fold_in(key, 100 + t)
            sb, ab = big.arrivals(sb, t, kt)
            ss, asml = small.arrivals(ss, t, kt)
            np.testing.assert_array_equal(np.asarray(ab.energy[:3]),
                                          np.asarray(asml.energy))


# -------------------------------------------------------------- sharded

@ragged
@multidevice
def test_ragged_grid_sharded_matches_vmap(sim, params0):
    """The 8-host-device sharded path runs ragged grids and agrees with
    the vmap path (float32 reassociation tolerance on loss; exact
    participation)."""
    study = Study("rag", num_steps=20, axes={
        "scheduler": "alg2", "arrivals": "binary",
        "n_clients": [3, 5, 8], "seeds": 2})
    plain = study.run(sim=sim, params0=params0)
    sharded = study.run(sim=sim, params0=params0,
                        config=ExecutionConfig(mesh=make_cell_mesh()))
    for name in plain:
        np.testing.assert_allclose(np.asarray(plain[name].history.loss),
                                   np.asarray(sharded[name].history.loss),
                                   rtol=2e-4, atol=1e-5)
        np.testing.assert_array_equal(
            np.asarray(plain[name].history.participation),
            np.asarray(sharded[name].history.participation))
        assert sharded[name].history.participation.shape[-1] == \
            plain[name].history.participation.shape[-1]
