"""Bounded LRU mapping with hit/miss/eviction accounting.

One policy, three users (DESIGN.md §11–§12): the serve layer's keyed
executable cache (:class:`repro.serve.ExecutableCache`), the
StudyService response store, and :meth:`repro.experiments.Study.
simulator`'s memoization — all were unbounded dicts before PR 8/9,
which a long-running service turns into a leak (every entry pins a
jitted executable, a full GridResult, or the closures/datasets it
captured). Lives outside both packages so the experiments layer never
imports the serve layer.

The cache is thread-safe: a :class:`BackgroundServer` flush thread, a
user thread, and the ``stop()`` drain all hammer one
:class:`ExecutableCache` concurrently, so every mutation of the
underlying ``OrderedDict`` (including ``move_to_end`` on a hit) holds
an internal lock. :meth:`get_or_create` is the atomic
check-build-insert concurrent callers need — a plain get/put pair has
a race window where two threads both miss and both build (a
double-compile for an executable cache).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable


class LRUCache:
    """Least-recently-used bounded mapping (thread-safe).

    ``get`` refreshes recency and counts a hit or miss; ``put`` inserts
    (refreshing recency on overwrite) and evicts the coldest entry past
    ``maxsize``, invoking ``on_evict(key, value)`` so owners can release
    per-entry resources. ``on_evict`` runs *outside* the internal lock —
    it may call back into the cache. Counters survive :meth:`clear` —
    they describe the cache's lifetime, not its current contents.
    """

    def __init__(self, maxsize: int = 32,
                 on_evict: Callable[[Any, Any], None] | None = None):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = int(maxsize)
        self._data: OrderedDict = OrderedDict()
        self._on_evict = on_evict
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, default=None):
        with self._lock:
            try:
                value = self._data[key]
            except KeyError:
                self.misses += 1
                return default
            self._data.move_to_end(key)
            self.hits += 1
            return value

    def _insert_locked(self, key, value) -> list:
        """Insert under the held lock; return evicted pairs for the
        caller to notify outside it."""
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        evicted = []
        while len(self._data) > self.maxsize:
            evicted.append(self._data.popitem(last=False))
            self.evictions += 1
        return evicted

    def _notify(self, evicted) -> None:
        if self._on_evict is not None:
            for old_key, old_value in evicted:
                self._on_evict(old_key, old_value)

    def put(self, key, value) -> None:
        with self._lock:
            evicted = self._insert_locked(key, value)
        self._notify(evicted)

    def get_or_create(self, key, factory: Callable[[], Any]):
        """Atomic get-else-build-else-insert.

        Exactly one caller's ``factory()`` runs per missing key even
        under contention — the whole check-build-insert sequence holds
        the lock (the lock is reentrant, so a factory may read the
        cache, but it must not block on another thread that needs it).
        Counts one hit or one miss, like ``get``.
        """
        with self._lock:
            try:
                value = self._data[key]
            except KeyError:
                self.misses += 1
                value = factory()
                evicted = self._insert_locked(key, value)
            else:
                self._data.move_to_end(key)
                self.hits += 1
                return value
        self._notify(evicted)
        return value

    def pop(self, key, default=None):
        """Remove and return ``key`` without eviction accounting (the
        entry left by request, it wasn't pushed out)."""
        with self._lock:
            return self._data.pop(key, default)

    def __contains__(self, key) -> bool:  # no recency/counter side effects
        with self._lock:
            return key in self._data

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def values(self):
        with self._lock:
            return list(self._data.values())

    def keys(self):
        with self._lock:
            return list(self._data.keys())

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def stats(self) -> dict:
        """Lifetime counters + current occupancy, one flat dict."""
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "size": len(self._data),
                    "maxsize": self.maxsize}
