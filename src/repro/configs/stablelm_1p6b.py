"""stablelm-1.6b — small dense decoder (StableLM 2).

[hf:stabilityai/stablelm-2-1_6b] 24L, d_model=2048, 32 heads (MHA,
kv=32), d_ff=5632, vocab=100352. LayerNorm (with bias) per the model
card; gated SiLU FFN.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab=100352,
    rope_theta=10000.0,
    long_context_window=8192,
    norm="layernorm",
    act="silu",
    use_bias=True,
    dtype_name="bfloat16",
    remat=True,
    citation="[hf:stabilityai/stablelm-2-1_6b]",
)
