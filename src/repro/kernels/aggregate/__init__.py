from repro.kernels.aggregate.ops import compose_masks, masked_scaled_aggregate
from repro.kernels.aggregate.ref import masked_scaled_aggregate_ref

__all__ = ["compose_masks", "masked_scaled_aggregate",
           "masked_scaled_aggregate_ref"]
