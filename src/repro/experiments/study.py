"""Declarative studies: composable sweep axes → labeled grid results.

A :class:`Study` is the experiment-facing spec of a whole grid — the
cross-product of registered sweep axes (:mod:`repro.experiments.axes`)
over a fixed step budget:

    study = (Study("fig1_grid", num_steps=1000)
             .axis("scheduler", ["alg1", "benchmark1", "benchmark2", "oracle"])
             .axis("arrivals", ["periodic", "binary", "uniform"])
             .axis("seeds", 8))
    result = study.run(grads_fn=..., p=..., optimizer=..., params0=w0,
                       config=ExecutionConfig(mesh=make_cell_mesh()))
    result.reduce(metric, over="seed")["alg1_periodic"]

``Study.run`` owns simulator construction (cached per argument identity,
so repeated runs of the same study hit the jit cache instead of
re-tracing every group) and dispatches to the single execution core
(:func:`repro.experiments.engine.execute_cells`): batched vmap,
device-sharded shard_map (``ExecutionConfig.mesh``), or the sequential
per-cell baseline (``ExecutionConfig.sequential``). Resolution groups
cells by component structure exactly as the engine compiles them — a
4-scheduler × 3-arrival × 8-seed study still traces 12 computations.

Named studies (``fig1``, ``fig1_grid``, ``capacity_sweep``,
``day_night``, ``population_scaling``) live in a registry
(:func:`register_study` / :func:`get_study`) that subsumes the legacy
grid registry — :func:`repro.experiments.get_grid` resolves through it.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Sequence

import numpy as np

from repro._lru import LRUCache
from repro.core.trainer import ClientSimulator
from repro.experiments import engine
from repro.experiments.axes import AXIS_ORDER, get_axis
from repro.experiments.results import GridResult
from repro.experiments.scenario import FIG1_SCHEDULERS, Scenario

#: Bound on the per-Study simulator memoization (:meth:`Study.simulator`).
#: Each entry pins a ClientSimulator — and, transitively, every compiled
#: executable the engine's jit cache keyed on it plus the datasets its
#: grads_fn closure captured — so the cache must not grow without bound
#: in a long-running process (DESIGN.md §11). LRU with the same policy
#: as the serve layer's executable cache (:mod:`repro._lru`).
SIM_CACHE_SIZE = 8


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """How a study executes — everything that is not *what* to run.

    mesh : device mesh for sharded execution: 1-D cells mesh
        (DESIGN.md §5), 1-D ``clients`` mesh (within-cell client-axis
        sharding, DESIGN.md §8) or 2-D ``(cells, clients)`` grid mesh
        (:func:`repro.experiments.placement.make_grid_mesh`); None (or
        1 device) → single-device vmap path. The mesh may span
        processes in a ``jax.distributed`` session (DESIGN.md §13 —
        bring it up via :mod:`repro.launch.distributed` and build from
        global devices, e.g. ``placement.make_multihost_mesh()``);
        dispatch is unchanged, and results come back as host numpy on
        every process.
    eval_fn : optional (params) -> metric pytree, evaluated inside the
        compiled loop every ``eval_every`` steps.
    eval_every : eval chunk length; 0 → one eval at the end when
        ``eval_fn`` is set.
    sequential : run the per-cell baseline (one traced scan per cell)
        instead of the batched engine — for cross-checks and timing.
    client_reduction : cross-shard aggregation under a ``clients`` mesh
        axis — ``"psum"`` (default: bandwidth-optimal, f32 tolerance vs
        the vmap path), ``"gather"`` (the bitwise differential oracle),
        ``"fused[_bf16]"`` (fused reduce-and-update kernel, plain sgd()
        only), or ``"psum_bf16"`` (bf16-on-the-wire partials, f32
        accumulation) — DESIGN.md §9. Ignored without a clients axis.
    degrade : arm the graceful-degradation ladder (DESIGN.md §10): a
        group whose sharded dispatch raises ``ValueError`` retries one
        reduction rung down (fused → psum → gather) and finally on the
        single-device vmap path, recording every move
        (``GridResult.downgrades``). Off by default — errors raise.
    checkpoint_dir : directory for preemption-safe execution
        (:func:`repro.experiments.engine.execute_cells_resumable`): the
        study runs in checkpointed chunks and a killed run resumes from
        here, bitwise identical to the uninterrupted run. Incompatible
        with ``mesh`` / ``sequential`` / ``eval_fn``.
    checkpoint_every : chunk length between checkpoints (0 → one chunk,
        i.e. checkpoint only at the end).
    checkpoint_keep : retained checkpoints per structure group.
    halt_on_divergence : stop advancing a structure group once every
        (scenario, seed) lane has gone non-finite; the unrun tail
        reports NaN metrics with ``finite=False``. Resumable path only.
    """

    mesh: Any = None
    eval_fn: Callable | None = None
    eval_every: int = 0
    sequential: bool = False
    client_reduction: str = "psum"
    degrade: bool = False
    checkpoint_dir: str | None = None
    checkpoint_every: int = 0
    checkpoint_keep: int = 3
    halt_on_divergence: bool = False

    # ------------------------------------------------------ serialization

    def to_manifest(self) -> dict:
        """``execution-config/v1`` envelope (DESIGN.md §11). ``mesh`` /
        ``eval_fn`` hold live objects and must be None — manifests run
        the vmap path."""
        from repro.experiments import manifest

        return manifest.execution_config_to_manifest(self)

    def to_json(self, **json_kw) -> str:
        import json

        return json.dumps(self.to_manifest(), **json_kw)

    @classmethod
    def from_manifest(cls, doc: dict) -> "ExecutionConfig":
        from repro.experiments import manifest

        return manifest.execution_config_from_manifest(doc)

    @classmethod
    def from_json(cls, text: str) -> "ExecutionConfig":
        from repro.experiments import manifest

        return manifest.execution_config_from_manifest(manifest.loads(text))


class Study:
    """Declarative sweep spec: named axes × a step budget.

    Axes are given either at construction (``axes={...}``, scalar values
    = fixed, sequences = swept) or via the chainable :meth:`axis`. The
    ``seeds`` axis is special: the engine vmaps it inside each cell, so
    it never appears in cell names and surfaces as the ``seed`` axis of
    the :class:`GridResult`.
    """

    def __init__(self, name: str = "study", *, num_steps: int,
                 axes: dict | None = None):
        self.name = name
        self.num_steps = int(num_steps)
        self._axes: dict[str, tuple] = {}
        self._fixed: set[str] = set()
        self._sim_cache = LRUCache(maxsize=SIM_CACHE_SIZE)
        for axis, values in (axes or {}).items():
            self.axis(axis, values)

    def axis(self, name: str, values) -> "Study":
        """Set one sweep axis; a scalar fixes it, a sequence sweeps it.

        Unknown axis names raise with the registered alternatives.
        Returns self for chaining.
        """
        spec = get_axis(name)  # validates; raises ValueError with axis_names()
        if name == "seeds":
            # seeds is a count or an explicit list, never a sweep of lists
            self._axes[name] = values
            return self
        fixed = spec.is_value(values)
        if fixed:
            values = (values,)
            self._fixed.add(name)
        else:
            values = tuple(values)
            self._fixed.discard(name)
            if not values:
                raise ValueError(f"axis {name!r} needs at least one value")
        self._axes[name] = values
        return self

    @property
    def axes(self) -> dict[str, tuple]:
        """Resolved axes in canonical order (seeds last)."""
        ordered = [n for n in AXIS_ORDER if n in self._axes]
        ordered += [n for n in self._axes if n not in ordered]
        return {n: self._axes[n] for n in ordered}

    def seeds(self) -> int | Sequence[int]:
        return self._axes.get("seeds", 8)

    # -------------------------------------------------------- serialization

    def to_manifest(self) -> dict:
        """``study/v1`` envelope: name, step budget, ordered axes with
        fixed/swept flags, seeds (:mod:`repro.experiments.manifest`)."""
        from repro.experiments import manifest

        return manifest.study_to_manifest(self)

    def to_json(self, **json_kw) -> str:
        import json

        return json.dumps(self.to_manifest(), **json_kw)

    @classmethod
    def from_manifest(cls, doc: dict) -> "Study":
        """Decode a ``study/v1`` envelope — typed-config-from-dict over
        the axis/scheduler/arrival/fault registries; unknown names raise
        naming the registry and its valid keys."""
        from repro.experiments import manifest

        return manifest.study_from_manifest(doc)

    @classmethod
    def from_json(cls, text: str) -> "Study":
        from repro.experiments import manifest

        return manifest.study_from_manifest(manifest.loads(text))

    def _seed_values(self) -> tuple:
        seeds = self.seeds()
        return tuple(range(seeds)) if isinstance(seeds, int) else tuple(seeds)

    # ---------------------------------------------------------- resolution

    def _sweep_axes(self) -> dict[str, tuple]:
        return {n: v for n, v in self.axes.items() if n != "seeds"}

    def resolve(self) -> list[Scenario]:
        """Cross-product the axes into named Scenario cells."""
        return [sc for sc, _ in self._resolve_labeled()]

    def _resolve_labeled(self) -> list[tuple[Scenario, dict]]:
        sweep = self._sweep_axes()
        if "scheduler" not in sweep or "arrivals" not in sweep:
            raise ValueError(
                f"study {self.name!r} needs at least the scheduler and "
                f"arrivals axes; have {list(sweep)}")
        cells = []
        for combo in itertools.product(*sweep.values()):
            labels = dict(zip(sweep.keys(), combo))
            draft: dict = {"n_clients": 8, "horizon": self.num_steps + 1,
                           "taus": None, "scheduler_kwargs": {},
                           "arrival_kwargs": {}}
            parts = []
            for axis, value in labels.items():
                spec = get_axis(axis)
                spec.apply(draft, value)
                part = spec.fmt(value, axis in self._fixed)
                if part is not None:
                    parts.append(part)
            name = "_".join(parts) if parts else "cell"
            cells.append((Scenario(name=name, **draft), labels))
        engine.check_unique_names([sc for sc, _ in cells])
        return cells

    # ----------------------------------------------------------- execution

    def simulator(self, *, grads_fn, p, optimizer, loss_fn=None,
                  use_kernel: bool = False) -> ClientSimulator:
        """Build (or reuse) the study's ClientSimulator.

        The grid engine's jit cache keys on the simulator by identity,
        so the study memoizes construction on its ingredients —
        ``study.run(...)`` called twice with the same functions
        re-traces nothing. Functions are compared by equality (bound
        methods like ``problem.suboptimality`` are a fresh object per
        attribute access but compare equal); the weight vector ``p`` by
        value.

        The memoization is a **bounded LRU** (:data:`SIM_CACHE_SIZE`
        entries): a long-running driver cycling through many distinct
        problems evicts the coldest simulator instead of pinning every
        executable-plus-dataset ever built. :meth:`cache_stats` /
        :meth:`clear_cache` expose the counters.
        """
        key = (grads_fn, optimizer, loss_fn, use_kernel,
               tuple(np.asarray(p, np.float32).reshape(-1).tolist()))
        return self._sim_cache.get_or_create(
            key, lambda: ClientSimulator(
                grads_fn=grads_fn, p=p, optimizer=optimizer,
                loss_fn=loss_fn, use_kernel=use_kernel))

    def cache_stats(self) -> dict:
        """Hit/miss/eviction counters + occupancy of the simulator
        memoization (:meth:`simulator`)."""
        return self._sim_cache.stats()

    def clear_cache(self, *, engine_caches: bool = True) -> dict:
        """Drop the study's memoized simulators — and, by default, the
        engine's compiled-executable caches they key (an evicted
        simulator alone would stay pinned by the process-global jit
        cache). Returns the final :meth:`cache_stats` snapshot so
        callers can log what the cache did before it was dropped."""
        stats = self._sim_cache.stats()
        self._sim_cache.clear()
        if engine_caches:
            engine.clear_cache()
        return stats

    def run(self, *, params0, grads_fn=None, p=None, optimizer=None,
            loss_fn=None, use_kernel: bool = False,
            sim: ClientSimulator | None = None,
            config: ExecutionConfig | None = None) -> GridResult:
        """Execute the whole study and return a labeled :class:`GridResult`.

        Pass either a prebuilt ``sim`` or the simulator ingredients
        (``grads_fn`` / ``p`` / ``optimizer`` [+ ``loss_fn`` /
        ``use_kernel``] — memoized, see :meth:`simulator`). Everything
        about *how* to execute lives in ``config``.
        """
        cfg = config or ExecutionConfig()
        if sim is None:
            if grads_fn is None or p is None or optimizer is None:
                raise ValueError(
                    "either pass a prebuilt sim= or all of "
                    "grads_fn/p/optimizer")
            sim = self.simulator(grads_fn=grads_fn, p=p, optimizer=optimizer,
                                 loss_fn=loss_fn, use_kernel=use_kernel)
        cells = self._resolve_labeled()
        if cfg.checkpoint_dir is not None:
            conflicts = [n for n, v in (("mesh", cfg.mesh),
                                        ("sequential", cfg.sequential),
                                        ("eval_fn", cfg.eval_fn)) if v]
            if conflicts:
                raise ValueError(
                    f"checkpoint_dir (resumable execution) is incompatible "
                    f"with {conflicts} — run those studies unchunked")
            results = engine.execute_cells_resumable(
                [sc for sc, _ in cells], sim=sim, params0=params0,
                num_steps=self.num_steps, seeds=self.seeds(),
                checkpoint_dir=cfg.checkpoint_dir,
                checkpoint_every=cfg.checkpoint_every,
                keep=cfg.checkpoint_keep,
                halt_on_divergence=cfg.halt_on_divergence)
        else:
            results = engine.execute_cells(
                [sc for sc, _ in cells], sim=sim, params0=params0,
                num_steps=self.num_steps, seeds=self.seeds(),
                eval_fn=cfg.eval_fn, eval_every=cfg.eval_every,
                mesh=cfg.mesh, sequential=cfg.sequential,
                client_reduction=cfg.client_reduction, degrade=cfg.degrade)
        axes = dict(self._sweep_axes())
        axes["seed"] = self._seed_values()
        return GridResult(
            cells={sc.name: results[sc.name] for sc, _ in cells},
            labels={sc.name: labels for sc, labels in cells},
            axes=axes, name=self.name,
            downgrades=engine.last_downgrades())


def build_components(*, scheduler: str, arrivals, n_clients: int,
                     horizon: int, taus_profile="paper", capacity=None):
    """One cell's (scheduler, energy) pair straight from the axis
    registry — the single-run entry point ``repro.launch.train`` uses,
    so drivers and studies build components through one code path."""
    study = Study("cell", num_steps=horizon - 1,
                  axes={"scheduler": scheduler, "arrivals": arrivals,
                        "n_clients": n_clients, "taus_profile": taus_profile})
    if capacity is not None:
        study.axis("capacity", capacity)
    (cell,) = study.resolve()
    return cell.build()


# ------------------------------------------------------------ study registry

_STUDIES: dict[str, Callable[..., Study]] = {}


def register_study(name: str):
    """Decorator: register a named Study factory ``(**kw) -> Study``."""

    def deco(fn):
        _STUDIES[name] = fn
        return fn

    return deco


def get_study(name: str, **kw) -> Study:
    try:
        factory = _STUDIES[name]
    except KeyError:
        raise ValueError(
            f"unknown study {name!r}; have {study_names()}") from None
    return factory(**kw)


def study_names() -> list[str]:
    return sorted(_STUDIES)


@register_study("fig1")
def _fig1(n_clients: int = 40, num_steps: int = 1000, taus_profile="paper",
          seeds=8) -> Study:
    """Paper Figure 1 verbatim: 4 methods on periodic (eq. 37) arrivals."""
    return Study("fig1", num_steps=num_steps, axes={
        "scheduler": list(FIG1_SCHEDULERS), "arrivals": "periodic",
        "n_clients": n_clients, "taus_profile": taus_profile,
        "seeds": seeds})


@register_study("fig1_grid")
def _fig1_grid(n_clients: int = 40, num_steps: int = 1000,
               taus_profile="paper", seeds=8) -> Study:
    """Scenario-diversity extension: 4 methods × all 3 stationary
    arrival families."""
    return Study("fig1_grid", num_steps=num_steps, axes={
        "scheduler": list(FIG1_SCHEDULERS),
        "arrivals": ["periodic", "binary", "uniform"],
        "n_clients": n_clients, "taus_profile": taus_profile,
        "seeds": seeds})


@register_study("capacity_sweep")
def _capacity_sweep(n_clients: int = 8, num_steps: int = 2000,
                    capacities: Sequence[float] = (1.0, 2.0, 4.0),
                    taus_profile="paper", seeds=8) -> Study:
    """Battery-capacity sweep for the beyond-paper adaptive scheduler —
    one leaf-stacked compiled computation for the whole sweep."""
    return Study("capacity_sweep", num_steps=num_steps, axes={
        "scheduler": "battery_adaptive", "arrivals": "binary",
        "capacity": [float(c) for c in capacities],
        "n_clients": n_clients, "taus_profile": taus_profile,
        "seeds": seeds})


@register_study("day_night")
def _day_night(n_clients: int = 8, num_steps: int = 2000, period: int = 50,
               contrast: float = 3.0, taus_profile="paper",
               seeds=8) -> Study:
    """Non-stationary day/night β_t (arXiv:2102.11274 regime): the
    energy-aware schedulers vs the energy-agnostic baseline under a
    periodic harvest-rate profile with the same mean rate 1/τ."""
    return Study("day_night", num_steps=num_steps, axes={
        "scheduler": ["alg2", "benchmark1", "battery_adaptive", "oracle"],
        "arrivals": ("day_night",
                     {"period": period, "contrast": contrast}),
        "n_clients": n_clients, "taus_profile": taus_profile,
        "seeds": seeds})


@register_study("population_scaling")
def _population_scaling(n_clients: Sequence[int] = (4, 8, 16),
                        num_steps: int = 1000, taus_profile="paper",
                        seeds=8) -> Study:
    """Client-population scaling curve as ONE compiled computation:
    population size is a *data* axis (DESIGN.md §7) — every cell is
    padded to the simulator capacity ``len(sim.p)`` with an active-row
    mask, so all N values of the scheduler × arrival structure share a
    single trace. The caller's ``sim``/``grads_fn``/``p`` must be built
    at capacity ≥ max(n_clients); each cell reweights (and crops its
    participation history) to its own N."""
    return Study("population_scaling", num_steps=num_steps, axes={
        "scheduler": "alg2", "arrivals": "binary",
        "n_clients": [int(n) for n in n_clients],
        "taus_profile": taus_profile, "seeds": seeds})
