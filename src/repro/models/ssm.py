"""State-space / recurrent blocks: Mamba2 (SSD), mLSTM, sLSTM.

Mamba2 and mLSTM are both *scalar-decay gated linear recurrences* on a
matrix state,

    H_t = a_t · H_{t-1} + k_t v_tᵀ,      y_t = q_tᵀ H_t

so they share one engine: :func:`chunked_gla` — a chunked
(intra-chunk-quadratic + inter-chunk-scan) evaluation that is
sub-quadratic in sequence length, TPU-friendly (chunk matmuls hit the
MXU), and exact (not an approximation). Decode is the O(1) single-step
update :func:`gla_step`. This is the hardware adaptation of the papers'
CUDA kernels (Mamba2's SSD / xLSTM's fused scan) to TPU: chunk matmuls
replace warp-level scans.

Numerical notes: the recurrence runs in float32; decays are handled in
log-space. Gates use sigmoid (not exp with max-stabilizer as in xLSTM) —
a documented simplification (DESIGN.md) that keeps the state bounded.

sLSTM has a true hidden-to-hidden recurrent matrix (non-associative), so
it runs as a ``lax.scan`` over time — also the honest TPU answer, since
the original's speed relies on GPU register-level tricks with no MXU
analogue.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_norm, dense, dense_init, maybe_shard, norm_init

_LOG_EPS = 1e-12


# ===================================================================== GLA

def chunked_gla(a, k, v, q, h0=None, chunk: int = 64):
    """Chunked gated linear recurrence.

    a: (B,S,H) decay in (0,1];   k,q: (B,S,H,Dk);   v: (B,S,H,Dv)
    Returns y: (B,S,H,Dv) and final state (B,H,Dk,Dv).
    """
    b, s, h = a.shape
    dk, dv = k.shape[-1], v.shape[-1]
    if s % chunk != 0:
        pad = chunk - s % chunk
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = a.shape[1]
    nc = sp // chunk

    f32 = lambda x: x.astype(jnp.float32)
    a, k, v, q = f32(a), f32(k), f32(v), f32(q)
    # (nc, B, chunk, H, ...) for scan.
    resh = lambda x: x.reshape((b, nc, chunk) + x.shape[2:]).swapaxes(0, 1)
    a_c, k_c, v_c, q_c = resh(a), resh(k), resh(v), resh(q)
    la = jnp.cumsum(jnp.log(jnp.maximum(a_c, _LOG_EPS)), axis=2)  # (nc,B,c,H)

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def body(hstate, xs):
        la_i, k_i, v_i, q_i = xs  # (B,c,H,...)
        # inter-chunk: y += decay(start→t) · qᵀ H_prev
        qd = q_i * jnp.exp(la_i)[..., None]
        y_inter = jnp.einsum("bthd,bhdv->bthv", qd, hstate)
        # intra-chunk (quadratic in `chunk` only)
        ratio = jnp.exp(la_i[:, :, None, :] - la_i[:, None, :, :])  # (B,t,s,H)
        scores = jnp.einsum("bthd,bshd->btsh", q_i, k_i) * ratio
        scores = scores * tri[None, :, :, None]
        y_intra = jnp.einsum("btsh,bshv->bthv", scores, v_i)
        # carry: H ← decay(chunk)·H + Σ_s decay(s→end)·k_s v_sᵀ
        dec_end = jnp.exp(la_i[:, -1:, :] - la_i)  # (B,c,H)
        h_new = (jnp.exp(la_i[:, -1])[..., None, None] * hstate
                 + jnp.einsum("bshd,bshv,bsh->bhdv", k_i, v_i, dec_end))
        return h_new, y_inter + y_intra

    if h0 is None:
        h0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    h_fin, ys = jax.lax.scan(body, f32(h0), (la, k_c, v_c, q_c))
    y = ys.swapaxes(0, 1).reshape(b, sp, h, dv)[:, :s]
    return y, h_fin


def gla_step(hstate, a_t, k_t, v_t, q_t):
    """One decode step. hstate: (B,H,Dk,Dv); a_t: (B,H); k/q: (B,H,Dk);
    v: (B,H,Dv). Returns (y (B,H,Dv), new_state)."""
    f32 = lambda x: x.astype(jnp.float32)
    h_new = (f32(a_t)[..., None, None] * f32(hstate)
             + f32(k_t)[..., :, None] * f32(v_t)[..., None, :])
    y = jnp.einsum("bhd,bhdv->bhv", f32(q_t), h_new)
    return y, h_new


# ============================================================== causal conv

def init_causal_conv(key, channels, width, dtype):
    return {"w": (jax.random.normal(key, (width, channels)) * (width ** -0.5)
                  ).astype(dtype),
            "b": jnp.zeros((channels,), dtype)}


def causal_conv(params, x, state=None):
    """Depthwise causal conv. x: (B,S,C). state: (B,width-1,C) or None.
    Returns (y, new_state) — new_state holds the trailing width-1 inputs."""
    width = params["w"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * params["w"][i] for i in range(width))
    y = y + params["b"]
    new_state = xp[:, -(width - 1):] if width > 1 else state
    return y, new_state


# ================================================================== Mamba2

def mamba2_dims(d_model, head_dim=64, expand=2):
    d_inner = expand * d_model
    return d_inner, d_inner // head_dim


def init_mamba2(key, d_model, d_state, dtype, head_dim=64, expand=2,
                conv_width=4):
    d_inner, n_heads = mamba2_dims(d_model, head_dim, expand)
    ks = jax.random.split(key, 5)
    conv_ch = d_inner + 2 * d_state
    return {
        "in_proj": dense_init(
            ks[0], d_model, 2 * d_inner + 2 * d_state + n_heads, dtype),
        "conv": init_causal_conv(ks[1], conv_ch, conv_width, dtype),
        "a_log": jnp.zeros((n_heads,), jnp.float32),         # A = −exp(a_log)
        "dt_bias": jnp.full((n_heads,), -2.0, jnp.float32),  # softplus ≈ 0.13
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "gate_norm": norm_init(d_inner, dtype),
        "out_proj": dense_init(ks[4], d_inner, d_model, dtype),
    }


def _mamba2_preact(params, x, d_state, head_dim, conv_state=None):
    """Shared by train & decode paths: projections, conv, gates."""
    b, s, d_model = x.shape
    d_inner, n_heads = mamba2_dims(d_model, head_dim)
    zxbcdt = dense(params["in_proj"], x)
    z, xin, bmat, cmat, dt_raw = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + d_state, 2 * d_inner + 2 * d_state],
        axis=-1)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out, conv_state = causal_conv(params["conv"], conv_in, conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = jnp.exp(-jnp.exp(params["a_log"]) * dt)                            # decay
    xh = xin.reshape(b, s, n_heads, head_dim)
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, s, n_heads, d_state))
    v = xh.astype(jnp.float32) * dt[..., None]
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, s, n_heads, d_state))
    return z, xh, a, k, v, q, conv_state, d_inner, n_heads


def apply_mamba2(params, x, *, d_state, head_dim=64, chunk=64):
    """Training / prefill path. x: (B,S,D) -> y (B,S,D)."""
    b, s, d_model = x.shape
    z, xh, a, k, v, q, _, d_inner, n_heads = _mamba2_preact(
        params, x, d_state, head_dim)
    y, _ = chunked_gla(a, k, v, q, chunk=chunk)          # (B,S,H,hd)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = apply_norm(params["gate_norm"], y * jax.nn.silu(z))
    return dense(params["out_proj"], y)


def init_mamba2_state(batch, d_model, d_state, dtype, head_dim=64,
                      conv_width=4):
    d_inner, n_heads = mamba2_dims(d_model, head_dim)
    return {
        "conv": jnp.zeros((batch, conv_width - 1, d_inner + 2 * d_state), dtype),
        "ssm": jnp.zeros((batch, n_heads, d_state, head_dim), jnp.float32),
    }


def decode_mamba2(params, x, state, *, d_state, head_dim=64):
    """One-token decode. x: (B,1,D) -> (y (B,1,D), new state)."""
    b, _, d_model = x.shape
    z, xh, a, k, v, q, conv_state, d_inner, n_heads = _mamba2_preact(
        params, x, d_state, head_dim, conv_state=state["conv"])
    y, ssm = gla_step(state["ssm"], a[:, 0], k[:, 0], v[:, 0], q[:, 0])
    y = y[:, None] + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = apply_norm(params["gate_norm"], y * jax.nn.silu(z))
    return dense(params["out_proj"], y), {"conv": conv_state, "ssm": ssm}


# =================================================================== mLSTM

def init_mlstm(key, d_model, n_heads, dtype, expand=2, conv_width=4):
    d_inner = expand * d_model
    dh = d_inner // n_heads
    ks = jax.random.split(key, 7)
    # q/k/v are per-head block-diagonal (xLSTM's proj_blocksize): each head
    # mixes only its own channels — H·dh² params instead of d_inner².
    blockdiag = lambda k: (jax.random.normal(k, (n_heads, dh, dh))
                           * (dh ** -0.5)).astype(dtype)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv": init_causal_conv(ks[1], d_inner, conv_width, dtype),
        "wq": blockdiag(ks[2]),
        "wk": blockdiag(ks[3]),
        "wv": blockdiag(ks[4]),
        "w_gates": dense_init(ks[5], d_model, 2 * n_heads, jnp.float32,
                              use_bias=True),
        "out_norm": norm_init(d_inner, dtype),
        "out_proj": dense_init(ks[6], d_inner, d_model, dtype),
    }


def _mlstm_preact(params, x, n_heads, conv_state=None):
    b, s, d_model = x.shape
    up = dense(params["in_proj"], x)
    xin, z = jnp.split(up, 2, axis=-1)
    conv_out, conv_state = causal_conv(params["conv"], xin, conv_state)
    conv_out = jax.nn.silu(conv_out)
    d_inner = conv_out.shape[-1]
    dh = d_inner // n_heads
    hs = lambda t: t.reshape(b, s, n_heads, dh)
    bd = lambda w, t: jnp.einsum("bshd,hde->bshe", hs(t), w)
    q = bd(params["wq"], conv_out) * (dh ** -0.5)
    k = bd(params["wk"], conv_out) * (dh ** -0.5)
    v = bd(params["wv"], xin)
    gates = dense(params["w_gates"], x.astype(jnp.float32))
    i_g, f_g = jnp.split(gates, 2, axis=-1)               # (B,S,H)
    i_g = jax.nn.sigmoid(i_g)
    f_g = jax.nn.sigmoid(f_g + 3.0)                       # bias toward remember
    # Normalizer trick: v' = [v, 1]; the extra column accumulates n_t.
    v_ext = jnp.concatenate(
        [v.astype(jnp.float32), jnp.ones(v.shape[:-1] + (1,), jnp.float32)],
        axis=-1)
    k_in = k.astype(jnp.float32) * i_g[..., None]
    return z, q, k_in, v_ext, f_g, conv_state, d_inner, dh


def _mlstm_out(params, y_ext, z, b, s, d_inner, dtype):
    num, den = y_ext[..., :-1], y_ext[..., -1:]
    h = num / (jnp.abs(den) + 1.0)
    h = h.reshape(b, s, d_inner).astype(dtype)
    h = apply_norm(params["out_norm"], h) * jax.nn.silu(z)
    return dense(params["out_proj"], h)


def apply_mlstm(params, x, *, n_heads, chunk=64):
    b, s, _ = x.shape
    z, q, k_in, v_ext, f_g, _, d_inner, dh = _mlstm_preact(params, x, n_heads)
    y_ext, _ = chunked_gla(f_g, k_in, v_ext, q.astype(jnp.float32), chunk=chunk)
    return _mlstm_out(params, y_ext, z, b, s, d_inner, x.dtype)


def init_mlstm_state(batch, d_model, n_heads, dtype, expand=2, conv_width=4):
    d_inner = expand * d_model
    dh = d_inner // n_heads
    return {
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, n_heads, dh, dh + 1), jnp.float32),
    }


def decode_mlstm(params, x, state, *, n_heads):
    b, _, _ = x.shape
    z, q, k_in, v_ext, f_g, conv_state, d_inner, dh = _mlstm_preact(
        params, x, n_heads, conv_state=state["conv"])
    y, ssm = gla_step(state["ssm"], f_g[:, 0], k_in[:, 0], v_ext[:, 0],
                      q[:, 0].astype(jnp.float32))
    y = _mlstm_out(params, y[:, None], z, b, 1, d_inner, x.dtype)
    return y, {"conv": conv_state, "ssm": ssm}


# =================================================================== sLSTM

def init_slstm(key, d_model, n_heads, dtype):
    dh = d_model // n_heads
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], d_model, 4 * d_model, dtype, use_bias=True),
        # Block-diagonal recurrence: per-head (dh, 4*dh).
        "r": (jax.random.normal(ks[1], (n_heads, dh, 4 * dh)) * (dh ** -0.5)
              ).astype(dtype),
        "out_proj": dense_init(ks[2], d_model, d_model, dtype),
    }


def slstm_cell(params, xt, state, n_heads):
    """xt: (B, D) pre-projected NOT — raw input at one step. state: dict of
    (B, H, dh) tensors c, n, h. Returns (h_flat (B,D), new_state)."""
    b, d_model = xt.shape
    dh = d_model // n_heads
    pre = dense(params["w_in"], xt).reshape(b, n_heads, 4 * dh)
    rec = jnp.einsum("bhd,hde->bhe", state["h"], params["r"])
    i_r, f_r, z_r, o_r = jnp.split((pre + rec).astype(jnp.float32), 4, axis=-1)
    i_g = jax.nn.sigmoid(i_r)
    f_g = jax.nn.sigmoid(f_r + 1.0)
    z_g = jnp.tanh(z_r)
    o_g = jax.nn.sigmoid(o_r)
    c = f_g * state["c"] + i_g * z_g
    n = f_g * state["n"] + i_g
    h = o_g * c / jnp.maximum(n, 1.0)          # f32 carry (scan-stable)
    new = {"c": c, "n": n, "h": h}
    return h.reshape(b, d_model).astype(xt.dtype), new


def init_slstm_state(batch, d_model, n_heads):
    dh = d_model // n_heads
    zeros = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return {"c": zeros, "n": zeros, "h": zeros.astype(jnp.float32)}


def apply_slstm(params, x, *, n_heads):
    """Sequential scan over time (non-associative recurrence)."""
    b, s, d_model = x.shape
    state0 = init_slstm_state(b, d_model, n_heads)
    state0 = {k: v.astype(jnp.float32) for k, v in state0.items()}

    def body(state, xt):
        h, new = slstm_cell(params, xt, state, n_heads)
        return new, h

    _, hs = jax.lax.scan(body, state0, x.swapaxes(0, 1))
    y = hs.swapaxes(0, 1)
    return dense(params["out_proj"], y)


def decode_slstm(params, x, state, *, n_heads):
    h, new = slstm_cell(params, x[:, 0], state, n_heads)
    return dense(params["out_proj"], h[:, None]), new
