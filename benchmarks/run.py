"""Benchmark harness — one module per paper table/figure.

  fig1           paper Figure 1 (accuracy vs iteration, 4 schedulers)
  theory         Theorem 1 bound vs empirical (+ error-floor sweep)
  kernels_bench  kernel-adjacent micro-benchmarks
  roofline_table dry-run roofline terms per (arch x shape x mesh)

Prints ``name,us_per_call,derived`` CSV. Select with ``--only``.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,theory] [--fast]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="shrink fig1 iterations for CI-speed runs")
    args = ap.parse_args()

    sys.path.insert(0, ".")  # examples/ imports
    from benchmarks import fig1, kernels_bench, roofline_table, theory

    suites = {
        "fig1": lambda: fig1.run(iters=100 if args.fast else 250),
        "theory": theory.run,
        "kernels_bench": kernels_bench.run,
        "roofline_table": roofline_table.run,
    }
    selected = [s.strip() for s in args.only.split(",") if s.strip()] \
        or list(suites)

    print("name,us_per_call,derived")
    failed = []
    for name in selected:
        try:
            for row in suites[name]():
                print(row, flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
