"""Fault-injection + non-finite quarantine tests (DESIGN.md §10).

The load-bearing guarantees:

* **Fault-free configs are bitwise unchanged** — every registered fault
  family at rate/size 0 reproduces the no-fault trajectory exactly, for
  every scheduler. The fault layer composes through the existing RNG
  streams by domain-separated ``fold_in`` (never by widening a split
  arity), so arming it cannot perturb a clean run.
* **Dropped rows are exact zeros** through the masked aggregation
  kernels — a dropped client's gradient may be NaN-poisoned and still
  contributes nothing.
* **Quarantine** — a NaN-diverged cell is reported (``diverged``
  first-bad-step per seed) while sibling cells of the same grid are
  bitwise unaffected.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_quadratic
from repro.core.energy import make_arrivals
from repro.core.faults import (
    FAULT_SALT,
    DropUpdates,
    fault_family_names,
    make_fault,
    pad_faults,
)
from repro.core.scheduling import make_scheduler
from repro.core.trainer import ClientSimulator
from repro.experiments import ExecutionConfig, Scenario, Study, engine
from repro.optim import sgd

pytestmark = pytest.mark.faults

ALL_SCHEDULERS = ("alg1", "alg2", "benchmark1", "benchmark2", "oracle",
                  "battery_adaptive")

#: Every registered family at its do-nothing setting.
RATE0 = {
    "drop": {"rate": 0.0},
    "corrupt": {"rate": 0.0, "scale": 0.0},
    "stale": {"rate": 0.0, "delay": 2},
    "offline": {"start": 0, "length": 0},
    "drop_corrupt": {"drop_rate": 0.0, "corrupt_rate": 0.0, "scale": 0.0},
}

N, DIM, STEPS = 8, 6, 25


@pytest.fixture(scope="module")
def problem():
    return make_quadratic(jax.random.PRNGKey(2), n_clients=N, dim=DIM)


@pytest.fixture(scope="module")
def sim(problem):
    return ClientSimulator(
        grads_fn=lambda p, k, t: problem.all_grads(p, key=k, noise=0.05),
        p=problem.p, optimizer=sgd(0.02), loss_fn=problem.suboptimality)


def params0():
    return jnp.full((DIM,), 4.0)


def _cells(sim, scenarios, seeds=2, **kw):
    return engine.execute_cells(scenarios, sim=sim, params0=params0(),
                                num_steps=STEPS, seeds=seeds, **kw)


def _assert_cells_bitwise(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ------------------------------------------------ registry & pytree basics

def test_registry_families():
    assert set(RATE0) <= set(fault_family_names())


def test_fault_components_are_pytrees():
    for kind, kw in RATE0.items():
        f = make_fault(kind, N, **kw)
        leaves, treedef = jax.tree_util.tree_flatten(f)
        back = jax.tree_util.tree_unflatten(treedef, leaves)
        assert type(back) is type(f)


def test_make_fault_unknown_kind_raises():
    with pytest.raises(ValueError, match="fault"):
        make_fault("meteor_strike", N)


def test_rate_validation():
    with pytest.raises(ValueError):
        DropUpdates(rate=1.5)
    with pytest.raises(ValueError):
        DropUpdates(rate=-0.1)


def test_pad_faults_none_passthrough():
    assert pad_faults(None, 16) is None


def test_pad_faults_unknown_component_raises():
    with pytest.raises(TypeError):
        pad_faults(object(), 16)


# --------------------------------------------- rate-0 bitwise regression

@pytest.mark.parametrize("scheduler", ALL_SCHEDULERS)
def test_rate0_faults_bitwise_identical(sim, scheduler):
    """Every fault family at rate 0 reproduces the fault-free grid
    exactly — all schedulers, same seeds, bit for bit."""
    base = Scenario(name="clean", scheduler=scheduler, arrivals="binary",
                    n_clients=N, horizon=STEPS + 1)
    armed = [Scenario(name=k, scheduler=scheduler, arrivals="binary",
                      n_clients=N, horizon=STEPS + 1, faults=k,
                      fault_kwargs=dict(kw)) for k, kw in RATE0.items()]
    res = _cells(sim, [base] + armed)
    ref = np.asarray(res["clean"].history.loss)
    for k in RATE0:
        np.testing.assert_array_equal(
            np.asarray(res[k].history.loss), ref, err_msg=k)
        for la, lb in zip(jax.tree_util.tree_leaves(res[k].params),
                          jax.tree_util.tree_leaves(res["clean"].params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        assert np.all(np.asarray(res[k].diverged) == -1)


def test_fault_salt_never_widens_split():
    """The fault key is fold_in(k_grad, FAULT_SALT), a pure function of
    the existing per-step key — the no-fault streams cannot move."""
    k = jax.random.PRNGKey(0)
    forked = jax.random.fold_in(k, FAULT_SALT)
    assert not np.array_equal(np.asarray(k), np.asarray(forked))


# ---------------------------------------------------- family semantics

def test_drop_reduces_weight_sum(sim):
    sc = [Scenario(name="clean", scheduler="alg1", arrivals="periodic",
                   n_clients=N, horizon=STEPS + 1),
          Scenario(name="drop", scheduler="alg1", arrivals="periodic",
                   n_clients=N, horizon=STEPS + 1, faults="drop",
                   fault_kwargs={"rate": 0.5})]
    res = _cells(sim, sc, seeds=4)
    w_clean = float(np.asarray(res["clean"].history.weight_sum).mean())
    w_drop = float(np.asarray(res["drop"].history.weight_sum).mean())
    assert w_drop < 0.75 * w_clean
    assert np.all(np.isfinite(np.asarray(res["drop"].history.loss)))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_dropped_rows_contribute_exact_zero(problem, use_kernel):
    """drop_corrupt(drop_rate=1, corrupt_rate=1, scale=NaN): every
    gradient row is NaN-poisoned *and* dropped each step. If dropped
    rows contributed anything but exact zeros through the (masked)
    aggregation path, params would go NaN instantly; instead they never
    move and stay finite — on both the reference matvec and the Pallas
    kernel path."""
    leak = Scenario(name="leak", scheduler="alg1", arrivals="periodic",
                    n_clients=N, horizon=STEPS + 1, faults="drop_corrupt",
                    fault_kwargs={"drop_rate": 1.0, "corrupt_rate": 1.0,
                                  "scale": float("nan")})
    sim_k = ClientSimulator(
        grads_fn=lambda p, k, t: problem.all_grads(p, key=k, noise=0.05),
        p=problem.p, optimizer=sgd(0.02), loss_fn=problem.suboptimality,
        use_kernel=use_kernel)
    res = _cells(sim_k, [leak], seeds=3)
    hist = res["leak"].history
    assert np.all(np.asarray(hist.finite))
    assert np.all(np.asarray(hist.weight_sum) == 0.0)
    loss = np.asarray(hist.loss)
    np.testing.assert_array_equal(
        loss, np.broadcast_to(loss[..., :1], loss.shape))
    assert np.all(np.asarray(res["leak"].diverged) == -1)


def test_stale_updates_replay_delayed_gradients(sim):
    """StaleUpdates(rate=1, delay=k): before step k every update is
    dropped (nothing to replay); afterwards the trajectory moves."""
    sc = Scenario(name="stale", scheduler="oracle", arrivals="periodic",
                  n_clients=N, horizon=STEPS + 1, faults="stale",
                  fault_kwargs={"rate": 1.0, "delay": 3})
    res = _cells(sim, [sc], seeds=2)
    w = np.asarray(res["stale"].history.weight_sum)
    # first `delay` steps: all updates dropped -> zero delivered weight
    assert np.all(w[..., :3] == 0.0)
    assert np.any(w[..., 3:] > 0.0)
    assert np.all(np.isfinite(np.asarray(res["stale"].history.loss)))


def test_offline_window_masks_whole_population(sim):
    sc = Scenario(name="off", scheduler="oracle", arrivals="periodic",
                  n_clients=N, horizon=STEPS + 1, faults="offline",
                  fault_kwargs={"start": 5, "length": 4})
    clean = Scenario(name="clean", scheduler="oracle", arrivals="periodic",
                     n_clients=N, horizon=STEPS + 1)
    res = _cells(sim, [sc, clean], seeds=2)
    w = np.asarray(res["off"].history.weight_sum)
    wc = np.asarray(res["clean"].history.weight_sum)
    assert np.all(w[..., 5:9] == 0.0)
    np.testing.assert_array_equal(w[..., :5], wc[..., :5])


def test_periodic_offline_windows(sim):
    sc = Scenario(name="off", scheduler="oracle", arrivals="periodic",
                  n_clients=N, horizon=STEPS + 1, faults="offline",
                  fault_kwargs={"start": 2, "length": 2, "period": 10})
    res = _cells(sim, [sc], seeds=1)
    w = np.asarray(res["off"].history.weight_sum)[0]
    off_steps = {2, 3, 12, 13, 22, 23} & set(range(STEPS))
    for t in range(STEPS):
        assert (w[t] == 0.0) == (t in off_steps), t


# ----------------------------------------------------------- quarantine

def test_poisoned_cell_quarantined_siblings_bitwise(sim):
    """A NaN-poisoned cell reports first-bad-step per seed; the clean
    cells of the same grid are bitwise what they are without it."""
    clean = [Scenario(name=f"{s}_clean", scheduler=s, arrivals="periodic",
                      n_clients=N, horizon=STEPS + 1)
             for s in ("alg1", "benchmark1")]
    bad = Scenario(name="poisoned", scheduler="alg1", arrivals="periodic",
                   n_clients=N, horizon=STEPS + 1, faults="corrupt",
                   fault_kwargs={"rate": 1.0, "scale": float("nan")})
    with_bad = _cells(sim, clean + [bad], seeds=3)
    without = _cells(sim, clean, seeds=3)

    div = np.asarray(with_bad["poisoned"].diverged)
    assert div.shape == (3,)
    assert np.all(div == 0)  # NaN scale poisons step 0
    fin = np.asarray(with_bad["poisoned"].history.finite)
    assert not fin.any()
    for sc in clean:
        _assert_cells_bitwise(with_bad[sc.name], without[sc.name])
        assert np.all(np.asarray(with_bad[sc.name].diverged) == -1)

    summary = engine.divergence_summary(with_bad)
    assert summary["poisoned"] == {"n_diverged": 3, "first_bad_step": 0}
    assert summary["alg1_clean"] == {"n_diverged": 0, "first_bad_step": -1}


def test_divergence_is_absorbing(sim):
    """Late-onset divergence: finite flags are monotone (True then
    False), and first-bad-step matches the onset."""
    sc = Scenario(name="late", scheduler="oracle", arrivals="periodic",
                  n_clients=N, horizon=STEPS + 1, faults="corrupt",
                  fault_kwargs={"rate": 0.05, "scale": float("inf")})
    res = _cells(sim, [sc], seeds=6)
    fin = np.asarray(res["late"].history.finite)
    div = np.asarray(res["late"].diverged)
    for r in range(fin.shape[0]):
        f = fin[r]
        if div[r] < 0:
            assert f.all()
        else:
            assert f[:div[r]].all() and not f[div[r]:].any()


# ------------------------------------------------------- study integration

def test_faults_axis_in_study(problem):
    study = (Study("faults_axis", num_steps=STEPS)
             .axis("scheduler", "alg1").axis("arrivals", "periodic")
             .axis("faults", [None, ("drop", {"rate": 0.3})])
             .axis("seeds", 2))
    res = study.run(
        grads_fn=lambda p, k, t: problem.all_grads(p, key=k, noise=0.05),
        p=problem.p, optimizer=sgd(0.02), loss_fn=problem.suboptimality,
        params0=params0())
    assert set(res.axes) == {"scheduler", "arrivals", "faults", "seed"}
    names = list(res)
    assert any("nofault" in n for n in names)
    assert any("drop" in n for n in names)
    sub = res.sel(faults=None)
    assert len(sub) == 1
    recs = res.to_records()
    assert all({"n_diverged", "first_bad_step"} <= set(r) for r in recs)
    assert res.divergence()[names[0]]["n_diverged"] == 0


def test_faults_require_flat_carry(problem):
    sim = ClientSimulator(
        grads_fn=lambda p, k, t: {"w": problem.all_grads(p["w"])},
        p=problem.p, optimizer=sgd(0.02), flat=False)
    with pytest.raises(ValueError, match="flat-carry"):
        sim.run(jax.random.PRNGKey(0), {"w": params0()}, 5,
                scheduler=make_scheduler("oracle", N),
                energy=make_arrivals("periodic", N, 6),
                faults=DropUpdates(rate=0.5))


# ------------------------------------------------- graceful degradation

@pytest.mark.multidevice
def test_faults_under_client_mesh_raise_without_degrade(sim):
    from repro.experiments import make_client_mesh

    sc = Scenario(name="d", scheduler="alg1", arrivals="periodic",
                  n_clients=N, horizon=STEPS + 1, faults="drop",
                  fault_kwargs={"rate": 0.3})
    with pytest.raises(ValueError, match="clients mesh"):
        _cells(sim, [sc], mesh=make_client_mesh())


@pytest.mark.multidevice
def test_degrade_ladder_falls_back_to_vmap(sim):
    """Faulted cells under a clients mesh walk the reduction ladder,
    then fall back to vmap — recorded, logged, and bitwise equal to the
    plain vmap run."""
    from repro.experiments import make_client_mesh

    sc = Scenario(name="d", scheduler="alg1", arrivals="periodic",
                  n_clients=N, horizon=STEPS + 1, faults="drop",
                  fault_kwargs={"rate": 0.3})
    ref = _cells(sim, [sc])
    got = _cells(sim, [sc], mesh=make_client_mesh(), degrade=True)
    _assert_cells_bitwise(got["d"], ref["d"])
    recs = engine.last_downgrades()
    assert recs and recs[-1].stage == "placement"
    assert recs[-1].to_value == "vmap"
    assert "d" in recs[-1].group
    # records are JSON-serializable for machine consumption
    import json

    assert json.loads(recs[-1].to_json())["stage"] == "placement"


def test_no_downgrades_on_clean_run(sim):
    sc = Scenario(name="c", scheduler="alg1", arrivals="periodic",
                  n_clients=N, horizon=STEPS + 1)
    _cells(sim, [sc], degrade=True)
    assert engine.last_downgrades() == ()
