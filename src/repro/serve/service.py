"""Structure-batched Study service: manifests in, labeled results out.

:class:`StudyService` is the request-driven front end of the scenario
engine (DESIGN.md §11). The service owns the *model context* — one
:class:`~repro.core.trainer.ClientSimulator` (grads_fn, weights,
optimizer) and the initial parameters — while clients submit
**manifests** (:mod:`repro.experiments.manifest`): what to run, never
code. The pipeline per batch:

1. **Admit** — :meth:`submit` parses/validates the manifest (unknown
   registry names fail here, naming the registry), resolves its cells,
   and checks the population capacity. Invalid requests raise at submit;
   admitted requests queue.
2. **Batch** — :meth:`flush` drains the queue and groups requests by
   dispatch signature (step budget, seed list, ExecutionConfig). Each
   group's cells — across *all* its requests — go to
   :func:`repro.experiments.engine.execute_cells` as one scenario list,
   so the engine's structure grouping applies across requests: any mix
   of population sizes of one component structure shares a single
   compiled trace (the PR 4 invariant), and repeat structures are pure
   dispatch through the keyed :class:`~repro.serve.cache.
   ExecutableCache`.
3. **Demux** — results are split back per request (cell names are
   namespaced ``<rid>/<cell>`` on the wire and restored in responses),
   each response carrying its own labeled :class:`~repro.experiments.
   GridResult`, summary records, quarantine report (diverged cells are
   *reported*, per PR 7 semantics — they never fail sibling cells or
   sibling requests), cache/batching counters and timings.

Execution errors fail only the dispatch group that raised — sibling
groups' responses still complete, and every waiter is released.

:class:`BackgroundServer` runs the flush loop on a worker thread with a
small batching window, which is what gives concurrent submitters the
cross-request structure collapse.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Sequence

from repro.experiments import engine, manifest as manifest_mod
from repro.experiments.results import GridResult
from repro.experiments.study import ExecutionConfig, Study
from repro.serve.cache import ExecutableCache

#: ExecutionConfig fields a manifest-driven request must leave unset:
#: they either carry live objects (mesh, eval_fn) or select execution
#: paths the batching engine does not serve (sequential baseline,
#: resumable checkpointing — run those through Study.run directly).
_UNSERVABLE = ("mesh", "eval_fn", "sequential", "checkpoint_dir")


@dataclasses.dataclass
class ServeResponse:
    """One request's result envelope.

    ``records`` are :meth:`GridResult.to_records` rows (per-cell seed
    stats + quarantine fields); ``quarantined`` names the cells with at
    least one diverged seed; ``batch`` describes the dispatch this
    request shared (sibling request count, merged cell count, structure
    dispatches, new compiles); ``cache`` is the executable-cache
    snapshot after the dispatch; ``timings`` carries per-request
    ``latency_us`` (submit → response) and the batch's ``execute_us``.
    ``error`` is set — and result fields empty — when the request's
    dispatch group failed.
    """

    request_id: str
    study: str
    records: list = dataclasses.field(default_factory=list)
    divergence: dict = dataclasses.field(default_factory=dict)
    quarantined: list = dataclasses.field(default_factory=list)
    batch: dict = dataclasses.field(default_factory=dict)
    cache: dict = dataclasses.field(default_factory=dict)
    timings: dict = dataclasses.field(default_factory=dict)
    result: GridResult | None = None
    error: str | None = None


@dataclasses.dataclass
class _Request:
    rid: str
    study: Study
    config: ExecutionConfig
    cells: list  # [(Scenario, labels)] resolved at submit
    seeds_key: tuple
    submitted_at: float
    done: threading.Event


class StudyService:
    """Request-driven scenario-evaluation service (module docstring).

    Parameters mirror :meth:`repro.experiments.Study.run`'s simulator
    ingredients — the service is the long-lived owner of exactly one
    simulator, so every request's jit keys agree. ``cache_size`` bounds
    the keyed executable cache; ``metric`` (``cell -> (R,)``) customizes
    the per-seed scalar behind response records.
    """

    def __init__(self, *, params0, grads_fn=None, p=None, optimizer=None,
                 loss_fn=None, use_kernel: bool = False, sim=None,
                 cache_size: int = 32,
                 metric: Callable | None = None):
        self._sim = engine._resolve_sim(sim, grads_fn, p, optimizer,
                                        loss_fn, use_kernel)
        self._params0 = params0
        self._cache = ExecutableCache(maxsize=cache_size)
        self._metric = metric
        self._lock = threading.Lock()
        self._pending: list[_Request] = []
        self._requests: dict[str, _Request] = {}
        self._responses: dict[str, ServeResponse] = {}
        self._ids = itertools.count()
        self._n_requests = 0
        self._n_cells = 0
        self._n_flushes = 0

    # ------------------------------------------------------------ admission

    @property
    def capacity(self) -> int:
        """Population capacity N_cap = len(sim.p) — the ceiling every
        request's ``n_clients`` must respect."""
        return int(self._sim.p.shape[0])

    def _parse(self, manifest, config):
        if isinstance(manifest, Study):
            return manifest, config
        if isinstance(manifest, str):
            manifest = manifest_mod.loads(manifest)
        study, mconfig = manifest_mod.request_from_manifest(manifest)
        if config is not None and mconfig is not None:
            raise ValueError(
                "request carries an execution config both in the manifest "
                "and as the config= argument — pass one")
        return study, (mconfig if config is None else config)

    def submit(self, manifest, config: ExecutionConfig | None = None) -> str:
        """Admit one request; returns its id.

        ``manifest`` is a JSON string, a ``study/v1`` or
        ``study-request/v1`` dict, or a Study instance. Invalid requests
        — malformed manifest, unknown registry name, unserveable config,
        population above capacity — raise here, before anything queues.
        """
        study, config = self._parse(manifest, config)
        config = config or ExecutionConfig()
        bad = [f for f in _UNSERVABLE if getattr(config, f)]
        if bad:
            raise ValueError(
                f"ExecutionConfig fields {bad} are not serveable — the "
                f"service batches requests on the vmap engine; run those "
                f"studies through Study.run directly")
        cells = study._resolve_labeled()  # validates axes & unique names
        over = [f"{sc.name} (N={sc.n_clients})" for sc, _ in cells
                if sc.n_clients > self.capacity]
        if over:
            raise ValueError(
                f"request exceeds the service population capacity "
                f"N_cap={self.capacity}: {over}")
        with self._lock:
            rid = f"r{next(self._ids):04d}"
            req = _Request(
                rid=rid, study=study, config=config, cells=cells,
                seeds_key=study._seed_values(),
                submitted_at=time.perf_counter(),
                done=threading.Event())
            self._pending.append(req)
            self._requests[rid] = req
            self._n_requests += 1
        return rid

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    # ------------------------------------------------------------- dispatch

    def flush(self) -> list[ServeResponse]:
        """Execute every pending request, batched, and release waiters.

        Requests group by dispatch signature (num_steps, seeds, config);
        each group's cells merge into one ``execute_cells`` call, where
        the engine collapses same-structure cells — across requests —
        onto shared compiled traces via the keyed executable cache.
        """
        with self._lock:
            batch, self._pending = self._pending, []
        if not batch:
            return []
        self._n_flushes += 1

        dispatch: dict[tuple, list[_Request]] = {}
        for req in batch:
            key = (req.study.num_steps, req.seeds_key, req.config)
            dispatch.setdefault(key, []).append(req)

        responses = []
        for (num_steps, seeds_key, config), reqs in dispatch.items():
            responses.extend(
                self._run_dispatch(num_steps, seeds_key, config, reqs))
        return responses

    def _run_dispatch(self, num_steps, seeds_key, config, reqs):
        merged, owner = [], {}
        for req in reqs:
            for sc, _labels in req.cells:
                wire = f"{req.rid}/{sc.name}"
                merged.append(dataclasses.replace(sc, name=wire))
                owner[wire] = req
        before = self._cache.stats()
        t0 = time.perf_counter()
        try:
            results = engine.execute_cells(
                merged, sim=self._sim, params0=self._params0,
                num_steps=num_steps, seeds=list(seeds_key),
                client_reduction=config.client_reduction,
                executable_cache=self._cache.bind(config))
        except Exception as e:  # noqa: BLE001 — fail this group, not siblings
            responses = []
            for req in reqs:
                resp = ServeResponse(request_id=req.rid,
                                     study=req.study.name,
                                     error=f"{type(e).__name__}: {e}")
                self._finish(req, resp)
                responses.append(resp)
            return responses
        execute_us = (time.perf_counter() - t0) * 1e6
        after = self._cache.stats()
        delta = {k: after[k] - before[k]
                 for k in ("hits", "misses", "evictions", "compiles")}
        self._n_cells += len(merged)

        now = time.perf_counter()
        responses = []
        for req in reqs:
            cells = {sc.name: results[f"{req.rid}/{sc.name}"]
                     for sc, _ in req.cells}
            labels = {sc.name: lab for sc, lab in req.cells}
            axes = dict(req.study._sweep_axes())
            axes["seed"] = seeds_key
            grid = GridResult(cells=cells, labels=labels, axes=axes,
                              name=req.study.name)
            div = grid.divergence()
            resp = ServeResponse(
                request_id=req.rid,
                study=req.study.name,
                records=grid.to_records(self._metric),
                divergence=div,
                quarantined=sorted(n for n, d in div.items()
                                   if d["n_diverged"] > 0),
                batch={"requests": len(reqs), "cells": len(merged),
                       "dispatches": delta["hits"] + delta["misses"],
                       "cache_hits": delta["hits"],
                       "new_compiles": delta["compiles"]},
                cache=after,
                timings={"latency_us": (now - req.submitted_at) * 1e6,
                         "execute_us": execute_us},
                result=grid)
            self._finish(req, resp)
            responses.append(resp)
        return responses

    def _finish(self, req: _Request, resp: ServeResponse) -> None:
        with self._lock:
            self._responses[req.rid] = resp
        req.done.set()

    # ------------------------------------------------------------- results

    def result(self, rid: str) -> ServeResponse:
        """The response for ``rid`` (KeyError if not yet flushed)."""
        with self._lock:
            try:
                return self._responses[rid]
            except KeyError:
                raise KeyError(
                    f"no response for request {rid!r} yet — call flush() "
                    f"or run a BackgroundServer") from None

    def wait(self, rid: str, timeout: float | None = None) -> ServeResponse:
        """Block until ``rid`` has been served (by any flushing thread)."""
        with self._lock:
            req = self._requests.get(rid)
        if req is None:
            raise KeyError(f"unknown request id {rid!r}")
        if not req.done.wait(timeout):
            raise TimeoutError(f"request {rid!r} not served in {timeout}s")
        return self.result(rid)

    def stats(self) -> dict:
        """Service lifetime counters + executable-cache stats."""
        with self._lock:
            out = {"requests": self._n_requests, "flushes": self._n_flushes,
                   "cells": self._n_cells}
        out.update(self._cache.stats())
        out["executable_entries"] = self._cache.cache_entries()
        return out


class BackgroundServer:
    """Worker thread that flushes a :class:`StudyService` continuously.

    ``window_s`` is the batching window: once the queue goes non-empty
    the server waits that long before flushing, so a burst of
    submissions lands in one batch (and one structure-grouped dispatch)
    instead of N. Use as a context manager::

        with BackgroundServer(service):
            rids = [service.submit(m) for m in manifests]
            responses = [service.wait(r) for r in rids]
    """

    def __init__(self, service: StudyService, window_s: float = 0.002,
                 poll_s: float = 0.0005):
        self._service = service
        self._window_s = float(window_s)
        self._poll_s = float(poll_s)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> "BackgroundServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="study-serve")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            if self._service.pending:
                time.sleep(self._window_s)  # let the burst accumulate
                self._service.flush()
            else:
                time.sleep(self._poll_s)

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        self._service.flush()  # drain anything admitted during shutdown

    def __enter__(self) -> "BackgroundServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
