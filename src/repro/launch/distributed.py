"""Multi-host grid execution: ``jax.distributed`` init + worker CLI.

The process-spanning rung of the scaling ladder (DESIGN.md §13,
ROADMAP "Multi-host grids"). Three layers, top to bottom:

1. :func:`initialize` / :func:`init_from_env` — bring up the
   ``jax.distributed`` runtime from CLI flags or the ``REPRO_DIST_*``
   environment (``repro._env.distributed_env``). Must run before the
   jax backend initializes; on CPU hosts it selects the ``gloo``
   cross-process collectives and the placeholder device count first.
2. The worker CLI (``python -m repro.launch.distributed``) — runs the
   canonical differential job (a ragged Fig-1 sub-grid on the quadratic
   problem, ≥ 2 schedulers × ragged populations) through the *unchanged*
   ``Study.run`` / ``execute_cells`` dispatch on a process-spanning
   mesh, asserts the one-compile-per-structure-group guarantee, and
   writes results + a per-process report.
3. :func:`launch_simulated` — the CI story: spawn N copies of this CLI
   as subprocesses on one machine, each pinned to its own slice of CPU
   placeholder devices (the ``repro._env`` template, same subprocess
   trick as the SIGKILL suites), coordinated over localhost. No
   accelerators required; ``--simulate N`` does the same from the
   command line.

Real two-host launch (see README)::

    # host A (coordinator)               # host B
    python -m repro.launch.distributed \\
        --coordinator hostA:9876 \\
        --num-processes 2 --process-id 0  # ... --process-id 1

This module keeps its top level jax-free: workers import it, configure
the environment, and only then let jax in.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import socket
import subprocess
import sys
import time

from repro._env import (
    DIST_COORDINATOR,
    DIST_LOCAL_DEVICES,
    DIST_NUM_PROCESSES,
    DIST_PROCESS_ID,
    distributed_env,
    ensure_host_device_count,
)

#: src/ directory containing the ``repro`` package — what workers need
#: on PYTHONPATH (``repro`` is a namespace package; __file__ works).
_SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

_DEVICE_COUNT_FLAG = re.compile(
    r"--xla_force_host_platform_device_count=\d+\s*")


def initialize(coordinator: str, num_processes: int, process_id: int,
               local_devices: int | None = None) -> None:
    """Bring up the ``jax.distributed`` runtime for this process.

    Order matters and is owned here so callers can't get it wrong:
    placeholder-device count first (XLA client flags are read at first
    jax import), then the CPU cross-process collectives implementation
    (fixed at backend initialization — stock CPU jaxlib otherwise
    refuses multi-process computations outright), then
    ``jax.distributed.initialize``. A ``num_processes == 1`` call is a
    no-op beyond the device-count flag, so single-host drivers can share
    the code path.
    """
    if local_devices is not None:
        ensure_host_device_count(local_devices)
    if num_processes <= 1:
        return
    import jax

    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):  # a jax without the option
        pass
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)


def init_from_env() -> bool:
    """Initialize from the ``REPRO_DIST_*`` environment if present.

    Returns True when a distributed runtime was brought up, False when
    the environment carries no distributed configuration (single-process
    session). Partial configuration raises (see
    :func:`repro._env.distributed_env`).
    """
    cfg = distributed_env()
    if cfg is None:
        return False
    initialize(cfg["coordinator"], cfg["num_processes"], cfg["process_id"],
               local_devices=cfg["local_devices"])
    return cfg["num_processes"] > 1


# ------------------------------------------------------ canonical job

#: Fixed shape of the differential job: capacity-8 quadratic population,
#: two scheduler structures, ragged cells (DESIGN.md §13).
JOB_N_CAP, JOB_DIM = 8, 5
JOB_SCHEDULERS = ("alg1", "benchmark1")
JOB_POPULATIONS = (5, 8)  # ragged: one below-capacity cell per structure


def make_job_sim():
    """The job's ClientSimulator — deterministic gradients and the
    elementwise-plus-one-sum loss that is bit-stable under vmap (the
    same recipe as the client-sharding bitwise suite), so gather-mode
    multi-process runs can be held to *bitwise* equality with the
    single-process vmap engine."""
    import jax
    import jax.numpy as jnp

    from repro.core import ClientSimulator, make_quadratic
    from repro.optim import sgd

    master = make_quadratic(jax.random.PRNGKey(2), n_clients=JOB_N_CAP,
                            dim=JOB_DIM, hetero=1.0)
    w_star = master.w_star
    return ClientSimulator(
        grads_fn=lambda w, k, t: master.all_grads(w),
        p=master.p, optimizer=sgd(0.02),
        loss_fn=lambda w: jnp.sum((w - w_star) ** 2))


def make_job_study(num_steps: int = 25, seeds: int = 2):
    """Ragged Fig-1 sub-grid: 2 scheduler structures × ragged
    populations × seeds — 2 structure groups, every group ragged."""
    from repro.experiments import Study

    return (Study("multihost_fig1", num_steps=num_steps)
            .axis("scheduler", list(JOB_SCHEDULERS))
            .axis("arrivals", "periodic")
            .axis("n_clients", list(JOB_POPULATIONS))
            .axis("seeds", seeds))


def job_params0():
    import jax.numpy as jnp

    return jnp.full((JOB_DIM,), 4.0)


def flatten_results(tag: str, results) -> dict:
    """``{tag|cell|leafpath: np.ndarray}`` — the npz layout both the
    workers and the comparing test/bench build, so equality checks are
    plain key-wise array comparisons."""
    import numpy as np

    flat = {}
    for cell, res in results.items():
        fields = {"params": res.params, "loss": res.history.loss,
                  "participation": res.history.participation,
                  "weight_sum": res.history.weight_sum,
                  "finite": res.history.finite, "diverged": res.diverged}
        for field, leaf in fields.items():
            if leaf is not None:
                flat[f"{tag}|{cell}|{field}"] = np.asarray(leaf)
    return flat


def reference_results(num_steps: int = 25, seeds: int = 2):
    """The single-process vmap-engine oracle for the canonical job."""
    study = make_job_study(num_steps, seeds)
    return study.run(sim=make_job_sim(), params0=job_params0()).cells


# ------------------------------------------------------- worker body

def _build_mesh(kind: str):
    from repro.experiments import placement

    if kind == "clients":
        return placement.make_client_mesh()
    if kind == "multihost":
        return placement.make_multihost_mesh()
    if kind == "cells":
        return placement.make_cell_mesh()
    raise ValueError(f"unknown mesh kind {kind!r} "
                     "(have clients, multihost, cells)")


def run_worker(args) -> dict:
    """Execute the canonical job on this (possibly multi-)process.

    One pass per (mesh, reduction) combo: dispatch through the unchanged
    ``Study.run``, assert the trace-count guarantee (one
    ``_run_group_sharded`` compile per structure group per process,
    zero on the warm repeat), optionally time warm dispatches, and
    collect everything into the report dict. Process 0 additionally
    saves the flattened results npz.
    """
    import jax
    import numpy as np

    from repro.experiments import ExecutionConfig, engine, placement

    sim = make_job_sim()
    study = make_job_study(args.steps, args.seeds)
    params0 = job_params0()
    _, _, groups = engine.resolve_structure_groups(study.resolve(), sim=sim)
    n_groups = len(groups)
    report = {
        "process_id": jax.process_index(),
        "process_count": jax.process_count(),
        "global_devices": jax.device_count(),
        "local_devices": jax.local_device_count(),
        "combos": {},
    }
    flat_all = {}
    for kind in args.mesh.split(","):
        mesh = _build_mesh(kind)
        spans = placement.mesh_process_count(mesh)
        for reduction in args.reduction.split(","):
            tag = f"{kind}-{reduction}"
            cfg = ExecutionConfig(mesh=mesh, client_reduction=reduction)
            before = placement._run_group_sharded._cache_size()
            result = study.run(sim=sim, params0=params0, config=cfg)
            compiles = placement._run_group_sharded._cache_size() - before
            if compiles != n_groups:
                raise AssertionError(
                    f"{tag}: expected one compile per structure group "
                    f"({n_groups}), traced {compiles}")
            study.run(sim=sim, params0=params0, config=cfg)
            warm = placement._run_group_sharded._cache_size() - before
            if warm != n_groups:
                raise AssertionError(
                    f"{tag}: warm repeat recompiled ({warm - n_groups} "
                    "new traces)")
            timing_us = None
            if args.timing_iters > 0:
                t0 = time.perf_counter()
                for _ in range(args.timing_iters):
                    study.run(sim=sim, params0=params0, config=cfg)
                timing_us = (time.perf_counter() - t0) / args.timing_iters \
                    * 1e6
            report["combos"][tag] = {
                "mesh_shape": dict(mesh.shape),
                "mesh_process_span": spans,
                "compiles": compiles,
                "warm_new_compiles": warm - n_groups,
                "dispatch_us": timing_us,
                "us_per_step": (timing_us / args.steps
                                if timing_us is not None else None),
            }
            flat_all.update(flatten_results(tag, result.cells))
    if args.out and jax.process_index() == 0:
        os.makedirs(args.out, exist_ok=True)
        np.savez(os.path.join(args.out, "results.npz"), **flat_all)
    if args.out:
        path = os.path.join(args.out,
                            f"report_p{jax.process_index()}.json")
        os.makedirs(args.out, exist_ok=True)
        with open(path, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    return report


# ------------------------------------------------- simulated harness

def _free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def launch_simulated(num_processes: int = 2, local_devices: int = 4, *,
                     argv=(), timeout: float = 600.0,
                     ) -> list[subprocess.CompletedProcess]:
    """Run ``num_processes`` copies of the worker CLI on this machine.

    Each worker is a fresh interpreter pinned to its own
    ``local_devices`` CPU placeholder devices and coordinated over a
    fresh localhost port — the simulated multi-host CI story
    (DESIGN.md §13). The parent's own XLA device-count flag is stripped
    from the children's environment so the per-worker pin always wins
    (the parent test/bench session typically forced 8 devices already).
    Returns the completed processes in process-id order; raises if any
    worker exits non-zero (its stderr in the message) or hangs past
    ``timeout``.
    """
    port = _free_port()
    procs = []
    for pid in range(num_processes):
        env = dict(os.environ)
        env[DIST_COORDINATOR] = f"127.0.0.1:{port}"
        env[DIST_NUM_PROCESSES] = str(num_processes)
        env[DIST_PROCESS_ID] = str(pid)
        env[DIST_LOCAL_DEVICES] = str(local_devices)
        env["XLA_FLAGS"] = _DEVICE_COUNT_FLAG.sub(
            "", env.get("XLA_FLAGS", "")).strip()
        env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.distributed", *argv],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True))
    done, deadline = [], time.monotonic() + timeout
    try:
        for pid, proc in enumerate(procs):
            left = max(1.0, deadline - time.monotonic())
            out, err = proc.communicate(timeout=left)
            done.append(subprocess.CompletedProcess(
                proc.args, proc.returncode, out, err))
    except subprocess.TimeoutExpired:
        for proc in procs:
            proc.kill()
        raise
    bad = [(i, p) for i, p in enumerate(done) if p.returncode != 0]
    if bad:
        i, p = bad[0]
        raise RuntimeError(
            f"simulated worker {i}/{num_processes} exited "
            f"{p.returncode}:\n{p.stderr[-4000:]}")
    return done


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.distributed",
        description="multi-host grid worker / simulated-multihost driver")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0 (or REPRO_DIST_COORDINATOR)")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--local-devices", type=int, default=None,
                    help="CPU placeholder devices for this process")
    ap.add_argument("--simulate", type=int, default=0, metavar="N",
                    help="spawn N local workers instead of being one")
    ap.add_argument("--mesh", default="clients",
                    help="comma list of clients|multihost|cells")
    ap.add_argument("--reduction", default="gather",
                    help="comma list of gather|psum|... client reductions")
    ap.add_argument("--steps", type=int, default=25)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--timing-iters", type=int, default=0)
    ap.add_argument("--out", default="",
                    help="directory for results.npz + report_p*.json")
    args = ap.parse_args(argv)

    if args.simulate:
        passthrough = ["--mesh", args.mesh, "--reduction", args.reduction,
                       "--steps", str(args.steps),
                       "--seeds", str(args.seeds),
                       "--timing-iters", str(args.timing_iters)]
        if args.out:
            passthrough += ["--out", args.out]
        results = launch_simulated(
            args.simulate, args.local_devices or 4, argv=passthrough)
        for proc in results:
            sys.stdout.write(proc.stdout)
        print(f"simulated {args.simulate}-process run complete")
        return 0

    if args.coordinator is not None:
        initialize(args.coordinator, args.num_processes or 1,
                   args.process_id or 0, local_devices=args.local_devices)
    elif not init_from_env() and args.local_devices:
        ensure_host_device_count(args.local_devices)

    report = run_worker(args)
    print(json.dumps(report, indent=1, sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
