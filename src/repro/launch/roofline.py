"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs  / (chips × 197e12  bf16 FLOP/s)
    memory     = HLO_bytes  / (chips × 819e9   B/s HBM)
    collective = coll_bytes / (chips × 50e9    B/s/link ICI)

``cost_analysis()`` yields flops / bytes accessed; collective bytes are
NOT in cost_analysis — they are parsed from the post-SPMD HLO text by
summing the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

Whether cost_analysis is per-device or whole-module depends on the
backend's partitioning; the dry-run records ``flops_scope`` by comparing
against the analytic MODEL_FLOPS so tables are interpreted consistently.
"""

from __future__ import annotations

import math
import re

from repro.configs.base import ArchConfig
from repro.configs.shapes import INPUT_SHAPES

PEAK_FLOPS = 197e12   # bf16 / chip (TPU v5e)
HBM_BW = 819e9        # B/s / chip
LINK_BW = 50e9        # B/s / link (ICI)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ag = bf16[2,1024,512]{2,1,0} all-gather(%x), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective kind from HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if kind.endswith("-done"):
            continue
        out[kind] += _shape_bytes(dtype, dims)
        counts[kind] += 1
    total = sum(out.values())
    return {"per_kind": out, "counts": counts, "total": total}


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, chips: int,
                   per_device: bool) -> dict:
    """Terms in seconds. ``per_device``: whether flops/bytes already
    describe one chip's program (post-SPMD module) or the whole mesh."""
    div = 1 if per_device else chips
    compute = flops / div / PEAK_FLOPS
    memory = bytes_accessed / div / HBM_BW
    collective = collective_bytes / div / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    return terms


# -------------------------------------------------------- analytic FLOPs

def active_params(cfg: ArchConfig) -> float:
    """Parameters touched per token (MoE: top-k experts + shared)."""
    import jax
    from repro.models import init_lm

    shapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]

    total = 0.0
    for path, leaf in flat:
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        n = math.prod(leaf.shape)
        if "moe/w_" in keys and cfg.n_experts:
            n = n * cfg.top_k / cfg.n_experts
        total += n
    return total


def model_flops(cfg: ArchConfig, shape_name: str) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference)."""
    shape = INPUT_SHAPES[shape_name]
    n_act = active_params(cfg)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch
