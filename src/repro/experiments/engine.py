"""Grid-batched scenario execution: one compiled computation per group.

The paper's headline evidence is a *grid* of runs — schedulers × arrival
processes × seeds. Because schedulers and energy processes are
registered pytrees (see :mod:`repro.core.energy` /
:mod:`repro.core.scheduling`), a whole grid collapses into a handful of
compiled computations:

1. Scenarios are grouped by the **pytree structure** of their built
   (scheduler, energy) pair — same dataclass types, same static
   metadata, same leaf shapes/dtypes.
2. Each group's component leaves are stacked along a new scenario axis.
3. One jitted function (:data:`_run_group`) runs
   ``vmap(scenarios) ∘ vmap(seeds)`` over :meth:`ClientSimulator.run`'s
   ``lax.scan`` — so XLA traces and compiles **once per group**, not
   once per (scenario, seed) cell.

**Ragged client populations** (DESIGN.md §7): when scenarios differ in
``n_clients``, the client count becomes a *data* axis instead of a
*shape* axis — every cell's per-client component leaves are padded to
the simulator's population capacity ``N_cap = len(sim.p)``, an
``active_mask`` marks the rows that exist, and each cell carries its
own zero-padded data weights (``subpopulation_p``). All population
sizes of one scheduler × arrival family then share a **single**
compiled computation, and masked rows contribute exactly zero gradient
and zero scheduler probability mass — per-cell numerics are bit-for-bit
those of the natural-N run (``tests/test_ragged.py``).

:func:`run_grid_sequential` executes the identical cells one traced scan
at a time — the pre-refactor execution model — and exists for numerical
cross-checks and wall-clock comparison (``benchmarks/fig1.py`` times
both).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import logging
import os
from functools import partial
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trainer import ClientSimulator, SimHistory
from repro.experiments.scenario import Scenario

_LOG = logging.getLogger("repro.experiments.engine")


class CellResult(NamedTuple):
    """Per-scenario result; every leaf carries a leading seed axis R.

    params   : final model parameters, leaves (R, ...)
    history  : SimHistory with leaves (R, T, ...)
    evals    : eval_fn outputs with leaves (R, num_evals, ...), or None
    diverged : (R,) int32 — first step index at which the seed's params
               went non-finite (−1: the run stayed finite throughout).
               The per-cell quarantine record (DESIGN.md §10), computed
               from the ``history.finite`` per-step isfinite flags.
    """

    params: Any
    history: SimHistory
    evals: Any = None
    diverged: Any = None


def _group_key(scheduler, energy, faults=None):
    """Hashable trace signature: pytree structure + leaf shapes/dtypes."""
    leaves, treedef = jax.tree_util.tree_flatten((scheduler, energy, faults))
    return treedef, tuple((l.shape, str(l.dtype)) for l in leaves)


def _stack(components):
    """Leaf-wise stack of same-structure pytrees along a new scenario axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *components)


def population_mask(n_clients: int, n_total: int) -> jax.Array:
    """(n_total,) float32 mask: 1 for the first ``n_clients`` rows."""
    return (jnp.arange(n_total) < n_clients).astype(jnp.float32)


def subpopulation_p(p, n_clients: int, n_total: int | None = None) -> jax.Array:
    """Data weights of the ``n_clients``-prefix subpopulation of ``p``,
    renormalized over the active rows only and zero-padded to
    ``n_total`` (default ``len(p)``).

    This is *the* unbiasedness-under-masking rule (DESIGN.md §7): the
    paper's p_i = D_i/D must sum to 1 over the clients that exist, so a
    ragged cell's weights are the master prefix renormalized — computed
    here, in f32, by both the padded engine path and (via this shared
    helper) the per-N baselines the equivalence tests compare against.
    """
    p = jnp.asarray(p, jnp.float32)
    n_total = int(p.shape[0]) if n_total is None else int(n_total)
    if not 1 <= n_clients <= n_total:
        raise ValueError(
            f"n_clients={n_clients} outside [1, {n_total}]")
    pref = p[:n_clients] / jnp.sum(p[:n_clients])
    if n_clients == n_total:
        return pref
    return jnp.concatenate(
        [pref, jnp.zeros((n_total - n_clients,), jnp.float32)])


def _pad_built(built, n_cap: int):
    """(scheduler, energy, faults) built at natural n → padded to n_cap
    rows (``faults`` may be None)."""
    from repro.core.energy import pad_arrivals
    from repro.core.faults import pad_faults
    from repro.core.scheduling import pad_scheduler

    scheduler, energy, faults = built
    return (pad_scheduler(scheduler, n_cap), pad_arrivals(energy, n_cap),
            pad_faults(faults, n_cap))


def _cell_mask_p(sc: "Scenario", sim: ClientSimulator, n_cap: int):
    """(active_mask, p) for one cell of a ragged group. A full-capacity
    cell gets an all-ones mask and the caller's ``sim.p``
    *unrenormalized*: multiplying by 1.0 and reusing p verbatim keeps it
    bit-identical to the unmasked run, whereas renormalizing would
    perturb it whenever p does not sum to exactly 1.0 in f32."""
    if sc.n_clients == n_cap:
        return jnp.ones((n_cap,), jnp.float32), sim.p
    return (population_mask(sc.n_clients, n_cap),
            subpopulation_p(sim.p, sc.n_clients, n_cap))


class StructureGroup(NamedTuple):
    """One structure group of a resolved grid — the leaf-stacked
    component batch the engine dispatches as ONE compiled computation.

    ``key`` is the :func:`_group_key` trace signature; ``members`` index
    into the caller's scenario list; ``scheduler`` / ``energy`` /
    ``faults`` carry a leading scenario axis S (``faults`` is None for
    fault-free groups); ``active`` / ``p`` are the (S, N_cap) ragged
    operands, both None when the group is uniformly at capacity.
    """

    key: Any
    members: list[int]
    scheduler: Any
    energy: Any
    faults: Any
    active: Any
    p: Any
    ragged: bool


def resolve_structure_groups(
    scenarios: Sequence[Scenario], *, sim: ClientSimulator,
) -> tuple[list[str], int, list[StructureGroup]]:
    """Group scenario cells by padded component structure.

    The shared front half of every batched execution path
    (:func:`execute_cells` and :func:`execute_cells_resumable` resolve
    through here, so both agree on names, padding, raggedness and group
    membership — which is what makes the chunked path bitwise the
    unchunked one). Below-capacity components are padded to
    ``N_cap = len(sim.p)`` (an identity at capacity) and grouping is on
    the padded structure; raggedness is decided per group, so uniform
    groups keep their mask-free compiled programs.

    Returns ``(names, n_cap, groups)`` in input order.
    """
    scenarios = list(scenarios)
    names = check_unique_names(scenarios)
    n_cap = int(sim.p.shape[0])
    over = [f"{sc.name} (N={sc.n_clients})" for sc in scenarios
            if sc.n_clients > n_cap]
    if over:
        raise ValueError(
            f"scenario population exceeds the simulator capacity "
            f"N_cap={n_cap} (len(sim.p)): {over}")
    built = [sc.build() + (sc.build_faults(),) for sc in scenarios]
    padded = [b if sc.n_clients == n_cap else _pad_built(b, n_cap)
              for sc, b in zip(scenarios, built)]
    grouped: dict[Any, list[int]] = {}
    for idx, (sch, en, flt) in enumerate(padded):
        grouped.setdefault(_group_key(sch, en, flt), []).append(idx)

    groups = []
    for gkey, members in grouped.items():
        ragged = any(scenarios[i].n_clients != n_cap for i in members)
        sch_batch = _stack([padded[i][0] for i in members])
        en_batch = _stack([padded[i][1] for i in members])
        # A fault-free group's components are all None — tree_map over
        # all-None pytrees has no leaves and returns None, so the group
        # dispatches the pre-fault-layer program verbatim.
        flt_batch = _stack([padded[i][2] for i in members])
        active_batch, p_batch = None, None
        if ragged:
            masks, ps = zip(*(_cell_mask_p(scenarios[i], sim, n_cap)
                              for i in members))
            active_batch, p_batch = jnp.stack(masks), jnp.stack(ps)
        groups.append(StructureGroup(gkey, members, sch_batch, en_batch,
                                     flt_batch, active_batch, p_batch,
                                     ragged))
    return names, n_cap, groups


def _crop_cell(cell: "CellResult", n: int, n_cap: int) -> "CellResult":
    """Slice the padded client axis of per-client outputs back to n."""
    if n == n_cap:
        return cell
    hist = cell.history._replace(
        participation=cell.history.participation[..., :n])
    return cell._replace(history=hist)


def _attach_divergence(cell: "CellResult") -> "CellResult":
    """Fill ``CellResult.diverged`` from the per-step isfinite flags.

    Host-side post-processing (the flags were the cheap in-scan
    reduction); ``diverged[r]`` is the first step index whose post-step
    params were non-finite for seed r, or −1 when the whole run stayed
    finite. Divergence is absorbing under every built-in optimizer
    (NaN params → NaN grads → NaN params), so first-bad-step plus the
    flag tail fully characterize the quarantined trajectory.
    """
    fin = cell.history.finite
    if fin is None:  # hand-built history without flags — nothing to report
        return cell
    bad = ~np.asarray(fin)
    first = np.where(bad.any(axis=-1), bad.argmax(axis=-1), -1)
    return cell._replace(diverged=jnp.asarray(first, jnp.int32))


def divergence_summary(results: dict[str, "CellResult"]) -> dict[str, dict]:
    """Per-cell quarantine stats: ``{name: {n_diverged, first_bad_step}}``.

    ``first_bad_step`` is the earliest diverged seed's first non-finite
    step (−1 when every seed stayed finite). The same numbers surface
    per-study through :meth:`repro.experiments.GridResult.divergence`.
    """
    out = {}
    for name, cell in results.items():
        d = np.asarray(cell.diverged) if cell.diverged is not None \
            else np.array([-1])
        bad = d[d >= 0]
        out[name] = {"n_diverged": int(bad.size),
                     "first_bad_step": int(bad.min()) if bad.size else -1}
    return out


def _group_body(scheduler, energy, faults, active, p, params0, keys, *,
                sim: ClientSimulator, num_steps: int, eval_fn=None,
                eval_every: int = 0):
    """vmap(scenario axis) ∘ vmap(seed axis) over one simulator scan —
    the shared computation behind :data:`_run_group` (process-global jit
    cache) and :func:`make_group_runner` (per-instance evictable cache,
    the serve layer's executable store). Both wrappers trace the same
    body, so their compiled programs are identical and results are
    bitwise interchangeable."""

    def one(sch, en, flt, act, pw, key):
        out = sim.run(key, params0, num_steps, scheduler=sch, energy=en,
                      faults=flt, p=pw, active_mask=act,
                      eval_fn=eval_fn, eval_every=eval_every)
        return CellResult(*out) if eval_fn is not None else CellResult(*out, None)

    over_seeds = jax.vmap(one, in_axes=(None, None, None, None, None, 0))
    over_scenarios = jax.vmap(over_seeds, in_axes=(0, 0, 0, 0, 0, None))
    return over_scenarios(scheduler, energy, faults, active, p, keys)


@partial(jax.jit, static_argnames=("sim", "num_steps", "eval_fn", "eval_every"))
def _run_group(scheduler, energy, faults, active, p, params0, keys, *,
               sim: ClientSimulator, num_steps: int, eval_fn=None,
               eval_every: int = 0):
    """Process-global jit wrapper of :func:`_group_body`.

    ``scheduler`` / ``energy`` / ``faults`` leaves carry a leading
    scenario axis S (``faults`` is None for fault-free groups);
    ``active`` / ``p`` are (S, N_cap) ragged-population operands (both
    None for uniform grids); ``keys`` is (R, 2). Compiled once per
    (sim, group structure) — probe ``_run_group._cache_size()`` to
    assert trace counts.

    The static ``sim`` / ``eval_fn`` are hashed by identity, so each
    distinct closure (and the datasets it captures) stays referenced by
    the jit cache for process lifetime. Benchmarks and tests are short
    lived; a long-running service issuing many distinct grids should
    route execution through an ``executable_cache``
    (:class:`repro.serve.ExecutableCache` — bounded, per-entry eviction)
    or call :func:`clear_cache` between sweeps.
    """
    return _group_body(scheduler, energy, faults, active, p, params0, keys,
                       sim=sim, num_steps=num_steps, eval_fn=eval_fn,
                       eval_every=eval_every)


def make_group_runner(*, sim: ClientSimulator, num_steps: int, eval_fn=None,
                      eval_every: int = 0, on_trace=None):
    """A *fresh* jit wrapper around :func:`_group_body`.

    Unlike :data:`_run_group` — whose cache is process-global and only
    clearable wholesale — each runner owns its jit cache, so dropping
    the runner (e.g. on LRU eviction from
    :class:`repro.serve.ExecutableCache`) releases its compiled
    executables and the closures they pin. ``on_trace`` is called each
    time the body is (re)traced — i.e. on every new compilation — which
    is how the serve layer counts compiles without jax internals.
    """

    def _runner(scheduler, energy, faults, active, p, params0, keys):
        if on_trace is not None:
            on_trace()
        return _group_body(scheduler, energy, faults, active, p, params0,
                           keys, sim=sim, num_steps=num_steps,
                           eval_fn=eval_fn, eval_every=eval_every)

    return jax.jit(_runner)


def structure_fingerprint(group_key) -> str:
    """Short stable digest of a :func:`_group_key` trace signature —
    the cache-key / response-visible name of one component structure."""
    return hashlib.sha256(str(group_key).encode()).hexdigest()[:12]


def clear_cache() -> None:
    """Drop compiled grid executables (and the sim/eval_fn closures —
    with their captured datasets — that the jit cache keeps alive),
    for both the vmap and shard_map execution paths."""
    _run_group.clear_cache()
    from repro.experiments import placement

    placement.clear_cache()


def _seed_keys(seeds):
    if isinstance(seeds, int):
        seeds = range(seeds)
    seeds = list(seeds)
    if not seeds:
        raise ValueError("need at least one seed")
    return seeds, jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])


def check_unique_names(scenarios: Sequence[Scenario]) -> list[str]:
    """Scenario names key the result mapping — duplicates would silently
    overwrite cells. Shared by every execution path (batched, sequential,
    Study.resolve)."""
    names = [sc.name for sc in scenarios]
    if len(set(names)) != len(names):
        dups = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(
            f"scenario names must be unique, got duplicates {dups} in {names}")
    return names


def _resolve_sim(sim, grads_fn, p, optimizer, loss_fn, use_kernel):
    if sim is not None:
        return sim
    if grads_fn is None or p is None or optimizer is None:
        raise ValueError(
            "either pass a prebuilt sim= or all of grads_fn/p/optimizer")
    return ClientSimulator(grads_fn=grads_fn, p=p, optimizer=optimizer,
                           loss_fn=loss_fn, use_kernel=use_kernel)


# ------------------------------------------------- graceful degradation

#: Reduction fallback order (DESIGN.md §10): each step strips one
#: requirement — fused kernel first, then the bf16 wire, then the psum
#: collective — ending at ``gather``, the bitwise-oracle path with no
#: mesh-shape preconditions beyond a divisible cell axis.
_REDUCTION_LADDER: dict[str, tuple[str, ...]] = {
    "fused_bf16": ("psum_bf16", "psum", "gather"),
    "fused": ("psum", "gather"),
    "psum_bf16": ("psum", "gather"),
    "psum": ("gather",),
    "gather": (),
}


@dataclasses.dataclass(frozen=True)
class DowngradeRecord:
    """One structured graceful-degradation event (DESIGN.md §10).

    ``stage`` is the ladder rung that moved: ``"reduction"`` (client
    cross-shard aggregation fell one step down :data:`_REDUCTION_LADDER`)
    or ``"placement"`` (the sharded executor was abandoned for the
    single-device vmap path). ``group`` names the scenario cells that
    were re-dispatched; ``error`` is the stringified ValueError that
    triggered the move.
    """

    group: tuple[str, ...]
    stage: str
    from_value: str
    to_value: str
    error: str

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)


_LAST_DOWNGRADES: list[DowngradeRecord] = []


def last_downgrades() -> tuple[DowngradeRecord, ...]:
    """Downgrade records from the most recent degradable execution.

    Reset at the start of every :func:`execute_cells` call; empty means
    every group ran at its requested placement/reduction."""
    return tuple(_LAST_DOWNGRADES)


def _record_downgrade(group, stage, frm, to, err) -> DowngradeRecord:
    rec = DowngradeRecord(group=tuple(group), stage=stage,
                          from_value=str(frm), to_value=str(to),
                          error=str(err))
    _LAST_DOWNGRADES.append(rec)
    _LOG.warning("degraded %s %s -> %s: %s", stage, frm, to, rec.to_json())
    return rec


def execute_cells(
    scenarios: Sequence[Scenario],
    *,
    sim: ClientSimulator,
    params0,
    num_steps: int,
    seeds: int | Sequence[int] = 8,
    eval_fn=None,
    eval_every: int = 0,
    mesh=None,
    sequential: bool = False,
    client_reduction: str = "psum",
    degrade: bool = False,
    executable_cache=None,
) -> dict[str, CellResult]:
    """Execute scenario × seed cells with a prebuilt simulator.

    The single execution core behind :meth:`Study.run` and the legacy
    :func:`run_grid` / :func:`run_grid_sequential` shims. Batched mode
    groups cells by component structure and runs one compiled
    vmap(scenarios)∘vmap(seeds) computation per group (sharded across
    ``mesh`` when given); ``sequential=True`` runs one traced scan per
    cell — the pre-refactor model kept for cross-checks and timing.

    Populations may be **ragged**: scenarios whose ``n_clients`` differ
    from the simulator's capacity ``N_cap = len(sim.p)`` are padded to
    N_cap with an active-row mask and per-cell renormalized weights
    (:func:`subpopulation_p`), so every population size of one
    scheduler × arrival structure shares a single compiled computation.
    Raggedness is decided **per structure group**: a group whose members
    are all at full capacity runs the unmasked legacy program
    bit-for-bit (and keeps its jit cache entry) even when other groups
    of the same grid mix populations; a full-capacity cell inside a
    mixed group runs under an all-ones mask with the caller's ``p``
    verbatim — also bit-identical. Per-client outputs
    (``history.participation``) are cropped back to the natural n.
    ``grads_fn`` must always emit N_cap rows — ragged cells simply
    ignore the rows of clients that don't exist.

    ``mesh`` may carry a ``clients`` axis (1-D ``make_client_mesh`` or
    2-D ``make_grid_mesh``, DESIGN.md §8): each cell's client axis is
    then sharded within the cell, ``client_reduction`` selecting the
    cross-shard aggregation — ``"psum"`` (default, bandwidth-optimal,
    f32 tolerance vs the vmap path), ``"gather"`` (bitwise oracle), or
    ``"fused[_bf16]"`` / ``"psum_bf16"`` (fused reduce-and-update kernel
    and/or bf16 wire; DESIGN.md §9).

    ``degrade=True`` arms the graceful-degradation ladder (DESIGN.md
    §10): a group whose sharded dispatch raises ``ValueError`` (mesh
    shape, reduction preconditions, fault/shard conflicts) is retried
    one rung down :data:`_REDUCTION_LADDER`, and when the ladder is
    exhausted, on the single-device vmap path. Every move is logged and
    recorded (:func:`last_downgrades`). Off by default — precondition
    errors raise, as before.

    ``executable_cache`` (vmap path only; DESIGN.md §11) replaces the
    process-global :data:`_run_group` jit cache with a caller-owned
    keyed store: each structure group dispatches through
    ``executable_cache.group_runner((group_key, ragged), sim=...,
    num_steps=..., eval_fn=..., eval_every=...)`` — a
    :func:`make_group_runner`-style jit callable the cache may memoize,
    bound, and evict. This is how :class:`repro.serve.StudyService`
    turns repeat traffic into pure dispatch while keeping executable
    memory bounded.
    """
    scenarios = list(scenarios)
    del _LAST_DOWNGRADES[:]
    names = check_unique_names(scenarios)
    seed_list, keys = _seed_keys(seeds)

    n_cap = int(sim.p.shape[0])
    over = [f"{sc.name} (N={sc.n_clients})" for sc in scenarios
            if sc.n_clients > n_cap]
    if over:
        raise ValueError(
            f"scenario population exceeds the simulator capacity "
            f"N_cap={n_cap} (len(sim.p)): {over}")

    if sequential:
        if mesh is not None:
            raise ValueError("sequential execution does not take a mesh")
        results = {}
        for sc in scenarios:
            scheduler, energy = sc.build()
            faults = sc.build_faults()
            active, p_cell = (None, None)
            if sc.n_clients != n_cap:
                scheduler, energy, faults = _pad_built(
                    (scheduler, energy, faults), n_cap)
                active, p_cell = _cell_mask_p(sc, sim, n_cap)
            per_seed = []
            for s in seed_list:
                out = sim.run(jax.random.PRNGKey(int(s)), params0, num_steps,
                              scheduler=scheduler, energy=energy,
                              faults=faults, p=p_cell, active_mask=active,
                              eval_fn=eval_fn, eval_every=eval_every)
                cell = CellResult(*out) if eval_fn is not None \
                    else CellResult(*out, None)
                per_seed.append(cell)
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *per_seed)
            cell = _crop_cell(stacked, sc.n_clients, n_cap)
            results[sc.name] = _attach_divergence(cell)
        return results

    sharded = mesh is not None and mesh.size > 1
    if sharded:
        from repro.experiments import placement

    _, _, groups = resolve_structure_groups(scenarios, sim=sim)

    results: list[CellResult | None] = [None] * len(scenarios)
    for grp in groups:

        def run_vmap(grp=grp):
            if executable_cache is not None:
                runner = executable_cache.group_runner(
                    (grp.key, grp.ragged), sim=sim, num_steps=num_steps,
                    eval_fn=eval_fn, eval_every=eval_every)
                return runner(grp.scheduler, grp.energy, grp.faults,
                              grp.active, grp.p, params0, keys)
            return _run_group(grp.scheduler, grp.energy, grp.faults,
                              grp.active, grp.p, params0, keys, sim=sim,
                              num_steps=num_steps, eval_fn=eval_fn,
                              eval_every=eval_every)

        if sharded:
            member_names = [names[i] for i in grp.members]
            reduction = client_reduction
            while True:
                try:
                    out = placement.run_group_sharded(
                        grp.scheduler, grp.energy, grp.active, grp.p, params0,
                        keys, sim=sim, num_steps=num_steps,
                        n_scenarios=len(grp.members), mesh=mesh,
                        eval_fn=eval_fn, eval_every=eval_every,
                        reduction=reduction, faults=grp.faults)
                    break
                except ValueError as e:
                    if not degrade:
                        raise
                    lower = _REDUCTION_LADDER.get(reduction, ())
                    if lower:
                        _record_downgrade(member_names, "reduction",
                                          reduction, lower[0], e)
                        reduction = lower[0]
                        continue
                    _record_downgrade(member_names, "placement",
                                      "sharded", "vmap", e)
                    out = run_vmap()
                    break
        else:
            out = run_vmap()
        for j, idx in enumerate(grp.members):
            cell = jax.tree_util.tree_map(lambda x: x[j], out)
            cell = _crop_cell(cell, scenarios[idx].n_clients, n_cap)
            results[idx] = _attach_divergence(cell)
    return dict(zip(names, results))


def run_grid(
    scenarios: Sequence[Scenario],
    *,
    grads_fn=None,
    p=None,
    optimizer=None,
    params0,
    num_steps: int,
    seeds: int | Sequence[int] = 8,
    loss_fn=None,
    use_kernel: bool = False,
    eval_fn=None,
    eval_every: int = 0,
    sim: ClientSimulator | None = None,
    mesh=None,
) -> dict[str, CellResult]:
    """Execute every scenario × seed cell, batched per component structure.

    .. deprecated:: prefer :meth:`repro.experiments.Study.run`, which
       owns simulator construction and returns a labeled
       :class:`~repro.experiments.GridResult`. This shim remains for
       hand-built irregular scenario lists.

    ``seeds`` is either a count (seeds 0..R−1) or an explicit list; seed
    ``s`` runs under ``jax.random.PRNGKey(s)``, bit-identical to a
    standalone ``ClientSimulator.run(PRNGKey(s), ...)`` of the same cell
    (up to float reassociation introduced by batching).

    ``mesh`` (a ``jax.sharding.Mesh``, e.g.
    :func:`repro.experiments.placement.make_cell_mesh`) shards each
    group's flattened (scenario × seed) cell axis across devices
    (DESIGN.md §5); a mesh with a ``clients`` axis
    (:func:`~repro.experiments.placement.make_client_mesh` /
    :func:`~repro.experiments.placement.make_grid_mesh`) additionally
    shards each cell's client axis within the cell (DESIGN.md §8).
    Without a mesh — or with a 1-device mesh — execution takes the
    single-device vmap path, bit-for-bit as before.

    The jit cache is keyed on ``sim`` by identity, so repeated calls
    with a fresh simulator (or fresh grads_fn/eval_fn lambdas) re-trace
    every group. A driver issuing the same grid many times should build
    the simulator once and pass it via ``sim`` (then grads_fn/p/
    optimizer/loss_fn/use_kernel are taken from it and the keyword
    values are ignored).

    Returns ``{scenario.name: CellResult}`` in input order.
    """
    sim = _resolve_sim(sim, grads_fn, p, optimizer, loss_fn, use_kernel)
    return execute_cells(scenarios, sim=sim, params0=params0,
                         num_steps=num_steps, seeds=seeds, eval_fn=eval_fn,
                         eval_every=eval_every, mesh=mesh)


def run_grid_sequential(
    scenarios: Sequence[Scenario],
    *,
    grads_fn=None,
    p=None,
    optimizer=None,
    params0,
    num_steps: int,
    seeds: int | Sequence[int] = 8,
    loss_fn=None,
    use_kernel: bool = False,
    eval_fn=None,
    eval_every: int = 0,
    sim: ClientSimulator | None = None,
) -> dict[str, CellResult]:
    """The pre-refactor execution model: one traced scan per cell.

    .. deprecated:: prefer ``Study.run(config=ExecutionConfig(
       sequential=True))``. Numerically equivalent to :func:`run_grid`
       (same per-seed keys); kept as the baseline for correctness
       cross-checks and for the batched-vs-sequential wall-clock
       comparison in ``benchmarks/fig1.py``.
    """
    sim = _resolve_sim(sim, grads_fn, p, optimizer, loss_fn, use_kernel)
    return execute_cells(scenarios, sim=sim, params0=params0,
                         num_steps=num_steps, seeds=seeds, eval_fn=eval_fn,
                         eval_every=eval_every, sequential=True)


# --------------------------------------------- preemption-safe execution

#: Manifest schema tag — bump on incompatible layout changes.
MANIFEST_FORMAT = "study-manifest/v1"


@partial(jax.jit, static_argnames=("sim", "spec"))
def _init_group(scheduler, energy, faults, keys, params0, *,
                sim: ClientSimulator, spec):
    """(S, R) batch of fresh scan carries — vmap(scenarios)∘vmap(seeds)
    of :meth:`ClientSimulator.init`. The carry template for checkpoint
    restore is ``jax.eval_shape`` of this function."""

    def one(sch, en, flt, key):
        return sim.init(key, params0, scheduler=sch, energy=en, faults=flt,
                        spec=spec)

    over_seeds = jax.vmap(one, in_axes=(None, None, None, 0))
    return jax.vmap(over_seeds, in_axes=(0, 0, 0, None))(
        scheduler, energy, faults, keys)


def _advance_body(carry, scheduler, energy, faults, active, p, *,
                  sim: ClientSimulator, num_steps: int, spec):
    """Advance an (S, R) carry batch ``num_steps`` rounds — one scan per
    lane under vmap∘vmap, the chunked twin of :func:`_group_body`.
    Because the step stream is a pure function of the carry, chunked
    advancement is bitwise identical to a single uninterrupted scan.
    Shared by :data:`_advance_group` (process-global jit cache) and
    :func:`make_chunk_runner` (per-instance evictable jit, the serve
    layer's resumable executable store)."""

    def one(c, sch, en, flt, act, pw):
        return sim.run_carry(c, num_steps, scheduler=sch, energy=en,
                             faults=flt, p=pw, active_mask=act, spec=spec,
                             donate=False)

    over_seeds = jax.vmap(one, in_axes=(0, None, None, None, None, None))
    return jax.vmap(over_seeds, in_axes=(0, 0, 0, 0, 0, 0))(
        carry, scheduler, energy, faults, active, p)


@partial(jax.jit, static_argnames=("sim", "num_steps", "spec"))
def _advance_group(carry, scheduler, energy, faults, active, p, *,
                   sim: ClientSimulator, num_steps: int, spec):
    """Process-global jit wrapper of :func:`_advance_body`."""
    return _advance_body(carry, scheduler, energy, faults, active, p,
                         sim=sim, num_steps=num_steps, spec=spec)


def make_chunk_runner(*, sim: ClientSimulator, chunk: int, spec,
                      on_trace=None):
    """A *fresh* jit wrapper around :func:`_advance_body` — the chunked
    twin of :func:`make_group_runner`.

    Each runner owns its jit cache, so the serve layer's
    :class:`repro.serve.ExecutableCache` can memoize one per
    (structure, chunk length, config) and genuinely release its compiled
    executables on eviction; ``on_trace`` counts (re)traces the same
    way. A warm resume — the same structure advancing through the same
    chunk length — is a pure cache hit: zero new compiles.
    """

    def _runner(carry, scheduler, energy, faults, active, p):
        if on_trace is not None:
            on_trace()
        return _advance_body(carry, scheduler, energy, faults, active, p,
                             sim=sim, num_steps=chunk, spec=spec)

    return jax.jit(_runner)


def study_fingerprint(scenarios, num_steps, seed_list, params0) -> str:
    """Content hash binding a checkpoint directory to one exact study:
    canonical scenario specs + horizon + seeds + initial-parameter bytes.
    Resume refuses a directory whose manifest fingerprint differs. The
    serve layer keys per-dispatch-group checkpoint subdirectories on
    this same hash, so a restarted service lands on the directory its
    predecessor was writing."""
    h = hashlib.sha256()
    for sc in scenarios:
        d = dataclasses.asdict(sc)
        if d.get("taus") is not None:
            d["taus"] = np.asarray(d["taus"]).tolist()
        h.update(json.dumps(d, sort_keys=True, default=repr).encode())
    h.update(json.dumps({"num_steps": int(num_steps),
                         "seeds": [int(s) for s in seed_list]}).encode())
    for leaf in jax.tree_util.tree_leaves(params0):
        arr = np.asarray(leaf)
        h.update(str((arr.shape, arr.dtype.name)).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _history_template(n_scen, n_seeds, t, n_cap):
    """Shape/dtype template of an (S, R, t) SimHistory chunk as saved in
    resumable checkpoints (see :meth:`ClientSimulator._history`)."""
    return SimHistory(
        loss=jax.ShapeDtypeStruct((n_scen, n_seeds, t), jnp.float32),
        participation=jax.ShapeDtypeStruct((n_scen, n_seeds, t, n_cap),
                                           jnp.float32),
        weight_sum=jax.ShapeDtypeStruct((n_scen, n_seeds, t), jnp.float32),
        finite=jax.ShapeDtypeStruct((n_scen, n_seeds, t), jnp.bool_))


def _pad_halted_history(history, num_steps: int):
    """Extend a halted group's history to the full horizon: NaN metrics,
    ``finite=False`` — the quarantine tail (DESIGN.md §10)."""
    done = int(np.asarray(history.loss).shape[2])
    pad = num_steps - done
    if pad <= 0:
        return history

    def ext(x, value):
        shape = x.shape[:2] + (pad,) + x.shape[3:]
        return np.concatenate(
            [np.asarray(x), np.full(shape, value, np.asarray(x).dtype)],
            axis=2)

    return SimHistory(loss=ext(history.loss, np.nan),
                      participation=ext(history.participation, np.nan),
                      weight_sum=ext(history.weight_sum, np.nan),
                      finite=ext(history.finite, False))


def _advance_resumable_group(
    grp: StructureGroup, *, gid: str, sim: ClientSimulator, spec, params0,
    keys, seed_list, num_steps: int, checkpoint_every: int,
    checkpoint_dir: str, keep: int, manifest: dict, manifest_path: str,
    halt_on_divergence: bool, executable_cache=None, progress=None,
) -> CellResult:
    """Advance ONE structure group to the horizon, checkpointed.

    The factored inner loop of :func:`execute_cells_resumable`: restore
    the group's newest complete checkpoint (or init fresh), advance in
    ``checkpoint_every``-step chunks, and write ``{carry, history}``
    plus the study manifest after every chunk. ``executable_cache``
    routes each chunk advance through a memoized
    :func:`make_chunk_runner` (warm resumes are zero-compile);
    ``progress(gid, step, num_steps)`` fires once after restore/init and
    once per completed chunk, which is how the serve layer reports
    per-chunk dispatch progress.
    """
    from repro.checkpoint import CheckpointManager, latest_step, \
        write_json_atomic
    from repro.core import aggregation

    n_cap = int(sim.p.shape[0])
    mgr = CheckpointManager(os.path.join(checkpoint_dir, gid), keep=keep)
    carry_tpl = jax.eval_shape(
        partial(_init_group, sim=sim, spec=spec),
        grp.scheduler, grp.energy, grp.faults, keys, params0)
    step = latest_step(mgr.directory)
    halted = manifest["groups"][gid]["halted"]
    if step is None:
        step = 0
        halted = False
        carry = _init_group(grp.scheduler, grp.energy, grp.faults, keys,
                            params0, sim=sim, spec=spec)
        history = None
    else:
        tpl = {"carry": carry_tpl,
               "history": _history_template(len(grp.members), len(seed_list),
                                            step, n_cap)}
        state, step = mgr.restore(tpl, step)
        carry, history = state["carry"], state["history"]
    if progress is not None:
        progress(gid, step, num_steps)

    def save_state(step, carry, history, halted):
        mgr.save(step, {"carry": carry, "history": history})
        manifest["groups"][gid]["step"] = step
        manifest["groups"][gid]["halted"] = bool(halted)
        write_json_atomic(manifest_path, manifest)

    while step < num_steps and not halted:
        chunk = min(checkpoint_every, num_steps - step)
        if executable_cache is not None:
            runner = executable_cache.chunk_runner(
                (grp.key, grp.ragged, chunk), sim=sim, chunk=chunk, spec=spec)
            carry, hist = runner(carry, grp.scheduler, grp.energy, grp.faults,
                                 grp.active, grp.p)
        else:
            carry, hist = _advance_group(
                carry, grp.scheduler, grp.energy, grp.faults, grp.active,
                grp.p, sim=sim, num_steps=chunk, spec=spec)
        hist = jax.tree_util.tree_map(np.asarray, hist)
        history = hist if history is None else jax.tree_util.tree_map(
            lambda a, b: np.concatenate([a, b], axis=2), history, hist)
        step += chunk
        if halt_on_divergence and not np.asarray(
                history.finite[..., -1]).any():
            halted = True
        save_state(step, carry, history, halted)
        if progress is not None:
            progress(gid, step, num_steps)

    if history is None:  # num_steps == 0 degenerate study
        history = jax.tree_util.tree_map(
            lambda s: np.zeros(s.shape, s.dtype),
            _history_template(len(grp.members), len(seed_list), 0, n_cap))
    if halted:
        history = _pad_halted_history(history, num_steps)

    if spec is None:
        params = carry.params
    else:
        unravel = lambda q: aggregation.unravel_pytree(q, spec)  # noqa: E731
        params = jax.vmap(jax.vmap(unravel))(jnp.asarray(carry.params))
    return CellResult(params=params,
                      history=SimHistory(*map(jnp.asarray, history)),
                      evals=None)


def execute_cells_resumable(
    scenarios: Sequence[Scenario],
    *,
    sim: ClientSimulator,
    params0,
    num_steps: int,
    seeds: int | Sequence[int] = 8,
    checkpoint_dir: str,
    checkpoint_every: int = 0,
    keep: int = 3,
    halt_on_divergence: bool = False,
    executable_cache=None,
    progress=None,
) -> dict[str, CellResult]:
    """Preemption-safe :func:`execute_cells`: chunked scans + checkpoints.

    Execution proceeds structure group by structure group (same grouping
    as the batched path — :func:`resolve_structure_groups`), each group
    advancing in ``checkpoint_every``-step chunks
    (:func:`_advance_resumable_group`); after every chunk the group's
    ``{carry, history}`` pytree is written atomically under
    ``checkpoint_dir/<gid>/step_<t>.npz`` and the study manifest
    (``manifest.json``) is rewritten. Because each chunk is a pure
    function of the carry, a run killed at *any* point — including
    mid-write, by ``kill -9`` — resumes from the directory and produces
    results **bitwise identical** to the uninterrupted run: completed
    groups restore their final checkpoint without re-execution, the
    in-flight group restores its newest complete checkpoint and replays
    only the tail.

    The manifest binds the directory to one exact study via
    :func:`study_fingerprint` (scenario specs + horizon + seeds +
    params0 bytes); resuming with anything changed raises. Layout::

        {"format": "study-manifest/v1", "fingerprint": "<sha256>",
         "num_steps": T, "checkpoint_every": K,
         "groups": {"g000": {"members": [...], "step": t,
                             "halted": false}, ...}}

    ``halt_on_divergence=True`` stops advancing a group once **every**
    (scenario, seed) lane has gone non-finite (divergence is absorbing);
    the unrun tail is reported as NaN metrics with ``finite=False``.
    Eval hooks and meshes are not supported on this path — run those
    studies unchunked.

    ``executable_cache`` (DESIGN.md §12) memoizes one fresh
    :func:`make_chunk_runner` jit wrapper per (structure, chunk length)
    — the serve layer binds its keyed :class:`repro.serve.
    ExecutableCache` here so repeat resumable traffic, including a warm
    resume after an interruption, adds zero new compiles.
    ``progress(gid, step, num_steps)`` reports per-chunk advancement.
    """
    from repro.checkpoint import write_json_atomic

    scenarios = list(scenarios)
    del _LAST_DOWNGRADES[:]  # no ladder here, but keep the report current
    seed_list, keys = _seed_keys(seeds)
    num_steps = int(num_steps)
    if checkpoint_every <= 0:
        checkpoint_every = num_steps

    names, n_cap, groups = resolve_structure_groups(scenarios, sim=sim)
    spec = sim.flat_spec(params0)
    gids = [f"g{g:03d}" for g in range(len(groups))]

    manifest_path = os.path.join(checkpoint_dir, "manifest.json")
    fingerprint = study_fingerprint(scenarios, num_steps, seed_list, params0)
    manifest = {
        "format": MANIFEST_FORMAT,
        "fingerprint": fingerprint,
        "num_steps": num_steps,
        "checkpoint_every": int(checkpoint_every),
        "groups": {gid: {"members": [names[i] for i in grp.members],
                         "step": 0, "halted": False}
                   for gid, grp in zip(gids, groups)},
    }
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            prev = json.load(f)
        if prev.get("format") != MANIFEST_FORMAT:
            raise ValueError(
                f"{manifest_path}: unknown manifest format "
                f"{prev.get('format')!r} (want {MANIFEST_FORMAT})")
        if prev.get("fingerprint") != fingerprint:
            raise ValueError(
                f"{manifest_path} belongs to a different study "
                f"(fingerprint mismatch) — refusing to resume; use a "
                f"fresh checkpoint_dir or delete the stale one")
        for gid in gids:
            got = prev["groups"].get(gid, {})
            manifest["groups"][gid]["halted"] = bool(got.get("halted", False))
    else:
        write_json_atomic(manifest_path, manifest)

    results: list[CellResult | None] = [None] * len(scenarios)
    for gid, grp in zip(gids, groups):
        out = _advance_resumable_group(
            grp, gid=gid, sim=sim, spec=spec, params0=params0, keys=keys,
            seed_list=seed_list, num_steps=num_steps,
            checkpoint_every=checkpoint_every, checkpoint_dir=checkpoint_dir,
            keep=keep, manifest=manifest, manifest_path=manifest_path,
            halt_on_divergence=halt_on_divergence,
            executable_cache=executable_cache, progress=progress)
        for j, idx in enumerate(grp.members):
            cell = jax.tree_util.tree_map(lambda x: x[j], out)
            cell = _crop_cell(cell, scenarios[idx].n_clients, n_cap)
            results[idx] = _attach_divergence(cell)
    return dict(zip(names, results))


def grid_summary(results: dict[str, CellResult], reducer=None) -> dict[str, dict]:
    """Per-scenario NaN-aware mean±std over the seed axis of a metric.

    ``reducer(cell) -> (R,)`` extracts one scalar per seed; default is
    the mean loss over the final 10% of steps. Diverged seeds (NaN/inf)
    are excluded from mean/std and counted in ``n_nan``
    (:func:`repro.experiments.results.seed_stats` — the same reduction
    backing :meth:`GridResult.reduce`).
    """
    from repro.experiments import results as results_mod

    reducer = results_mod.default_metric if reducer is None else reducer
    return {name: results_mod.seed_stats(reducer(cell))
            for name, cell in results.items()}
