"""Study-as-a-service: manifests in, batched execution, labeled results out.

* :mod:`repro.serve.cache` — :class:`ExecutableCache`, the bounded LRU
  of (structure fingerprint, ExecutionConfig)-keyed jit runners with
  hit/miss/eviction/compile counters.
* :mod:`repro.serve.service` — :class:`StudyService` (submit / flush /
  wait over serialized Study manifests, structure-batched through
  :func:`repro.experiments.engine.execute_cells`) and
  :class:`BackgroundServer` (the batching-window flush thread).

The wire format lives in :mod:`repro.experiments.manifest`; the key
pieces are re-exported here so a client script needs one import.
"""

from repro.experiments.manifest import (
    EXEC_FORMAT,
    REQUEST_FORMAT,
    STUDY_FORMAT,
    request_from_manifest,
    request_to_manifest,
    study_from_manifest,
    study_to_manifest,
)
from repro.serve.cache import BoundExecutableCache, ExecutableCache
from repro.serve.service import (
    DISPATCH_FORMAT,
    BackgroundServer,
    ServeResponse,
    StudyService,
)

__all__ = [
    "DISPATCH_FORMAT", "EXEC_FORMAT", "REQUEST_FORMAT", "STUDY_FORMAT",
    "BackgroundServer", "BoundExecutableCache", "ExecutableCache",
    "ServeResponse", "StudyService",
    "request_from_manifest", "request_to_manifest",
    "study_from_manifest", "study_to_manifest",
]
