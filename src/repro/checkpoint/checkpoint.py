"""Pytree checkpointing on npz — no external deps, structure-checked.

Leaves are flattened with ``jax.tree_util.tree_flatten_with_path`` so the
npz carries stable, human-readable keys; restore verifies the target
structure matches and re-dtypes leaves to the template.

``CheckpointManager`` adds step-indexed directories, atomic writes
(write-to-tmp + rename) and retention.
"""

from __future__ import annotations

import os
import re
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(path: str, tree: Any) -> None:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    for p, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype.name not in ("float64", "float32", "float16", "int64",
                                  "int32", "int16", "int8", "uint64",
                                  "uint32", "uint16", "uint8", "bool"):
            # bfloat16 / fp8 etc. don't survive npz — store as float32;
            # restore re-casts to the template dtype.
            arr = arr.astype(np.float32)
        arrays[_key_str(p)] = arr
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def restore_pytree(path: str, template: Any) -> Any:
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat:
            key = _key_str(p)
            if key not in data:
                raise KeyError(f"checkpoint {path} missing leaf {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(np.shape(leaf)):
                raise ValueError(
                    f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                    f"template {np.shape(leaf)}")
            leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


_STEP_RE = re.compile(r"^step_(\d+)\.npz$")


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := _STEP_RE.match(f))]
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step}.npz")

    def save(self, step: int, tree: Any) -> str:
        p = self.path(step)
        save_pytree(p, tree)
        self._retain()
        return p

    def restore(self, template: Any, step: int | None = None) -> tuple[Any, int]:
        if step is None:
            step = latest_step(self.directory)
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return restore_pytree(self.path(step), template), step

    def _retain(self) -> None:
        steps = sorted(
            int(m.group(1)) for f in os.listdir(self.directory)
            if (m := _STEP_RE.match(f)))
        for s in steps[:-self.keep] if self.keep else []:
            os.remove(self.path(s))

    def delete(self) -> None:
        shutil.rmtree(self.directory, ignore_errors=True)
