from repro.sharding.rules import (
    auto_spec,
    batch_specs,
    param_specs,
    state_specs,
    tree_shardings,
)

__all__ = ["param_specs", "batch_specs", "state_specs", "auto_spec",
           "tree_shardings"]
