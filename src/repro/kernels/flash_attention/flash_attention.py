"""Pallas TPU kernel: blockwise (flash) attention, causal + sliding window.

TPU adaptation of FlashAttention: the (S×T) score matrix never
materializes in HBM; q/k/v stream through VMEM in (bq, dh)/(bk, dh)
tiles, the running max/denominator live in VMEM scratch across the
sequential kv-grid dimension, and each tile product is an MXU matmul.
GQA is handled *in the index map* — query head h reads kv head
h // (H/Hkv) — so grouped kv is never replicated in memory.

Sliding-window masking makes the kernel sub-quadratic in effect (fully
masked tiles are skipped with ``pl.when``), which is what qualifies dense
archs for the ``long_500k`` shape.

Grid: (B, H, nq, nk), nk innermost/sequential ("arbitrary" semantics).
Scratch per step: acc (bq, dh) f32 + m,l (bq, 128) f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_STAT = 128  # lane width for m/l scratch columns
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal, window, bq, bk, scale):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    k_start = ik * bk

    # Tile-level skip test (static shapes, dynamic predicate).
    live = jnp.asarray(True)
    if causal:
        live = live & (k_start <= q_start + bq - 1)
    if window > 0:
        live = live & (k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                               # (bq, bk)
        # Rows with everything masked: p would be exp(NEG_INF - NEG_INF)=1.
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                      # (bq, 1)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, causal=True, window=0,
                           block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                           interpret=False):
    """q: (B, H, S, Dh); k, v: (B, Hkv, T, Dh) -> (B, H, S, Dh)."""
    b, h, s, dh = q.shape
    _, hkv, t, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    bq = min(block_q, s)
    bk = min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    grid = (b, h, s // bq, t // bk)
    scale = dh ** -0.5

    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               bq=bq, bk=bk, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, _STAT), jnp.float32),
            pltpu.VMEM((bq, _STAT), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
