"""minitron-4b — width/depth-pruned Nemotron dense decoder.

[arXiv:2407.14679] 32L, d_model=3072, 24 heads (GQA kv=8), d_ff=9216,
vocab=256000.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    arch_type="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab=256000,
    rope_theta=10000.0,
    long_context_window=8192,
    norm="rmsnorm",
    act="silu",
    gated_mlp=False,  # nemotron uses squared-relu non-gated FFN
    dtype_name="bfloat16",
    remat=True,
    citation="[arXiv:2407.14679]",
)
