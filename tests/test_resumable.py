"""Preemption-safe execution tests (DESIGN.md §10).

The contract under test: a checkpointed Study killed at *any* point —
including ``kill -9`` between the npz write and the manifest update —
resumes from its directory and produces results **bitwise identical** to
the uninterrupted run. The kill/resume case is the one test in the suite
that spawns a subprocess (it must actually die, not unwind).
"""

import json
import os
import signal
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import make_quadratic
from repro.core.trainer import ClientSimulator
from repro.experiments import ExecutionConfig, Scenario, Study, engine
from repro.optim import sgd

pytestmark = pytest.mark.faults

N, DIM, STEPS = 8, 6, 30


@pytest.fixture(scope="module")
def problem():
    return make_quadratic(jax.random.PRNGKey(2), n_clients=N, dim=DIM)


@pytest.fixture(scope="module")
def sim(problem):
    return ClientSimulator(
        grads_fn=lambda p, k, t: problem.all_grads(p, key=k, noise=0.05),
        p=problem.p, optimizer=sgd(0.02), loss_fn=problem.suboptimality)


def _scenarios():
    return [
        Scenario(name="alg1_per", scheduler="alg1", arrivals="periodic",
                 n_clients=N, horizon=STEPS + 1),
        Scenario(name="alg1_drop", scheduler="alg1", arrivals="periodic",
                 n_clients=N, horizon=STEPS + 1, faults="drop",
                 fault_kwargs={"rate": 0.3}),
        Scenario(name="bench_bin", scheduler="benchmark1", arrivals="binary",
                 n_clients=6, horizon=STEPS + 1),
    ]


def params0():
    return jnp.full((DIM,), 4.0)


def _assert_results_bitwise(a, b):
    assert list(a) == list(b)
    for name in a:
        for la, lb in zip(jax.tree_util.tree_leaves(a[name]),
                          jax.tree_util.tree_leaves(b[name])):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=name)


def test_chunked_equals_unchunked_equals_plain(sim, tmp_path):
    """Chunked resumable execution (any chunk size) is bitwise the plain
    batched engine — the scan is a pure function of the carry."""
    ref = engine.execute_cells(_scenarios(), sim=sim, params0=params0(),
                               num_steps=STEPS, seeds=3)
    one = engine.execute_cells_resumable(
        _scenarios(), sim=sim, params0=params0(), num_steps=STEPS, seeds=3,
        checkpoint_dir=str(tmp_path / "one"), checkpoint_every=0)
    chunked = engine.execute_cells_resumable(
        _scenarios(), sim=sim, params0=params0(), num_steps=STEPS, seeds=3,
        checkpoint_dir=str(tmp_path / "chunk"), checkpoint_every=7)
    _assert_results_bitwise(one, ref)
    _assert_results_bitwise(chunked, ref)


def test_completed_dir_replays_without_advancing(sim, tmp_path):
    """Re-running over a finished directory restores every group from
    its final checkpoint — results bitwise equal to the first pass."""
    kw = dict(sim=sim, params0=params0(), num_steps=STEPS, seeds=2,
              checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=10)
    first = engine.execute_cells_resumable(_scenarios(), **kw)
    again = engine.execute_cells_resumable(_scenarios(), **kw)
    _assert_results_bitwise(again, first)
    manifest = json.load(open(tmp_path / "ck" / "manifest.json"))
    assert manifest["format"] == engine.MANIFEST_FORMAT
    assert all(g["step"] == STEPS for g in manifest["groups"].values())


def test_fingerprint_mismatch_refuses_resume(sim, tmp_path):
    kw = dict(sim=sim, params0=params0(), num_steps=STEPS, seeds=2,
              checkpoint_dir=str(tmp_path / "ck"))
    engine.execute_cells_resumable(_scenarios(), **kw)
    with pytest.raises(ValueError, match="fingerprint"):
        engine.execute_cells_resumable(
            _scenarios(), sim=sim, params0=params0() + 1.0, num_steps=STEPS,
            seeds=2, checkpoint_dir=str(tmp_path / "ck"))


def test_halt_on_divergence_quarantines_tail(sim, tmp_path):
    """A fully-diverged group stops advancing between chunks; its unrun
    tail reports NaN metrics with finite=False, and the manifest records
    the halt. Clean sibling groups run to completion bitwise unchanged."""
    bad = Scenario(name="poison", scheduler="alg1", arrivals="periodic",
                   n_clients=N, horizon=STEPS + 1, faults="corrupt",
                   fault_kwargs={"rate": 1.0, "scale": float("nan")})
    scs = _scenarios()[:1] + [bad]
    res = engine.execute_cells_resumable(
        scs, sim=sim, params0=params0(), num_steps=STEPS, seeds=2,
        checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=10,
        halt_on_divergence=True)
    hist = res["poison"].history
    assert np.asarray(hist.loss).shape[-1] == STEPS
    assert not np.asarray(hist.finite).any()
    assert np.isnan(np.asarray(hist.loss)[..., -1]).all()
    assert np.all(np.asarray(res["poison"].diverged) == 0)
    manifest = json.load(open(tmp_path / "ck" / "manifest.json"))
    halted = [g for g in manifest["groups"].values() if g["halted"]]
    assert len(halted) == 1 and halted[0]["step"] == 10

    ref = engine.execute_cells(_scenarios()[:1], sim=sim, params0=params0(),
                               num_steps=STEPS, seeds=2)
    for la, lb in zip(jax.tree_util.tree_leaves(res["alg1_per"]),
                      jax.tree_util.tree_leaves(ref["alg1_per"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_study_checkpointed_run(sim, tmp_path):
    """Study.run(config=ExecutionConfig(checkpoint_dir=...)) routes to
    the resumable engine and matches the unchunked Study bitwise."""
    study = (Study("resume", num_steps=STEPS)
             .axis("scheduler", "alg1").axis("arrivals", "periodic")
             .axis("faults", [None, ("drop", {"rate": 0.3})])
             .axis("seeds", 2))
    plain = study.run(sim=sim, params0=params0())
    ck = study.run(sim=sim, params0=params0(), config=ExecutionConfig(
        checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=8))
    assert list(plain) == list(ck)
    for name in plain:
        np.testing.assert_array_equal(
            np.asarray(plain[name].history.loss),
            np.asarray(ck[name].history.loss), err_msg=name)
    assert ck.downgrades == ()


def test_study_checkpoint_config_conflicts(sim, tmp_path):
    study = (Study("conflict", num_steps=STEPS)
             .axis("scheduler", "alg1").axis("arrivals", "periodic")
             .axis("seeds", 2))
    cfg = ExecutionConfig(checkpoint_dir=str(tmp_path / "ck"),
                          sequential=True)
    with pytest.raises(ValueError, match="incompatible"):
        study.run(sim=sim, params0=params0(), config=cfg)


# ------------------------------------------------------ kill -9 / resume

_CHILD = textwrap.dedent("""
    import os, signal, sys
    import jax, jax.numpy as jnp
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.core import make_quadratic
    from repro.core.trainer import ClientSimulator
    from repro.experiments import engine
    from repro.experiments.scenario import Scenario

    from repro.optim import sgd

    ckdir, kill_after = sys.argv[1], int(sys.argv[2])
    saves = 0
    orig_save = CheckpointManager.save

    def save(self, step, tree):
        global saves
        out = orig_save(self, step, tree)
        saves += 1
        if saves >= kill_after:
            # SIGKILL mid-grid: after an npz landed, before (or between)
            # manifest updates — the hardest crash window.
            os.kill(os.getpid(), signal.SIGKILL)
        return out

    CheckpointManager.save = save

    N, DIM, STEPS = 8, 6, 30
    problem = make_quadratic(jax.random.PRNGKey(2), n_clients=N, dim=DIM)
    sim = ClientSimulator(
        grads_fn=lambda p, k, t: problem.all_grads(p, key=k, noise=0.05),
        p=problem.p, optimizer=sgd(0.02), loss_fn=problem.suboptimality)
    scenarios = [
        Scenario(name="alg1_per", scheduler="alg1", arrivals="periodic",
                 n_clients=N, horizon=STEPS + 1),
        Scenario(name="alg1_drop", scheduler="alg1", arrivals="periodic",
                 n_clients=N, horizon=STEPS + 1, faults="drop",
                 fault_kwargs={"rate": 0.3}),
        Scenario(name="bench_bin", scheduler="benchmark1", arrivals="binary",
                 n_clients=6, horizon=STEPS + 1),
    ]
    engine.execute_cells_resumable(
        scenarios, sim=sim, params0=jnp.full((DIM,), 4.0), num_steps=STEPS,
        seeds=2, checkpoint_dir=ckdir, checkpoint_every=8)
    raise SystemExit(99)  # must never get here
""")


def test_kill9_and_resume_bitwise(sim, tmp_path):
    """Launch the study in a subprocess, SIGKILL it right after its
    second checkpoint write (mid-grid, manifest possibly stale), then
    resume in-process: the finished results must be bitwise identical to
    a never-interrupted run. The only subprocess-spawning test in the
    suite."""
    ckdir = str(tmp_path / "ck")
    script = tmp_path / "child.py"
    script.write_text(_CHILD)
    # repro is a namespace package (no __init__.py) — locate src via
    # __path__ rather than __file__ (which is None).
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(script), ckdir, "2"],
                          env=env, capture_output=True, text=True,
                          timeout=300)
    assert proc.returncode == -signal.SIGKILL, (proc.returncode, proc.stderr)

    # It really died mid-grid: some checkpoints exist, no complete study.
    assert os.path.isdir(ckdir)
    groups = [d for d in os.listdir(ckdir) if d.startswith("g")]
    assert groups, os.listdir(ckdir)
    manifest = json.load(open(os.path.join(ckdir, "manifest.json")))
    assert any(g["step"] < STEPS for g in manifest["groups"].values())

    resumed = engine.execute_cells_resumable(
        _scenarios(), sim=sim, params0=params0(), num_steps=STEPS, seeds=2,
        checkpoint_dir=ckdir, checkpoint_every=8)
    ref = engine.execute_cells(_scenarios(), sim=sim, params0=params0(),
                               num_steps=STEPS, seeds=2)
    _assert_results_bitwise(resumed, ref)


# ------------------------------------- kill -9 / recover (serve, §12)

_SERVE_CHILD = textwrap.dedent("""
    import os, signal, sys
    import jax, jax.numpy as jnp
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.core import make_quadratic
    from repro.experiments import ExecutionConfig, Study
    from repro.optim import sgd
    from repro.serve import StudyService

    root, kill_after = sys.argv[1], int(sys.argv[2])
    saves = 0
    orig_save = CheckpointManager.save

    def save(self, step, tree):
        global saves
        out = orig_save(self, step, tree)
        saves += 1
        if saves >= kill_after:
            os.kill(os.getpid(), signal.SIGKILL)
        return out

    CheckpointManager.save = save

    N, DIM, STEPS = 8, 6, 30
    problem = make_quadratic(jax.random.PRNGKey(2), n_clients=N, dim=DIM)
    service = StudyService(
        grads_fn=lambda p, k, t: problem.all_grads(p, key=k, noise=0.05),
        p=problem.p, optimizer=sgd(0.02), loss_fn=problem.suboptimality,
        params0=jnp.full((DIM,), 4.0), checkpoint_root=root)
    cfg = ExecutionConfig(checkpoint_every=8)
    for name, n in (("alpha", N), ("beta", 6)):
        study = (Study(name, num_steps=STEPS).axis("scheduler", "alg1")
                 .axis("arrivals", "periodic").axis("n_clients", n)
                 .axis("seeds", [0, 1]))
        service.submit(study, cfg)
    service.flush()  # SIGKILLed mid-dispatch by the save hook
    raise SystemExit(99)  # must never get here
""")


@pytest.mark.serve
def test_service_kill9_and_recover_bitwise(sim, tmp_path):
    """The tentpole acceptance test: a StudyService dispatch SIGKILLed
    mid-run in a subprocess is recovered by a FRESH service pointed at
    the same checkpoint root — recover() finds the dispatch.json record,
    resubmits its studies, resumes from the surviving checkpoints, and
    the responses are bitwise identical (every tree leaf) to the same
    dispatch run uninterrupted."""
    from repro.experiments import ExecutionConfig, Study
    from repro.serve import StudyService

    root = str(tmp_path / "serve-ck")
    script = tmp_path / "serve_child.py"
    script.write_text(_SERVE_CHILD)
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(script), root, "2"],
                          env=env, capture_output=True, text=True,
                          timeout=300)
    assert proc.returncode == -signal.SIGKILL, (proc.returncode, proc.stderr)

    # it died mid-dispatch: the recovery record landed before execution,
    # and at least one group is short of the horizon
    dirs = [d for d in os.listdir(root) if d.startswith("d")]
    assert len(dirs) == 1, os.listdir(root)
    rec = json.load(open(os.path.join(root, dirs[0], "dispatch.json")))
    assert rec["format"] == "serve-dispatch/v1"
    assert len(rec["studies"]) == 2
    manifest = json.load(open(os.path.join(root, dirs[0], "manifest.json")))
    assert any(g["step"] < STEPS for g in manifest["groups"].values())

    def serve_studies(service):
        cfg = ExecutionConfig(checkpoint_every=8)
        for name, n in (("alpha", N), ("beta", 6)):
            study = (Study(name, num_steps=STEPS).axis("scheduler", "alg1")
                     .axis("arrivals", "periodic").axis("n_clients", n)
                     .axis("seeds", [0, 1]))
            service.submit(study, cfg)
        return {r.study: r for r in service.flush()}

    def make_svc(ckroot):
        return StudyService(grads_fn=sim.grads_fn, p=sim.p,
                            optimizer=sim.optimizer, loss_fn=sim.loss_fn,
                            params0=params0(), checkpoint_root=ckroot)

    # the uninterrupted reference: the SAME dispatch (same merged batch
    # composition) served end-to-end against a different root
    reference = serve_studies(make_svc(str(tmp_path / "ref-ck")))
    assert all(r.error is None for r in reference.values())

    fresh = make_svc(root)
    rids = fresh.recover()
    assert len(rids) == 2
    by_name = {fresh.result(r).study: fresh.result(r) for r in rids}
    assert set(by_name) == {"alpha", "beta"}
    for name in ("alpha", "beta"):
        resp = by_name[name]
        assert resp.error is None
        assert resp.batch["resumed_steps"] > 0  # it resumed, not recomputed
        ref = reference[name].result
        assert set(resp.result.cells) == set(ref.cells)
        for cell in ref.cells:
            for la, lb in zip(
                    jax.tree_util.tree_leaves(ref.cells[cell]),
                    jax.tree_util.tree_leaves(resp.result.cells[cell])):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                              err_msg=f"{name}/{cell}")


# --------------------------------------------------- train.py --resume

def test_train_resume_matches_straight_run(tmp_path):
    """launch.train --checkpoint-dir/--resume: preempt at half the steps
    (--halt-at, so both legs build components for the same --steps
    horizon), resume to the end — the resumed loss stream is bitwise the
    straight run's tail."""
    from repro.launch.train import main

    def args(ckdir, *extra):
        return ["--arch", "stablelm-1.6b", "--reduced",
                "--steps", "12", "--global-batch", "4",
                "--seq-len", "16", "--n-clients", "4",
                "--scheduler", "alg1", "--arrivals", "periodic",
                "--ckpt-every", "6", "--checkpoint-dir", str(ckdir), *extra]

    straight = main(args(tmp_path / "a"))
    halted = main(args(tmp_path / "b", "--halt-at", "6"))
    resumed = main(args(tmp_path / "b", "--resume"))
    assert len(straight) == 12 and len(halted) == 6 and len(resumed) == 6
    np.testing.assert_array_equal(np.asarray(halted),
                                  np.asarray(straight[:6]))
    np.testing.assert_array_equal(np.asarray(resumed),
                                  np.asarray(straight[6:]))
