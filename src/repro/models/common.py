"""Shared model utilities: sharding hints, norms, linears, activations.

Parameters are plain nested dicts of jnp arrays; every layer is an
``init_*(key, ...) -> params`` plus a pure ``apply`` function. Sharding is
annotated *inside* the model via :func:`maybe_shard`, which is a no-op
outside a mesh context (CPU smoke tests) and a
``with_sharding_constraint`` inside one (dry-run / production) — the
MaxText pattern.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

try:  # non-deprecated home of the mesh context (jax ≥ 0.4.x internals)
    from jax._src.mesh import thread_resources as _thread_resources
except ImportError:  # pragma: no cover - older jax
    from jax.interpreters.pxla import thread_resources as _thread_resources


def current_mesh():
    mesh = _thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


import os

# Perf-iteration toggle (EXPERIMENTS.md §Perf): divisibility-aware
# activation sharding. When on (default), a constraint axis that does not
# evenly divide the tensor dim is dropped instead of handed to GSPMD —
# non-divisible constraints (e.g. 8 kv heads on a 16-way model axis)
# trigger "involuntary full rematerialization" resharding copies.
_DIVCHECK = os.environ.get("REPRO_DIVCHECK", "1") != "0"


def maybe_shard(x, *spec):
    """Constrain ``x`` to PartitionSpec(*spec) if a mesh is active.

    Axis names absent from the active mesh are dropped (so the same model
    code runs on (data, model), (pod, data, model) or no mesh at all), as
    are axes that don't divide the corresponding dim (see _DIVCHECK).
    Entries may be None, a name, or a tuple of names.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _filter(entry, dim):
        if entry is None:
            return None
        axes = tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)
        kept = tuple(a for a in axes if a in sizes)
        if not kept:
            return None
        if _DIVCHECK:
            total = 1
            for a in kept:
                total *= sizes[a]
            if dim % total != 0:
                return None
        return kept if len(kept) > 1 else kept[0]

    filtered = tuple(_filter(e, d) for e, d in zip(spec, x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*filtered)))


# ---------------------------------------------------------------- initializers

def normal_init(key, shape, dtype, stddev):
    return (stddev * jax.random.normal(key, shape)).astype(dtype)


def lecun_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return normal_init(key, shape, dtype, fan_in ** -0.5)


# ---------------------------------------------------------------- primitives

def dense_init(key, d_in, d_out, dtype, use_bias=False, stddev=None):
    p = {"w": lecun_init(key, (d_in, d_out), dtype) if stddev is None
         else normal_init(key, (d_in, d_out), dtype, stddev)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def norm_init(d, dtype, kind="rmsnorm"):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(params, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def activation(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------- rotary

def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x, positions, theta=1e4):
    """Rotary embedding. x: (..., S, H, Dh); positions: broadcastable (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    ang = ang[..., None, :]  # head axis
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta=1e4, sections=(16, 24, 24)):
    """Qwen2-VL M-RoPE [arXiv:2409.12191]: the Dh/2 frequency slots are
    partitioned into (temporal, height, width) sections, each rotated by
    its own position stream. positions3: (3, ..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    sections = tuple(sections)
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(dh, theta)  # (half,)
    # Build per-slot position: (..., S, half)
    pos_parts = []
    start = 0
    for k, sec in enumerate(sections):
        p = positions3[k][..., None].astype(jnp.float32)
        pos_parts.append(jnp.broadcast_to(p, p.shape[:-1] + (sec,)))
        start += sec
    pos = jnp.concatenate(pos_parts, axis=-1)  # (..., S, half)
    ang = (pos * freqs)[..., None, :]  # head axis
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- misc

def stack_init(key, n, init_fn):
    """vmap an init over a leading layer axis -> stacked params for scan."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))
