"""Pytree-native optimizers (no external deps).

API mirrors the usual gradient-transformation style:

    opt = adam(3e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""

from repro.optim.optimizers import (
    Optimizer,
    adam,
    adamw,
    apply_updates,
    chain_clip,
    momentum,
    resolve_lr,
    sgd,
)
from repro.optim.schedules import (
    constant_schedule,
    cosine_schedule,
    inverse_time_schedule,
    warmup_cosine_schedule,
)

__all__ = [
    "Optimizer",
    "sgd",
    "momentum",
    "adam",
    "adamw",
    "apply_updates",
    "chain_clip",
    "resolve_lr",
    "constant_schedule",
    "cosine_schedule",
    "inverse_time_schedule",
    "warmup_cosine_schedule",
]
