"""Scenario-serving driver: Study manifests in, batched results out.

Front end of :class:`repro.serve.StudyService` (DESIGN.md §11). The
driver owns the model context — a synthetic heterogeneous quadratic
population at ``--capacity`` — and serves JSON Study manifests against
it, batching every submitted request through the structure-grouped
engine so same-structure studies (any mix of population sizes) share
one compiled trace:

    # serve manifest files
    PYTHONPATH=src python -m repro.launch.serve m1.json m2.json

    # self-contained demo batch: 8 mixed-population requests,
    # one structure, one compile
    PYTHONPATH=src python -m repro.launch.serve --demo

    # preemption-safe serving (DESIGN.md §12): checkpoint every 20
    # steps under --checkpoint-root; a killed run is picked up with
    # --recover, which resumes partial dispatches bitwise
    PYTHONPATH=src python -m repro.launch.serve --demo \
        --checkpoint-root /tmp/serve-ck --checkpoint-every 20
    PYTHONPATH=src python -m repro.launch.serve \
        --checkpoint-root /tmp/serve-ck --recover

Prints one summary line per request (cells, quarantined cells, latency)
plus the batch/cache counters that show the single-trace collapse.
Replaces the seed-era LM decode driver; `examples/serve_batch.py` is
the scripted client-side walkthrough.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.core.convergence import make_quadratic
from repro.experiments import ExecutionConfig, Study
from repro.optim import sgd
from repro.serve import StudyService


def demo_manifests(n_requests: int = 8, num_steps: int = 60,
                   capacity: int = 8, seeds=(0, 1)) -> list[str]:
    """Mixed-population, single-structure request burst: every study is
    the same scheduler × arrival structure at a different population
    size N ≤ capacity — the shape the service collapses onto one trace."""
    sizes = [3 + (i % (capacity - 2)) for i in range(n_requests)]
    out = []
    for i, n in enumerate(sizes):
        study = (Study(f"demo{i}", num_steps=num_steps)
                 .axis("scheduler", "alg1")
                 .axis("arrivals", "periodic")
                 .axis("n_clients", int(n))
                 .axis("seeds", list(seeds)))
        out.append(study.to_json())
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="serve Study manifests against a shared model context")
    ap.add_argument("manifests", nargs="*",
                    help="paths to study/v1 or study-request/v1 JSON files")
    ap.add_argument("--demo", action="store_true",
                    help="serve a built-in mixed-population demo batch")
    ap.add_argument("--demo-requests", type=int, default=8)
    ap.add_argument("--demo-steps", type=int, default=60)
    ap.add_argument("--capacity", type=int, default=8,
                    help="model-context population capacity N_cap")
    ap.add_argument("--dim", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--cache-size", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-root", default=None,
                    help="directory for resumable dispatch checkpoints "
                         "(enables --checkpoint-every and --recover)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint cadence in steps; > 0 routes "
                         "dispatches through the preemption-safe "
                         "chunked path (requires --checkpoint-root)")
    ap.add_argument("--recover", action="store_true",
                    help="resume every partial dispatch recorded under "
                         "--checkpoint-root before serving new requests")
    args = ap.parse_args(argv)

    if not args.manifests and not args.demo and not args.recover:
        ap.error("give manifest files, --demo, or --recover")
    if args.checkpoint_every and not args.checkpoint_root:
        ap.error("--checkpoint-every requires --checkpoint-root")
    if args.recover and not args.checkpoint_root:
        ap.error("--recover requires --checkpoint-root")

    payloads = []
    for path in args.manifests:
        with open(path) as f:
            payloads.append((path, f.read()))
    if args.demo:
        payloads += [(f"demo[{i}]", m) for i, m in enumerate(demo_manifests(
            args.demo_requests, args.demo_steps, args.capacity))]

    prob = make_quadratic(jax.random.PRNGKey(args.seed), args.capacity,
                          dim=args.dim)
    service = StudyService(
        grads_fn=lambda w, k, t: prob.all_grads(w), p=prob.p,
        optimizer=sgd(args.lr), params0=jnp.zeros(args.dim),
        cache_size=args.cache_size, checkpoint_root=args.checkpoint_root)

    responses = []
    rids = {}
    if args.recover:
        recovered = service.recover()
        responses += [service.result(r) for r in recovered]
        rids.update({r: "recovered" for r in recovered})
        print(f"recovered {len(recovered)} request(s) from "
              f"{args.checkpoint_root}")

    config = None
    if args.checkpoint_every:
        config = ExecutionConfig(checkpoint_every=args.checkpoint_every)
    for origin, text in payloads:
        rids[service.submit(text, config)] = origin
    responses += service.flush()

    for resp in responses:
        origin = rids.get(resp.request_id, "?")
        if resp.error is not None:
            print(f"{resp.request_id} {resp.study!r} ({origin}): "
                  f"ERROR {resp.error}")
            continue
        quarantined = (f" quarantined={resp.quarantined}"
                       if resp.quarantined else "")
        resumed = (f" checkpointed(resumed_steps="
                   f"{resp.batch['resumed_steps']})"
                   if resp.batch.get("resumable") else "")
        print(f"{resp.request_id} {resp.study!r} ({origin}): "
              f"{len(resp.records)} cell(s), "
              f"latency {resp.timings['latency_us'] / 1e3:.1f} ms"
              f"{quarantined}{resumed}")
    stats = service.stats()
    print("service:", json.dumps(stats, sort_keys=True))
    return responses


if __name__ == "__main__":
    main()
