"""Benchmark: render the dry-run roofline table (§Roofline) as CSV rows."""

from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")


def run() -> list[str]:
    if not os.path.exists(RESULTS):
        return ["roofline_table,0,missing (run repro.launch.dryrun first)"]
    with open(RESULTS) as f:
        results = json.load(f)
    rows = []
    for key in sorted(results):
        r = results[key]
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        rows.append(
            f"dryrun_{key.replace('|', '_')},{r['compile_seconds'] * 1e6:.0f},"
            f"compute={t['compute_s']:.3e};memory={t['memory_s']:.3e};"
            f"collective={t['collective_s']:.3e};"
            f"bottleneck={t['bottleneck']};"
            f"useful_flops={'%.2f' % ratio if ratio else 'na'};"
            f"accounting={r.get('layer_accounting', '?')}")
    ok = len(results)
    rows.append(f"dryrun_pairs_compiled,0,count={ok}")
    return rows
