"""Render the §Roofline markdown table from dryrun.json into EXPERIMENTS.md
(replaces the <!-- ROOFLINE_TABLE --> marker block).

    PYTHONPATH=src python -m benchmarks.render_md
"""

from __future__ import annotations

import json
import os
import re

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "results", "dryrun.json")
EXPERIMENTS = os.path.join(HERE, "..", "EXPERIMENTS.md")

MARK = "<!-- ROOFLINE_TABLE -->"


def fmt(x):
    return f"{x:.2e}" if x else "0"


def render() -> str:
    with open(RESULTS) as f:
        results = json.load(f)
    singles = {k: v for k, v in results.items() if v["mesh"] == "16x16"}
    multis = {k: v for k, v in results.items() if v["mesh"] == "2x16x16"}

    out = ["## §Roofline — single-pod 16×16 (256 chips), unrolled accounting",
           "",
           "Terms in seconds/step (compute = HLO_FLOPs/(chip·197e12); "
           "memory = HLO_bytes/(chip·819e9); collective = coll_bytes/"
           "(chip·50e9)). `useful` = MODEL_FLOPS(6·N_act·D or 2·N_act·D) / "
           "total-HLO-FLOPs — the fraction of compiled compute that is "
           "model math (rest: remat recompute, attention O(S²), dispatch).",
           "",
           "| arch | shape | compute_s | memory_s | collective_s | "
           "bottleneck | useful | coll GB (AG/AR/RS/A2A/CP) |",
           "|---|---|---|---|---|---|---|---|"]
    for k in sorted(singles):
        r = singles[k]
        t = r["roofline"]
        pk = r["collectives"]["per_kind"]
        gb = "/".join(f"{pk[c] / 1e9:.1f}" for c in
                      ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        ratio = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(t['compute_s'])} | "
            f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
            f"**{t['bottleneck']}** | "
            f"{('%.2f' % ratio) if ratio else '—'} | {gb} |")

    out += ["",
            f"Multi-pod 2×16×16: **{len(multis)} pairs compiled** "
            "(scan artifacts — coherence proof; per-layer terms live in "
            "the single-pod table). Bottleneck distribution: "]
    from collections import Counter
    c = Counter(v["roofline"]["bottleneck"] for v in multis.values())
    out[-1] += ", ".join(f"{k}={v}" for k, v in sorted(c.items())) + "."
    return "\n".join(out)


def main():
    table = render()
    with open(EXPERIMENTS) as f:
        text = f.read()
    block = f"{MARK}\n{table}\n{MARK}"
    if text.count(MARK) == 2:
        text = re.sub(f"{MARK}.*?{MARK}", block, text, flags=re.S)
    else:
        text = text.replace(MARK, block, 1)
    with open(EXPERIMENTS, "w") as f:
        f.write(text)
    print(f"rendered {len(table.splitlines())} lines into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
