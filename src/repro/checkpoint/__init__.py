from repro.checkpoint.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
)

__all__ = ["save_pytree", "restore_pytree", "CheckpointManager", "latest_step"]
