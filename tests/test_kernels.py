"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Randomized property-based variants live in ``test_kernels_properties.py``
(skipped cleanly when ``hypothesis`` is unavailable).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.aggregate import (
    masked_scaled_aggregate,
    masked_scaled_aggregate_ref,
)
from repro.kernels.aggregate.aggregate import masked_scaled_aggregate_kernel
from repro.kernels.flash_attention.flash_attention import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssm_scan.ops import gla_scan
from repro.kernels.ssm_scan.ref import gla_scan_ref
from repro.kernels.ssm_scan.ssm_scan import gla_scan_kernel


# ------------------------------------------------------------- aggregate

@pytest.mark.parametrize("n,p,block_p", [
    (8, 64, 32), (40, 1000, 256), (3, 130, 128), (129, 257, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_aggregate_sweep(n, p, block_p, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    g = jax.random.normal(k1, (n, p)).astype(dtype)
    w = jax.random.uniform(k2, (n,))
    out = masked_scaled_aggregate_kernel(g, w, block_p=block_p,
                                         interpret=True)
    ref = masked_scaled_aggregate_ref(g, w)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_aggregate_masking_zeroes_clients():
    g = jnp.ones((4, 16))
    w = jnp.asarray([0.0, 2.0, 0.0, 1.0])
    out = masked_scaled_aggregate(g, w)
    np.testing.assert_allclose(out, 3.0)


@pytest.mark.ragged
@pytest.mark.parametrize("in_dtype,out_dtype", [
    (jnp.float32, None), (jnp.bfloat16, jnp.float32),
])
def test_aggregate_mask_operand_rows_are_exact_zeros(in_dtype, out_dtype):
    """The mask operand is a row *select* on the tiled reduction: a
    masked row contributes exactly 0 — not an epsilon — even when its
    weight is nonzero and its contents are inf/NaN garbage (a ×0
    multiply would produce NaN)."""
    n, p = 6, 300
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    g = jax.random.normal(k1, (n, p)).astype(in_dtype)
    # rows 1 and 4 are dead: poison them with non-finite garbage
    garbage = jnp.full((p,), jnp.inf, in_dtype)
    g = g.at[1].set(garbage).at[4].set(jnp.nan)
    w = jax.random.uniform(k2, (n,)) + 0.5  # all weights nonzero
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0])
    out = masked_scaled_aggregate_kernel(g, w, mask, block_p=128,
                                         interpret=True,
                                         out_dtype=out_dtype)
    assert np.isfinite(np.asarray(out, np.float32)).all()
    ref = masked_scaled_aggregate_ref(
        jnp.where(mask[:, None] > 0, g, jnp.zeros((), in_dtype)), w)
    tol = 1e-6 if in_dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
    # exactness, not epsilon: a masked-only change to g leaves the
    # output bit-identical
    g2 = g.at[1].set(-garbage).at[4].set(1e30)
    out2 = masked_scaled_aggregate_kernel(g2, w, mask, block_p=128,
                                          interpret=True,
                                          out_dtype=out_dtype)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(out2, np.float32))


@pytest.mark.ragged
def test_aggregate_mask_none_is_bit_identical_to_unmasked():
    """mask=None keeps the original two-operand program (no behavior
    drift for uniform populations); an all-ones mask agrees exactly."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    g = jax.random.normal(k1, (7, 130))
    w = jax.random.uniform(k2, (7,))
    base = masked_scaled_aggregate_kernel(g, w, block_p=64, interpret=True)
    ones = masked_scaled_aggregate_kernel(g, w, jnp.ones((7,)), block_p=64,
                                          interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(ones))
    np.testing.assert_allclose(np.asarray(base),
                               np.asarray(masked_scaled_aggregate_ref(g, w)),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------- flash attention

@pytest.mark.parametrize("b,h,hkv,s,dh,causal,window,bq,bk", [
    (1, 2, 1, 64, 16, True, 0, 16, 16),
    (2, 4, 2, 128, 32, True, 0, 32, 32),
    (1, 2, 2, 128, 16, True, 32, 32, 32),
    (1, 8, 1, 64, 64, True, 0, 16, 16),      # extreme GQA
    (1, 2, 1, 64, 16, False, 0, 16, 16),     # bidirectional
    (1, 1, 1, 256, 16, True, 64, 64, 64),    # long + window
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, hkv, s, dh, causal, window, bq, bk,
                               dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, s, dh)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, dh)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, dh)).astype(dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-3 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_attention():
    """Kernel result == the model's _sdpa reference path."""
    from repro.models.attention import _sdpa, causal_mask
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, s, h, hkv, dh = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    out_k = flash_attention_kernel(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=True, window=0, block_q=16, block_k=16,
        interpret=True).swapaxes(1, 2)
    out_m = _sdpa(q, k, v, causal_mask(s))
    np.testing.assert_allclose(out_k, out_m, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------- ssm scan

@pytest.mark.parametrize("b,s,h,dk,dv,chunk", [
    (1, 32, 2, 8, 8, 8), (2, 64, 3, 16, 32, 16), (1, 50, 1, 4, 4, 16),
])
def test_gla_scan_sweep(b, s, h, dk, dv, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    a = jax.random.uniform(ks[0], (b, s, h), minval=0.6, maxval=1.0)
    k = jax.random.normal(ks[1], (b, s, h, dk)) * 0.3
    v = jax.random.normal(ks[2], (b, s, h, dv))
    q = jax.random.normal(ks[3], (b, s, h, dk)) * 0.3
    y = gla_scan(a, k, v, q, chunk=chunk)
    fold = lambda x: x.swapaxes(1, 2).reshape((b * h, s) + x.shape[3:])
    ref = gla_scan_ref(fold(a), fold(k), fold(v), fold(q)) \
        .reshape(b, h, s, dv).swapaxes(1, 2)
    np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4)


# ------------------------------------------------- compiler-params shim

def test_compiler_params_shim_resolves_installed_symbol():
    """``repro.kernels.CompilerParams`` must be the one dataclass the
    installed jax exports (``CompilerParams`` on new releases,
    ``TPUCompilerParams`` before the rename) — every kernel module
    imports this single shim instead of re-probing pltpu."""
    from jax.experimental.pallas import tpu as pltpu

    import repro.kernels as rk

    expected = getattr(pltpu, "CompilerParams", None) \
        or pltpu.TPUCompilerParams
    assert rk.CompilerParams is expected
    params = rk.tpu_compiler_params(dimension_semantics=("arbitrary",))
    assert isinstance(params, rk.CompilerParams)


def test_kernel_modules_use_shared_shim():
    """No kernel module keeps a private getattr-probe: they all bind the
    package-level shim object."""
    import importlib

    import repro.kernels as rk

    fa = importlib.import_module("repro.kernels.flash_attention.flash_attention")
    ss = importlib.import_module("repro.kernels.ssm_scan.ssm_scan")
    assert fa._CompilerParams is rk.CompilerParams
    assert ss._CompilerParams is rk.CompilerParams
