"""Config registry: the 10 assigned architectures (+ the paper's CNN task).

``get_config(name)`` / ``--arch <id>`` resolve through ``REGISTRY``.
"""

from repro.configs.base import ArchConfig
from repro.configs.shapes import (
    DEFAULT_N_CLIENTS,
    INPUT_SHAPES,
    InputShape,
    effective_window,
    input_specs,
)

from repro.configs import (
    command_r_35b,
    deepseek_coder_33b,
    llama4_scout_17b,
    minitron_4b,
    phi35_moe_42b,
    qwen2_vl_2b,
    stablelm_1p6b,
    whisper_tiny,
    xlstm_1p3b,
    zamba2_2p7b,
)

REGISTRY = {
    c.CONFIG.name: c.CONFIG
    for c in (
        phi35_moe_42b, minitron_4b, whisper_tiny, llama4_scout_17b,
        zamba2_2p7b, xlstm_1p3b, deepseek_coder_33b, stablelm_1p6b,
        command_r_35b, qwen2_vl_2b,
    )
}


def get_config(name: str) -> ArchConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}") from None


def arch_names():
    return sorted(REGISTRY)


__all__ = [
    "ArchConfig", "REGISTRY", "get_config", "arch_names",
    "INPUT_SHAPES", "InputShape", "input_specs", "effective_window",
    "DEFAULT_N_CLIENTS",
]
