"""GQA attention: train/prefill path, decode path with KV cache.

Features required across the assigned architectures:
  * grouped-query attention (n_kv_heads ≤ n_heads)        — all archs
  * RoPE / M-RoPE (qwen2-vl) / no-rope (whisper, learned pos)
  * causal, bidirectional (whisper encoder), cross (whisper decoder)
  * sliding-window variant (sub-quadratic; enables long_500k on dense)
  * KV cache decode — full cache or ring buffer (sliding window)
  * optional Pallas flash-attention kernel for the prefill/train path

Tensor convention: x (B, S, D); q (B, S, H, Dh); kv (B, S, Hkv, Dh).
Sharding: heads split along "model", batch along ("pod","data").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (
    apply_mrope,
    apply_rope,
    dense,
    dense_init,
    maybe_shard,
)

NEG_INF = -1e30


def init_attention(key, d_model, n_heads, n_kv_heads, head_dim, dtype,
                   use_bias=False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, n_heads * head_dim, dtype, use_bias),
        "wk": dense_init(kk, d_model, n_kv_heads * head_dim, dtype, use_bias),
        "wv": dense_init(kv, d_model, n_kv_heads * head_dim, dtype, use_bias),
        "wo": dense_init(ko, n_heads * head_dim, d_model, dtype, use_bias),
    }


def _split_heads(x, n, dh):
    return x.reshape(x.shape[:-1] + (n, dh))


def _rope(q, k, positions, theta, m_rope, mrope_sections):
    if positions is None:
        return q, k
    if m_rope:
        return (apply_mrope(q, positions, theta, mrope_sections),
                apply_mrope(k, positions, theta, mrope_sections))
    return apply_rope(q, positions, theta), apply_rope(k, positions, theta)


def _sdpa(q, k, v, mask):
    """Reference scaled-dot-product GQA attention.

    q: (B,S,H,Dh), k/v: (B,T,Hkv,Dh); mask: (B,1,S,T) or (S,T) additive or
    None. Handles GQA by reshaping q into (Hkv, group). Accumulation is
    f32 via preferred_element_type — K/V are NOT materialized in f32 (that
    copy doubled decode cache-read bytes; EXPERIMENTS.md §Perf, climb 2).
    """
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qs = (q * (dh ** -0.5)).reshape(b, s, hkv, g, dh)
    logits = jnp.einsum("bshgd,bthd->bhgst", qs, k,
                        preferred_element_type=jnp.float32)
    if mask is not None:
        while mask.ndim < logits.ndim:
            mask = mask[None]
        logits = logits + mask
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, dh).astype(q.dtype)


def causal_mask(s, t_len=None, window=0, offset=0):
    """Additive (S, T) mask. ``offset`` = absolute position of query 0
    relative to key 0 (for prefill continuation). ``window > 0`` keeps only
    keys within ``window`` positions behind the query (sliding window)."""
    t_len = t_len or s
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(t_len)[None, :]
    ok = kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention(params, x, *, n_heads, n_kv_heads, head_dim,
              positions=None, rope_theta=1e4, m_rope=False,
              mrope_sections=(16, 24, 24), causal=True, window=0,
              kv_override=None, use_flash=False):
    """Full-sequence attention (train / prefill / encoder / cross).

    kv_override: (B, T, D) memory for cross-attention (whisper decoder);
    when set, ``causal`` is ignored (full visibility of the memory).
    """
    b, s, _ = x.shape
    q = _split_heads(dense(params["wq"], x), n_heads, head_dim)
    kv_in = x if kv_override is None else kv_override
    k = _split_heads(dense(params["wk"], kv_in), n_kv_heads, head_dim)
    v = _split_heads(dense(params["wv"], kv_in), n_kv_heads, head_dim)
    q = maybe_shard(q, ("pod", "data"), None, "model", None)
    k = maybe_shard(k, ("pod", "data"), None, "model", None)
    v = maybe_shard(v, ("pod", "data"), None, "model", None)
    if kv_override is None:
        q, k = _rope(q, k, positions, rope_theta, m_rope, mrope_sections)

    if use_flash and kv_override is None and causal:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=True, window=window)
    else:
        mask = None
        if kv_override is None and causal:
            mask = causal_mask(s, k.shape[1], window=window)
        out = _sdpa(q, k, v, mask)
    out = maybe_shard(out, ("pod", "data"), None, "model", None)
    y = dense(params["wo"], out.reshape(b, s, n_heads * head_dim))
    return maybe_shard(y, ("pod", "data"), None, None)


# ------------------------------------------------------------------ decode

def init_kv_cache(batch, n_kv_heads, head_dim, cache_len, dtype):
    """cache_len = full seq for dense attention, window for SWA (ring)."""
    shape = (batch, cache_len, n_kv_heads, head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def decode_attention(params, x, cache, pos, *, n_heads, n_kv_heads, head_dim,
                     rope_theta=1e4, m_rope=False, mrope_sections=(16, 24, 24),
                     window=0, kv_override=None, use_rope=True):
    """One-token decode. x: (B, 1, D); pos: scalar int — absolute position.

    Full attention: cache length = max context; entry ``pos`` is written.
    Sliding window: cache is a ring buffer of length ``window``; slot
    ``pos % window`` is overwritten. Returns (y, new_cache).
    """
    b = x.shape[0]
    q = _split_heads(dense(params["wq"], x), n_heads, head_dim)
    if kv_override is not None:
        k = _split_heads(dense(params["wk"], kv_override), n_kv_heads, head_dim)
        v = _split_heads(dense(params["wv"], kv_override), n_kv_heads, head_dim)
        out = _sdpa(q, k, v, None)
        y = dense(params["wo"], out.reshape(b, 1, n_heads * head_dim))
        return y, cache

    k_new = _split_heads(dense(params["wk"], x), n_kv_heads, head_dim)
    v_new = _split_heads(dense(params["wv"], x), n_kv_heads, head_dim)
    if use_rope:
        posv = jnp.full((b, 1), pos)
        if m_rope:
            posv3 = jnp.broadcast_to(posv, (3,) + posv.shape)
            q = apply_mrope(q, posv3, rope_theta, mrope_sections)
            k_new = apply_mrope(k_new, posv3, rope_theta, mrope_sections)
        else:
            q = apply_rope(q, posv, rope_theta)
            k_new = apply_rope(k_new, posv, rope_theta)

    cache_len = cache["k"].shape[1]
    slot = (pos % cache_len) if window > 0 else pos
    # Keep every cache operand on ONE sharding (batch on data, head_dim on
    # model) across the dynamic-update — otherwise GSPMD replicates the
    # full cache (f32!) around the DUS: measured 68.7 GB of all-gather per
    # decode step on minitron decode_32k (EXPERIMENTS.md §Perf, climb 2).
    cache_spec = (("pod", "data"), None, None, "model")
    # q on the same (batch, …, head_dim) split: the q·K contraction over
    # head_dim then stays local per shard (tiny logits all-reduce) instead
    # of all-gathering K (34 GB/step measured).
    q = maybe_shard(q, ("pod", "data"), None, None, "model")
    k_new = maybe_shard(k_new, *cache_spec)
    v_new = maybe_shard(v_new, *cache_spec)
    k_in = maybe_shard(cache["k"], *cache_spec)
    v_in = maybe_shard(cache["v"], *cache_spec)
    k_cache = maybe_shard(
        jax.lax.dynamic_update_slice_in_dim(k_in, k_new, slot, axis=1),
        *cache_spec)
    v_cache = maybe_shard(
        jax.lax.dynamic_update_slice_in_dim(v_in, v_new, slot, axis=1),
        *cache_spec)

    # Validity of cache slots: absolute position of slot j.
    j = jnp.arange(cache_len)
    if window > 0:
        # Ring buffer: slot j holds absolute position with (abs % L == j),
        # the latest such ≤ pos. Valid iff abs > pos − window and abs ≥ 0.
        abs_pos = pos - ((pos - j) % cache_len)
        valid = (abs_pos >= 0) & (abs_pos >= pos - window + 1)
    else:
        valid = j <= pos
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[None, :]  # (1, T)
    out = _sdpa(q, k_cache, v_cache, mask)
    y = dense(params["wo"], out.reshape(b, 1, n_heads * head_dim))
    y = maybe_shard(y, ("pod", "data"), None, None)
    return y, {"k": k_cache, "v": v_cache}
