from repro.checkpoint.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
    write_json_atomic,
)

__all__ = ["save_pytree", "restore_pytree", "CheckpointManager",
           "latest_step", "write_json_atomic"]
