"""Benchmark: Theorem 1 — empirical suboptimality vs the analytic bound,
and the ηLC/(2μ) error floor sweep (Remark 1).

Each step-size's seed batch runs through the scenario engine
(:func:`repro.experiments.run_grid`) as a single compiled computation,
and the empirical floor is reported as mean±std across seeds instead of
a single-seed point estimate.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    make_quadratic,
    max_step_size,
    theorem1_bound,
    variance_constant,
)
from repro.experiments import Scenario, clear_cache, run_grid
from repro.optim import sgd

TAUS = (1, 5, 10, 20)
SEEDS = 8


def run() -> list[str]:
    t0 = time.time()
    n = 8
    problem = make_quadratic(jax.random.PRNGKey(3), n, dim=8, hetero=0.5)
    taus = [TAUS[i % 4] for i in range(n)]
    steps = 2000
    scenario = Scenario(name="alg1_periodic", scheduler="alg1",
                        arrivals="periodic", n_clients=n, horizon=steps + 1,
                        taus=taus)

    rows = []
    eta_max = max_step_size(problem.mu, problem.lsmooth)
    radius = float(jnp.linalg.norm(problem.w_star)) + 10.0
    g2 = problem.grad_second_moment_bound(radius)
    c = float(variance_constant(problem.p, jnp.asarray(taus, jnp.float32), g2))
    f0 = float(problem.suboptimality(jnp.full((8,), 5.0)))

    for frac in (0.1, 0.25, 0.5):
        eta = frac * eta_max
        results = run_grid(
            [scenario],
            grads_fn=lambda p, k, t: problem.all_grads(p),
            p=problem.p, optimizer=sgd(eta),
            params0=jnp.full((8,), 5.0), num_steps=steps, seeds=SEEDS,
            loss_fn=problem.suboptimality)
        finals = np.asarray(results["alg1_periodic"].history.loss[:, -100:]
                            ).mean(axis=1)  # (SEEDS,)
        emp, emp_std = float(finals.mean()), float(finals.std())
        bound = float(theorem1_bound(steps, f0, problem.mu, problem.lsmooth,
                                     eta, c))
        rows.append(
            f"theorem1_eta{frac},{(time.time() - t0) * 1e6:.0f},"
            f"empirical={emp:.4g};empirical_std={emp_std:.2g};"
            f"seeds={SEEDS};bound={bound:.4g};holds={emp <= bound}")
    clear_cache()  # each eta traced its own grid; don't pin them all
    return rows
