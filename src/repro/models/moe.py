"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is **scatter/gather based** (not the GShard one-hot-einsum): the
one-hot dispatch einsum costs O(K·T²/E·d) FLOPs which would poison the
roofline tables; scatter-add into an expert buffer is O(T·K·d).

The buffer layout is **hierarchical / shard-local** (EXPERIMENTS.md §Perf,
hillclimb 1): capacity slots are partitioned by data shard — token t on
data shard s can only occupy slots in shard s's slice, so the
position-in-expert cumsum runs per shard-row and the scatter writes stay
local to the data shard. The buffer is sharded (experts → "model",
capacity → ("pod","data")); without the capacity-axis sharding GSPMD
replicates the whole expert computation on every data shard (measured
4.06× FLOPs on a (4,2) mesh, ~16× at production), and without the
shard-local slot arithmetic it replicates the token buffers around the
scatter (measured 3.4 TB of all-gather per phi3.5 train step).

Capacity is enforced per (expert, data-shard) — the standard
expert-parallel semantics; overflowing tokens drop (combine weight 0,
residual passes through).

Covers both assigned MoE archs:
  * phi3.5-moe  — 16 experts, top-2, no shared expert
  * llama4-scout — 16 experts, top-1 + always-on shared expert
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (
    activation,
    current_mesh,
    dense,
    dense_init,
    lecun_init,
    maybe_shard,
)


def init_moe(key, d_model, d_ff, n_experts, dtype, use_bias=False,
             shared_expert=False, shared_d_ff=None):
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_gate": lecun_init(ks[1], (n_experts, d_model, d_ff), dtype, fan_in=d_model),
        "w_up": lecun_init(ks[2], (n_experts, d_model, d_ff), dtype, fan_in=d_model),
        "w_down": lecun_init(ks[3], (n_experts, d_ff, d_model), dtype, fan_in=d_ff),
    }
    if shared_expert:
        from repro.models.blocks import init_mlp  # local import to avoid cycle
        p["shared"] = init_mlp(ks[4], d_model, shared_d_ff or d_ff, dtype, use_bias)
    return p


def _data_shards(t: int) -> int:
    """Number of data shards the token axis is split over (1 off-mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("pod", 1) * sizes.get("data", 1)
    return dp if dp > 1 and t % dp == 0 else 1


def _local_moe(router_w, w_gate, w_up, w_down, xt, *, n_experts, top_k,
               act, capacity, e_start, e_count):
    """Per-device MoE over a slice of experts (shard_map body helper).

    xt: (t_local, d) — this data shard's tokens (replicated across the
    model axis). w_*: (e_count, …) — this model shard's experts. Returns
    this shard's *partial* output (only its experts' contributions) and
    the local router stats; caller psums over "model".
    """
    act_fn = activation(act)
    t, d = xt.shape
    logits = xt.astype(jnp.float32) @ router_w                 # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    flat_e = top_e.reshape(-1)                                 # (tK,)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, -1) - 1
    mine = (flat_e >= e_start) & (flat_e < e_start + e_count)
    keep = (pos < capacity) & mine
    local_e = jnp.clip(flat_e - e_start, 0, e_count - 1)
    slot = local_e * capacity + jnp.minimum(pos, capacity - 1)

    tok_idx = jnp.repeat(jnp.arange(t), top_k)
    contrib = jnp.where(keep[:, None], xt[tok_idx], 0.0)
    buf = jnp.zeros((e_count * capacity, d), xt.dtype).at[slot].add(
        jnp.where(keep[:, None], contrib, 0.0), mode="drop")
    buf = buf.reshape(e_count, capacity, d)

    h = act_fn(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, w_up)
    out = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(
        e_count * capacity, d)

    gathered = out[slot]
    w = jnp.where(keep, top_p.reshape(-1), 0.0).astype(xt.dtype)
    y = jnp.zeros((t, d), xt.dtype).at[tok_idx].add(gathered * w[:, None])

    frac = jnp.mean(jax.nn.one_hot(top_e[:, 0], n_experts,
                                   dtype=jnp.float32), axis=0)
    aux = n_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
    return y, aux


def _apply_moe_shardmap(params, x, *, n_experts, top_k, act,
                        capacity_factor, mesh):
    """Expert-parallel MoE via shard_map (EXPERIMENTS.md §Perf climb 1).

    Activations are sharded on batch over ("pod","data") and replicated
    over "model"; experts are sharded over "model". Every model shard
    dispatches the SAME local tokens to ITS expert slice — entirely
    device-local scatter/gather (GSPMD never sees it) — and the partial
    outputs combine with one psum over "model". Collective cost per layer:
    one (t_local, d) all-reduce; the 3.4 TB/step of GSPMD scatter-add
    replication in the global-scatter formulation disappears.
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    tp = sizes.get("model", 1)
    b, s, d = x.shape
    dp = 1
    for a in dp_axes:
        dp *= sizes[a]
    tl = (b // dp) * s
    capacity = int(max(1, (tl * top_k * capacity_factor) // n_experts))
    e_count = n_experts // tp

    def body(router_w, w_gate, w_up, w_down, xs):
        midx = jax.lax.axis_index("model")
        xt = xs.reshape(-1, d)
        y, aux = _local_moe(router_w, w_gate, w_up, w_down, xt,
                            n_experts=n_experts, top_k=top_k, act=act,
                            capacity=capacity, e_start=midx * e_count,
                            e_count=e_count)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, dp_axes + ("model",))
        return y.reshape(xs.shape), aux

    in_specs = (P(), P("model", None, None), P("model", None, None),
                P("model", None, None), P(dp_axes, None, None))
    out_specs = (P(dp_axes, None, None), P())
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    return fn(params["router"]["w"], params["w_gate"], params["w_up"],
              params["w_down"], x)


def apply_moe(params, x, *, n_experts, top_k, act="silu",
              capacity_factor=1.25, shared_expert=False):
    """x: (B, S, D) -> (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s

    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp = sizes.get("pod", 1) * sizes.get("data", 1)
        tp = sizes["model"]
        if n_experts % tp == 0 and b % max(dp, 1) == 0:
            y, aux = _apply_moe_shardmap(
                params, x, n_experts=n_experts, top_k=top_k, act=act,
                capacity_factor=capacity_factor, mesh=mesh)
            if shared_expert:
                from repro.models.blocks import apply_mlp
                y = y + apply_mlp(params["shared"], x, act=act)
            return y, aux
    xt = x.reshape(t, d)
    act_fn = activation(act)

    logits = dense(params["router"], xt.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)                # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)    # renormalize

    ds = _data_shards(t)
    tl = t // ds                                              # tokens/shard
    cap = int(max(1, (tl * top_k * capacity_factor) // n_experts))

    # Shard-local position in expert: cumsum per shard-row.
    flat_e = top_e.reshape(ds, tl * top_k)                    # (DS, tlK)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos = jnp.sum(jnp.cumsum(onehot, axis=1) * onehot, -1) - 1  # (DS, tlK)
    keep = (pos < cap).reshape(-1)
    shard_ix = jnp.repeat(jnp.arange(ds), tl * top_k)
    slot = ((flat_e.reshape(-1) * ds + shard_ix) * cap
            + jnp.minimum(pos.reshape(-1), cap - 1))          # (T*K,)

    tok_idx = jnp.repeat(jnp.arange(t), top_k)
    contrib = jnp.where(keep[:, None], xt[tok_idx], 0.0)
    contrib = maybe_shard(contrib, ("pod", "data"), None)
    buf = jnp.zeros((n_experts * ds * cap, d), x.dtype).at[slot].add(
        contrib.astype(x.dtype), mode="drop")
    buf = buf.reshape(n_experts, ds * cap, d)
    buf = maybe_shard(buf, "model", ("pod", "data"), None)    # EP × DP

    h = act_fn(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, params["w_up"])
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out = maybe_shard(out, "model", ("pod", "data"), None)
    out = out.reshape(n_experts * ds * cap, d)

    # Gather back with combine weights.
    gathered = out[slot]                                       # (T*K, D)
    gathered = maybe_shard(gathered, ("pod", "data"), None)
    w = jnp.where(keep, top_p.reshape(-1), 0.0).astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[tok_idx].add(gathered * w[:, None])
    y = maybe_shard(y, ("pod", "data"), None)

    if shared_expert:
        from repro.models.blocks import apply_mlp
        # keep (B, S, D) rank for the mlp's activation sharding constraint
        y = y + apply_mlp(params["shared"], x, act=act).reshape(t, d)

    # Switch load-balance aux loss: E · Σ_e f_e · P_e.
    frac = jnp.mean(jax.nn.one_hot(top_e[:, 0], n_experts, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(frac * mean_p)

    return y.reshape(b, s, d), aux
