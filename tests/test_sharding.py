"""Sharding rule tests (pure spec logic — no multi-device runtime needed)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import SUFFIX_RULES, _fit_spec, auto_spec, param_specs


class FakeMesh:
    """Duck-typed mesh carrying only names/shape (spec logic is pure)."""

    def __init__(self, shape_by_name):
        self.axis_names = tuple(shape_by_name)
        self.devices = np.empty(tuple(shape_by_name.values()))


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_fit_spec_pads_leading_axes():
    spec = _fit_spec((8, 4, 4096, 6400), ("data", "model"),
                     {"data": 16, "model": 16})
    assert spec == P(None, None, "data", "model")


def test_fit_spec_drops_nondivisible():
    spec = _fit_spec((51865, 384), ("model", "data"),
                     {"data": 16, "model": 16})
    assert spec == P(None, "data")  # 51865 % 16 != 0 -> replicated axis


def test_param_specs_on_real_tree():
    from repro.configs import get_config
    from repro.models import init_lm
    cfg = get_config("stablelm-1.6b")
    shapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    specs = param_specs(shapes, MESH)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    by_path = {"/".join(str(getattr(k, "key", k)) for k in path): spec
               for path, spec in flat}
    # attention projections sharded fsdp+tp (leading scan axis replicated)
    assert by_path["stack/seg0/attn/wq/w"] == P(None, "data", "model")
    assert by_path["stack/seg0/attn/wo/w"] == P(None, "model", "data")
    assert by_path["stack/seg0/mlp/down/w"] == P(None, "model", "data")
    # norms replicated
    assert by_path["stack/seg0/ln1/scale"] == P()
    # embed: vocab 100352 % 16 == 0 -> model; d 2048 % 16 == 0 -> data
    assert by_path["embed/w"] == P("model", "data")


def test_param_specs_moe_expert_parallel():
    from repro.configs import get_config
    from repro.models import init_lm
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    shapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    specs = param_specs(shapes, MESH)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    by_path = {"/".join(str(getattr(k, "key", k)) for k in path): spec
               for path, spec in flat}
    assert by_path["stack/seg0/moe/w_gate"] == P(None, "model", "data", None)
    assert by_path["stack/seg0/moe/w_down"] == P(None, "model", None, "data")
    assert by_path["stack/seg0/moe/router/w"] == P(None, None, None)


def test_auto_spec_batch_and_model():
    assert auto_spec((256, 4096), MESH_MP) == P(("pod", "data"), "model")
    assert auto_spec((256,), MESH_MP) == P(("pod", "data"))
    # batch=1 (long_500k): batch replicated, later axis gets model
    spec = auto_spec((1, 8192, 8, 128), MESH_MP)
    assert spec[0] is None
    assert "model" in spec


def test_every_rule_spec_is_wellformed():
    for suffix, spec in SUFFIX_RULES:
        assert isinstance(suffix, str) and len(spec) >= 1


def test_maybe_shard_noop_without_mesh():
    from repro.models.common import maybe_shard
    x = jnp.ones((4, 4))
    y = maybe_shard(x, "data", "model")
    np.testing.assert_array_equal(x, y)
