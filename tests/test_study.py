"""Study API tests: axis registry, resolution/naming, Study.run vs the
legacy engine (numerics + compile counts), GridResult selection and
NaN-aware reduction, and cache teardown."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_quadratic
from repro.experiments import (
    ExecutionConfig,
    GridResult,
    Study,
    axis_names,
    build_components,
    clear_cache,
    get_grid,
    get_study,
    grid_summary,
    make_cell_mesh,
    run_grid,
    run_grid_sequential,
    seed_stats,
    study_names,
)
from repro.experiments import engine, placement
from repro.optim import sgd

multidevice = pytest.mark.multidevice


@pytest.fixture(scope="module")
def problem():
    return make_quadratic(jax.random.PRNGKey(2), n_clients=6, dim=5,
                          hetero=1.0)


@pytest.fixture(scope="module")
def run_kwargs(problem):
    return dict(
        grads_fn=lambda p, k, t: problem.all_grads(p, key=k, noise=0.05),
        p=problem.p, optimizer=sgd(0.02),
        loss_fn=problem.suboptimality, params0=jnp.full((5,), 4.0))


# -------------------------------------------------------------- registries

def test_unknown_axis_name_lists_alternatives():
    study = Study("s", num_steps=10)
    with pytest.raises(ValueError, match="unknown sweep axis 'frobnicate'"):
        study.axis("frobnicate", [1, 2])
    # the error names the registered axes so the typo is self-correcting
    with pytest.raises(ValueError, match="scheduler"):
        study.axis("schedulers", ["alg1"])


def test_unknown_study_and_grid_names():
    with pytest.raises(ValueError, match="unknown study"):
        get_study("fig2")
    with pytest.raises(ValueError, match="unknown scenario grid"):
        get_grid("fig2")


def test_axis_names_canonical_order():
    names = axis_names()
    assert names[:7] == ["scheduler", "arrivals", "capacity", "n_clients",
                         "taus_profile", "faults", "seeds"]


def test_study_registry_names():
    assert {"fig1", "fig1_grid", "capacity_sweep", "day_night",
            "population_scaling"} <= set(study_names())


def test_capacity_sweep_cell_naming():
    scens = get_study("capacity_sweep", n_clients=4, num_steps=100,
                      capacities=(1.0, 2.5, 4.0)).resolve()
    assert [s.name for s in scens] == [
        "battery_adaptive_binary_c1", "battery_adaptive_binary_c2.5",
        "battery_adaptive_binary_c4"]
    for s, c in zip(scens, (1.0, 2.5, 4.0)):
        scheduler, _ = s.build()
        assert float(scheduler.capacity) == c


def test_fig1_names_match_legacy_grid():
    """The Study naming convention reproduces the seed-era cell names."""
    study_names_ = [s.name for s in
                    get_study("fig1_grid", n_clients=4, num_steps=10).resolve()]
    assert study_names_[:3] == ["alg1_periodic", "alg1_binary", "alg1_uniform"]
    legacy = get_grid("fig1_grid", n_clients=4, horizon=11)
    assert [s.name for s in legacy] == study_names_


def test_study_requires_identity_axes():
    with pytest.raises(ValueError, match="scheduler"):
        Study("s", num_steps=10, axes={"arrivals": "binary"}).resolve()


def test_resolve_rejects_duplicate_cell_names():
    # sweeping the same scheduler value twice collides
    study = Study("s", num_steps=10, axes={
        "scheduler": ["alg1", "alg1"], "arrivals": "binary"})
    with pytest.raises(ValueError, match="unique"):
        study.resolve()


def test_run_grid_sequential_rejects_duplicate_names(problem, run_kwargs):
    """Regression: the sequential path used to silently overwrite
    duplicate scenario names (last cell won)."""
    from repro.experiments import Scenario

    scens = [Scenario("dup", "alg1", "periodic", 6, 11)] * 2
    kw = {k: v for k, v in run_kwargs.items() if k != "params0"}
    with pytest.raises(ValueError, match="unique"):
        run_grid_sequential(scens, params0=run_kwargs["params0"],
                            num_steps=10, seeds=2, **kw)


def test_build_components_single_cell():
    scheduler, energy = build_components(
        scheduler="battery_adaptive", arrivals="day_night", n_clients=4,
        horizon=101, capacity=4.0)
    assert scheduler.n_clients == 4
    assert float(scheduler.capacity) == 4.0
    assert type(energy).__name__ == "DayNightArrivals"


# ------------------------------------------------------- Study.run numerics

def test_study_matches_run_grid_numerics(problem, run_kwargs):
    """Acceptance: Study reproducing fig1_grid matches run_grid on the
    vmap path, tracing once per component structure (12 for the 96-cell
    4 scheduler x 3 arrivals x 8 seeds grid)."""
    steps, seeds = 60, 8
    study = get_study("fig1_grid", n_clients=6, num_steps=steps, seeds=seeds)
    before = engine._run_group._cache_size()
    res = study.run(**run_kwargs)
    assert engine._run_group._cache_size() - before == 12  # not 96
    assert len(res) == 12

    kw = {k: v for k, v in run_kwargs.items() if k != "params0"}
    legacy = run_grid(get_grid("fig1_grid", n_clients=6, horizon=steps + 1),
                      params0=run_kwargs["params0"], num_steps=steps,
                      seeds=seeds, **kw)
    assert set(res) == set(legacy)
    for name in legacy:
        np.testing.assert_array_equal(np.asarray(res[name].history.loss),
                                      np.asarray(legacy[name].history.loss))
        np.testing.assert_array_equal(np.asarray(res[name].params),
                                      np.asarray(legacy[name].params))


def test_study_run_memoizes_simulator(problem, run_kwargs):
    """Repeated study.run with the same ingredients must hit the jit
    cache — including bound-method loss_fns that are a fresh object per
    attribute access."""
    study = get_study("fig1", n_clients=6, num_steps=20, seeds=2)
    study.run(grads_fn=run_kwargs["grads_fn"], p=problem.p,
              optimizer=run_kwargs["optimizer"],
              loss_fn=problem.suboptimality,
              params0=run_kwargs["params0"])
    before = engine._run_group._cache_size()
    study.run(grads_fn=run_kwargs["grads_fn"], p=problem.p,
              optimizer=run_kwargs["optimizer"],
              loss_fn=problem.suboptimality,  # fresh bound method
              params0=run_kwargs["params0"])
    assert engine._run_group._cache_size() == before


def test_study_sequential_config_matches_batched(problem, run_kwargs):
    study = get_study("fig1", n_clients=6, num_steps=40, seeds=2)
    batched = study.run(**run_kwargs)
    seq = study.run(**run_kwargs,
                    config=ExecutionConfig(sequential=True))
    for name in batched:
        np.testing.assert_allclose(np.asarray(batched[name].history.loss),
                                   np.asarray(seq[name].history.loss),
                                   rtol=2e-4, atol=1e-5)


# ------------------------------------------- new axes end-to-end (vmap path)

def test_capacity_axis_end_to_end_vmap(problem, run_kwargs):
    """A capacity sweep is ONE structure group (capacity is a leaf):
    3 cells, 1 trace."""
    study = get_study("capacity_sweep", n_clients=6, num_steps=50, seeds=3)
    before = engine._run_group._cache_size()
    res = study.run(**run_kwargs)
    assert engine._run_group._cache_size() - before == 1
    assert res.axes["capacity"] == (1.0, 2.0, 4.0)
    for cell in res.values():
        assert cell.history.loss.shape == (3, 50)
        assert np.isfinite(np.asarray(cell.history.loss)).all()


def test_day_night_axis_end_to_end_vmap(problem, run_kwargs):
    study = get_study("day_night", n_clients=6, num_steps=50, seeds=3)
    res = study.run(**run_kwargs)
    assert set(res) == {"alg2_day_night", "benchmark1_day_night",
                        "battery_adaptive_day_night", "oracle_day_night"}
    for cell in res.values():
        assert np.isfinite(np.asarray(cell.history.loss)).all()
    # the energy-aware scaled scheduler keeps Σω ≈ 1 in expectation even
    # under the non-stationary rate; the unscaled benchmark does not
    wsum = np.asarray(res["alg2_day_night"].history.weight_sum).mean()
    assert 0.6 < wsum < 1.4


@multidevice
def test_capacity_and_day_night_sharded(problem, run_kwargs):
    """Acceptance: both new axes run through Study.run under the
    8-device sharded path and match the vmap path."""
    mesh = make_cell_mesh()
    for name in ("capacity_sweep", "day_night"):
        study = get_study(name, n_clients=6, num_steps=40, seeds=3)
        plain = study.run(**run_kwargs)
        sharded = study.run(**run_kwargs, config=ExecutionConfig(mesh=mesh))
        for cell in plain:
            np.testing.assert_allclose(
                np.asarray(plain[cell].history.loss),
                np.asarray(sharded[cell].history.loss),
                rtol=2e-4, atol=1e-5)
            np.testing.assert_array_equal(
                np.asarray(plain[cell].history.participation),
                np.asarray(sharded[cell].history.participation))


def test_population_scaling_groups_by_n():
    """Ragged client counts cannot share a trace: the n_clients axis
    resolves to one structure group per population size."""
    study = get_study("population_scaling", n_clients=(4, 6), num_steps=20,
                      seeds=2)
    scens = study.resolve()
    assert [s.name for s in scens] == ["alg2_binary_n4", "alg2_binary_n6"]
    groups = {}
    for s in scens:
        sch, en = s.build()
        leaves, treedef = jax.tree_util.tree_flatten((sch, en))
        key = (treedef, tuple(l.shape for l in leaves))
        groups.setdefault(key, []).append(s.name)
    assert len(groups) == 2


# --------------------------------------------------- GridResult + reductions

def _toy_result():
    def cell(losses):
        from repro.core.trainer import SimHistory
        from repro.experiments import CellResult

        loss = jnp.asarray(losses)[:, None] * jnp.ones((1, 20))
        hist = SimHistory(loss=loss,
                          participation=jnp.ones((len(losses), 20, 2)),
                          weight_sum=jnp.ones((len(losses), 20)))
        return CellResult(params=jnp.zeros((len(losses), 3)), history=hist)

    cells = {
        "alg1_periodic": cell([1.0, 2.0]),
        "alg1_binary": cell([3.0, float("nan")]),
        "oracle_periodic": cell([5.0, 6.0]),
        "oracle_binary": cell([7.0, 8.0]),
    }
    labels = {
        "alg1_periodic": {"scheduler": "alg1", "arrivals": "periodic"},
        "alg1_binary": {"scheduler": "alg1", "arrivals": "binary"},
        "oracle_periodic": {"scheduler": "oracle", "arrivals": "periodic"},
        "oracle_binary": {"scheduler": "oracle", "arrivals": "binary"},
    }
    axes = {"scheduler": ("alg1", "oracle"),
            "arrivals": ("periodic", "binary"), "seed": (0, 1)}
    return GridResult(cells, labels, axes, name="toy")


def test_gridresult_sel_and_mapping():
    res = _toy_result()
    assert len(res) == 4 and "alg1_binary" in res
    sub = res.sel(scheduler="alg1")
    assert list(sub) == ["alg1_periodic", "alg1_binary"]
    assert "scheduler" not in sub.axes  # scalar selection drops the axis
    assert sub.axes["arrivals"] == ("periodic", "binary")
    only = res.sel(scheduler="oracle", arrivals="binary").only()
    assert only is res["oracle_binary"]
    with pytest.raises(ValueError, match="selectable"):
        res.sel(battery="x")
    with pytest.raises(KeyError):
        res.sel(scheduler="nonexistent")


def test_gridresult_sel_absent_value_names_axis_and_valid_values():
    """Regression: selecting an axis value absent from the grid must
    raise a KeyError naming the axis and its valid values — not an
    opaque empty result."""
    res = _toy_result()
    with pytest.raises(KeyError, match=r"axis 'scheduler' has no value "
                                       r"'alg9'.*'alg1'.*'oracle'"):
        res.sel(scheduler="alg9")
    # list selectors validate every member
    with pytest.raises(KeyError, match=r"axis 'arrivals' has no value "
                                       r"'uniform'.*'periodic'.*'binary'"):
        res.sel(arrivals=["periodic", "uniform"])


def _single_cell_result(losses=(1.0, 2.0)):
    cells, labels, axes = {}, {}, {"scheduler": ("alg1",),
                                   "arrivals": ("periodic",),
                                   "seed": tuple(range(len(losses)))}
    toy = _toy_result()
    cells["alg1_periodic"] = toy["alg1_periodic"]
    if losses != (1.0, 2.0):
        from repro.core.trainer import SimHistory
        from repro.experiments import CellResult

        loss = jnp.asarray(losses)[:, None] * jnp.ones((1, 20))
        cells["alg1_periodic"] = CellResult(
            params=jnp.zeros((len(losses), 3)),
            history=SimHistory(loss=loss,
                               participation=jnp.ones((len(losses), 20, 2)),
                               weight_sum=jnp.ones((len(losses), 20))))
    labels["alg1_periodic"] = {"scheduler": "alg1", "arrivals": "periodic"}
    return GridResult(cells, labels, axes, name="single")


def test_gridresult_sel_and_reduce_on_single_cell():
    """Regression: a fully-degenerate (1-cell) grid still selects and
    reduces instead of returning an empty mapping."""
    res = _single_cell_result()
    sub = res.sel(scheduler="alg1", arrivals="periodic")
    assert len(sub) == 1
    assert sub.only() is res["alg1_periodic"]
    stats = res.reduce()
    assert stats["alg1_periodic"]["mean"] == pytest.approx(1.5)
    pooled = res.reduce(over="arrivals")
    assert pooled["all"]["n_seeds"] == 2
    with pytest.raises(KeyError, match="axis 'scheduler' has no value"):
        res.sel(scheduler="oracle")


def test_gridresult_sel_and_reduce_on_all_nan_seeds():
    """Regression: a cell whose every seed diverged reduces to NaN
    mean/std with n_nan == n_seeds — and never raises."""
    res = _single_cell_result(losses=(float("nan"), float("nan")))
    stats = res.reduce()["alg1_periodic"]
    assert stats["n_nan"] == 2 and stats["n_seeds"] == 2
    assert np.isnan(stats["mean"]) and np.isnan(stats["std"])
    sub = res.sel(scheduler="alg1")
    assert sub.reduce(over="arrivals")["all"]["n_nan"] == 2
    recs = res.to_records()
    assert recs[0]["n_nan"] == 2


def test_gridresult_sel_with_unhashable_axis_values(problem, run_kwargs):
    """Regression: axis values may be unhashable — a (kind, kwargs)
    arrival pair or an explicit taus list; sel must compare by equality,
    never hash."""
    study = get_study("day_night", n_clients=6, num_steps=10, seeds=2)
    res = study.run(**run_kwargs)
    sub = res.sel(scheduler="alg2")
    assert list(sub) == ["alg2_day_night"]
    # selecting the tuple-valued arrivals axis by its verbatim value
    arrivals_val = res.labels("alg2_day_night")["arrivals"]
    assert isinstance(arrivals_val, tuple)
    assert len(res.sel(arrivals=arrivals_val)) == len(res)

    study2 = Study("taus", num_steps=10, axes={
        "scheduler": ["alg1", "oracle"], "arrivals": "periodic",
        "n_clients": 6, "taus_profile": [1, 2, 4], "seeds": 2})
    res2 = study2.run(**run_kwargs)
    assert list(res2.sel(scheduler="oracle")) == ["oracle_periodic"]


def test_study_run_rejects_mesh_plus_sequential(problem, run_kwargs):
    """A contradictory config must error, not silently run single-device
    sequential while the caller believes it benchmarked the mesh."""
    study = get_study("fig1", n_clients=6, num_steps=10, seeds=2)
    cfg = ExecutionConfig(mesh=make_cell_mesh(), sequential=True)
    with pytest.raises(ValueError, match="sequential"):
        study.run(**run_kwargs, config=cfg)


def test_gridresult_reduce_is_nan_aware():
    res = _toy_result()
    stats = res.reduce()  # default: tail mean of loss per seed
    assert stats["alg1_periodic"]["mean"] == pytest.approx(1.5)
    assert stats["alg1_periodic"]["n_nan"] == 0
    # one diverged seed: excluded from stats, counted — not poisoning
    assert stats["alg1_binary"]["mean"] == pytest.approx(3.0)
    assert stats["alg1_binary"]["std"] == pytest.approx(0.0)
    assert stats["alg1_binary"]["n_seeds"] == 2
    assert stats["alg1_binary"]["n_nan"] == 1


def test_gridresult_reduce_over_axis_pools():
    res = _toy_result()
    pooled = res.reduce(over="arrivals")
    # alg1 pools 4 seed-values incl. one NaN
    assert pooled["alg1"]["n_seeds"] == 4
    assert pooled["alg1"]["n_nan"] == 1
    assert pooled["alg1"]["mean"] == pytest.approx((1 + 2 + 3) / 3)
    assert pooled["oracle"]["mean"] == pytest.approx(6.5)
    with pytest.raises(ValueError, match="unknown axis"):
        res.reduce(over="capacity")


def test_grid_summary_shares_nan_aware_reduction():
    """Satellite: the legacy grid_summary path uses the same NaN-aware
    seed_stats as GridResult.reduce."""
    res = _toy_result()
    legacy = grid_summary(dict(res.items()))
    modern = res.reduce()
    assert legacy == modern
    assert legacy["alg1_binary"]["n_nan"] == 1
    assert np.isfinite(legacy["alg1_binary"]["mean"])


def test_seed_stats_all_nan():
    s = seed_stats([float("nan"), float("nan")])
    assert s["n_nan"] == 2 and s["n_seeds"] == 2
    assert np.isnan(s["mean"]) and np.isnan(s["std"])


def test_gridresult_to_records_and_json(tmp_path):
    res = _toy_result()
    recs = res.to_records()
    assert recs[0]["name"] == "alg1_periodic"
    assert recs[0]["scheduler"] == "alg1"
    assert recs[0]["arrivals"] == "periodic"
    assert {"mean", "std", "n_seeds", "n_nan"} <= set(recs[0])

    path = tmp_path / "grid.json"
    text = res.to_json(str(path))
    doc = json.loads(text)
    assert doc == json.loads(path.read_text())
    assert doc["study"] == "toy"
    assert doc["axes"]["scheduler"] == ["alg1", "oracle"]
    assert len(doc["records"]) == 4


def test_gridresult_to_json_handles_numpy_in_nested_labels():
    """Regression: (kind, kwargs) axis values may carry numpy scalars;
    to_json must recurse into dicts/arrays when sanitizing."""
    res = _toy_result()
    res.axes = {**res.axes,
                "arrivals": (("day_night", {"period": np.int64(50)}),
                             "binary")}
    doc = json.loads(res.to_json())
    assert doc["axes"]["arrivals"][0] == ["day_night", {"period": 50}]


# ------------------------------------------------------------ cache teardown

@multidevice
def test_clear_cache_drops_both_paths(problem, run_kwargs):
    """Satellite: clear_cache must drop BOTH the vmap and the shard_map
    executables (and the dataset-pinning closures they reference)."""
    study = get_study("fig1", n_clients=6, num_steps=10, seeds=2)
    study.run(**run_kwargs)
    study.run(**run_kwargs, config=ExecutionConfig(mesh=make_cell_mesh()))
    assert engine._run_group._cache_size() > 0
    assert placement._run_group_sharded._cache_size() > 0
    clear_cache()
    assert engine._run_group._cache_size() == 0
    assert placement._run_group_sharded._cache_size() == 0


# ------------------------------------------------- bounded simulator cache

def test_simulator_cache_is_bounded_lru(problem, run_kwargs):
    """The per-Study simulator memoization must not grow without bound:
    cycling through more than SIM_CACHE_SIZE distinct weight vectors
    evicts the coldest entry (a long-running service would otherwise pin
    every simulator-plus-dataset ever built)."""
    from repro.experiments.study import SIM_CACHE_SIZE

    study = Study("bounded", num_steps=10)
    kw = dict(grads_fn=run_kwargs["grads_fn"],
              optimizer=run_kwargs["optimizer"])
    for i in range(SIM_CACHE_SIZE + 2):
        study.simulator(p=np.full(6, 1.0 + i), **kw)
    stats = study.cache_stats()
    assert stats["size"] == stats["maxsize"] == SIM_CACHE_SIZE
    assert stats["evictions"] == 2
    assert stats["misses"] == SIM_CACHE_SIZE + 2

    # the hottest entry survives; the oldest was evicted and rebuilds
    study.simulator(p=np.full(6, float(SIM_CACHE_SIZE + 1)), **kw)
    assert study.cache_stats()["hits"] == 1
    study.simulator(p=np.full(6, 1.0), **kw)
    assert study.cache_stats()["evictions"] == 3  # refilling evicts again


def test_repeated_run_still_hits_jit_cache_under_lru(problem, run_kwargs):
    """Regression guard for the LRU swap: the memoization must keep the
    PR 2 guarantee that repeated Study.run re-traces nothing."""
    study = get_study("fig1", n_clients=6, num_steps=15, seeds=2)
    study.run(**run_kwargs)
    before = engine._run_group._cache_size()
    study.run(**run_kwargs)
    assert engine._run_group._cache_size() == before
    stats = study.cache_stats()
    assert stats["hits"] >= 1 and stats["size"] == 1


def test_study_clear_cache_reports_and_drops(problem, run_kwargs):
    study = get_study("fig1", n_clients=6, num_steps=10, seeds=2)
    study.run(**run_kwargs)
    assert engine._run_group._cache_size() > 0
    final = study.clear_cache()
    assert final["size"] == 1  # snapshot of what the cache held
    assert study.cache_stats()["size"] == 0
    assert engine._run_group._cache_size() == 0  # engine caches dropped too

    study.run(**run_kwargs)  # still works after teardown
    # counters survive clear() (lifetime telemetry); occupancy restarts
    assert study.cache_stats()["misses"] == 2
    assert study.cache_stats()["size"] == 1


def test_study_clear_cache_can_spare_engine_caches(problem, run_kwargs):
    study = get_study("fig1", n_clients=6, num_steps=10, seeds=2)
    study.run(**run_kwargs)
    compiled = engine._run_group._cache_size()
    assert compiled > 0
    study.clear_cache(engine_caches=False)
    assert engine._run_group._cache_size() == compiled
