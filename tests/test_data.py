"""Data pipeline tests: synthetic generators, partitioners, batchers."""

import jax
import numpy as np

from repro.data import (
    ClientBatcher,
    GlobalBatcher,
    dirichlet_partition,
    group_label_skew_partition,
    iid_partition,
    make_image_classification,
    make_lm_tokens,
)


def test_image_dataset_learnable_structure():
    ds = make_image_classification(0, 600, n_classes=4, noise=0.2)
    assert ds.images.shape == (600, 32, 32, 3)
    # same-class pairs are closer than cross-class pairs (prototype task)
    by_class = [ds.images[ds.labels == k] for k in range(4)]
    intra = np.mean([np.linalg.norm(c[0] - c[1]) for c in by_class])
    inter = np.linalg.norm(by_class[0][0] - by_class[1][0])
    assert intra < inter


def test_lm_tokens_markov_structure():
    lm = make_lm_tokens(0, 64, 128, vocab=101)
    assert lm.tokens.shape == (64, 129)
    assert lm.tokens.min() >= 0 and lm.tokens.max() < 101
    # bigram shift appears: P(next == (31*prev+7)%V) well above 1/V
    prev = lm.tokens[:, :-1].ravel()
    nxt = lm.tokens[:, 1:].ravel()
    hit = np.mean(nxt == (prev * 31 + 7) % 101)
    assert hit > 0.2


def test_iid_partition_covers_everything():
    parts = iid_partition(0, 103, 7)
    allidx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(allidx, np.arange(103))


def test_dirichlet_partition_skews_labels():
    labels = np.tile(np.arange(10), 100)
    parts = dirichlet_partition(0, labels, 5, alpha=0.1)
    fracs = []
    for ix in parts:
        if len(ix) == 0:
            continue
        counts = np.bincount(labels[ix], minlength=10) / len(ix)
        fracs.append(counts.max())
    assert np.mean(fracs) > 0.3  # strongly skewed vs uniform 0.1


def test_group_label_skew_alignment():
    labels = np.tile(np.arange(8), 200)
    parts = group_label_skew_partition(0, labels, n_clients=8, n_groups=4,
                                       skew=0.9)
    for i, ix in enumerate(parts):
        g = i % 4
        frac_fav = np.mean(labels[ix] % 4 == g)
        assert frac_fav > 0.8, (i, frac_fav)


def test_client_batcher_p_and_shapes():
    data = [{"x": np.ones((n, 3)) * i} for i, n in enumerate([10, 30])]
    cb = ClientBatcher(data, batch_size=4)
    np.testing.assert_allclose(cb.p, [0.25, 0.75])
    b = cb.sample(jax.random.PRNGKey(0))
    assert b["x"].shape == (2, 4, 3)
    np.testing.assert_allclose(np.asarray(b["x"][0]), 0.0)
    np.testing.assert_allclose(np.asarray(b["x"][1]), 1.0)


def test_global_batcher_client_slots():
    data = {"t": np.arange(40).reshape(40, 1)}
    parts = [np.arange(0, 10), np.arange(10, 20),
             np.arange(20, 30), np.arange(30, 40)]
    gb = GlobalBatcher(data, n_clients=4, global_batch=8, client_index=parts)
    batch = gb.sample(jax.random.PRNGKey(0))
    ids = np.asarray(batch["client_ids"])
    np.testing.assert_array_equal(ids, [0, 0, 1, 1, 2, 2, 3, 3])
    vals = np.asarray(batch["t"])[:, 0]
    for j, c in enumerate(ids):
        assert c * 10 <= vals[j] < (c + 1) * 10
