"""Pallas TPU kernel: chunked gated-linear-recurrence (SSD) scan.

The Mamba2 / mLSTM recurrence  H_t = a_t H_{t-1} + k_t v_tᵀ,
y_t = q_tᵀ H_t  evaluated chunkwise: the (dk, dv) state lives in VMEM
scratch across the sequential chunk-grid dimension; each grid step does
two MXU matmuls (intra-chunk quadratic + inter-chunk state read) and one
rank-c state update. This is the TPU adaptation of Mamba2's SSD CUDA
kernel: chunk matmuls on the MXU replace the GPU's warp-level scan
(DESIGN.md §Hardware adaptation).

Grid: (B·H, S/chunk) — chunk axis sequential ("arbitrary").
VMEM per step: chunk·(2dk+dv) inputs + dk·dv state + chunk² scores.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

_LOG_EPS = 1e-12


def _gla_kernel(a_ref, k_ref, v_ref, q_ref, y_ref, h_ref, *, chunk):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)                      # (c,)
    k = k_ref[0].astype(jnp.float32)                      # (c, dk)
    v = v_ref[0].astype(jnp.float32)                      # (c, dv)
    q = q_ref[0].astype(jnp.float32)                      # (c, dk)
    la = jnp.cumsum(jnp.log(jnp.maximum(a, _LOG_EPS)))    # (c,)

    # inter-chunk: decay(start→t) · qᵀ H_prev
    qd = q * jnp.exp(la)[:, None]
    y = jax.lax.dot(qd, h_ref[...], preferred_element_type=jnp.float32)

    # intra-chunk (causal, decay-weighted)
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (c, c)
    ratio = jnp.exp(la[:, None] - la[None, :])
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    scores = jnp.where(tri, scores * ratio, 0.0)
    y = y + jax.lax.dot(scores, v, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state carry: H ← decay(chunk)·H + Σ_s decay(s→end) k_s v_sᵀ
    dec_end = jnp.exp(la[-1] - la)                         # (c,)
    kw = k * dec_end[:, None]
    h_ref[...] = (jnp.exp(la[-1]) * h_ref[...]
                  + jax.lax.dot_general(kw, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def gla_scan_kernel(a, k, v, q, *, chunk=64, interpret=False):
    """a: (BH, S); k,q: (BH, S, dk); v: (BH, S, dv) -> y (BH, S, dv).

    S must be a multiple of ``chunk`` (ops.py pads).
    """
    bh, s = a.shape
    dk, dv = k.shape[-1], v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)
    kernel = functools.partial(_gla_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, k, v, q)
