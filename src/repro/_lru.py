"""Bounded LRU mapping with hit/miss/eviction accounting.

One policy, two users (DESIGN.md §11): the serve layer's keyed
executable cache (:class:`repro.serve.ExecutableCache`) and
:meth:`repro.experiments.Study.simulator`'s memoization — both were
unbounded dicts before PR 8, which a long-running service turns into a
leak (every entry pins a jitted executable and the closures/datasets it
captured). Lives outside both packages so the experiments layer never
imports the serve layer.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable


class LRUCache:
    """Least-recently-used bounded mapping.

    ``get`` refreshes recency and counts a hit or miss; ``put`` inserts
    (refreshing recency on overwrite) and evicts the coldest entry past
    ``maxsize``, invoking ``on_evict(key, value)`` so owners can release
    per-entry resources. Counters survive :meth:`clear` — they describe
    the cache's lifetime, not its current contents.
    """

    def __init__(self, maxsize: int = 32,
                 on_evict: Callable[[Any, Any], None] | None = None):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = int(maxsize)
        self._data: OrderedDict = OrderedDict()
        self._on_evict = on_evict
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, default=None):
        try:
            value = self._data[key]
        except KeyError:
            self.misses += 1
            return default
        self._data.move_to_end(key)
        self.hits += 1
        return value

    def put(self, key, value) -> None:
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        while len(self._data) > self.maxsize:
            old_key, old_value = self._data.popitem(last=False)
            self.evictions += 1
            if self._on_evict is not None:
                self._on_evict(old_key, old_value)

    def __contains__(self, key) -> bool:  # no recency/counter side effects
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    def values(self):
        return list(self._data.values())

    def clear(self) -> None:
        self._data.clear()

    def stats(self) -> dict:
        """Lifetime counters + current occupancy, one flat dict."""
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._data),
                "maxsize": self.maxsize}
