"""Model zoo: composable blocks + stacks covering all assigned archs."""

from repro.models.cnn import cnn_accuracy, cnn_forward, cnn_loss, init_cnn
from repro.models.common import count_params
from repro.models.transformer import (
    decode_cache_len,
    decode_step,
    encode,
    forward,
    init_decode_state,
    init_lm,
    per_example_loss,
)

__all__ = [
    "init_cnn", "cnn_forward", "cnn_loss", "cnn_accuracy",
    "count_params",
    "init_lm", "forward", "per_example_loss",
    "init_decode_state", "decode_step", "decode_cache_len", "encode",
]
