"""Quickstart: energy-harvesting distributed SGD in ~60 lines.

Builds the paper's setting on a closed-form quadratic: 8 clients with
heterogeneous periodic energy (τ cycling through 1/5/10/20), and compares
Algorithm 1 against the paper's two benchmarks and the full-participation
oracle — the whole scheduler grid, over several seeds, as a handful of
compiled computations via the scenario engine. Run:

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_quadratic
from repro.experiments import get_grid, grid_summary, run_grid
from repro.optim import sgd

N_CLIENTS, STEPS, ETA, SEEDS = 8, 1000, 0.01, 8  # t=1000 as in paper Fig. 1
TAUS = [(1, 5, 10, 20)[i % 4] for i in range(N_CLIENTS)]


def main():
    problem = make_quadratic(jax.random.PRNGKey(0), N_CLIENTS, dim=10,
                             hetero=1.0)
    # The paper's 4 methods on periodic (eq. 37) arrivals, from the registry.
    scenarios = get_grid("fig1", n_clients=N_CLIENTS, horizon=STEPS + 1,
                         taus=TAUS)

    def grads_fn(params, key, t):
        return problem.all_grads(params, key=key, noise=0.05)

    print(f"{N_CLIENTS} clients, energy periods {TAUS}, {SEEDS} seeds")
    results = run_grid(
        scenarios, grads_fn=grads_fn, p=problem.p, optimizer=sgd(ETA),
        params0=jnp.full((10,), 5.0), num_steps=STEPS, seeds=SEEDS,
        loss_fn=problem.suboptimality)

    summary = grid_summary(
        results, reducer=lambda c: c.history.loss[:, -100:].mean(axis=-1))
    print(f"{'scenario':<22} {'final subopt':>22} {'mean weight Σω':>16}")
    finals = {}
    for name, cell in results.items():
        s = summary[name]
        finals[name] = s["mean"]
        print(f"{name:<22} {s['mean']:>13.5f} ± {s['std']:<7.5f}"
              f"{float(np.asarray(cell.history.weight_sum).mean()):>16.3f}")

    assert finals["alg1_periodic"] < finals["benchmark1_periodic"], \
        "Alg1 must beat B1"
    assert finals["alg1_periodic"] < finals["benchmark2_periodic"], \
        "Alg1 must beat B2"
    print("\nAlgorithm 1 (unbiased energy-aware) beats both benchmarks ✓")


if __name__ == "__main__":
    main()
