"""Benchmark: paper Figure 1 as a full scenario grid.

Runs the ``fig1_grid`` study (4 paper schedulers × 3 arrival families ×
``seeds`` seeds) on a reduced-scale CNN image task through
:meth:`repro.experiments.Study.run` (one compiled computation per
scheduler × arrival structure), then runs the *identical* cells through
the sequential per-cell baseline (``ExecutionConfig(sequential=True)``,
one traced scan per cell — the pre-scenario-engine execution model) and
reports both wall-clocks. With ≥ 2 devices (``benchmarks/run.py`` forces
8 CPU host devices) the same study also runs device-sharded
(``ExecutionConfig(mesh=...)``, DESIGN.md §5); cold (compile-inclusive)
and warm (steady-state, jit-cache-hit) wall-clocks are reported for the
batched-vs-sharded comparison, since large-grid sweeps amortize
compilation.

Emits ``name,us_per_call,derived`` CSV rows: per-cell mean±std final
test accuracy across seeds (NaN-aware — a diverged seed surfaces as
``n_nan``), the grid wall-clocks, batched and sharded speedups, and the
paper's full Fig-1 ordering check alg1 ≥ benchmark1 ≥ benchmark2 on
periodic arrivals. ``examples/paper_cifar.py --full`` remains the
paper-exact variant.
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp


def _setup(n_clients: int, hw: int, batch: int, seed: int = 0):
    from repro.data import (
        ClientBatcher,
        group_label_skew_partition,
        make_confusable_image_classification,
    )
    from repro.models.cnn import cnn_accuracy, init_cnn

    n_train, n_test = 96 * n_clients, 512
    ds = make_confusable_image_classification(
        seed, n_train + n_test, image_shape=(hw, hw, 3),
        similarity=0.9, noise=0.8)
    train_x, train_y = ds.images[:n_train], ds.labels[:n_train]
    test_x = jnp.asarray(ds.images[n_train:])
    test_y = jnp.asarray(ds.labels[n_train:])
    parts = group_label_skew_partition(seed, train_y, n_clients, 4, skew=1.0)
    per_client = [{"x": train_x[ix], "y": train_y[ix]} for ix in parts]
    batcher = ClientBatcher(per_client, batch_size=batch, seed=seed)
    params0 = init_cnn(jax.random.PRNGKey(seed), image_hw=hw)

    from examples.paper_cifar import per_client_grads_fn
    grads_fn = per_client_grads_fn(batcher, hw)
    eval_fn = lambda p: cnn_accuracy(p, test_x, test_y)
    return grads_fn, eval_fn, batcher.p, params0


def _quadratic_grid_rows(iters: int, seeds: int) -> list[str]:
    """Sharded-vs-batched warm wall-clocks on the paper's quadratic cells.

    Same 4-scheduler × 3-arrival × ``seeds`` grid shape as the CNN run,
    but each cell is the Theorem-1 quadratic problem: per-step compute is
    tiny, so single-device execution is dispatch-bound and the flattened
    cell axis parallelizes across devices.
    """
    from repro.core import ClientSimulator, make_quadratic
    from repro.experiments import ExecutionConfig, get_study, make_cell_mesh
    from repro.optim import sgd

    n_clients, dim = 8, 64
    problem = make_quadratic(jax.random.PRNGKey(2), n_clients=n_clients,
                             dim=dim, hetero=1.0)
    sim = ClientSimulator(
        grads_fn=lambda p, k, t: problem.all_grads(p, key=k, noise=0.05),
        p=problem.p, optimizer=sgd(0.02), loss_fn=problem.suboptimality)
    study = get_study("fig1_grid", n_clients=n_clients, num_steps=iters,
                      seeds=seeds)
    params0 = jnp.full((dim,), 4.0)
    mesh = make_cell_mesh()
    n_cells = len(study.resolve()) * seeds

    def timed(config=None):
        t0 = time.time()
        res = study.run(sim=sim, params0=params0, config=config)
        jax.block_until_ready([c.params for c in res.values()])
        return time.time() - t0

    sharded = ExecutionConfig(mesh=mesh)
    timed()                      # compile batched
    timed(sharded)               # compile sharded
    dt_b = timed()
    dt_s = timed(sharded)
    speed = dt_b / dt_s
    n_dev = jax.device_count()
    print(f"quadratic grid ({n_cells} cells x {iters} steps, warm): "
          f"batched {dt_b:.2f}s vs sharded {dt_s:.2f}s over {n_dev} devices "
          f"-> {speed:.2f}x", file=sys.stderr)
    return [
        f"quadgrid_batched_warm,{dt_b * 1e6:.0f},cells={n_cells};iters={iters}",
        f"quadgrid_sharded_warm,{dt_s * 1e6:.0f},"
        f"cells={n_cells};iters={iters};devices={n_dev}",
        f"quadgrid_sharded_speedup,{dt_s * 1e6:.0f},"
        f"speedup={speed:.2f};devices={n_dev};sharded_faster={dt_s < dt_b};"
        f"timing_ref=quadgrid_sharded_warm",
    ]


def _population_scaling_rows(iters: int, seeds: int) -> list[str]:
    """Ragged-population series (DESIGN.md §7): the ``population_scaling``
    study runs N ∈ {8, 16, 32} as ONE compiled computation (population
    size is a data axis — cells padded to N_cap=32 under an active
    mask), timed against the sequential per-cell baseline. The trace
    count is recorded so the series also tracks the
    one-compile-per-structure guarantee."""
    from repro.core import ClientSimulator, make_quadratic
    from repro.experiments import ExecutionConfig, get_study
    from repro.experiments import engine
    from repro.optim import sgd

    n_cap, dim, pops = 32, 64, (8, 16, 32)
    problem = make_quadratic(jax.random.PRNGKey(5), n_clients=n_cap,
                             dim=dim, hetero=1.0)
    w_star = problem.w_star
    sim = ClientSimulator(
        grads_fn=lambda p, k, t: problem.all_grads(p),
        p=problem.p, optimizer=sgd(0.02),
        loss_fn=lambda w: jnp.sum((w - w_star) ** 2))
    study = get_study("population_scaling", n_clients=pops, num_steps=iters,
                      seeds=seeds)
    params0 = jnp.full((dim,), 4.0)

    def timed(config=None):
        t0 = time.time()
        res = study.run(sim=sim, params0=params0, config=config)
        jax.block_until_ready([c.params for c in res.values()])
        return time.time() - t0

    before = engine._run_group._cache_size()
    timed()                                   # compile batched
    traces = engine._run_group._cache_size() - before
    seq = ExecutionConfig(sequential=True)
    timed(seq)                                # compile sequential
    dt_b, dt_s = timed(), timed(seq)
    speed = dt_s / dt_b
    n_cells = len(pops) * seeds
    print(f"population_scaling N={pops} ({n_cells} cells x {iters} steps, "
          f"warm): batched {dt_b:.2f}s ({traces} trace) vs sequential "
          f"{dt_s:.2f}s -> {speed:.2f}x", file=sys.stderr)
    return [
        f"popscale_batched_warm,{dt_b * 1e6:.0f},"
        f"cells={n_cells};iters={iters};traces={traces}",
        f"popscale_sequential_warm,{dt_s * 1e6:.0f},"
        f"cells={n_cells};iters={iters}",
        f"popscale_batched_speedup,{dt_b * 1e6:.0f},"
        f"speedup={speed:.2f};traces={traces};batched_faster={dt_b < dt_s};"
        f"timing_ref=popscale_batched_warm",
    ]


def _collective_scan_cost(mesh, dim: int, iters: int, timed) -> float:
    """Measured per-round collective cost of the client topology: a scan
    of ``iters`` steps whose whole body is one ``(P,)``-sized psum —
    exactly the cross-shard traffic the fused reduction leaves per step.
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    axis = mesh.axis_names[0]
    spec = PartitionSpec()

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec,), out_specs=spec,
             check_rep=False)
    def collective_scan(w):
        scale = 1.0 / mesh.devices.size

        def body(c, _):
            return jax.lax.psum(c * scale, axis), None

        return jax.lax.scan(body, w, None, length=iters)[0]

    return timed(lambda: collective_scan(jnp.ones((dim,))))


def _large_n_rows(iters: int = 20, dim: int = 16,
                  pops=(1024, 4096, 10240)) -> list[str]:
    """Within-cell client-sharding series (DESIGN.md §8–9): one quadratic
    cell per N ∈ {1024, 4096, 10240}, run unsharded (single-device vmap
    over clients) and client-sharded across all host devices through a
    client-aware grads_fn (each shard computes only its own gradient
    rows), once per reduction mode: ``gather`` (bitwise oracle — the
    whole (N, P) buffer crosses the interconnect), ``psum`` (local
    partial + (P,) collective) and ``fused`` (the psum wiring with the
    SGD update folded into the local reduce). All wall-clocks warm,
    min-of-3.

    Two tiers per N, because the host CPU time-slices the D virtual
    devices on its cores: the serialized multi-device wall-clocks
    (``largeN_sharded/psum/fused``) measure the *aggregate* work of all
    D device programs — on a host with fewer than D cores that is ~D×
    the per-round latency a real D-device deployment would see, so it
    can only show sharding's overhead, never its parallelism. The
    headline ``largeN_speedup_N*`` therefore reports the measured
    **round critical path** of the fused mode: one shard's program
    (``largeN_pershard_N*`` — the same scheduler/arrival/reduce-update
    step over the N/D-client shard, run to completion on one device)
    plus the measured per-round collective cost of the topology
    (``largeN_collective``). Both components are direct wall-clock
    measurements on this host; the serialized whole-topology ratios are
    kept alongside in the same row (``wall_speedup_*``) so neither
    number is ever presented as the other. ``largeN_crossover`` records
    the smallest N where the critical-path speedup reaches 1.0."""
    from repro.core import ClientSimulator, make_quadratic
    from repro.core.energy import make_arrivals
    from repro.core.scheduling import make_scheduler
    from repro.experiments.placement import make_client_mesh, run_client_sharded
    from repro.optim import sgd

    REDUCTIONS = ("gather", "psum", "fused")
    n_dev = jax.device_count()
    if n_dev < 2:
        print("largeN client-sharding: skipped (single device)",
              file=sys.stderr)
        return []
    mesh = make_client_mesh()
    params0 = jnp.full((dim,), 2.0)
    key = jax.random.PRNGKey(0)

    def timed(fn, reps: int = 3):
        jax.block_until_ready(fn())        # warm the jit cache
        best = float("inf")
        for _ in range(reps):
            t0 = time.time()
            jax.block_until_ready(fn())
            best = min(best, time.time() - t0)
        return best

    dt_coll = _collective_scan_cost(mesh, dim, iters, timed)
    rows = [f"largeN_collective,{dt_coll * 1e6:.0f},"
            f"iters={iters};dim={dim};devices={n_dev}"]
    crossover = None

    def make_sim(a, b, p, w_star):
        def grads_fn(w, k, t, clients=None):
            if clients is None:
                return jnp.einsum("nij,j->ni", a, w) - b
            return jnp.einsum("nij,j->ni", a[clients], w) - b[clients]

        return ClientSimulator(
            grads_fn=grads_fn, p=p, optimizer=sgd(0.01),
            loss_fn=lambda w, _ws=w_star: jnp.sum((w - _ws) ** 2))

    for n in pops:
        if n % n_dev:
            print(f"largeN: skipped N={n} (not divisible by {n_dev} devices)",
                  file=sys.stderr)
            continue
        prob = make_quadratic(jax.random.PRNGKey(11), n_clients=n, dim=dim,
                              hetero=1.0)
        sim = make_sim(prob.a, prob.b, prob.p, prob.w_star)
        scheduler = make_scheduler("alg2", n)
        energy = make_arrivals("binary", n, iters + 1)

        unsharded = jax.jit(lambda k, _s=sim, _sc=scheduler, _e=energy:
                            _s.run(k, params0, iters, scheduler=_sc,
                                   energy=_e))
        dt_u = timed(lambda: unsharded(key))
        dt = {red: timed(lambda _r=red: run_client_sharded(
            sim, key, params0, iters, scheduler=scheduler, energy=energy,
            mesh=mesh, reduction=_r)) for red in REDUCTIONS}

        # One shard's program, run alone on one device: the same
        # step (grads over its rows, local reduce, replicated update)
        # over the N/D-client slice of the same problem.
        n_local = n // n_dev
        sim_l = make_sim(prob.a[:n_local], prob.b[:n_local],
                         prob.p[:n_local], prob.w_star)
        shard_run = jax.jit(
            lambda k, _s=sim_l, _sc=make_scheduler("alg2", n_local),
            _e=make_arrivals("binary", n_local, iters + 1):
            _s.run(k, params0, iters, scheduler=_sc, energy=_e))
        dt_shard = timed(lambda: shard_run(key))

        dt_round = dt_shard + dt_coll
        speed = dt_u / dt_round
        wall = {red: dt_u / dt[red] for red in REDUCTIONS}
        print(f"largeN N={n} ({iters} steps, warm): unsharded {dt_u:.3f}s; "
              f"serialized-{n_dev}dev "
              + " / ".join(f"{r} {dt[r]:.3f}s" for r in REDUCTIONS)
              + f"; per-shard {dt_shard:.3f}s + collective {dt_coll:.3f}s "
              f"-> round {dt_round:.3f}s ({speed:.2f}x)", file=sys.stderr)
        rows += [
            f"largeN_unsharded_N{n},{dt_u * 1e6:.0f},"
            f"iters={iters};dim={dim}",
            f"largeN_sharded_N{n},{dt['gather'] * 1e6:.0f},"
            f"iters={iters};dim={dim};devices={n_dev};reduction=gather;"
            f"wall=serialized",
            f"largeN_psum_N{n},{dt['psum'] * 1e6:.0f},"
            f"iters={iters};dim={dim};devices={n_dev};reduction=psum;"
            f"wall=serialized",
            f"largeN_fused_N{n},{dt['fused'] * 1e6:.0f},"
            f"iters={iters};dim={dim};devices={n_dev};reduction=fused;"
            f"wall=serialized",
            f"largeN_pershard_N{n},{dt_shard * 1e6:.0f},"
            f"iters={iters};dim={dim};n_local={n_local}",
            f"largeN_speedup_N{n},{dt_round * 1e6:.0f},"
            f"speedup={speed:.2f};basis=critical_path;"
            f"pershard_us={dt_shard * 1e6:.0f};"
            f"collective_us={dt_coll * 1e6:.0f};"
            f"wall_speedup_fused={wall['fused']:.2f};"
            f"wall_speedup_psum={wall['psum']:.2f};"
            f"wall_speedup_gather={wall['gather']:.2f};"
            f"devices={n_dev};reduction=fused;"
            f"sharded_faster={speed >= 1.0}",
        ]
        if crossover is None and speed >= 1.0:
            crossover = n
    # Derived series (us_per_call=0 — not a timing): the smallest swept N
    # where the fused sharded path beats the unsharded run.
    rows.append(f"largeN_crossover,0,"
                f"n={crossover if crossover is not None else 'none'};"
                f"devices={n_dev};reduction=fused;basis=critical_path")
    return rows


def _faultpath_overhead_rows(iters: int, seeds: int) -> list[str]:
    """Fault-path overhead series (DESIGN.md §10): the same 4-scheduler
    quadratic cells run warm three ways — no ``faults`` component at all
    (baseline; fault-free scans compile with zero fault machinery),
    a rate-0 ``drop`` component (the guarded per-step fault branch is in
    the compiled scan but injects nothing), and an actively injecting
    ``drop_corrupt`` component. The contract the series tracks: carrying
    the rate-0 fault branch costs ≤ 5 % over the fault-free scan
    (``within_budget``); the injecting timing is informational."""
    from repro.core import ClientSimulator, make_quadratic
    from repro.experiments import Scenario
    from repro.experiments.engine import execute_cells
    from repro.optim import sgd

    n_clients, dim = 8, 64
    problem = make_quadratic(jax.random.PRNGKey(7), n_clients=n_clients,
                             dim=dim, hetero=1.0)
    sim = ClientSimulator(
        grads_fn=lambda p, k, t: problem.all_grads(p, key=k, noise=0.05),
        p=problem.p, optimizer=sgd(0.02), loss_fn=problem.suboptimality)
    params0 = jnp.full((dim,), 4.0)

    def cells(faults, kwargs):
        return [Scenario(name=s, scheduler=s, arrivals="periodic",
                         n_clients=n_clients, horizon=iters + 1,
                         faults=faults, fault_kwargs=kwargs)
                for s in ("alg1", "alg2", "benchmark1", "benchmark2")]

    def timed(scs, reps: int = 3):
        def once():
            res = execute_cells(scs, sim=sim, params0=params0,
                                num_steps=iters, seeds=seeds)
            jax.block_until_ready([c.params for c in res.values()])
        once()                               # warm the jit cache
        best = float("inf")
        for _ in range(reps):
            t0 = time.time()
            once()
            best = min(best, time.time() - t0)
        return best

    dt_clean = timed(cells(None, {}))
    dt_rate0 = timed(cells("drop", {"rate": 0.0}))
    dt_inject = timed(cells("drop_corrupt", {"drop_rate": 0.2,
                                             "corrupt_rate": 0.05,
                                             "scale": 3.0}))
    overhead = dt_rate0 / dt_clean
    n_cells = 4 * seeds
    print(f"faultpath ({n_cells} cells x {iters} steps, warm): "
          f"clean {dt_clean:.2f}s vs rate-0 faults {dt_rate0:.2f}s "
          f"({overhead:.3f}x) vs injecting {dt_inject:.2f}s",
          file=sys.stderr)
    return [
        f"faultpath_clean_warm,{dt_clean * 1e6:.0f},"
        f"cells={n_cells};iters={iters}",
        f"faultpath_rate0_warm,{dt_rate0 * 1e6:.0f},"
        f"cells={n_cells};iters={iters};faults=drop;rate=0",
        f"faultpath_inject_warm,{dt_inject * 1e6:.0f},"
        f"cells={n_cells};iters={iters};faults=drop_corrupt",
        f"faultpath_overhead,{dt_rate0 * 1e6:.0f},"
        f"overhead={overhead:.3f};budget=1.05;"
        f"within_budget={overhead <= 1.05};"
        f"timing_ref=faultpath_rate0_warm",
    ]


def run(iters: int = 100, seeds: int = 8, n_clients: int = 8) -> list[str]:
    from repro.core import ClientSimulator
    from repro.experiments import (
        ARRIVAL_KINDS,
        ExecutionConfig,
        FIG1_SCHEDULERS,
        clear_cache,
        get_study,
    )
    from repro.optim import sgd

    hw, batch, lr = 8, 2, 0.05
    grads_fn, eval_fn, p, params0 = _setup(n_clients, hw, batch)
    study = get_study("fig1_grid", n_clients=n_clients, num_steps=iters,
                      seeds=seeds)
    # One simulator for every execution config: repeat study.run calls
    # with the same sim hit the jit cache instead of re-tracing.
    sim = ClientSimulator(grads_fn=grads_fn, p=p, optimizer=sgd(lr))
    cfg = ExecutionConfig(eval_fn=eval_fn, eval_every=iters)
    n_cells = len(study.resolve()) * seeds

    def timed(config):
        t0 = time.time()
        res = study.run(sim=sim, params0=params0, config=config)
        jax.block_until_ready([c.evals for c in res.values()])
        return res, time.time() - t0

    results, dt_batched = timed(cfg)
    _, dt_seq = timed(ExecutionConfig(eval_fn=eval_fn, eval_every=iters,
                                      sequential=True))

    # Device-sharded execution: same cells, flattened cell axis across
    # all devices. Warm timings re-run with the same sim (jit-cache hit)
    # so the batched-vs-sharded comparison reflects steady-state
    # large-grid throughput rather than compile time.
    n_dev = jax.device_count()
    sharded_rows = []
    if n_dev >= 2:
        from repro.experiments import make_cell_mesh
        sh_cfg = ExecutionConfig(eval_fn=eval_fn, eval_every=iters,
                                 mesh=make_cell_mesh())
        _, dt_sharded = timed(sh_cfg)
        _, dt_sharded_warm = timed(sh_cfg)
        _, dt_batched_warm = timed(cfg)
        sh_speed = dt_batched_warm / dt_sharded_warm
        print(f"fig1 grid sharded over {n_dev} devices: "
              f"cold {dt_sharded:.1f}s, warm {dt_sharded_warm:.1f}s vs "
              f"batched warm {dt_batched_warm:.1f}s -> {sh_speed:.1f}x",
              file=sys.stderr)
        sharded_rows = [
            f"fig1_grid_sharded,{dt_sharded * 1e6:.0f},"
            f"cells={n_cells};iters={iters};devices={n_dev}",
            f"fig1_grid_sharded_warm,{dt_sharded_warm * 1e6:.0f},"
            f"cells={n_cells};iters={iters};devices={n_dev}",
            f"fig1_grid_batched_warm,{dt_batched_warm * 1e6:.0f},"
            f"cells={n_cells};iters={iters}",
            f"fig1_grid_sharded_speedup,{dt_sharded_warm * 1e6:.0f},"
            f"speedup={sh_speed:.2f};devices={n_dev};"
            f"sharded_faster={dt_sharded_warm < dt_batched_warm};"
            f"timing_ref=fig1_grid_sharded_warm",
        ]
        # The CNN cells above are compute-bound: on a host whose cores
        # the batched path already saturates (this CI container has 2),
        # cell sharding cannot beat intra-op parallelism. The paper's
        # Theorem-1 quadratic cells are the dispatch-bound regime —
        # tiny ops, long scans — where cell sharding pays whenever
        # devices have real parallelism and the cell count divides the
        # device count (padding lanes do real work; see DESIGN.md §5),
        # so the trajectory tracks that 96-cell grid as its own series.
        sharded_rows.extend(_quadratic_grid_rows(iters=400, seeds=seeds))
    else:
        print("fig1 grid sharded: skipped (single device)", file=sys.stderr)

    # Per-scenario wall-clocks: each scheduler × arrival scenario is its
    # own component-structure group, so re-running it alone through the
    # same engine hits the jit cache — an honest warm per-group timing.
    # (Previously every fig1_<name> row carried the identical
    # grid-total/n_cells value — 12 series, one number; the bench-schema
    # validator now rejects that shape.)
    from repro.experiments.engine import execute_cells

    per_group_us = {}
    for sc in study.resolve():
        t0 = time.time()
        res1 = execute_cells([sc], sim=sim, params0=params0,
                             num_steps=iters, seeds=seeds,
                             eval_fn=eval_fn, eval_every=iters)
        jax.block_until_ready([c.evals for c in res1.values()])
        per_group_us[sc.name] = (time.time() - t0) * 1e6

    # Final test accuracy per seed = the single end-of-run eval.
    # NaN-aware: a diverged seed is excluded from mean/std, counted in n_nan.
    acc = results.reduce(metric=lambda c: c.evals[:, -1])
    rows = []
    for name in results:
        s = acc[name]
        rows.append(f"fig1_{name},{per_group_us[name] / seeds:.0f},"
                    f"acc_mean={s['mean']:.3f};acc_std={s['std']:.3f};"
                    f"seeds={s['n_seeds']};n_nan={s['n_nan']};"
                    f"timing=warm_group")

    speedup = dt_seq / dt_batched
    # Meta output goes to stderr — stdout is the harness's CSV stream.
    print(f"fig1 grid: {n_cells} cells "
          f"({len(FIG1_SCHEDULERS)}x{len(ARRIVAL_KINDS)}x{seeds} seeds), "
          f"{iters} iters; "
          f"batched {dt_batched:.1f}s vs sequential {dt_seq:.1f}s "
          f"-> {speedup:.1f}x", file=sys.stderr)
    rows.append(f"fig1_grid_batched,{dt_batched * 1e6:.0f},"
                f"cells={n_cells};iters={iters}")
    rows.append(f"fig1_grid_sequential,{dt_seq * 1e6:.0f},"
                f"cells={n_cells};iters={iters}")
    rows.append(f"fig1_grid_speedup,{dt_batched * 1e6:.0f},"
                f"speedup={speedup:.2f};batched_faster={dt_batched < dt_seq};"
                f"timing_ref=fig1_grid_batched")
    rows.extend(sharded_rows)
    # 4× the CNN iteration budget: 400 steps on the full run (matching
    # the quadgrid series' scale), 160 under --fast.
    rows.extend(_population_scaling_rows(iters=4 * iters, seeds=seeds))
    # Within-cell client sharding at large N (DESIGN.md §8).
    rows.extend(_large_n_rows())
    # Fault-injection path overhead (DESIGN.md §10) — same 400/160-step
    # scale as the quadgrid series.
    rows.extend(_faultpath_overhead_rows(iters=4 * iters, seeds=seeds))

    # Paper ordering on the paper's (periodic) arrivals, seed-averaged:
    # the full chain alg1 ≥ benchmark1 ≥ benchmark2 (Fig. 1), each link
    # checked with a small tolerance so seed noise on a tie is not a
    # failure, and the failed link (if any) named in the output. The
    # comparisons are written so NaN (diverged run) fails the link, and
    # non-positive accuracies are flagged as degenerate outright.
    a = {m: acc[f"{m}_periodic"]["mean"] for m in FIG1_SCHEDULERS}
    tol = 0.01
    links = (("alg1", "benchmark1"), ("benchmark1", "benchmark2"))
    failed = [f"{hi}<{lo}" for hi, lo in links
              if not (a[hi] >= a[lo] - tol)]
    if not all(a[m] > 0 for m in ("alg1", "benchmark1", "benchmark2")):
        failed.append("degenerate_accuracy")
    ok = not failed
    rows.append(f"fig1_ordering,{dt_batched * 1e6:.0f},"
                f"ordering_ok={ok};failed_links={'|'.join(failed) or 'none'};"
                f"alg1={a['alg1']:.3f};benchmark1={a['benchmark1']:.3f};"
                f"benchmark2={a['benchmark2']:.3f};"
                f"timing_ref=fig1_grid_batched")
    # Release the compiled grid + the dataset-capturing closures it pins
    # (the harness process may go on to run other suites).
    clear_cache()
    return rows
