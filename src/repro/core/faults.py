"""Client fault injection (delivery faults), as registered JAX pytrees.

The paper's premise is clients that are *intermittently unable to
participate*. The energy process models the benign case — a client with
no energy simply does not compute. This module models the hostile
remainder of the distributed-systems reality: a client that *did*
compute an update which is then lost, delayed, or corrupted on its way
to the server (cf. over-the-air aggregation with channel corruption,
arXiv 2205.12869, and EH devices with unreliable links).

Every fault family is a ``jax.tree_util.register_dataclass`` pytree,
mirroring the arrival-family pattern (:mod:`repro.core.energy`): rates
and window tables are leaves, so a family of faulted scenarios stacks
leaf-wise and executes under one compiled grid computation, and a fault
component rides through ``jit``/``vmap``/``lax.scan`` as an ordinary
traced argument.

Protocol (structural; all methods pure):

    init(key, n_clients, n_params) -> state          (pytree; () if stateless)
    apply(state, t, key, g) -> (state, g, keep)
    pad_clients(n_total)    -> same family, per-client leaves padded

``apply`` sees the flat per-client gradient buffer ``g`` of shape
``(N, P)`` (fault injection requires flat-carry execution, DESIGN.md
§5) and returns the possibly-transformed buffer plus ``keep`` — an
``(N,)`` float32 0/1 *delivery* mask (1 = the update reached the
server) or None when the family never drops. The simulator composes
``keep`` into the existing ``active_mask`` row-select machinery
(:func:`repro.core.aggregation.compose_masks`), so a dropped row
contributes an *exact zero* through the masked Pallas kernels even when
its gradient payload is NaN/inf — the DESIGN.md §7 poison-row guarantee
is the fault-injection substrate. Zero-weighting (``weights * keep``)
keeps ``weight_sum`` an honest record of delivered mass.

Randomness is drawn with the shape-independent per-client helpers
(:func:`repro.core.energy.client_uniform`), so a padded (ragged) run
faults exactly the same rows as the natural-N run, and a fault family
at rate 0 is the bitwise identity on the no-fault trajectory.

Four concrete families + a combinator:

* ``DropUpdates``     — Bernoulli(rate) update loss per client per round.
* ``CorruptGradients``— Bernoulli(rate) row corruption: ``g_i <- g_i *
                        scale`` (scale may be NaN/inf to model poison).
* ``StaleUpdates``    — Bernoulli(rate) delay-``k`` replay: the server
                        receives the update the client sent ``k`` rounds
                        ago (dropped while no history exists, t < k).
* ``OfflineWindows``  — deterministic forced-outage intervals
                        (start/length, optionally repeating).
* ``CompositeFault``  — apply several families in sequence, delivery
                        masks composed multiplicatively.

The module also owns the **fault-family registry**
(:func:`register_fault_family` / :func:`make_fault`), from which the
experiment layer builds its ``faults`` sweep axis.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.energy import (_check_pad, _concrete, _pad_leaf,
                               client_uniform)

#: Domain-separation constant for the per-step fault key: the simulator
#: derives ``k_fault = fold_in(k_grad, FAULT_SALT)`` instead of widening
#: the step's ``random.split`` arity, so every pre-existing RNG stream
#: (scheduler, energy, gradients) is bitwise unchanged whether or not a
#: fault component is present. The value ("FAUL") is far above any
#: client index or counter the gradient path folds in.
FAULT_SALT = 0x4641554C


def _as_rate(rate, name: str = "rate"):
    """Validate a Bernoulli rate leaf (scalar or (N,)) when concrete.

    Tracers and opaque pytree-unflatten placeholders pass through
    untouched (DESIGN.md §3) — validation/conversion fires only on
    concrete values.
    """
    conc = _concrete(rate)
    if conc is None:
        return rate
    if ((conc < 0) | (conc > 1)).any():
        raise ValueError(f"{name} must lie in [0, 1], got {conc}")
    return jnp.asarray(rate, jnp.float32)


@dataclasses.dataclass(frozen=True)
class DropUpdates:
    """Bernoulli update loss: each round, client ``i``'s update is lost
    with probability ``rate_i`` (scalar or per-client leaf)."""

    rate: jax.Array

    def __post_init__(self):
        object.__setattr__(self, "rate", _as_rate(self.rate))

    def init(self, key, n_clients: int, n_params: int):
        return ()

    def apply(self, state, t, key, g):
        u = client_uniform(key, g.shape[0])
        keep = (u >= self.rate).astype(jnp.float32)
        return state, g, keep

    def pad_clients(self, n_total: int):
        if jnp.ndim(self.rate) == 0:
            return self
        pad = _check_pad(self.rate.shape[0], n_total)
        # Padded rows never drop (rate 0) — they are masked out of the
        # aggregation anyway; a valid rate keeps the draw finite.
        return DropUpdates(_pad_leaf(self.rate, pad, 0.0))


@dataclasses.dataclass(frozen=True)
class CorruptGradients:
    """Bernoulli row corruption: with probability ``rate_i`` the row is
    scaled by ``scale`` before aggregation. ``scale`` may be any float —
    large (scaled attack), NaN/inf (poison), 0 (silent zeroing). The
    update is still *delivered* (keep is None); pair with
    :class:`DropUpdates` via :class:`CompositeFault` to model detected
    corruption."""

    rate: jax.Array
    scale: jax.Array

    def __post_init__(self):
        object.__setattr__(self, "rate", _as_rate(self.rate))
        if _concrete(self.scale) is not None or isinstance(
                self.scale, (int, float)):
            object.__setattr__(self, "scale",
                               jnp.asarray(self.scale, jnp.float32))

    def init(self, key, n_clients: int, n_params: int):
        return ()

    def apply(self, state, t, key, g):
        u = client_uniform(key, g.shape[0])
        hit = u < self.rate
        g = jnp.where(hit[:, None], g * self.scale.astype(g.dtype), g)
        return state, g, None

    def pad_clients(self, n_total: int):
        if jnp.ndim(self.rate) == 0:
            return self
        pad = _check_pad(self.rate.shape[0], n_total)
        return CorruptGradients(_pad_leaf(self.rate, pad, 0.0), self.scale)


@dataclasses.dataclass(frozen=True)
class StaleUpdates:
    """Delay-``k`` replay: with probability ``rate_i`` the server receives
    the update client ``i`` computed ``delay`` rounds ago instead of the
    fresh one. While no history exists (t < delay) a stale-hit row is
    *dropped* (keep 0) rather than replayed as zero. State is a
    ``(delay, N, P)`` float32 ring buffer of past gradient rows, indexed
    by ``t mod delay``."""

    rate: jax.Array
    delay: int = 1

    def __post_init__(self):
        object.__setattr__(self, "rate", _as_rate(self.rate))
        if int(self.delay) < 1:
            raise ValueError(f"delay must be >= 1, got {self.delay}")
        object.__setattr__(self, "delay", int(self.delay))

    def init(self, key, n_clients: int, n_params: int):
        return jnp.zeros((self.delay, n_clients, n_params), jnp.float32)

    def apply(self, state, t, key, g):
        slot = t % self.delay
        old = state[slot]
        u = client_uniform(key, g.shape[0])
        hit = u < self.rate
        replay = hit & (t >= self.delay)
        dropped = hit & (t < self.delay)
        g_out = jnp.where(replay[:, None], old.astype(g.dtype), g)
        keep = 1.0 - dropped.astype(jnp.float32)
        # Record what the client *sent* this round (the fresh gradient),
        # after reading the slot it overwrites (the t - delay entry).
        state = state.at[slot].set(g.astype(jnp.float32))
        return state, g_out, keep

    def pad_clients(self, n_total: int):
        rate = self.rate
        if jnp.ndim(rate) != 0:
            rate = _pad_leaf(rate, _check_pad(rate.shape[0], n_total), 0.0)
        return StaleUpdates(rate, delay=self.delay)


@dataclasses.dataclass(frozen=True)
class OfflineWindows:
    """Deterministic forced-outage intervals: client ``i`` is offline
    (update dropped) on steps ``t`` with ``0 <= (t - start_i) < length_i``,
    repeating every ``period_i`` steps when ``period_i > 0``. All three
    are leaves — scalar (one window profile for everyone) or (N,)."""

    start: jax.Array
    length: jax.Array
    period: jax.Array = 0

    def __post_init__(self):
        for f in ("start", "length", "period"):
            v = _concrete(getattr(self, f))
            if v is None:
                continue
            if (v < 0).any():
                raise ValueError(f"{f} must be >= 0, got {v}")
            object.__setattr__(self, f,
                               jnp.asarray(getattr(self, f), jnp.int32))

    def init(self, key, n_clients: int, n_params: int):
        return ()

    def apply(self, state, t, key, g):
        rel = t - self.start
        pos = jnp.where(self.period > 0,
                        rel % jnp.maximum(self.period, 1), rel)
        off = (rel >= 0) & (pos < self.length)
        keep = jnp.broadcast_to(1.0 - off.astype(jnp.float32),
                                (g.shape[0],))
        return state, g, keep

    def pad_clients(self, n_total: int):
        vals = {}
        for f in ("start", "length", "period"):
            v = getattr(self, f)
            if jnp.ndim(v) != 0:
                v = _pad_leaf(v, _check_pad(v.shape[0], n_total), 0)
            vals[f] = v
        # length 0 on padded rows -> never offline (and masked anyway).
        return OfflineWindows(**vals)


@dataclasses.dataclass(frozen=True)
class CompositeFault:
    """Apply several fault families in sequence (gradient transforms
    chain, delivery masks compose multiplicatively). Each part draws
    from an independently folded subkey, so a composite containing two
    Bernoulli families does not correlate their coin flips."""

    parts: tuple

    def __post_init__(self):
        object.__setattr__(self, "parts", tuple(self.parts))
        if not self.parts:
            raise ValueError("CompositeFault needs at least one part")

    def init(self, key, n_clients: int, n_params: int):
        return tuple(p.init(jax.random.fold_in(key, i), n_clients, n_params)
                     for i, p in enumerate(self.parts))

    def apply(self, state, t, key, g):
        from repro.core.aggregation import compose_masks

        new_state, keep = [], None
        for i, (p, s) in enumerate(zip(self.parts, state)):
            s, g, k = p.apply(s, t, jax.random.fold_in(key, i), g)
            new_state.append(s)
            keep = compose_masks(keep, k)
        return tuple(new_state), g, keep

    def pad_clients(self, n_total: int):
        return CompositeFault(tuple(p.pad_clients(n_total)
                                    for p in self.parts))


for _cls, _fields in ((DropUpdates, ["rate"]),
                      (CorruptGradients, ["rate", "scale"]),
                      (OfflineWindows, ["start", "length", "period"]),
                      (CompositeFault, ["parts"])):
    jax.tree_util.register_dataclass(_cls, data_fields=_fields,
                                     meta_fields=[])
jax.tree_util.register_dataclass(StaleUpdates, data_fields=["rate"],
                                 meta_fields=["delay"])


# ------------------------------------------------ fault-family registry

_FAULT_FAMILIES: dict = {}


def register_fault_family(name: str):
    """Decorator: register a named fault-family factory with signature
    ``(n_clients, **kw) -> fault``. :func:`make_fault` dispatches by
    name; the experiment layer's ``faults`` sweep axis is built from
    this registry (mirroring :func:`repro.core.energy.
    register_arrival_family`)."""

    def deco(fn):
        _FAULT_FAMILIES[name] = fn
        return fn

    return deco


def fault_family_names() -> list[str]:
    return sorted(_FAULT_FAMILIES)


def make_fault(kind: str, n_clients: int, **kw):
    """Fault-component factory by registered family name."""
    try:
        factory = _FAULT_FAMILIES[kind]
    except KeyError:
        raise ValueError(
            f"unknown fault kind {kind!r}; have {fault_family_names()}"
        ) from None
    return factory(n_clients, **kw)


@register_fault_family("drop")
def _drop(n_clients, *, rate=0.0):
    return DropUpdates(rate)


@register_fault_family("corrupt")
def _corrupt(n_clients, *, rate=0.0, scale=0.0):
    return CorruptGradients(rate, scale)


@register_fault_family("stale")
def _stale(n_clients, *, rate=0.0, delay=1):
    return StaleUpdates(rate, delay=delay)


@register_fault_family("offline")
def _offline(n_clients, *, start=0, length=0, period=0):
    return OfflineWindows(start, length, period)


@register_fault_family("drop_corrupt")
def _drop_corrupt(n_clients, *, drop_rate=0.0, corrupt_rate=0.0, scale=0.0):
    """Composite convenience family: independent Bernoulli drop + row
    corruption — the channel model of over-the-air aggregation."""
    return CompositeFault((DropUpdates(drop_rate),
                           CorruptGradients(corrupt_rate, scale)))


def pad_faults(fault, n_total: int):
    """Pad a fault component's per-client leaves to ``n_total`` rows
    (protocol dispatch to ``pad_clients``; identity at capacity and for
    scalar-leaf families). Padded rows are neutral — they never fault —
    and are masked out of aggregation regardless (DESIGN.md §7)."""
    if fault is None:
        return None
    try:
        method = fault.pad_clients
    except AttributeError:
        raise TypeError(
            f"{type(fault)!r} does not implement pad_clients(); ragged "
            "client populations need every fault family to define its "
            "padding rule") from None
    return method(n_total)
