"""Placement layer: device-sharded grid execution (DESIGN.md §5, §8).

:func:`repro.experiments.run_grid` batches a structure-group's cells as
``vmap(scenarios) ∘ vmap(seeds)`` on one device. This module places the
same computation across a device mesh instead, along two composable
axes:

**Cell axis** (``"cells"``, DESIGN.md §5) — across-cell parallelism:

1. the (scenario S × seed R) cell block is **flattened** into one cell
   axis C = S·R (scheduler/energy leaves repeated over seeds, PRNG keys
   tiled over scenarios),
2. C is **padded** to a device-divisible count by repeating cell 0 — a
   valid cell, so the padded lanes run real arithmetic instead of
   producing NaNs — and the pad is sliced off before results are
   reshaped back to (S, R, ...),
3. the block executes under ``shard_map``: cells sharded along the
   cell axis, ``params0`` replicated, each device running the same
   jitted ``vmap(ClientSimulator.run)`` over its local cells.

**Client axis** (``"clients"``, DESIGN.md §8) — within-cell parallelism
for populations one device cannot hold: every per-client operand — the
component leaves whose leading (post-cell) dimension is the population
capacity, the ``active_mask`` / ``p`` ragged operands, the scheduler and
energy *state*, and the ``(N, P)`` gradient buffer — is sharded over the
client axis, while params / optimizer state stay **replicated** (every
shard applies the identical server update, so no parameter broadcast is
ever needed). The per-step reduction crosses the axis once: by default
an ``all_gather`` of the gradient rows followed by the *identical*
unsharded reduction on every shard (bit-for-bit the single-device
numbers), or — ``reduction="psum"`` — one local matvec/kernel launch
plus a ``(P,)`` psum (bandwidth-optimal, f32-reassociation tolerance).
Per-client RNG folds in *global* client indices
(:func:`repro.core.energy.client_sharding`), so shard-local rows draw
exactly the unsharded run's bits.

The two axes compose: ``make_grid_mesh(cells=4, clients=2)`` runs 4-way
cell sharding with each cell's population split over 2 devices.

Single-device callers never enter this module — ``run_grid`` without a
``mesh`` (or with a 1-device mesh) takes the pure-vmap path bit-for-bit
unchanged. CPU CI exercises the sharded paths via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(``tests/conftest.py``).

**Multi-host meshes** (DESIGN.md §13): every factory here builds from
*global* devices, so under an initialized ``jax.distributed`` runtime
(:mod:`repro.launch.distributed`) the same meshes span processes —
:func:`make_client_mesh` puts the client axis across hosts (the ROADMAP
mapping: the only per-step collective is the ``(P,)``-sized reduction),
:func:`make_multihost_mesh` pins the cell axis across processes with
clients process-local. Dispatch stays the same ``shard_map`` programs;
the only multi-process difference is at the host boundary — inputs are
lifted to replicated global ``jax.Array``s (every process holds the
full host value, so lifting moves no data) and results are gathered
back to every host as numpy (:func:`run_group_sharded` /
:func:`run_client_sharded` do both automatically when the mesh spans
processes). Gather mode keeps its bitwise contract across hosts;
psum keeps f32-reassociation tolerance.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.core.energy import client_sharding
from repro.core.scheduling import shard_scheduler
from repro.core.trainer import SimHistory

#: Default mesh-axis name for the flattened (scenario × seed) cell axis.
CELL_AXIS = "cells"

#: Mesh-axis name for within-cell client sharding. Unlike the cell axis
#: (any single-axis name works, for back-compat), the client axis is
#: recognized *by this name*.
CLIENT_AXIS = "clients"


def device_topology(devices=None) -> str:
    """``"N global devices across K processes"`` — the phrase every
    mesh-shape error uses, so multi-process failures never conflate
    local and global device counts."""
    devices = jax.devices() if devices is None else list(np.ravel(devices))
    procs = {d.process_index for d in devices}
    return (f"{len(devices)} global device(s) across "
            f"{max(len(procs), 1)} process(es)")


def mesh_process_count(mesh: Mesh) -> int:
    """Number of distinct processes the mesh's devices live on — > 1
    means the mesh spans hosts and dispatch must go through the
    global-array boundary (DESIGN.md §13)."""
    return len({d.process_index for d in mesh.devices.flat})


def _device_slice(n_devices: int | None, devices=None):
    """The first ``n_devices`` of ``devices`` (default: all *global*
    devices). ``devices=`` is the explicit multi-host escape hatch —
    pass any iterable of jax devices to pin a layout by hand."""
    devices = list(jax.devices()) if devices is None \
        else list(np.ravel(devices))
    if n_devices is not None:
        if not 1 <= n_devices <= len(devices):
            raise ValueError(
                f"n_devices={n_devices} outside [1, {len(devices)}] — "
                f"have {device_topology(devices)}")
        devices = devices[:n_devices]
    return devices


def make_cell_mesh(n_devices: int | None = None, *,
                   axis_name: str = CELL_AXIS, devices=None) -> Mesh:
    """1-D mesh over the first ``n_devices`` (default: all) global
    devices; ``devices=`` pins an explicit layout.

    The cell axis is embarrassingly parallel, so grid sharding wants a
    flat mesh regardless of how production training meshes are shaped
    (``repro.launch.mesh`` re-exports this for drivers). Under
    ``jax.distributed`` the default spans every process's devices in
    process order.
    """
    return Mesh(np.array(_device_slice(n_devices, devices)), (axis_name,))


def make_client_mesh(n_devices: int | None = None, *, devices=None) -> Mesh:
    """1-D ``("clients",)`` mesh: within-cell client-axis sharding only
    (DESIGN.md §8). The population capacity must divide the mesh size.
    Under ``jax.distributed`` the default layout spans processes — the
    ROADMAP's client-axis-onto-host-axis mapping, where the only
    per-step collective crossing hosts is the ``(P,)`` reduction."""
    return Mesh(np.array(_device_slice(n_devices, devices)), (CLIENT_AXIS,))


def make_grid_mesh(cells: int, clients: int, *, devices=None) -> Mesh:
    """2-D ``(cells, clients)`` mesh over the first ``cells·clients``
    devices: cell sharding across the first axis composed with
    within-cell client sharding across the second. ``devices=`` pins an
    explicit layout (e.g. a process-spanning one —
    :func:`make_multihost_mesh` builds the canonical version)."""
    pool = list(jax.devices()) if devices is None else list(np.ravel(devices))
    if cells * clients > len(pool):
        raise ValueError(
            f"make_grid_mesh(cells={cells}, clients={clients}) needs "
            f"{cells * clients} global devices, have "
            f"{device_topology(pool)}")
    devices = _device_slice(cells * clients, pool)
    return Mesh(np.array(devices).reshape(cells, clients),
                (CELL_AXIS, CLIENT_AXIS))


def _devices_by_process() -> list[list]:
    """Global devices grouped by owning process, both in stable order."""
    by_proc: dict[int, list] = {}
    for d in jax.devices():
        by_proc.setdefault(d.process_index, []).append(d)
    return [by_proc[p] for p in sorted(by_proc)]


def make_multihost_mesh(cells: int | None = None,
                        clients: int | None = None) -> Mesh:
    """2-D ``(cells, clients)`` mesh with the **cell axis crossing
    processes** and every client-axis row inside one process
    (DESIGN.md §13): the within-cell reduction never crosses a host;
    only the cell axis spans the interconnect, and the cell axis has no
    per-step collective at all.

    Defaults: ``cells`` = the process count (one cell shard per host),
    ``clients`` = the local devices each cell row can use. For the dual
    layout — client axis across hosts, the ROADMAP's ``(P,)``-psum
    mapping — use :func:`make_client_mesh`, whose global-device default
    already spans processes; for a process-spanning 1-D cells mesh use
    :func:`make_cell_mesh` (global devices are process-major).

    Single-process sessions degenerate to ``make_grid_mesh`` layouts,
    so the same driver code runs anywhere.
    """
    grid = _devices_by_process()
    n_proc, local = len(grid), min(len(g) for g in grid)
    cells = n_proc if cells is None else int(cells)
    if cells % n_proc != 0:
        raise ValueError(
            f"make_multihost_mesh(cells={cells}): the cell axis must "
            f"divide evenly over processes — have {device_topology()}")
    rows_per_proc = cells // n_proc
    width = local // rows_per_proc if clients is None else int(clients)
    if width < 1 or rows_per_proc * width > local:
        raise ValueError(
            f"make_multihost_mesh(cells={cells}, clients={clients}) needs "
            f"{rows_per_proc}×{width} devices per process, have "
            f"{local} local — {device_topology()}")
    rows = [g[r * width:(r + 1) * width]
            for g in grid for r in range(rows_per_proc)]
    return Mesh(np.array(rows), (CELL_AXIS, CLIENT_AXIS))


def _mesh_axes(mesh: Mesh) -> tuple[str | None, str | None]:
    """(cell_axis, client_axis) names of a grid mesh, either possibly
    None. 1-D meshes keep the legacy rule — any axis name is the cell
    axis — unless the axis is literally named ``"clients"``; 2-D meshes
    must be (cell_axis, "clients")."""
    names = mesh.axis_names
    if len(names) == 1:
        if names[0] == CLIENT_AXIS:
            return None, CLIENT_AXIS
        return names[0], None
    if len(names) == 2 and names[1] == CLIENT_AXIS \
            and names[0] != CLIENT_AXIS:
        return names[0], CLIENT_AXIS
    raise ValueError(
        "grid sharding needs a 1-D mesh (the flattened cell axis, or a "
        f"'{CLIENT_AXIS}' axis for within-cell sharding) or a 2-D "
        f"(cells, '{CLIENT_AXIS}') mesh; got axes {names} — build one "
        "with make_cell_mesh() / make_client_mesh() / make_grid_mesh()")


def _cell_axis(mesh: Mesh) -> str:
    """Legacy validator: the (sole) cell axis of a cells-only mesh."""
    cell_ax, client_ax = _mesh_axes(mesh)
    if client_ax is not None or cell_ax is None:
        raise ValueError(
            f"expected a cells-only 1-D mesh, got axes {mesh.axis_names}")
    return cell_ax


def _check_client_shards(n_cap: int, shards: int) -> int:
    if n_cap % shards != 0:
        raise ValueError(
            f"client-axis sharding needs the population capacity to divide "
            f"the '{CLIENT_AXIS}' mesh axis: N_cap={n_cap} over {shards} "
            f"shards (pad the population to a multiple — DESIGN.md §8)")
    return n_cap // shards


def client_leaf_specs(tree, n_cap: int, *, client_axis: str,
                      cell_axis: str | None = None, lead: int = 0):
    """Per-leaf ``PartitionSpec`` list (``tree_leaves`` order) for a
    component under client sharding: a leaf whose axis ``lead`` (the
    first post-batch axis) has the population capacity ``n_cap`` is
    treated as per-client and sharded over ``client_axis``; every other
    leaf (scalar hyperparameters) is replicated across it. ``lead=1``
    with ``cell_axis`` set prepends cell sharding on axis 0 (the grid
    path). Returned as a flat list — the sharded runners pass component
    *leaves* through ``shard_map`` and unflatten inside the body, so
    registered-dataclass constructors only ever see (local) arrays.

    The rule is shape-based: a non-per-client hyperparameter vector that
    coincidentally has length ``n_cap`` on that axis would be sharded
    too — a component with such a leaf must not be run client-sharded
    (none of the built-ins has one).
    """
    lead_spec = (cell_axis,) * lead

    def one(leaf):
        if leaf.ndim > lead and leaf.shape[lead] == n_cap:
            return PartitionSpec(*lead_spec, client_axis)
        return PartitionSpec(*lead_spec)

    return [one(leaf) for leaf in jax.tree_util.tree_leaves(tree)]


def flatten_cells(scheduler, energy, keys, *, n_scenarios: int,
                  active=None, p=None, faults=None):
    """(S-stacked components, (R, 2) keys) → C = S·R flat cell arrays.

    Cell ``c = s·R + r`` pairs scenario ``s`` with seed ``r``, matching
    ``x.reshape(S, R, ...)`` on the way back out. ``active`` / ``p`` are
    the optional (S, N_cap) ragged-population operands, ``faults`` the
    optional S-stacked fault component, repeated over seeds like the
    components (None passes through).
    """
    r = keys.shape[0]
    rep = lambda x: jnp.repeat(x, r, axis=0)
    sch_c = jax.tree_util.tree_map(rep, scheduler)
    en_c = jax.tree_util.tree_map(rep, energy)
    flt_c = jax.tree_util.tree_map(rep, faults)
    active_c = jax.tree_util.tree_map(rep, active)
    p_c = jax.tree_util.tree_map(rep, p)
    keys_c = jnp.tile(keys, (n_scenarios, 1))
    return sch_c, en_c, flt_c, active_c, p_c, keys_c


def pad_cells(tree, n_cells: int, n_devices: int):
    """Pad the leading cell axis to a multiple of ``n_devices`` by
    repeating cell 0 (valid data — no NaN lanes); returns the padded
    tree and the padded count."""
    pad = (-n_cells) % n_devices
    if pad == 0:
        return tree, n_cells

    def _pad(x):
        return jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])])

    return jax.tree_util.tree_map(_pad, tree), n_cells + pad


@partial(jax.jit,
         static_argnames=("sim", "num_steps", "eval_fn", "eval_every", "mesh",
                          "reduction", "replicate_out"))
def _run_group_sharded(scheduler, energy, faults, active, p, params0, keys,
                       *, sim, num_steps: int, eval_fn=None,
                       eval_every: int = 0, mesh: Mesh,
                       reduction: str = "psum", replicate_out: bool = False):
    """shard_map'd twin of ``engine._run_group``.

    ``scheduler`` / ``energy`` / ``keys`` leaves carry a leading
    (device-divisible) flat cell axis, as do the optional
    ``active`` / ``p`` ragged-population operands (both None for
    uniform cells-only grids); ``params0`` is replicated. Each device
    vmaps the simulator scan over its local cells. When the mesh
    carries a ``clients`` axis, each cell's per-client operands are
    additionally sharded over it and the simulator runs under a
    :func:`repro.core.energy.client_sharding` context (DESIGN.md §8) —
    ``p`` is then always materialized by the caller. Compiled once per
    (sim, group structure, mesh) — probe
    ``_run_group_sharded._cache_size()`` to assert trace counts.
    """
    from repro.experiments.engine import CellResult

    cell_ax, client_ax = _mesh_axes(mesh)
    cells = PartitionSpec(cell_ax) if cell_ax is not None else PartitionSpec()
    replicated = PartitionSpec()
    sch_leaves, sch_def = jax.tree_util.tree_flatten(scheduler)
    en_leaves, en_def = jax.tree_util.tree_flatten(energy)
    flt_leaves, flt_def = jax.tree_util.tree_flatten(faults)
    if flt_leaves and client_ax is not None:
        raise ValueError(
            "fault injection is not supported under a clients mesh axis "
            "(DESIGN.md §10) — use a cells-only mesh or drop the fault "
            "component")

    if client_ax is None:
        in_specs = ([cells] * len(sch_leaves), [cells] * len(en_leaves),
                    [cells] * len(flt_leaves),
                    cells, cells, cells, replicated)
        out_specs = cells
    else:
        n_cap = int(sim.p.shape[0])
        _check_client_shards(n_cap, mesh.shape[client_ax])
        percell = lambda t: client_leaf_specs(
            t, n_cap, client_axis=client_ax, cell_axis=cell_ax, lead=1)
        rows = PartitionSpec(cell_ax, client_ax)
        in_specs = (percell(scheduler), percell(energy), [], rows, rows,
                    cells, replicated)
        out_specs = CellResult(
            params=cells,
            history=SimHistory(loss=cells,
                               participation=PartitionSpec(
                                   cell_ax, None, client_ax),
                               weight_sum=cells,
                               finite=cells),
            evals=cells)

    def local(sch_lv, en_lv, flt_lv, act, pw, ks, p0):
        sch = jax.tree_util.tree_unflatten(sch_def, sch_lv)
        en = jax.tree_util.tree_unflatten(en_def, en_lv)
        flt = jax.tree_util.tree_unflatten(flt_def, flt_lv)

        def one(s, e, f, a, w, k):
            out = sim.run(k, p0, num_steps, scheduler=s, energy=e, faults=f,
                          p=w, active_mask=a,
                          eval_fn=eval_fn, eval_every=eval_every)
            return CellResult(*out) if eval_fn is not None \
                else CellResult(*out, None)

        over_cells = jax.vmap(one, in_axes=(0, 0, 0, 0, 0, 0))
        if client_ax is None:
            return over_cells(sch, en, flt, act, pw, ks)
        shards = mesh.shape[client_ax]
        sch = shard_scheduler(sch, int(sim.p.shape[0]) // shards)
        with client_sharding(client_ax, shards, reduction):
            return over_cells(sch, en, flt, act, pw, ks)

    fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    out = fn(sch_leaves, en_leaves, flt_leaves, active, p, keys, params0)
    if replicate_out:
        # Multi-process dispatch: assemble fully-replicated outputs
        # *inside* this executable (a compiler-scheduled all-gather) so
        # every process can read results locally. Fetching sharded
        # outputs with per-leaf host-side allgathers instead is racy on
        # the gloo CPU transport — concurrent mixed-size collectives
        # from separate executables collide (DESIGN.md §13).
        out = jax.lax.with_sharding_constraint(
            out, jax.sharding.NamedSharding(mesh, PartitionSpec()))
    return out


@partial(jax.jit,
         static_argnames=("sim", "num_steps", "eval_fn", "eval_every", "mesh",
                          "reduction", "replicate_out"))
def _run_cell_client_sharded(scheduler, energy, active, p, params0, key, *,
                             sim, num_steps: int, eval_fn=None,
                             eval_every: int = 0, mesh: Mesh,
                             reduction: str = "psum",
                             replicate_out: bool = False):
    """Single-cell client-sharded execution: one population spanning the
    whole ``clients`` mesh (no cell axis, no cell vmap)."""
    client_ax = CLIENT_AXIS
    n_cap = int(sim.p.shape[0])
    shards = mesh.shape[client_ax]
    n_local = _check_client_shards(n_cap, shards)
    percell = lambda t: client_leaf_specs(t, n_cap, client_axis=client_ax)
    rows, replicated = PartitionSpec(client_ax), PartitionSpec()
    hist = SimHistory(loss=replicated,
                      participation=PartitionSpec(None, client_ax),
                      weight_sum=replicated,
                      finite=replicated)
    out_specs = (replicated, hist) if eval_fn is None \
        else (replicated, hist, replicated)
    sch_leaves, sch_def = jax.tree_util.tree_flatten(scheduler)
    en_leaves, en_def = jax.tree_util.tree_flatten(energy)

    def local(sch_lv, en_lv, act, pw, k, p0):
        sch = shard_scheduler(
            jax.tree_util.tree_unflatten(sch_def, sch_lv), n_local)
        en = jax.tree_util.tree_unflatten(en_def, en_lv)
        with client_sharding(client_ax, shards, reduction):
            return sim.run(k, p0, num_steps, scheduler=sch, energy=en,
                           p=pw, active_mask=act,
                           eval_fn=eval_fn, eval_every=eval_every)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(percell(scheduler), percell(energy), rows, rows,
                             replicated, replicated),
                   out_specs=out_specs, check_rep=False)
    out = fn(sch_leaves, en_leaves, active, p, key, params0)
    if replicate_out:
        # See _run_group_sharded: in-executable assembly for the
        # multi-process return boundary.
        out = jax.lax.with_sharding_constraint(
            out, jax.sharding.NamedSharding(mesh, PartitionSpec()))
    return out


def clear_cache() -> None:
    """Drop compiled sharded-grid executables (see engine.clear_cache)."""
    _run_group_sharded.clear_cache()
    _run_cell_client_sharded.clear_cache()


# ------------------------------------------- multi-process host boundary

def replicate_to_mesh(tree, mesh: Mesh):
    """Lift host-local arrays to *replicated* global ``jax.Array``s.

    Every process in a multi-controller session computes the identical
    host-side grid (same scenarios, same padding, same PRNG keys), so
    each already holds the full value of every operand — the lift is
    pure bookkeeping: each process populates its addressable shards
    from its local copy, no data moves. The jitted ``shard_map``s then
    reshard replicated → cells/clients-sharded internally, which is
    local slicing under SPMD. None leaves pass through.
    """
    sharding = jax.sharding.NamedSharding(mesh, PartitionSpec())

    def one(x):
        x = np.asarray(x)
        return jax.make_array_from_callback(
            x.shape, sharding, lambda idx, x=x: x[idx])

    return jax.tree_util.tree_map(one, tree)


def fetch_to_host(tree):
    """Materialize global result arrays as numpy on **every** process —
    the return boundary of a multi-process dispatch.

    The runners request fully-replicated outputs (``replicate_out=True``
    lowers the assembly all-gather into the compiled executable), so the
    common path is a plain local read. A leaf that still arrives sharded
    (outputs of user ``eval_fn``s routed around the runners) falls back
    to a host-driven allgather — correct, but serialized per leaf, since
    concurrent mixed-size collectives from separate executables collide
    on the gloo CPU transport (DESIGN.md §13). Downstream host-side
    assembly (crop, divergence attach, GridResult) then runs unchanged
    on all hosts."""
    from jax.experimental import multihost_utils

    def one(x):
        if getattr(x, "is_fully_addressable", True) or \
                getattr(x, "is_fully_replicated", False):
            return np.asarray(x)
        return np.asarray(multihost_utils.process_allgather(x, tiled=True))

    return jax.tree_util.tree_map(one, tree)


def run_client_sharded(sim, key, params0, num_steps: int, *, scheduler=None,
                       energy=None, mesh: Mesh, p=None, active_mask=None,
                       eval_fn=None, eval_every: int = 0,
                       reduction: str = "psum"):
    """Run ONE cell with its client axis sharded across ``mesh``.

    The within-cell entry point (DESIGN.md §8) for populations a single
    device cannot hold: arrivals/battery state, scheduler rows,
    ``active_mask``/``p`` and the ``(N, P)`` gradient buffer live
    sharded over the mesh's ``clients`` axis; params and optimizer state
    stay replicated. Same signature contract as
    :meth:`ClientSimulator.run` (returns ``(params, history[, evals])``
    with the participation history assembled back to the full client
    axis). The default ``reduction="psum"`` is bandwidth-optimal (the
    collective moves P floats, not N·P — float32 reassociation
    tolerance vs the unsharded run); ``reduction="gather"`` is the
    bit-for-bit differential oracle, and ``"fused[_bf16]"`` /
    ``"psum_bf16"`` select the fused reduce-and-update kernel and/or a
    bf16 wire (DESIGN.md §9 decision table). The capacity ``len(sim.p)``
    must divide the mesh's client-axis size.
    """
    cell_ax, client_ax = _mesh_axes(mesh)
    if client_ax is None:
        raise ValueError(
            f"run_client_sharded needs a mesh with a '{CLIENT_AXIS}' axis; "
            f"got axes {mesh.axis_names}")
    if cell_ax is not None and mesh.shape[cell_ax] != 1:
        raise ValueError(
            "run_client_sharded executes a single cell — the mesh's cell "
            f"axis must have size 1, got {mesh.shape[cell_ax]}")
    scheduler = sim.scheduler if scheduler is None else scheduler
    energy = sim.energy if energy is None else energy
    if scheduler is None or energy is None:
        raise ValueError("scheduler/energy must be given (or set on sim)")
    if p is None:
        p = sim.p
    args = (scheduler, energy, active_mask, p, params0, key)
    multiprocess = mesh_process_count(mesh) > 1
    if multiprocess:
        args = replicate_to_mesh(args, mesh)
    out = _run_cell_client_sharded(
        *args, sim=sim, num_steps=num_steps, eval_fn=eval_fn,
        eval_every=eval_every, mesh=mesh, reduction=reduction,
        replicate_out=multiprocess)
    return fetch_to_host(out) if multiprocess else out


def run_group_sharded(scheduler, energy, active, p, params0, keys, *, sim,
                      num_steps: int, n_scenarios: int, mesh: Mesh,
                      faults=None, eval_fn=None, eval_every: int = 0,
                      reduction: str = "psum"):
    """Execute one structure-group's (S × R) cell block across ``mesh``.

    Flatten → pad → shard_map → slice off padding → reshape to (S, R).
    ``active`` / ``p`` are the optional (S, N_cap) ragged-population
    operands (engine-level client padding; DESIGN.md §7), sharded along
    the cell axis exactly like the components. Per-cell numerics match
    the vmap path to float32 reassociation tolerance (each cell is the
    same ``ClientSimulator.run`` under the same per-seed PRNG key).

    A mesh carrying a ``clients`` axis additionally shards every
    per-client operand of every cell across it (DESIGN.md §8);
    ``reduction`` selects the cross-shard aggregation — ``"psum"``
    (default, bandwidth-optimal), ``"gather"`` (the bitwise oracle), or
    ``"fused[_bf16]"`` / ``"psum_bf16"`` (DESIGN.md §9).
    """
    cell_ax, client_ax = _mesh_axes(mesh)  # validate before any device work
    if client_ax is not None and (
            faults is not None or sim.faults is not None):
        raise ValueError(
            "fault injection is not supported under a clients mesh axis "
            "(DESIGN.md §10) — use a cells-only mesh or drop the fault "
            "component")
    r = keys.shape[0]
    n_cells = n_scenarios * r
    if client_ax is not None and p is None:
        # The simulator's constructor default cannot be used sharded —
        # the closed-over full (N,) vector would be replicated against
        # (n_local,) decisions — so materialize it as a sharded operand.
        p = jnp.broadcast_to(sim.p, (n_scenarios,) + sim.p.shape)
    sch_c, en_c, flt_c, active_c, p_c, keys_c = flatten_cells(
        scheduler, energy, keys, n_scenarios=n_scenarios, active=active, p=p,
        faults=faults)
    cell_shards = mesh.shape[cell_ax] if cell_ax is not None else 1
    (sch_c, en_c, flt_c, active_c, p_c, keys_c), _ = pad_cells(
        (sch_c, en_c, flt_c, active_c, p_c, keys_c), n_cells, cell_shards)
    args = (sch_c, en_c, flt_c, active_c, p_c, params0, keys_c)
    multiprocess = mesh_process_count(mesh) > 1
    if multiprocess:
        args = replicate_to_mesh(args, mesh)
    out = _run_group_sharded(*args, sim=sim, num_steps=num_steps,
                             eval_fn=eval_fn, eval_every=eval_every,
                             mesh=mesh, reduction=reduction,
                             replicate_out=multiprocess)
    if multiprocess:
        out = fetch_to_host(out)
    return jax.tree_util.tree_map(
        lambda x: x[:n_cells].reshape((n_scenarios, r) + x.shape[1:]), out)
