"""Pure-jnp oracle for blockwise attention (causal / sliding window / GQA)."""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, H, S, Dh); k, v: (B, Hkv, T, Dh) -> (B, H, S, Dh)."""
    b, h, s, dh = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32) * (dh ** -0.5)
    qf = qf.reshape(b, hkv, g, s, dh)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qf, k.astype(jnp.float32))
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, s, dh).astype(q.dtype)
