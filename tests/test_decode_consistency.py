"""Decode path == train path: token-by-token decode must reproduce the
teacher-forced forward logits for every block family (the strongest
correctness invariant — exercises KV caches, ring buffers, SSM states,
conv states and the shared-block cache plumbing at once)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import (
    decode_step,
    encode,
    forward,
    init_decode_state,
    init_lm,
)
from repro.models.transformer import decode_cache_len

B, S = 2, 12

CASES = {
    "dense": ArchConfig(name="t", arch_type="dense", n_layers=2, d_model=32,
                        n_heads=4, n_kv_heads=2, d_ff=64, vocab=61),
    "swa": ArchConfig(name="t", arch_type="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=61,
                      sliding_window=5),
    "moe": ArchConfig(name="t", arch_type="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab=61,
                      n_experts=4, top_k=2, moe_capacity_factor=4.0),
    "mamba2": ArchConfig(name="t", arch_type="ssm", n_layers=2, d_model=32,
                         n_heads=4, n_kv_heads=4, d_ff=0, vocab=61,
                         ssm_state=8, ssm_head_dim=8, gla_chunk=4,
                         superblock=(("mamba2", 2, False),)),
    "mlstm": ArchConfig(name="t", arch_type="ssm", n_layers=2, d_model=32,
                        n_heads=2, n_kv_heads=2, d_ff=0, vocab=61,
                        gla_chunk=4, superblock=(("mlstm", 2, False),)),
    "slstm": ArchConfig(name="t", arch_type="ssm", n_layers=2, d_model=32,
                        n_heads=2, n_kv_heads=2, d_ff=64, vocab=61,
                        slstm_heads=2, superblock=(("slstm", 2, False),)),
    "hybrid_shared": ArchConfig(
        name="t", arch_type="hybrid", n_layers=4, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=61, ssm_state=8, ssm_head_dim=8,
        gla_chunk=4, superblock=(("mamba2", 1, False), ("attn_mlp", 1, True)),
        n_super=2),
    "whisper": ArchConfig(
        name="t", arch_type="audio", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=61, enc_dec=True, n_enc_layers=1,
        enc_len=6, pos_embed="sinusoidal", norm="layernorm", act="gelu",
        use_bias=True, gated_mlp=False, superblock=(("xattn", 2, False),)),
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_decode_matches_forward(case):
    cfg = CASES[case]
    key = jax.random.PRNGKey(42)
    params = init_lm(key, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    fwd_kwargs = {}
    memory = None
    if cfg.enc_dec:
        feats = jax.random.normal(jax.random.PRNGKey(2),
                                  (B, cfg.enc_len, cfg.d_model)) * 0.2
        fwd_kwargs["audio_feats"] = feats
        memory = encode(params, cfg, feats)
    ref_logits, _ = forward(params, cfg, tokens, **fwd_kwargs)

    cache_len = decode_cache_len(cfg, S)
    states = init_decode_state(cfg, B, cache_len)
    step = jax.jit(
        lambda p, t, s, pos: decode_step(p, cfg, t, s, pos, memory=memory))
    for t in range(S):
        logits, states = step(params, tokens[:, t:t + 1], states,
                              jnp.asarray(t))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(ref_logits[:, t], np.float32),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{case}: divergence at position {t}")


def test_vlm_decode_after_vision_prefix():
    """VLM: forward with a vision prefix vs decode continuing after it."""
    cfg = ArchConfig(name="t", arch_type="vlm", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=61,
                     m_rope=True, mrope_sections=(1, 1, 2),
                     n_vision_tokens=3)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    vis = jax.random.normal(jax.random.PRNGKey(2), (B, 3, cfg.d_model)) * 0.2
    ref, _ = forward(params, cfg, tokens, vision_embeds=vis)
    assert ref.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(ref.astype(jnp.float32))))
