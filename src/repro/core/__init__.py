"""Core of the reproduction: the paper's contribution.

* :mod:`repro.core.energy` — energy-arrival processes E_i^t (§II-B)
* :mod:`repro.core.scheduling` — Algorithm 1 / 2 + paper benchmarks (§III, §V)
* :mod:`repro.core.aggregation` — unbiased scaled server aggregation (eq. 11/12)
* :mod:`repro.core.convergence` — Theorem 1 / Corollary 1 constants & bounds
* :mod:`repro.core.trainer` — EnergyAwareTrainer (simulator + SPMD step)
"""

from repro.core.energy import (
    Arrivals,
    BinaryArrivals,
    DayNightArrivals,
    DeterministicArrivals,
    UniformArrivals,
    arrival_family_names,
    client_keys,
    client_randint,
    client_uniform,
    expected_participation,
    make_arrivals,
    pad_arrivals,
    register_arrival_family,
)
from repro.core.scheduling import (
    AlwaysOnScheduler,
    BatteryAdaptiveScheduler,
    BestEffortScheduler,
    Decision,
    EHAppointmentScheduler,
    WaitForAllScheduler,
    make_scheduler,
    mask_arrivals,
    pad_scheduler,
    register_scheduler,
    scheduler_names,
)
from repro.core.faults import (
    CompositeFault,
    CorruptGradients,
    DropUpdates,
    OfflineWindows,
    StaleUpdates,
    fault_family_names,
    make_fault,
    pad_faults,
    register_fault_family,
)
from repro.core.aggregation import (
    RavelSpec,
    aggregate_client_grads,
    compose_masks,
    aggregate_client_grads_flat,
    aggregate_client_grads_kernel,
    aggregate_client_grads_kernel_per_leaf,
    client_weights,
    per_example_coefficients,
    ravel_pytree,
    ravel_spec,
    ravel_stacked,
    reduce_flat,
    server_update,
    unravel_pytree,
)
from repro.core.convergence import (
    QuadraticProblem,
    biased_fixed_point,
    error_floor,
    make_quadratic,
    max_step_size,
    theorem1_bound,
    variance_constant,
)
from repro.core.trainer import ClientSimulator, build_energy_train_step

__all__ = [
    "Arrivals", "BinaryArrivals", "DayNightArrivals", "DeterministicArrivals",
    "UniformArrivals",
    "arrival_family_names", "client_keys", "client_randint",
    "client_uniform", "expected_participation", "make_arrivals",
    "pad_arrivals", "register_arrival_family",
    "AlwaysOnScheduler", "BatteryAdaptiveScheduler", "BestEffortScheduler",
    "Decision",
    "EHAppointmentScheduler", "WaitForAllScheduler", "make_scheduler",
    "mask_arrivals", "pad_scheduler", "register_scheduler",
    "scheduler_names",
    "CompositeFault", "CorruptGradients", "DropUpdates", "OfflineWindows",
    "StaleUpdates", "fault_family_names", "make_fault", "pad_faults",
    "register_fault_family",
    "RavelSpec", "aggregate_client_grads", "aggregate_client_grads_flat",
    "compose_masks",
    "aggregate_client_grads_kernel", "aggregate_client_grads_kernel_per_leaf",
    "client_weights",
    "per_example_coefficients", "ravel_pytree", "ravel_spec", "ravel_stacked",
    "reduce_flat", "server_update", "unravel_pytree",
    "QuadraticProblem", "biased_fixed_point", "error_floor", "make_quadratic",
    "max_step_size", "theorem1_bound", "variance_constant",
    "ClientSimulator", "build_energy_train_step",
]
