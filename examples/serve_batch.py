"""Study-as-a-service walkthrough: one compiled trace serves a mixed batch.

Eight clients submit serialized Study manifests concurrently — all the
same scheduler × arrival structure but *different population sizes* —
to a background StudyService. The service batches them into a single
structure-grouped dispatch, so the whole burst compiles exactly one
trace (the PR 4 padding invariant, applied across requests), and a
repeat submission afterwards is a pure executable-cache hit: zero new
compiles.

    PYTHONPATH=src python examples/serve_batch.py
"""

import jax
import jax.numpy as jnp

from repro.core.convergence import make_quadratic
from repro.experiments import Study
from repro.optim import sgd
from repro.serve import BackgroundServer, StudyService

CAPACITY = 8
DIM = 8
POPULATIONS = [3, 4, 5, 6, 7, 8, 3, 5]  # 8 requests, 6 distinct sizes


def make_manifest(i: int, n_clients: int) -> str:
    """One client's request: same structure every time, its own N."""
    study = (Study(f"client{i}", num_steps=80)
             .axis("scheduler", "alg2")
             .axis("arrivals", "binary")
             .axis("n_clients", n_clients)
             .axis("seeds", [0, 1, 2, 3]))
    return study.to_json()


def main():
    prob = make_quadratic(jax.random.PRNGKey(0), CAPACITY, dim=DIM)
    service = StudyService(
        grads_fn=lambda w, k, t: prob.all_grads(w), p=prob.p,
        optimizer=sgd(0.05), loss_fn=prob.suboptimality,
        params0=jnp.zeros(DIM), cache_size=16)

    manifests = [make_manifest(i, n) for i, n in enumerate(POPULATIONS)]
    print(f"submitting {len(manifests)} manifests, populations "
          f"{POPULATIONS}, capacity N_cap={CAPACITY}\n")

    with BackgroundServer(service) as _server:
        rids = [service.submit(m) for m in manifests]
        responses = [service.wait(rid, timeout=300) for rid in rids]

    for resp in responses:
        rec = resp.records[0]
        print(f"  {resp.request_id} {resp.study:>8}  N={rec['n_clients']}  "
              f"metric={rec['mean']:.4e}  "
              f"latency={resp.timings['latency_us'] / 1e3:8.1f} ms  "
              f"quarantined={resp.quarantined}")

    stats = service.stats()
    batch = responses[0].batch
    print(f"\nbatched {batch['requests']} requests / {batch['cells']} cells "
          f"into {batch['dispatches']} structure dispatch(es)")
    print(f"compiles={stats['compiles']} "
          f"(one trace for all {len(set(POPULATIONS))} population sizes), "
          f"executable entries={stats['executable_entries']}")
    assert stats["compiles"] == 1, "mixed batch should compile once"

    # Repeat traffic: the identical manifest set again -> the executable
    # cache serves the stored runner and its compiled trace, zero new
    # compiles.
    for m in manifests:
        service.submit(m)
    service.flush()
    again = service.stats()
    print(f"repeat submission: compiles={again['compiles']} (unchanged), "
          f"cache hits={again['hits']}")
    assert again["compiles"] == stats["compiles"]
    return responses


if __name__ == "__main__":
    main()
