"""Scheduler tests: Algorithm 1/2 + paper benchmarks."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import BinaryArrivals, DeterministicArrivals
from repro.core.scheduling import make_scheduler, scheduler_names


def run(scheduler, process, horizon, seed=0):
    key = jax.random.PRNGKey(seed)
    sstate = scheduler.init(key)
    estate = process.init(key)

    def body(carry, t):
        sstate, estate, key = carry
        key, k1, k2 = jax.random.split(key, 3)
        estate, arr = process.arrivals(estate, t, k1)
        sstate, dec = scheduler.step(sstate, t, k2, arr)
        return (sstate, estate, key), (dec.mask, dec.scale)

    _, (mask, scale) = jax.lax.scan(
        body, (sstate, estate, key), jnp.arange(horizon))
    return np.asarray(mask), np.asarray(scale)


def test_alg1_participation_rate_is_inverse_gap():
    taus = [1, 5, 10, 20]
    det = DeterministicArrivals.periodic(taus, horizon=4000)
    sch = make_scheduler("alg1", 4)
    mask, scale = run(sch, det, 4000)
    np.testing.assert_allclose(mask.mean(0), 1.0 / np.asarray(taus),
                               atol=0.01)
    # scale equals the gap captured at booking (tau for periodic)
    for i, tau in enumerate(taus):
        on = mask[:, i] > 0
        np.testing.assert_allclose(scale[on, i], tau)


def test_alg1_exactly_one_participation_per_interval():
    tau = 6
    det = DeterministicArrivals.periodic([tau], horizon=6 * 50)
    sch = make_scheduler("alg1", 1)
    mask, _ = run(sch, det, 6 * 50, seed=4)
    per_interval = mask[:, 0].reshape(-1, tau).sum(1)
    np.testing.assert_array_equal(per_interval, 1.0)


def test_benchmark1_is_unscaled_arrivals():
    det = DeterministicArrivals.periodic([3], horizon=30)
    sch = make_scheduler("benchmark1", 1)
    mask, scale = run(sch, det, 30)
    np.testing.assert_array_equal(mask[:, 0],
                                  (np.arange(30) % 3 == 0).astype(float))
    np.testing.assert_array_equal(scale, 1.0)


def test_benchmark2_fires_at_slowest_period():
    det = DeterministicArrivals.periodic([1, 5, 10, 20], horizon=100)
    sch = make_scheduler("benchmark2", 4)
    mask, scale = run(sch, det, 100)
    fires = np.flatnonzero(mask[:, 0])
    # all clients step together, once per 20 iterations (paper §V)
    np.testing.assert_array_equal(mask[fires].min(1), 1.0)
    assert len(fires) == 5
    assert np.all(np.diff(fires) == 20)
    np.testing.assert_array_equal(scale, 1.0)


def test_alg2_scaling_matches_gamma():
    betas = jnp.asarray([0.25, 0.5])
    proc = BinaryArrivals(betas)
    sch = make_scheduler("alg2", 2)
    mask, scale = run(sch, proc, 2000)
    np.testing.assert_allclose(mask.mean(0), betas, atol=0.04)
    np.testing.assert_allclose(scale[0], [4.0, 2.0])


def test_oracle_always_on():
    det = DeterministicArrivals.periodic([20], horizon=10)
    sch = make_scheduler("oracle", 1)
    mask, scale = run(sch, det, 10)
    np.testing.assert_array_equal(mask, 1.0)
    np.testing.assert_array_equal(scale, 1.0)


def test_registry():
    assert set(scheduler_names()) == {
        "alg1", "alg2", "benchmark1", "benchmark2", "oracle",
        "battery_adaptive"}
