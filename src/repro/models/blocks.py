"""Residual blocks — the composable units the stacks scan over.

Each block kind provides:

    init_<kind>(key, cfg)                     -> params
    apply_<kind>(params, x, ctx)              -> (x, aux)
    state_<kind>(cfg, batch, cache_len, dtype)-> decode state (or None)
    decode_<kind>(params, x, state, pos, ctx) -> (x, state)

``ctx`` is a dict with: positions, memory (enc-dec), window, use_flash.
``cfg`` is an :class:`repro.configs.base.ArchConfig`. Registered in
``BLOCKS`` so stacks are built from ``cfg.superblock`` declaratively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.attention import (
    attention,
    decode_attention,
    init_attention,
    init_kv_cache,
)
from repro.models.common import (
    activation,
    apply_norm,
    dense,
    dense_init,
    maybe_shard,
    norm_init,
)
from repro.models.moe import apply_moe, init_moe


# ------------------------------------------------------------------- MLP

def init_mlp(key, d_model, d_ff, dtype, use_bias=False, gated=True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": dense_init(k2, d_model, d_ff, dtype, use_bias),
         "down": dense_init(k3, d_ff, d_model, dtype, use_bias)}
    if gated:
        p["gate"] = dense_init(k1, d_model, d_ff, dtype, use_bias)
    return p


def apply_mlp(params, x, act="silu"):
    act_fn = activation(act)
    h = dense(params["up"], x)
    if "gate" in params:
        h = act_fn(dense(params["gate"], x)) * h
    else:
        h = act_fn(h)
    h = maybe_shard(h, ("pod", "data"), None, "model")
    return dense(params["down"], h)


def _attn_kwargs(cfg):
    return dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                m_rope=cfg.m_rope, mrope_sections=cfg.mrope_sections)


def _decode_attn_kwargs(cfg):
    # decode applies rotary internally at `pos`; skip it for sinusoidal /
    # no-pos configs (whisper) — the train path gets positions=None there.
    return dict(_attn_kwargs(cfg), use_rope=(cfg.pos_embed == "rope"))


# --------------------------------------------------------------- attn_mlp

def init_attn_mlp(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.resolved_head_dim, cfg.dtype, cfg.use_bias),
        "ln2": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.dtype, cfg.use_bias,
                        gated=cfg.gated_mlp),
    }


def apply_attn_mlp(params, x, ctx, cfg, causal=True):
    h = apply_norm(params["ln1"], x, cfg.norm)
    h = attention(params["attn"], h, positions=ctx.get("positions"),
                  causal=causal, window=ctx.get("window", 0),
                  use_flash=ctx.get("use_flash", False), **_attn_kwargs(cfg))
    x = x + h
    h = apply_norm(params["ln2"], x, cfg.norm)
    x = x + apply_mlp(params["mlp"], h, act=cfg.act)
    return x, jnp.zeros((), jnp.float32)


def state_attn_mlp(cfg, batch, cache_len, dtype):
    return init_kv_cache(batch, cfg.n_kv_heads, cfg.resolved_head_dim,
                         cache_len, dtype)


def decode_attn_mlp(params, x, state, pos, ctx, cfg):
    h = apply_norm(params["ln1"], x, cfg.norm)
    h, state = decode_attention(params["attn"], h, state, pos,
                                window=ctx.get("window", 0),
                                **_decode_attn_kwargs(cfg))
    x = x + h
    h = apply_norm(params["ln2"], x, cfg.norm)
    x = x + apply_mlp(params["mlp"], h, act=cfg.act)
    return x, state


# --------------------------------------------------------------- attn_moe

def init_attn_moe(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.resolved_head_dim, cfg.dtype, cfg.use_bias),
        "ln2": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        "moe": init_moe(k2, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.dtype,
                        cfg.use_bias, shared_expert=cfg.shared_expert),
    }


def apply_attn_moe(params, x, ctx, cfg):
    h = apply_norm(params["ln1"], x, cfg.norm)
    h = attention(params["attn"], h, positions=ctx.get("positions"),
                  causal=True, window=ctx.get("window", 0),
                  use_flash=ctx.get("use_flash", False), **_attn_kwargs(cfg))
    x = x + h
    h = apply_norm(params["ln2"], x, cfg.norm)
    y, aux = apply_moe(params["moe"], h, n_experts=cfg.n_experts,
                       top_k=cfg.top_k, act=cfg.act,
                       capacity_factor=cfg.moe_capacity_factor,
                       shared_expert=cfg.shared_expert)
    return x + y, aux


state_attn_moe = state_attn_mlp


def decode_attn_moe(params, x, state, pos, ctx, cfg):
    h = apply_norm(params["ln1"], x, cfg.norm)
    h, state = decode_attention(params["attn"], h, state, pos,
                                window=ctx.get("window", 0),
                                **_decode_attn_kwargs(cfg))
    x = x + h
    h = apply_norm(params["ln2"], x, cfg.norm)
    y, _ = apply_moe(params["moe"], h, n_experts=cfg.n_experts,
                     top_k=cfg.top_k, act=cfg.act,
                     capacity_factor=cfg.moe_capacity_factor,
                     shared_expert=cfg.shared_expert)
    return x + y, state


# ----------------------------------------------------------------- mamba2

def init_mamba2_block(key, cfg):
    return {
        "ln": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        "mixer": ssm.init_mamba2(key, cfg.d_model, cfg.ssm_state, cfg.dtype,
                                 head_dim=cfg.ssm_head_dim),
    }


def apply_mamba2_block(params, x, ctx, cfg):
    h = apply_norm(params["ln"], x, cfg.norm)
    y = ssm.apply_mamba2(params["mixer"], h, d_state=cfg.ssm_state,
                         head_dim=cfg.ssm_head_dim, chunk=cfg.gla_chunk)
    return x + y, jnp.zeros((), jnp.float32)


def state_mamba2_block(cfg, batch, cache_len, dtype):
    del cache_len
    return ssm.init_mamba2_state(batch, cfg.d_model, cfg.ssm_state, dtype,
                                 head_dim=cfg.ssm_head_dim)


def decode_mamba2_block(params, x, state, pos, ctx, cfg):
    del pos
    h = apply_norm(params["ln"], x, cfg.norm)
    y, state = ssm.decode_mamba2(params["mixer"], h, state,
                                 d_state=cfg.ssm_state,
                                 head_dim=cfg.ssm_head_dim)
    return x + y, state


# ------------------------------------------------------------------ mlstm

def init_mlstm_block(key, cfg):
    return {
        "ln": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        "mixer": ssm.init_mlstm(key, cfg.d_model, cfg.n_heads, cfg.dtype),
    }


def apply_mlstm_block(params, x, ctx, cfg):
    h = apply_norm(params["ln"], x, cfg.norm)
    y = ssm.apply_mlstm(params["mixer"], h, n_heads=cfg.n_heads,
                        chunk=cfg.gla_chunk)
    return x + y, jnp.zeros((), jnp.float32)


def state_mlstm_block(cfg, batch, cache_len, dtype):
    del cache_len
    return ssm.init_mlstm_state(batch, cfg.d_model, cfg.n_heads, dtype)


def decode_mlstm_block(params, x, state, pos, ctx, cfg):
    del pos
    h = apply_norm(params["ln"], x, cfg.norm)
    y, state = ssm.decode_mlstm(params["mixer"], h, state, n_heads=cfg.n_heads)
    return x + y, state


# ------------------------------------------------------------------ slstm

def init_slstm_block(key, cfg):
    k1, k2 = jax.random.split(key)
    ff = cfg.slstm_ff or max(64, (4 * cfg.d_model // 3 + 63) // 64 * 64)
    return {
        "ln1": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        "mixer": ssm.init_slstm(k1, cfg.d_model, cfg.slstm_heads, cfg.dtype),
        "ln2": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        "mlp": init_mlp(k2, cfg.d_model, ff, cfg.dtype, cfg.use_bias,
                        gated=False),
    }


def apply_slstm_block(params, x, ctx, cfg):
    h = apply_norm(params["ln1"], x, cfg.norm)
    x = x + ssm.apply_slstm(params["mixer"], h, n_heads=cfg.slstm_heads)
    h = apply_norm(params["ln2"], x, cfg.norm)
    x = x + apply_mlp(params["mlp"], h, act=cfg.act)
    return x, jnp.zeros((), jnp.float32)


def state_slstm_block(cfg, batch, cache_len, dtype):
    del cache_len, dtype
    return ssm.init_slstm_state(batch, cfg.d_model, cfg.slstm_heads)


def decode_slstm_block(params, x, state, pos, ctx, cfg):
    del pos
    h = apply_norm(params["ln1"], x, cfg.norm)
    y, state = ssm.decode_slstm(params["mixer"], h, state,
                                n_heads=cfg.slstm_heads)
    x = x + y
    h = apply_norm(params["ln2"], x, cfg.norm)
    x = x + apply_mlp(params["mlp"], h, act=cfg.act)
    return x, state


# --------------------------------------------------- encoder block (no mask)

def init_enc_attn_mlp(key, cfg):
    return init_attn_mlp(key, cfg)


def apply_enc_attn_mlp(params, x, ctx, cfg):
    return apply_attn_mlp(params, x, ctx, cfg, causal=False)


# --------------------------------------- enc-dec decoder block (whisper)

def init_xattn(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        "self": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.resolved_head_dim, cfg.dtype, cfg.use_bias),
        "ln2": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        "cross": init_attention(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.resolved_head_dim, cfg.dtype, cfg.use_bias),
        "ln3": norm_init(cfg.d_model, cfg.dtype, cfg.norm),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.dtype, cfg.use_bias,
                        gated=cfg.gated_mlp),
    }


def apply_xattn(params, x, ctx, cfg):
    h = apply_norm(params["ln1"], x, cfg.norm)
    x = x + attention(params["self"], h, positions=ctx.get("positions"),
                      causal=True, window=ctx.get("window", 0),
                      use_flash=ctx.get("use_flash", False),
                      **_attn_kwargs(cfg))
    h = apply_norm(params["ln2"], x, cfg.norm)
    x = x + attention(params["cross"], h, kv_override=ctx["memory"],
                      **_attn_kwargs(cfg))
    h = apply_norm(params["ln3"], x, cfg.norm)
    x = x + apply_mlp(params["mlp"], h, act=cfg.act)
    return x, jnp.zeros((), jnp.float32)


def state_xattn(cfg, batch, cache_len, dtype):
    return init_kv_cache(batch, cfg.n_kv_heads, cfg.resolved_head_dim,
                         cache_len, dtype)


def decode_xattn(params, x, state, pos, ctx, cfg):
    h = apply_norm(params["ln1"], x, cfg.norm)
    h, state = decode_attention(params["self"], h, state, pos,
                                window=ctx.get("window", 0),
                                **_decode_attn_kwargs(cfg))
    x = x + h
    h = apply_norm(params["ln2"], x, cfg.norm)
    h, _ = decode_attention(params["cross"], h, None, pos,
                            kv_override=ctx["memory"], **_attn_kwargs(cfg))
    x = x + h
    h = apply_norm(params["ln3"], x, cfg.norm)
    x = x + apply_mlp(params["mlp"], h, act=cfg.act)
    return x, state


# ------------------------------------------------------------------ registry

class BlockDef:
    def __init__(self, init, apply, state=None, decode=None):
        self.init = init
        self.apply = apply
        self.state = state
        self.decode = decode


BLOCKS = {
    "attn_mlp": BlockDef(init_attn_mlp, apply_attn_mlp, state_attn_mlp,
                         decode_attn_mlp),
    "attn_moe": BlockDef(init_attn_moe, apply_attn_moe, state_attn_moe,
                         decode_attn_moe),
    "mamba2": BlockDef(init_mamba2_block, apply_mamba2_block,
                       state_mamba2_block, decode_mamba2_block),
    "mlstm": BlockDef(init_mlstm_block, apply_mlstm_block, state_mlstm_block,
                      decode_mlstm_block),
    "slstm": BlockDef(init_slstm_block, apply_slstm_block, state_slstm_block,
                      decode_slstm_block),
    "enc_attn_mlp": BlockDef(init_enc_attn_mlp, apply_enc_attn_mlp),
    "xattn": BlockDef(init_xattn, apply_xattn, state_xattn, decode_xattn),
}
