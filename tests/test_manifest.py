"""Study/ExecutionConfig manifest round-trips and failure paths.

The serialization contract (DESIGN.md §11): ``to_json -> from_json`` is
an exact identity over every registered scheduler, arrival family, fault
family and sweep axis — and a malformed manifest fails at decode time
with an error that names the registry (and its valid keys) or the
offending key, never deep inside a compiled dispatch.
"""

import dataclasses
import json

import pytest

from repro.core.energy import arrival_family_names
from repro.core.faults import fault_family_names
from repro.core.scheduling import scheduler_names
from repro.experiments import ExecutionConfig, Study, axis_names
from repro.experiments.manifest import (
    EXEC_FORMAT,
    REQUEST_FORMAT,
    STUDY_FORMAT,
    decode_value,
    encode_value,
    request_from_manifest,
    request_to_manifest,
)

pytestmark = pytest.mark.serve


def base_study(**axes) -> Study:
    merged = {"scheduler": "alg1", "arrivals": "periodic",
              "n_clients": 4, "seeds": [0, 1], **axes}
    return Study("t", num_steps=50, axes=merged)


def assert_roundtrip(study: Study) -> Study:
    """from_json(to_json) must reproduce the manifest, the axes (values
    and fixed-ness), the seeds and the resolved cell names exactly."""
    back = Study.from_json(study.to_json())
    assert back.to_manifest() == study.to_manifest()
    assert back.axes == study.axes
    assert back._fixed == study._fixed
    assert back._seed_values() == study._seed_values()
    assert [sc.name for sc in back.resolve()] == \
        [sc.name for sc in study.resolve()]
    return back


# ------------------------------------------------------------- round-trips

@pytest.mark.parametrize("scheduler", scheduler_names())
def test_roundtrip_every_scheduler(scheduler):
    assert_roundtrip(base_study(scheduler=scheduler))


@pytest.mark.parametrize("family", arrival_family_names())
def test_roundtrip_every_arrival_family(family):
    value = (family, {"period": 50}) if family == "day_night" else family
    assert_roundtrip(base_study(arrivals=value))


@pytest.mark.parametrize("family", [None] + fault_family_names())
def test_roundtrip_every_fault_family(family):
    value = (family, {"rate": 0.25}) \
        if family in ("drop", "corrupt", "stale") else family
    assert_roundtrip(base_study(faults=value))


def test_roundtrip_every_builtin_axis_swept():
    """One study sweeping every built-in axis at once."""
    study = base_study(
        scheduler=["alg1", "alg2"],
        arrivals=["periodic", ("day_night", {"period": 20, "contrast": 2.0})],
        capacity=[1.0, 4.0],
        n_clients=[3, 4],
        taus_profile="paper",
        faults=[None, ("drop", {"rate": 0.5})])
    back = assert_roundtrip(study)
    assert len(back.resolve()) == len(study.resolve()) == 32


def test_roundtrip_explicit_taus_vector_stays_tuple():
    study = base_study(taus_profile=(4.0, 8.0, 16.0))
    back = assert_roundtrip(study)
    assert back.axes["taus_profile"] == ((4.0, 8.0, 16.0),)


def test_roundtrip_int_seed_count_and_explicit_list():
    assert Study.from_json(base_study(seeds=5).to_json())._seed_values() \
        == (0, 1, 2, 3, 4)
    assert Study.from_json(base_study(seeds=[7, 3]).to_json())._seed_values() \
        == (7, 3)


def test_roundtrip_fixed_vs_swept_singleton():
    """A 1-element sweep list is NOT a fixed axis: the value appears in
    cell names. The flag must survive the round-trip."""
    fixed = base_study(n_clients=4)
    swept = base_study(n_clients=[4])
    assert "n_clients" in fixed._fixed and "n_clients" not in swept._fixed
    assert_roundtrip(fixed)
    back = assert_roundtrip(swept)
    assert "n4" in back.resolve()[0].name


def test_execution_config_roundtrip():
    cfg = ExecutionConfig(client_reduction="gather", degrade=True,
                          checkpoint_every=25, halt_on_divergence=True)
    assert ExecutionConfig.from_json(cfg.to_json()) == cfg


def test_request_envelope_roundtrip():
    study = base_study()
    cfg = ExecutionConfig(client_reduction="gather")
    doc = request_to_manifest(study, cfg)
    assert doc["format"] == REQUEST_FORMAT
    back_study, back_cfg = request_from_manifest(
        json.loads(json.dumps(doc)))
    assert back_study.to_manifest() == study.to_manifest()
    assert back_cfg == cfg
    # bare study envelope is also an accepted request
    s2, c2 = request_from_manifest(study.to_manifest())
    assert s2.to_manifest() == study.to_manifest() and c2 is None


# ------------------------------------------------------------ failure paths

def _mangle(study: Study, axis: str, value):
    doc = study.to_manifest()
    for entry in doc["axes"]:
        if entry["axis"] == axis:
            entry["values"] = [encode_value(value)]
    return doc


def test_unknown_scheduler_names_registry():
    with pytest.raises(ValueError, match=r"scheduler registry has.*alg1"):
        Study.from_manifest(_mangle(base_study(), "scheduler", "sgd_magic"))


def test_unknown_arrival_family_names_registry():
    with pytest.raises(ValueError,
                       match=r"arrival-family registry has.*periodic"):
        Study.from_manifest(_mangle(base_study(), "arrivals", "solar"))


def test_unknown_fault_family_names_registry():
    study = base_study(faults="drop")
    with pytest.raises(ValueError, match=r"fault-family registry has.*drop"):
        Study.from_manifest(_mangle(study, "faults", "gamma_ray"))


def test_unknown_taus_profile_names_registry():
    study = base_study(taus_profile="paper")
    with pytest.raises(ValueError,
                       match=r"taus-profile registry has.*paper"):
        Study.from_manifest(_mangle(study, "taus_profile", "lunar"))


def test_unknown_axis_names_axis_registry():
    doc = base_study().to_manifest()
    doc["axes"].append({"axis": "warp_factor", "values": [9]})
    with pytest.raises(ValueError, match=r"unknown sweep axis 'warp_factor'"):
        Study.from_manifest(doc)
    # the error lists the registered axes
    with pytest.raises(ValueError, match=r"scheduler"):
        Study.from_manifest(doc)
    assert "scheduler" in axis_names()


def test_wrong_schema_version_rejected():
    doc = base_study().to_manifest()
    doc["format"] = "study/v2"
    with pytest.raises(ValueError,
                       match=rf"unsupported format 'study/v2'.*{STUDY_FORMAT}"):
        Study.from_manifest(doc)


def test_truncated_json_rejected():
    text = base_study().to_json()
    with pytest.raises(ValueError, match=r"not valid JSON"):
        Study.from_json(text[: len(text) // 2])


def test_unknown_manifest_key_rejected():
    doc = base_study().to_manifest()
    doc["stepz"] = 10
    with pytest.raises(ValueError, match=r"unknown key.*stepz.*valid keys"):
        Study.from_manifest(doc)


def test_empty_axis_values_rejected():
    doc = base_study().to_manifest()
    doc["axes"][0]["values"] = []
    with pytest.raises(ValueError, match=r"empty values"):
        Study.from_manifest(doc)


def test_live_execution_config_fields_not_serializable():
    cfg = ExecutionConfig(eval_fn=lambda p: p)
    with pytest.raises(ValueError, match=r"eval_fn holds a live object"):
        cfg.to_manifest()


def test_execution_config_unknown_key_rejected():
    doc = ExecutionConfig().to_manifest()
    doc["warp"] = 9
    with pytest.raises(ValueError, match=r"unknown key.*warp.*valid keys"):
        ExecutionConfig.from_manifest(doc)
    assert "mesh" not in doc  # live fields never serialize


def test_unserializable_value_names_location():
    with pytest.raises(ValueError, match=r"axis 'taus_profile'"):
        encode_value(lambda n: n, where="axis 'taus_profile'")


def test_tuple_tag_is_reserved():
    with pytest.raises(ValueError, match=r"__tuple__.*reserved"):
        encode_value({"__tuple__": [1]})


def test_codec_tuple_vs_list_distinction():
    v = ("day_night", {"period": 50, "xs": [1, 2]})
    assert decode_value(json.loads(json.dumps(encode_value(v)))) == v
    assert decode_value(encode_value([1, 2])) == [1, 2]
