"""Scenario engine tests: pytree contracts, grid ≡ per-cell equivalence,
and the one-trace-per-group compile-count guarantee."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_quadratic, make_scheduler, scheduler_names
from repro.core.energy import (
    BinaryArrivals,
    DayNightArrivals,
    DeterministicArrivals,
    UniformArrivals,
    expected_participation,
)
from repro.core.trainer import ClientSimulator
from repro.experiments import (
    Scenario,
    get_grid,
    grid_names,
    make_energy_process,
    run_grid,
    run_grid_sequential,
)
from repro.experiments import engine
from repro.optim import sgd


def all_processes():
    return [
        DeterministicArrivals.periodic([1, 4, 8], horizon=32),
        BinaryArrivals([0.2, 0.5, 1.0]),
        UniformArrivals([2, 5, 9]),
        DayNightArrivals.from_taus([1, 4, 8], period=10),
    ]


def all_schedulers():
    return [make_scheduler(name, 3) for name in scheduler_names()]


# ------------------------------------------------------------ pytree laws

@pytest.mark.parametrize("obj", all_processes() + all_schedulers(),
                         ids=lambda o: type(o).__name__)
def test_components_roundtrip_tree_flatten(obj):
    leaves, treedef = jax.tree_util.tree_flatten(obj)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(rebuilt) is type(obj)
    assert jax.tree_util.tree_structure(rebuilt) == treedef
    for a, b in zip(leaves, jax.tree_util.tree_leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("process", all_processes(),
                         ids=lambda o: type(o).__name__)
def test_process_passes_through_jit(process):
    """An energy process is an ordinary jit argument (no static closure)."""

    @jax.jit
    def first_arrivals(proc, key):
        state = proc.init(key)
        _, arr = proc.arrivals(state, jnp.asarray(0), key)
        return arr.energy, arr.gap, proc.expected_participation()

    energy, gap, part = first_arrivals(process, jax.random.PRNGKey(0))
    assert energy.shape == gap.shape == (3,)
    np.testing.assert_allclose(part, expected_participation(process))


@pytest.mark.parametrize("scheduler", all_schedulers(),
                         ids=lambda o: type(o).__name__)
def test_scheduler_passes_through_jit(scheduler):
    proc = BinaryArrivals([0.5, 0.5, 0.5])

    @jax.jit
    def one_step(sch, en, key):
        sstate, estate = sch.init(key), en.init(key)
        estate, arr = en.arrivals(estate, jnp.asarray(0), key)
        sstate, dec = sch.step(sstate, jnp.asarray(0), key, arr)
        return dec.mask, dec.scale

    mask, scale = one_step(scheduler, proc, jax.random.PRNGKey(1))
    assert mask.shape == scale.shape == (3,)


def test_no_isinstance_dispatch_for_unknown_process():
    class Custom:
        def expected_participation(self):
            return jnp.asarray([0.25])

    np.testing.assert_allclose(expected_participation(Custom()), [0.25])
    with pytest.raises(TypeError, match="protocol"):
        expected_participation(object())


def test_stacked_expected_participation_batches():
    """expected_participation() follows the trailing-axis convention, so
    a scenario-stacked process yields an (S, N) participation matrix."""
    procs = [DeterministicArrivals.periodic([1, 2, 4], horizon=8),
             DeterministicArrivals.periodic([2, 4, 8], horizon=8)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *procs)
    part = stacked.expected_participation()
    assert part.shape == (2, 3)
    np.testing.assert_allclose(
        part, np.stack([p.expected_participation() for p in procs]))


def test_scheduler_registry_rejects_unknown_kwargs():
    """Regression: extra kwargs used to be silently swallowed for every
    scheduler but battery_adaptive — a Scenario with typo'd (or
    identity-changing, e.g. scaled=False) scheduler_kwargs would run a
    different algorithm than requested."""
    with pytest.raises(TypeError, match="alg2.*scaled"):
        make_scheduler("alg2", 3, scaled=False)
    with pytest.raises(TypeError, match="capcity|capacity"):
        make_scheduler("oracle", 3, capcity=2.0)
    # battery_adaptive legitimately takes hyperparameters …
    assert float(make_scheduler("battery_adaptive", 3, capacity=4.0).capacity) == 4.0
    # … but still rejects typos via the dataclass constructor.
    with pytest.raises(TypeError):
        make_scheduler("battery_adaptive", 3, capcity=4.0)


def test_battery_capacity_sweep_stacks_leafwise():
    """Array hyperparameters are leaves: a capacity sweep is one stacked
    scheduler pytree, vmappable in a single computation."""
    scheds = [make_scheduler("battery_adaptive", 3, capacity=c)
              for c in (1.0, 2.0, 4.0)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *scheds)
    np.testing.assert_allclose(np.asarray(stacked.capacity), [1.0, 2.0, 4.0])

    proc = BinaryArrivals([0.5, 0.5, 0.5])

    def mean_mask(sch):
        def body(carry, t):
            sstate, estate, key = carry
            key, k1, k2 = jax.random.split(key, 3)
            estate, arr = proc.arrivals(estate, t, k1)
            sstate, dec = sch.step(sstate, t, k2, arr)
            return (sstate, estate, key), dec.mask

        key = jax.random.PRNGKey(0)
        init = (sch.init(key), proc.init(key), key)
        _, masks = jax.lax.scan(body, init, jnp.arange(200))
        return masks.mean()

    rates = jax.vmap(mean_mask)(stacked)
    # Energy conservation: participation rate ≈ arrival rate ∀ capacity.
    np.testing.assert_allclose(np.asarray(rates), 0.5, atol=0.1)


# ------------------------------------------------------- scenario registry

def test_registry_grids():
    assert {"fig1", "fig1_grid", "capacity_sweep"} <= set(grid_names())
    scens = get_grid("fig1_grid", n_clients=4, horizon=11)
    assert len(scens) == 12
    names = [s.name for s in scens]
    assert len(set(names)) == len(names)
    for sc in scens:
        scheduler, process = sc.build()
        assert scheduler.n_clients == 4
        assert process.n_clients == 4


def test_make_energy_process_kinds():
    det = make_energy_process("periodic", 4, 21)
    # Arrivals at multiples of τ inside [0, 21): 21, 5, 3, 2 of them.
    np.testing.assert_allclose(expected_participation(det),
                               [1.0, 5 / 21, 3 / 21, 2 / 21])
    binary = make_energy_process("binary", 4, 21)
    np.testing.assert_allclose(expected_participation(binary),
                               [1.0, 0.2, 0.1, 0.05])
    uniform = make_energy_process("uniform", 4, 21)
    np.testing.assert_allclose(expected_participation(uniform),
                               [1.0, 0.2, 0.1, 0.05])
    day_night = make_energy_process("day_night", 4, 21, period=20)
    np.testing.assert_allclose(expected_participation(day_night),
                               [1.0, 0.2, 0.1, 0.05], rtol=1e-6)
    with pytest.raises(ValueError):
        make_energy_process("fluvial", 4, 21)


# ------------------------------------------------------------ grid engine

@pytest.fixture(scope="module")
def problem():
    return make_quadratic(jax.random.PRNGKey(2), n_clients=6, dim=5,
                          hetero=1.0)


def _grid_kwargs(problem, steps):
    return dict(
        grads_fn=lambda p, k, t: problem.all_grads(p, key=k, noise=0.05),
        p=problem.p, optimizer=sgd(0.02),
        params0=jnp.full((5,), 4.0), num_steps=steps,
        loss_fn=problem.suboptimality)


def test_run_grid_matches_single_seed_runs(problem):
    """run_grid over seeds ≡ a loop of single-seed ClientSimulator.run
    calls given the same per-seed PRNG keys (float32 tolerance)."""
    steps, seeds = 150, [0, 1, 2, 3]
    scenarios = [
        Scenario("alg1_periodic", "alg1", "periodic", 6, steps + 1),
        Scenario("alg2_binary", "alg2", "binary", 6, steps + 1),
        Scenario("b2_uniform", "benchmark2", "uniform", 6, steps + 1),
    ]
    kw = _grid_kwargs(problem, steps)
    grid = run_grid(scenarios, seeds=seeds, **kw)

    sim = ClientSimulator(grads_fn=kw["grads_fn"], p=kw["p"],
                          optimizer=kw["optimizer"], loss_fn=kw["loss_fn"])
    for sc in scenarios:
        scheduler, energy = sc.build()
        for r, seed in enumerate(seeds):
            w, hist = sim.run(jax.random.PRNGKey(seed), kw["params0"], steps,
                              scheduler=scheduler, energy=energy)
            cell = grid[sc.name]
            np.testing.assert_allclose(
                np.asarray(cell.params[r]), np.asarray(w),
                rtol=2e-4, atol=1e-5)
            np.testing.assert_allclose(
                np.asarray(cell.history.loss[r]), np.asarray(hist.loss),
                rtol=2e-4, atol=1e-5)
            np.testing.assert_array_equal(
                np.asarray(cell.history.participation[r]),
                np.asarray(hist.participation))


def test_run_grid_compiles_once_per_group(problem):
    """4 schedulers × 2 arrival kinds × 6 seeds = 48 cells must trace the
    batched runner exactly once per (scheduler, energy) structure."""
    steps = 40
    scenarios = [
        Scenario(f"{s}_{a}", s, a, 6, steps + 1)
        for s in ("alg1", "benchmark1", "benchmark2", "oracle")
        for a in ("periodic", "binary")
    ]
    before = engine._run_group._cache_size()
    run_grid(scenarios, seeds=6, **_grid_kwargs(problem, steps))
    after = engine._run_group._cache_size()
    assert after - before == len(scenarios)  # == #groups, NOT #cells (×6 seeds)


def test_run_grid_groups_share_one_trace(problem):
    """Scenarios with identical component structure share a single trace:
    two same-kind cells differing only in hyperparameter *values*."""
    steps = 40
    scenarios = [
        Scenario("fast", "alg2", "binary", 6, steps + 1, taus=[1, 2, 2, 4, 4, 8]),
        Scenario("slow", "alg2", "binary", 6, steps + 1, taus=[2, 4, 4, 8, 8, 16]),
    ]
    before = engine._run_group._cache_size()
    res = run_grid(scenarios, seeds=3, **_grid_kwargs(problem, steps))
    after = engine._run_group._cache_size()
    assert after - before == 1
    assert set(res) == {"fast", "slow"}
    # Different β values really flowed through the shared trace.
    fast = np.asarray(res["fast"].history.participation).mean()
    slow = np.asarray(res["slow"].history.participation).mean()
    assert fast > slow


def test_run_grid_matches_sequential_baseline(problem):
    steps = 100
    scenarios = get_grid("fig1", n_clients=6, horizon=steps + 1)
    kw = _grid_kwargs(problem, steps)
    batched = run_grid(scenarios, seeds=3, **kw)
    sequential = run_grid_sequential(scenarios, seeds=3, **kw)
    assert set(batched) == set(sequential)
    for name in batched:
        np.testing.assert_allclose(
            np.asarray(batched[name].history.loss),
            np.asarray(sequential[name].history.loss),
            rtol=2e-4, atol=1e-5)


def test_run_grid_eval_chunking(problem):
    """eval_fn runs inside the compiled loop every eval_every steps and
    the chunked history is identical to the unchunked one."""
    steps = 60
    scenarios = [Scenario("alg1_periodic", "alg1", "periodic", 6, steps + 1)]
    kw = _grid_kwargs(problem, steps)
    with_eval = run_grid(scenarios, seeds=2, eval_fn=problem.suboptimality,
                         eval_every=20, **kw)
    plain = run_grid(scenarios, seeds=2, **kw)
    cell = with_eval["alg1_periodic"]
    assert cell.evals.shape == (2, 3)  # (seeds, num_steps // eval_every)
    np.testing.assert_allclose(
        np.asarray(cell.history.loss), np.asarray(plain["alg1_periodic"].history.loss),
        rtol=2e-4, atol=1e-5)
    # Eval at chunk k == logged loss at step (k+1)*eval_every − 1 (both
    # computed from the post-update params of that step).
    np.testing.assert_allclose(
        np.asarray(cell.evals),
        np.asarray(cell.history.loss[:, 19::20]), rtol=1e-5, atol=1e-6)


def test_run_grid_reuses_prebuilt_sim_trace(problem):
    """A prebuilt sim= makes repeated identical grids hit the jit cache
    (fresh per-call simulators would re-trace every group)."""
    steps = 30
    scenarios = [Scenario("alg1_periodic", "alg1", "periodic", 6, steps + 1)]
    kw = _grid_kwargs(problem, steps)
    sim = ClientSimulator(grads_fn=kw["grads_fn"], p=kw["p"],
                          optimizer=kw["optimizer"], loss_fn=kw["loss_fn"])
    run_grid(scenarios, seeds=2, sim=sim, params0=kw["params0"],
             num_steps=steps)
    before = engine._run_group._cache_size()
    out = run_grid(scenarios, seeds=2, sim=sim, params0=kw["params0"],
                   num_steps=steps)
    assert engine._run_group._cache_size() == before  # cache hit, no re-trace
    assert "alg1_periodic" in out


def test_run_grid_rejects_duplicate_names(problem):
    steps = 10
    scens = [Scenario("dup", "alg1", "periodic", 6, steps + 1)] * 2
    with pytest.raises(ValueError, match="unique"):
        run_grid(scens, seeds=2, **_grid_kwargs(problem, steps))
