"""The paper's experiment model: the McMahan et al. CIFAR CNN (~10⁶ params).

Paper §V: "the convolutional neural network architecture from [25]
(about 10^6 model parameters)" — two 5×5 conv layers (32, 64 channels)
with 2×2 max-pool, then dense 64 → 10 head. Implemented with
``lax.conv_general_dilated`` (NHWC).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init, normal_init


def init_cnn(key, *, in_channels=3, n_classes=10, image_hw=32,
             dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    flat = (image_hw // 4) * (image_hw // 4) * 64
    return {
        "conv1": {"w": normal_init(k1, (5, 5, in_channels, 32), dtype,
                                   (5 * 5 * in_channels) ** -0.5),
                  "b": jnp.zeros((32,), dtype)},
        "conv2": {"w": normal_init(k2, (5, 5, 32, 64), dtype,
                                   (5 * 5 * 32) ** -0.5),
                  "b": jnp.zeros((64,), dtype)},
        "fc1": dense_init(k3, flat, 64, dtype, use_bias=True),
        "head": dense_init(k4, 64, n_classes, dtype, use_bias=True),
    }


def _conv(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_forward(params, images):
    """images: (B, H, W, C) -> logits (B, n_classes)."""
    x = jax.nn.relu(_conv(params["conv1"], images))
    x = _maxpool2(x)
    x = jax.nn.relu(_conv(params["conv2"], x))
    x = _maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(dense(params["fc1"], x))
    return dense(params["head"], x)


def cnn_loss(params, images, labels):
    """Mean cross-entropy over the batch (scalar)."""
    logits = cnn_forward(params, images).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def cnn_accuracy(params, images, labels):
    logits = cnn_forward(params, images)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
