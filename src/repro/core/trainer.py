"""EnergyAwareTrainer — couples energy process, scheduler and SGD.

Two execution modes cover the paper-scale and framework-scale regimes:

1. :class:`ClientSimulator` — the paper's setting verbatim: N clients,
   per-client stochastic gradients (vmapped), server aggregation with
   ω_i = p_i·mask_i·scale_i. Whole loop runs under ``jax.lax.scan`` so a
   1000-iteration × 40-client run is one XLA computation.

2. :func:`build_energy_train_step` — the SPMD path used by
   ``repro.launch.train``: the global batch is partitioned into client
   slots; each example's loss is multiplied by its client coefficient
   (``repro.core.aggregation.per_example_coefficients``) so a *single*
   backward pass + the ordinary data-parallel all-reduce realizes the
   paper's eq. (11/12) with zero extra collective traffic.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import aggregation
from repro.core.energy import client_shard
from repro.core.scheduling import Decision
from repro.optim import Optimizer, apply_updates


class SimCarry(NamedTuple):
    params: Any
    opt_state: Any
    sched_state: Any
    energy_state: Any
    key: jax.Array
    t: jax.Array
    fault_state: Any = ()     # fault-component state ((): no/stateless faults)


class SimHistory(NamedTuple):
    loss: jax.Array           # (T,) global loss (if loss_fn given, else 0)
    participation: jax.Array  # (T, N) masks
    weight_sum: jax.Array     # (T,) Σ_i ω_i (≈1 in expectation for unbiased)
    finite: jax.Array = None  # (T,) bool — params finite after the step
    #                           (the per-step isfinite reduction behind
    #                           non-finite quarantine, DESIGN.md §10)


class ClientSimulator:
    """Paper-faithful N-client distributed-SGD simulator.

    Parameters
    ----------
    grads_fn : (params, key, t) -> (N,)-stacked gradient pytree.
        Owns data sampling (eq. 4); must return *local* gradients g_i.
    scheduler, energy : repro.core.scheduling / repro.core.energy pytrees.
        Optional at construction — every method also accepts them as
        explicit (traced) arguments, so a single simulator can execute a
        whole leaf-stacked family of scenarios under ``vmap``
        (:func:`repro.experiments.run_grid`). ``run``/``step`` also
        accept per-run ``p`` and ``active_mask`` overrides — the
        ragged-population mechanism (DESIGN.md §7): components padded to
        a common width run with ``active_mask`` marking the rows that
        exist; inactive rows contribute exactly zero gradient and zero
        scheduler probability mass, bit-for-bit matching the natural-N
        run.
    p : (N,) data weights p_i = D_i / D.
    optimizer : repro.optim.Optimizer applied to the aggregated update.
        For exact paper semantics use ``sgd(eta)``.
    loss_fn : optional (params) -> scalar global loss, logged per step.
    use_kernel : route aggregation through the Pallas kernel path.

    Under an active client-sharding context (DESIGN.md §8 — entered by
    the placement layer's ``run_client_sharded`` / ``clients``-mesh grid
    paths, never directly by users) the simulator runs with per-client
    state and the gradient buffer device-local and the aggregation
    reduced across the client mesh axis; requires flat-carry execution.

    flat : run the scan loop in flat parameter space (DESIGN.md §5):
        params and optimizer state live as single ``(P,)`` buffers in the
        scan carry, aggregation is one kernel/matvec per step, and the
        pytree is materialized only at the grads_fn/loss_fn/eval_fn
        boundaries. ``None`` (default) enables it whenever every param
        leaf shares one dtype; ``False`` restores full legacy semantics
        (per-leaf carry *and* per-leaf aggregation in leaf dtype);
        ``True`` raises on mixed-dtype params.
    """

    def __init__(self, *, grads_fn, p, optimizer: Optimizer,
                 scheduler=None, energy=None, faults=None,
                 loss_fn=None, use_kernel: bool = False,
                 flat: bool | None = None):
        self.grads_fn = grads_fn
        self.scheduler = scheduler
        self.energy = energy
        self.faults = faults
        self.p = jnp.asarray(p, jnp.float32)
        self.optimizer = optimizer
        self.loss_fn = loss_fn
        self.use_kernel = use_kernel
        self.flat = flat
        self._gfn_cache: dict = {}

    def _components(self, scheduler, energy):
        scheduler = self.scheduler if scheduler is None else scheduler
        energy = self.energy if energy is None else energy
        if scheduler is None or energy is None:
            raise ValueError(
                "scheduler/energy must be given either at construction or "
                "as arguments to init/step/run")
        return scheduler, energy

    def _fault(self, faults):
        """Constructor fault component unless overridden (None: no faults)."""
        return self.faults if faults is None else faults

    def _flat_spec(self, params):
        """RavelSpec for flat-carry execution, or None for the legacy path."""
        if self.flat is False:
            return None
        try:
            return aggregation.ravel_spec(params)
        except ValueError:
            if self.flat:
                raise
            return None

    def flat_spec(self, params):
        """Public :class:`~repro.core.aggregation.RavelSpec` accessor —
        the spec :meth:`run` executes under for these params (None when
        the legacy per-leaf path would be taken). Checkpoint drivers pass
        it to :meth:`init` / :meth:`run_carry` so a saved flat
        :class:`SimCarry` resumes in the same layout."""
        return self._flat_spec(params)

    def _flat_grads(self, spec):
        """Memoized RavelSpec-aware grads wrapper (the ravel boundary —
        :func:`repro.core.aggregation.make_flat_grads_fn`)."""
        fn = self._gfn_cache.get(spec)
        if fn is None:
            fn = aggregation.make_flat_grads_fn(
                self.grads_fn, spec, int(self.p.shape[0]))
            self._gfn_cache[spec] = fn
        return fn

    def init(self, key, params, *, scheduler=None, energy=None,
             faults=None, spec=None) -> SimCarry:
        """Build the scan carry; with ``spec`` params/opt_state are flat."""
        scheduler, energy = self._components(scheduler, energy)
        faults = self._fault(faults)
        if faults is not None and spec is None:
            raise ValueError(
                "fault injection (repro.core.faults) requires flat-carry "
                "execution: uniform-dtype params and flat != False "
                "(DESIGN.md §10)")
        if spec is not None:
            leaves = jax.tree_util.tree_leaves(params)
            params = aggregation.ravel_pytree(params, spec)
            if len(leaves) == 1 and params is leaves[0]:
                # Single-leaf ravel is a no-op reshape returning the
                # caller's array itself; the carry must own its storage
                # because run_carry donates it (DESIGN.md §9).
                params = jnp.array(params, copy=True)
        k_sched, k_energy, k_run = jax.random.split(key, 3)
        fault_state = ()
        if faults is not None:
            # Derived from k_run by domain-separated fold_in — never by
            # widening the split arity — so every fault-free RNG stream
            # is bitwise unchanged by the presence of a fault component.
            from repro.core.faults import FAULT_SALT

            fault_state = faults.init(
                jax.random.fold_in(k_run, FAULT_SALT),
                int(self.p.shape[0]), int(spec.total))
        return SimCarry(
            params=params,
            opt_state=self.optimizer.init(params),
            sched_state=scheduler.init(k_sched),
            energy_state=energy.init(k_energy),
            key=k_run,
            t=jnp.zeros((), jnp.int32),
            fault_state=fault_state,
        )

    def step(self, carry: SimCarry, scheduler=None, energy=None, *,
             p=None, active_mask=None, faults=None) -> tuple[SimCarry, dict]:
        """One server round on a pytree carry (public single-step API)."""
        return self._step(carry, scheduler, energy, None, p, active_mask,
                          faults)

    def _step(self, carry: SimCarry, scheduler, energy, spec,
              p=None, active_mask=None, faults=None) -> tuple[SimCarry, dict]:
        """Shared step body; ``spec`` non-None means carry.params is the
        raveled ``(P,)`` vector and aggregation stays in flat space.
        ``p`` overrides the constructor weights (ragged cells carry
        their own zero-padded, active-renormalized p); ``active_mask``
        is the (N,) 0/1 existing-client mask; ``faults`` an optional
        fault-injection component (:mod:`repro.core.faults`) applied to
        the flat gradient buffer before aggregation."""
        scheduler, energy = self._components(scheduler, energy)
        faults = self._fault(faults)
        shard = client_shard()
        if shard is not None and spec is None:
            raise ValueError(
                "client-axis sharding (DESIGN.md §8) requires flat-carry "
                "execution: uniform-dtype params and flat != False")
        if faults is not None:
            if spec is None:
                raise ValueError(
                    "fault injection (repro.core.faults) requires "
                    "flat-carry execution: uniform-dtype params and "
                    "flat != False (DESIGN.md §10)")
            if shard is not None:
                raise ValueError(
                    "fault injection is not supported under a clients "
                    "mesh axis (client-sharded fault state is future "
                    "work; DESIGN.md §10) — drop the clients axis or "
                    "the fault component")
        p = self.p if p is None else p
        key, k_arr, k_sched, k_grad = jax.random.split(carry.key, 4)
        energy_state, arr = energy.arrivals(carry.energy_state, carry.t, k_arr)
        sched_state, dec = scheduler.step(carry.sched_state, carry.t, k_sched,
                                          arr, active=active_mask)
        weights = aggregation.client_weights(p, dec)
        if active_mask is not None:
            # Defensive exactness: zero weight for rows that don't exist
            # even if a custom scheduler leaked probability mass to them
            # (×1 on active rows — bit-exact).
            weights = weights * active_mask
        wsum = None
        agg = params = opt_state = None
        fault_state = carry.fault_state
        row_mask = active_mask
        fusable = getattr(self.optimizer, "kind", "") == "sgd"
        if spec is not None:
            params_tree = aggregation.unravel_pytree(carry.params, spec)
            # The ravel boundary lives inside the wrapper: the scan body
            # sees one flat (N, P) — or, sharded, (n_local, P) — buffer
            # and carries no per-leaf concat.
            g = self._flat_grads(spec)(params_tree, k_grad, carry.t)
            if faults is not None:
                # Delivery faults transform the flat rows and/or return a
                # keep mask; keep composes into the active-row select so
                # a dropped row is an exact zero through the masked
                # kernels even when its payload is non-finite, and
                # zero-weighting keeps weight_sum the delivered mass.
                from repro.core.faults import FAULT_SALT

                k_fault = jax.random.fold_in(k_grad, FAULT_SALT)
                fault_state, g, keep = faults.apply(
                    carry.fault_state, carry.t, k_fault, g)
                if keep is not None:
                    weights = weights * keep
                    row_mask = aggregation.compose_masks(active_mask, keep)
            if shard is not None:
                mode, wire = aggregation.parse_reduction(shard.reduction)
                if mode == "fused":
                    if not fusable:
                        raise ValueError(
                            "reduction 'fused' bundles the SGD parameter "
                            "update into the reduction kernel and needs a "
                            "plain sgd() optimizer (kind='sgd'); use "
                            "'psum' for stateful/clipped optimizers")
                    params, opt_state, wsum = aggregation.fused_flat_sgd_update(
                        g, weights, carry.params, carry.opt_state,
                        self.optimizer, mask=row_mask,
                        use_kernel=self.use_kernel, shard=shard,
                        wire_dtype=wire)
                else:
                    agg, wsum = aggregation.reduce_flat_client_sharded(
                        g, weights, axis_name=shard.axis_name,
                        reduction=shard.reduction,
                        use_kernel=self.use_kernel, mask=row_mask)
            elif self.use_kernel and fusable:
                # Unsharded fused fast path: identical f32 op sequence to
                # reduce → −η·agg → add, collapsed into one Pallas launch.
                params, opt_state, _ = aggregation.fused_flat_sgd_update(
                    g, weights, carry.params, carry.opt_state,
                    self.optimizer, mask=row_mask, use_kernel=True)
            else:
                agg = aggregation.reduce_flat(g, weights,
                                              use_kernel=self.use_kernel,
                                              mask=row_mask)
        elif self.flat is False:
            # Full legacy semantics: per-leaf reductions (and per-leaf
            # kernel launches), leaf dtypes untouched — the escape hatch
            # and the reference the flat paths are tested against.
            stacked = self.grads_fn(carry.params, k_grad, carry.t)
            agg = (aggregation.aggregate_client_grads_kernel_per_leaf(
                       stacked, weights, active_mask) if self.use_kernel
                   else aggregation.aggregate_client_grads(stacked, weights,
                                                           active_mask))
        else:
            stacked = self.grads_fn(carry.params, k_grad, carry.t)
            agg = aggregation.aggregate_client_grads_flat(
                stacked, weights, use_kernel=self.use_kernel,
                mask=active_mask)
        if params is None:
            updates, opt_state = self.optimizer.update(
                agg, carry.opt_state, carry.params)
            params = apply_updates(carry.params, updates)
        loss_params = (aggregation.unravel_pytree(params, spec)
                       if spec is not None else params)
        loss = (self.loss_fn(loss_params) if self.loss_fn is not None
                else jnp.zeros((), jnp.float32))
        if spec is not None:
            finite = jnp.all(jnp.isfinite(params))
        else:
            finite = jnp.array(True)
            for leaf in jax.tree_util.tree_leaves(params):
                finite = jnp.logical_and(finite, jnp.all(jnp.isfinite(leaf)))
        out = {
            "loss": loss,
            "participation": dec.mask,
            "weight_sum": jnp.sum(weights) if wsum is None else wsum,
            "finite": finite,
        }
        new_carry = SimCarry(params=params, opt_state=opt_state,
                             sched_state=sched_state, energy_state=energy_state,
                             key=key, t=carry.t + 1,
                             fault_state=fault_state)
        return new_carry, out

    def run(self, key, params, num_steps: int, *, scheduler=None, energy=None,
            faults=None, p=None, active_mask=None, eval_fn=None,
            eval_every: int = 0):
        """Run the whole loop as one (or a few) ``lax.scan`` computations.

        ``p`` / ``active_mask`` override the constructor weights and mark
        the existing-client rows of a padded (ragged) population — see
        the class docstring and DESIGN.md §7.

        Without ``eval_fn``: returns ``(final_params, SimHistory)``.

        With ``eval_fn`` (params -> metric pytree): the scan runs in
        ``num_steps // eval_every`` chunks, evaluating after each chunk,
        and returns ``(final_params, SimHistory, evals)`` where every
        ``evals`` leaf has leading axis ``num_steps // eval_every``. This
        keeps evaluation *inside* the compiled computation so grid
        engines can vmap it (DESIGN.md §1).

        When the parameter pytree has a single leaf dtype (``flat``
        mode, the default), the scan carry holds params and optimizer
        state as single flat buffers: per step the loop issues exactly
        one aggregation kernel/matvec over the whole ``(N, P)`` gradient
        buffer and never round-trips optimizer state leaf-by-leaf; the
        pytree view exists only at the grads_fn/loss_fn/eval_fn
        boundaries (cheap slices/reshapes XLA fuses away). The returned
        ``final_params`` is always the original pytree structure.
        """
        scheduler, energy = self._components(scheduler, energy)
        faults = self._fault(faults)
        spec = self._flat_spec(params)
        carry = self.init(key, params, scheduler=scheduler, energy=energy,
                          faults=faults, spec=spec)

        def unflatten(p):
            return aggregation.unravel_pytree(p, spec) if spec is not None else p

        if eval_fn is None:
            carry, history = self.run_carry(
                carry, num_steps, scheduler=scheduler, energy=energy,
                faults=faults, p=p, active_mask=active_mask, spec=spec)
            return unflatten(carry.params), history

        if eval_every <= 0:
            eval_every = num_steps
        if num_steps % eval_every != 0:
            raise ValueError(
                f"num_steps={num_steps} must divide by eval_every={eval_every}")

        def body(c, _):
            return self._step(c, scheduler, energy, spec, p, active_mask,
                              faults)

        def chunk(c, _):
            c, outs = jax.lax.scan(body, c, None, length=eval_every)
            return c, (outs, eval_fn(unflatten(c.params)))

        carry, (outs, evals) = jax.lax.scan(
            chunk, carry, None, length=num_steps // eval_every)
        outs = jax.tree_util.tree_map(
            lambda x: x.reshape((num_steps,) + x.shape[2:]), outs)
        return unflatten(carry.params), self._history(outs), evals

    def _scan_steps(self, carry: SimCarry, num_steps: int, scheduler, energy,
                    p, active_mask, spec, faults=None):
        def body(c, _):
            return self._step(c, scheduler, energy, spec, p, active_mask,
                              faults)

        return jax.lax.scan(body, carry, None, length=num_steps)

    def run_carry(self, carry: SimCarry, num_steps: int, *, scheduler=None,
                  energy=None, faults=None, p=None, active_mask=None,
                  spec=None, donate: bool = True
                  ) -> tuple[SimCarry, SimHistory]:
        """Advance an existing carry ``num_steps`` rounds as one scan.

        The checkpoint/resume entry point: a :class:`SimCarry` from
        :meth:`init` (or from a restored checkpoint — the carry is an
        ordinary pytree, so :func:`repro.checkpoint.save_pytree` /
        ``restore_pytree`` round-trip it) resumes bitwise-identically to
        the uninterrupted run, because the whole step stream is a pure
        function of the carry. ``spec`` must be the
        :meth:`flat_spec` of the original params when the carry is flat
        (the default execution mode), None for the legacy pytree carry.
        Returns the advanced carry (same layout) and the chunk's
        :class:`SimHistory`.

        When called at the top level (not under an enclosing trace) on a
        **flat** carry, the scan runs under a jit that **donates** the
        input carry: the flat ``(P,)`` params/opt-state buffers alias
        the output instead of holding two live copies of the largest
        state in the loop (DESIGN.md §9). The input ``carry`` is
        consumed — rebind the result, as every call site here already
        does; restored checkpoints stay valid because donation consumes
        the device buffer, not the file. ``donate=False`` opts out.
        Legacy pytree carries (``spec=None``) never donate — their
        params leaves are the caller's own arrays. Under an outer trace
        (vmap/jit of a caller) the scan inlines as before and donation
        is the caller's concern.
        """
        scheduler, energy = self._components(scheduler, energy)
        faults = self._fault(faults)
        if donate and spec is not None and jax.core.trace_state_clean():
            carry, outs = _run_carry_donated(
                carry, scheduler, energy, faults, p, active_mask,
                sim=self, num_steps=int(num_steps), spec=spec)
        else:
            carry, outs = self._scan_steps(carry, num_steps, scheduler,
                                           energy, p, active_mask, spec,
                                           faults)
        return carry, self._history(outs)

    @staticmethod
    def _history(outs) -> SimHistory:
        return SimHistory(loss=outs["loss"], participation=outs["participation"],
                          weight_sum=outs["weight_sum"],
                          finite=outs["finite"])


@functools.partial(jax.jit, static_argnames=("sim", "num_steps", "spec"),
                   donate_argnums=(0,))
def _run_carry_donated(carry, scheduler, energy, faults, p, active_mask, *,
                       sim: ClientSimulator, num_steps: int, spec):
    """Top-level jit of the :meth:`ClientSimulator.run_carry` scan with
    the carry donated — input params/opt-state buffers alias the outputs.
    ``sim`` is static (hashed by identity; its fields select the trace),
    so each simulator instance owns its compiled executable."""
    return sim._scan_steps(carry, num_steps, scheduler, energy, p,
                           active_mask, spec, faults)


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def build_energy_train_step(
    *,
    per_example_loss_fn: Callable[..., jax.Array],
    optimizer: Optimizer,
    n_clients: int,
    p: jax.Array | None = None,
    aux_loss_weight: float = 0.0,
    flat: bool = False,
    use_kernel: bool = False,
):
    """SPMD train step with the paper's weighting baked into the loss.

    per_example_loss_fn(params, batch) must return per-example losses of
    shape (B,) — or (B,), aux_scalar when the model carries an auxiliary
    loss (MoE load balance). ``batch`` must contain ``client_ids`` (B,)
    int32. The returned step:

        train_step(state, batch, mask, scale) -> (state, metrics)

    where (mask, scale) are the (N,) scheduler outputs for this step.
    The aux loss (router load-balance) is weighted by mean(coeff·N) so a
    masked client contributes nothing to router statistics either — see
    DESIGN.md §4 (MoE note).

    ``flat=True`` routes the gradient through the same RavelSpec-aware
    flat boundary as :class:`ClientSimulator` (DESIGN.md §5/§8): the
    loss-path gradient is raveled into one ``(P,)`` buffer, optimizer
    state lives flat, and the pytree view is rebuilt only at the
    ``TrainState.params`` boundary. Elementwise-optimizer numerics are
    bitwise unchanged. With a plain tagged ``sgd()`` optimizer the flat
    step further routes through :func:`repro.core.aggregation.
    fused_flat_sgd_update` — the whole reduce-and-update as one fused
    pass (a single Pallas launch when ``use_kernel``, DESIGN.md §9); the
    f32 op sequence is unchanged. Leave ``flat`` False (the default) for
    pjit-sharded training — per-leaf optimizer state follows the
    parameter PartitionSpecs (``repro.sharding.rules``), a single flat
    buffer cannot.
    """
    if p is None:
        p = jnp.full((n_clients,), 1.0 / n_clients, jnp.float32)
    p = jnp.asarray(p, jnp.float32)

    def loss_fn(params, batch, weights):
        out = per_example_loss_fn(params, batch)
        aux = jnp.zeros((), jnp.float32)
        if isinstance(out, tuple):
            losses, aux = out
        else:
            losses = out
        bsz = losses.shape[0]
        coeff = aggregation.per_example_coefficients(
            batch["client_ids"], weights, bsz // n_clients)
        total = jnp.sum(coeff * losses)
        if aux_loss_weight:
            # Scale aux by the mean client weight so the energy mask also
            # de-biases router statistics.
            total = total + aux_loss_weight * aux * jnp.sum(weights)
        # Unweighted mean loss for logging.
        return total, jnp.mean(losses)

    def train_step(state: TrainState, batch, mask, scale):
        weights = aggregation.client_weights(p, Decision(mask=mask, scale=scale))
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        (total, mean_loss), grads = grad_fn(state.params, batch, weights)
        if flat:
            spec = aggregation.ravel_spec(state.params)
            gflat = aggregation.ravel_pytree(
                jax.tree_util.tree_map(lambda g: g.astype(spec.dtype), grads),
                spec)
            pflat = aggregation.ravel_pytree(state.params, spec)
            if getattr(optimizer, "kind", "") == "sgd":
                # The SPMD gradient is already reduced over examples, so
                # the fused op sees it as a one-client stack with unit
                # weight: one fused reduce-and-update pass (single Pallas
                # launch under use_kernel) replaces update+apply.
                pnew, opt_state, _ = aggregation.fused_flat_sgd_update(
                    gflat[None, :], jnp.ones((1,), jnp.float32), pflat,
                    state.opt_state, optimizer, use_kernel=use_kernel)
                params = aggregation.unravel_pytree(pnew, spec)
            else:
                updates, opt_state = optimizer.update(gflat, state.opt_state,
                                                      pflat)
                params = aggregation.unravel_pytree(pflat + updates, spec)
        else:
            updates, opt_state = optimizer.update(grads, state.opt_state,
                                                  state.params)
            params = apply_updates(state.params, updates)
        metrics = {
            "weighted_loss": total,
            "loss": mean_loss,
            "active_clients": jnp.sum(mask),
            "weight_sum": jnp.sum(weights),
        }
        return TrainState(params=params, opt_state=opt_state, step=state.step + 1), metrics

    def init_state(params) -> TrainState:
        if flat:
            spec = aggregation.ravel_spec(params)
            opt_state = optimizer.init(aggregation.ravel_pytree(params, spec))
        else:
            opt_state = optimizer.init(params)
        return TrainState(params=params, opt_state=opt_state,
                          step=jnp.zeros((), jnp.int32))

    return init_state, train_step
