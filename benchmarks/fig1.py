"""Benchmark: paper Figure 1 — test accuracy vs iteration, 4 methods.

Reduced-scale by default (CPU); ``examples/paper_cifar.py --full`` is the
paper-exact variant. Emits ``name,us_per_call,derived`` CSV rows where
``derived`` carries the final accuracies.
"""

from __future__ import annotations

import time


def run(iters: int = 250) -> list[str]:
    import examples.paper_cifar as pc
    t0 = time.time()
    final = pc.main(["--iters", str(iters), "--eval-every", str(iters // 5)])
    dt_us = (time.time() - t0) * 1e6
    rows = [f"fig1_{m},{dt_us / 4:.0f},acc={a:.3f}" for m, a in final.items()]
    ok = (final["alg1"] > final["benchmark1"] > 0
          and final["alg1"] > final["benchmark2"])
    rows.append(f"fig1_ordering,{dt_us:.0f},alg1>benchmarks={ok}")
    return rows
