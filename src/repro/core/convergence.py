"""Theorem 1 / Corollary 1 machinery + strongly-convex test problems.

Implements the paper's convergence constants exactly:

    C = ( Σ_i (T_i,max − 1) p_i²  +  Σ_i Σ_j p_i p_j ) G²          (eq. 21)

    E[F(w^T)] − F* ≤ (L/μ)(1−ημ)^T (F(w⁰) − F* − ηC/2) + ηLC/(2μ)  (eq. 20)

and provides a family of strongly-convex quadratic problems with
closed-form optima so tests/benchmarks can compare the *empirical*
suboptimality of every scheduler against the bound.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def variance_constant(p, t_max, g2) -> jax.Array:
    """C from eq. (21). ``t_max``: (N,) per-client T_{i,max} (or 1/β_i, T_i
    per Corollary 1). ``g2``: the second-moment bound G²."""
    p = jnp.asarray(p, jnp.float32)
    t_max = jnp.asarray(t_max, jnp.float32)
    return (jnp.sum((t_max - 1.0) * p**2) + jnp.sum(p) ** 2) * g2


def theorem1_bound(t, f0_gap, mu, lsmooth, eta, c) -> jax.Array:
    """Right-hand side of eq. (20) as a function of iteration t."""
    t = jnp.asarray(t, jnp.float32)
    decay = (lsmooth / mu) * (1.0 - eta * mu) ** t * (f0_gap - eta * c / 2.0)
    floor = eta * lsmooth * c / (2.0 * mu)
    return decay + floor


def error_floor(mu, lsmooth, eta, c) -> float:
    """The non-vanishing term ηLC/(2μ) (Remark 1)."""
    return float(eta * lsmooth * c / (2.0 * mu))


def max_step_size(mu, lsmooth) -> float:
    """η ≤ min{1/(2μ), 1/L} required by Theorem 1."""
    return float(min(1.0 / (2.0 * mu), 1.0 / lsmooth))


class QuadraticProblem(NamedTuple):
    """N-client quadratic: F_i(w) = ½ wᵀ A_i w − b_iᵀ w + c_i.

    Each A_i is symmetric PD, so F = Σ p_i F_i is μ-strongly convex with
    μ = λ_min(Σ p_i A_i), L = λ_max(Σ p_i A_i), and
    w* = (Σ p_i A_i)⁻¹ Σ p_i b_i — everything in closed form.
    """

    a: jax.Array       # (N, d, d)
    b: jax.Array       # (N, d)
    p: jax.Array       # (N,)
    w_star: jax.Array  # (d,)
    mu: float
    lsmooth: float

    @property
    def n_clients(self) -> int:
        return self.a.shape[0]

    @property
    def dim(self) -> int:
        return self.a.shape[1]

    def local_grad(self, i, w, key=None, noise=0.0):
        """∇F_i(w) (+ optional isotropic noise → 'stochastic' gradient)."""
        g = self.a[i] @ w - self.b[i]
        if key is not None and noise > 0.0:
            g = g + noise * jax.random.normal(key, g.shape)
        return g

    def all_grads(self, w, key=None, noise=0.0):
        """(N, d) stacked local gradients, optionally noisy."""
        g = jnp.einsum("nij,j->ni", self.a, w) - self.b
        if key is not None and noise > 0.0:
            g = g + noise * jax.random.normal(key, g.shape)
        return g

    def global_loss(self, w):
        quad = 0.5 * jnp.einsum("i,nij,j,n->", w, self.a, w, self.p)
        lin = jnp.einsum("ni,i,n->", self.b, w, self.p)
        return quad - lin

    def suboptimality(self, w):
        return self.global_loss(w) - self.global_loss(self.w_star)

    def grad_second_moment_bound(self, radius: float) -> float:
        """G² over the ball ||w − w*|| ≤ radius (deterministic gradients).

        ||∇F_i(w)|| = ||A_i(w − w*) + (A_i w* − b_i)||
                    ≤ L_i·radius + ||A_i w* − b_i||.
        """
        a = np.asarray(self.a)
        ws = np.asarray(self.w_star)
        b = np.asarray(self.b)
        worst = 0.0
        for i in range(a.shape[0]):
            li = float(np.linalg.eigvalsh(a[i]).max())
            resid = float(np.linalg.norm(a[i] @ ws - b[i]))
            worst = max(worst, (li * radius + resid) ** 2)
        return worst


def make_quadratic(
    key, n_clients: int, dim: int, hetero: float = 1.0, cond: float = 10.0
) -> QuadraticProblem:
    """Random well-conditioned quadratic with heterogeneous client optima.

    ``hetero`` controls how far apart the per-client minimizers are — the
    lever that makes Benchmark 1's bias visible (biased participation pulls
    w toward energy-rich clients' minimizers).
    """
    k1, k2, k3 = jax.random.split(key, 3)
    # Per-client SPD matrices with spectrum in [1, cond].
    qs = jax.random.normal(k1, (n_clients, dim, dim))

    def _spd(q):
        q, _ = jnp.linalg.qr(q)
        eigs = jnp.linspace(1.0, cond, dim)
        return (q * eigs) @ q.T

    a = jax.vmap(_spd)(qs)
    centers = hetero * jax.random.normal(k2, (n_clients, dim))
    b = jnp.einsum("nij,nj->ni", a, centers)
    p_raw = jax.random.uniform(k3, (n_clients,), minval=0.5, maxval=1.5)
    p = p_raw / jnp.sum(p_raw)

    a_bar = jnp.einsum("n,nij->ij", p, a)
    b_bar = jnp.einsum("n,ni->i", p, b)
    w_star = jnp.linalg.solve(a_bar, b_bar)
    eigs = jnp.linalg.eigvalsh(a_bar)
    return QuadraticProblem(
        a=a, b=b, p=p, w_star=w_star,
        mu=float(eigs[0]), lsmooth=float(eigs[-1]),
    )


def biased_fixed_point(problem: QuadraticProblem, participation: jax.Array) -> jax.Array:
    """Fixed point of *unscaled* best-effort SGD (Benchmark 1).

    With participation probabilities q_i and no rescaling, the expected
    update drives w to argmin Σ_i q_i p_i F_i — the biased optimum the
    paper warns about. Closed form for quadratics; used to *quantitatively*
    verify the bias claim, not just eyeball it.
    """
    q = jnp.asarray(participation, jnp.float32)
    a_bar = jnp.einsum("n,nij->ij", q * problem.p, problem.a)
    b_bar = jnp.einsum("n,ni->i", q * problem.p, problem.b)
    return jnp.linalg.solve(a_bar, b_bar)
