"""Benchmark: Theorem 1 — empirical suboptimality vs the analytic bound,
and the ηLC/(2μ) error floor sweep (Remark 1).

Each step-size's seed batch runs through the scenario engine as a
single-cell :class:`repro.experiments.Study`, and the empirical floor is
reported as NaN-aware mean±std across seeds
(:meth:`GridResult.reduce`) instead of a single-seed point estimate.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (
    make_quadratic,
    max_step_size,
    theorem1_bound,
    variance_constant,
)
from repro.experiments import Study, clear_cache
from repro.optim import sgd

TAUS = (1, 5, 10, 20)
SEEDS = 8


def run() -> list[str]:
    t0 = time.time()
    n = 8
    problem = make_quadratic(jax.random.PRNGKey(3), n, dim=8, hetero=0.5)
    taus = [TAUS[i % 4] for i in range(n)]
    steps = 2000
    study = Study("theorem1", num_steps=steps, axes={
        "scheduler": "alg1", "arrivals": "periodic", "n_clients": n,
        "taus_profile": taus, "seeds": SEEDS})

    rows = []
    eta_max = max_step_size(problem.mu, problem.lsmooth)
    radius = float(jnp.linalg.norm(problem.w_star)) + 10.0
    g2 = problem.grad_second_moment_bound(radius)
    c = float(variance_constant(problem.p, jnp.asarray(taus, jnp.float32), g2))
    f0 = float(problem.suboptimality(jnp.full((8,), 5.0)))
    grads_fn = lambda p, k, t: problem.all_grads(p)

    for frac in (0.1, 0.25, 0.5):
        eta = frac * eta_max
        results = study.run(
            grads_fn=grads_fn, p=problem.p, optimizer=sgd(eta),
            loss_fn=problem.suboptimality, params0=jnp.full((8,), 5.0))
        stats = results.reduce(
            metric=lambda cell: cell.history.loss[:, -100:].mean(axis=-1))
        s = stats["alg1_periodic"]
        bound = float(theorem1_bound(steps, f0, problem.mu, problem.lsmooth,
                                     eta, c))
        rows.append(
            f"theorem1_eta{frac},{(time.time() - t0) * 1e6:.0f},"
            f"empirical={s['mean']:.4g};empirical_std={s['std']:.2g};"
            f"seeds={s['n_seeds']};n_nan={s['n_nan']};bound={bound:.4g};"
            f"holds={s['mean'] <= bound}")
    clear_cache()  # each eta traced its own grid; don't pin them all
    return rows
