"""Sweep axes: the named, composable dimensions of a Study.

An **axis** is a registered factory that knows how to apply one swept
value to a cell draft (the constructor arguments of a
:class:`~repro.experiments.scenario.Scenario`) and how to format that
value into the cell's name. The cross-product of a Study's axes resolves
to Scenario cells exactly as the grid engine batches them — same
structure-grouping, same one-compile-per-structure guarantee.

Built-in axes (canonical resolution order):

    scheduler     registry names from repro.core.scheduling
    arrivals      family names from repro.core.energy (str, or
                  (kind, kwargs) for hyperparameterized families such as
                  ("day_night", {"period": 50}))
    capacity      battery capacity -> scheduler_kwargs["capacity"]
    n_clients     client-population size — a data axis: ragged values
                  pad to the simulator capacity under an active mask
                  (DESIGN.md §7), sharing one structure group
    taus_profile  named / explicit per-client energy-period profile
    seeds         seed count or explicit list (vmapped by the engine,
                  never part of cell naming)

The registry is open: :func:`register_axis` adds project-specific axes
(e.g. an EMA-rate sweep) that compose with the built-ins. Scheduler and
arrival *values* are validated against their own registries at
resolution time, so one layer of named factories subsumes
``make_scheduler`` / ``make_arrivals`` / the legacy grid registry.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.energy import default_taus

#: Canonical order in which axes cross-multiply and appear in cell names.
AXIS_ORDER = ("scheduler", "arrivals", "capacity", "n_clients",
              "taus_profile", "faults", "seeds")


def _default_is_value(v) -> bool:
    return isinstance(v, str) or not isinstance(v, (list, tuple))


@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """One registered sweep axis.

    ``apply(draft, value)`` folds a swept value into the cell draft (a
    dict of Scenario constructor arguments). ``fmt(value, fixed)``
    renders the value for the cell name — ``None`` omits it (the
    convention: identity axes *always* appear, shape/profile axes only
    when actually swept, seeds never). ``is_value(v)`` distinguishes one
    axis value from a sweep list — needed because some single values are
    themselves sequences (an explicit taus profile, an
    ``(arrival_kind, kwargs)`` pair).

    ``validate(value)`` — optional — checks one axis value against the
    registry that owns it (scheduler / arrival-family / fault-family /
    taus-profile names), raising ``ValueError`` that names the registry
    and its valid keys. The manifest layer
    (:mod:`repro.experiments.manifest`) calls it on every decoded value
    so a bad name fails at ``from_json`` time, not deep inside
    ``Scenario.build``.
    """

    name: str
    apply: Callable[[dict, Any], None]
    fmt: Callable[[Any, bool], str | None]
    is_value: Callable[[Any], bool] = _default_is_value
    doc: str = ""
    validate: Callable[[Any], None] | None = None


_AXES: dict[str, AxisSpec] = {}


def register_axis(name: str, *, apply, fmt=None, is_value=None,
                  doc: str = "", validate=None) -> AxisSpec:
    """Register a sweep axis. ``fmt`` defaults to omit-from-name."""
    spec = AxisSpec(name=name, apply=apply,
                    fmt=fmt or (lambda v, fixed: None),
                    is_value=is_value or _default_is_value, doc=doc,
                    validate=validate)
    _AXES[name] = spec
    return spec


def axis_names() -> list[str]:
    """All registered axes, canonical order first, extensions after."""
    ordered = [n for n in AXIS_ORDER if n in _AXES]
    return ordered + sorted(set(_AXES) - set(ordered))


def get_axis(name: str) -> AxisSpec:
    try:
        return _AXES[name]
    except KeyError:
        raise ValueError(
            f"unknown sweep axis {name!r}; have {axis_names()}") from None


# ------------------------------------------------------------ taus profiles

_TAUS_PROFILES: dict[str, Callable[[int], np.ndarray]] = {
    "paper": default_taus,
}


def register_taus_profile(name: str, fn: Callable[[int], Any]) -> None:
    """Register a named per-client energy-period profile ``fn(n) -> (N,)``."""
    _TAUS_PROFILES[name] = fn


def resolve_taus_profile(profile, n_clients: int) -> np.ndarray:
    """A profile is a registered name, an explicit per-client sequence
    (cycled over N like the paper's group assignment), or a callable."""
    if callable(profile):
        return np.asarray(profile(n_clients))
    if isinstance(profile, str):
        try:
            fn = _TAUS_PROFILES[profile]
        except KeyError:
            raise ValueError(
                f"unknown taus profile {profile!r}; have "
                f"{sorted(_TAUS_PROFILES)}") from None
        return np.asarray(fn(n_clients))
    taus = np.asarray(profile)
    if taus.ndim != 1 or taus.size == 0:
        raise ValueError(f"taus profile must be a 1-D sequence, got "
                         f"shape {taus.shape}")
    return np.array([taus[i % taus.size] for i in range(n_clients)])


def _fmt_taus(profile, fixed: bool) -> str | None:
    if fixed:  # not varying across cells -> not part of cell identity
        return None
    if isinstance(profile, str):
        return profile
    if callable(profile):
        return getattr(profile, "__name__", "taus")
    return "taus" + "x".join(f"{t:g}" for t in np.asarray(profile).reshape(-1))


# ------------------------------------------------------------ built-in axes

def _validate_scheduler(value) -> None:
    from repro.core.scheduling import scheduler_names

    if value not in scheduler_names():
        raise ValueError(
            f"unknown scheduler {value!r}; scheduler registry has "
            f"{scheduler_names()}")


def _family_kind(value):
    """The family name of a ``kind`` / ``(kind, kwargs)`` axis value."""
    if isinstance(value, tuple) and len(value) == 2:
        return value[0]
    return value


def _validate_arrivals(value) -> None:
    from repro.core.energy import arrival_family_names

    kind = _family_kind(value)
    if kind not in arrival_family_names():
        raise ValueError(
            f"unknown arrival family {kind!r}; arrival-family registry "
            f"has {arrival_family_names()}")


def _validate_faults(value) -> None:
    if value is None:  # the fault-free program
        return
    from repro.core.faults import fault_family_names

    kind = _family_kind(value)
    if kind not in fault_family_names():
        raise ValueError(
            f"unknown fault family {kind!r}; fault-family registry has "
            f"{fault_family_names()}")


def _validate_taus_profile(value) -> None:
    if isinstance(value, str) and value not in _TAUS_PROFILES:
        raise ValueError(
            f"unknown taus profile {value!r}; taus-profile registry has "
            f"{sorted(_TAUS_PROFILES)}")


def _apply_scheduler(draft: dict, value) -> None:
    draft["scheduler"] = str(value)


def _apply_arrivals(draft: dict, value) -> None:
    if isinstance(value, tuple):
        kind, kw = value
        draft["arrivals"] = str(kind)
        draft["arrival_kwargs"] = dict(kw)
    else:
        draft["arrivals"] = str(value)


def _fmt_arrivals(value, fixed: bool) -> str:
    if isinstance(value, tuple):
        kind, kw = value
        if fixed:  # kwargs don't vary across cells — kind identifies it
            return str(kind)
        tail = "".join(f"_{k}{v:g}" if isinstance(v, (int, float))
                       else f"_{k}{v}" for k, v in sorted(kw.items()))
        return f"{kind}{tail}"
    return str(value)


def _apply_capacity(draft: dict, value) -> None:
    draft.setdefault("scheduler_kwargs", {})["capacity"] = float(value)


def _apply_n_clients(draft: dict, value) -> None:
    draft["n_clients"] = int(value)


def _apply_taus_profile(draft: dict, value) -> None:
    draft["taus"] = resolve_taus_profile(value, draft["n_clients"])


def _arrivals_is_value(v) -> bool:
    if isinstance(v, tuple) and len(v) == 2 and isinstance(v[0], str) \
            and isinstance(v[1], dict):
        return True  # one hyperparameterized family, not a 2-kind sweep
    return _default_is_value(v)


def _taus_is_value(v) -> bool:
    if isinstance(v, (list, tuple)) and v \
            and all(isinstance(t, (int, float, np.integer, np.floating))
                    for t in v):
        return True  # one explicit per-client period vector
    return _default_is_value(v)


register_axis(
    "scheduler", apply=_apply_scheduler, fmt=lambda v, fixed: str(v),
    validate=_validate_scheduler,
    doc="scheduler registry name (repro.core.scheduling)")
register_axis(
    "arrivals", apply=_apply_arrivals, fmt=_fmt_arrivals,
    is_value=_arrivals_is_value, validate=_validate_arrivals,
    doc="arrival-family name (repro.core.energy), or (kind, kwargs)")
register_axis(
    "capacity", apply=_apply_capacity,
    fmt=lambda v, fixed: None if fixed else f"c{v:g}",
    doc="battery capacity -> scheduler_kwargs['capacity']")
register_axis(
    "n_clients", apply=_apply_n_clients,
    fmt=lambda v, fixed: None if fixed else f"n{v}",
    doc="client-population size; a DATA axis — ragged values are padded "
        "to the simulator capacity under an active mask (DESIGN.md §7), "
        "so every N shares one structure group")
register_axis(
    "taus_profile", apply=_apply_taus_profile, fmt=_fmt_taus,
    is_value=_taus_is_value, validate=_validate_taus_profile,
    doc="per-client energy-period profile: registered name, sequence, "
        "or callable(n)")


def _apply_faults(draft: dict, value) -> None:
    if value is None:
        draft["faults"] = None
    elif isinstance(value, tuple):
        kind, kw = value
        draft["faults"] = str(kind)
        draft["fault_kwargs"] = dict(kw)
    else:
        draft["faults"] = str(value)


def _fmt_faults(value, fixed: bool) -> str | None:
    if value is None:
        return None if fixed else "nofault"
    return _fmt_arrivals(value, fixed)


def _faults_is_value(v) -> bool:
    return v is None or _arrivals_is_value(v)


register_axis(
    "faults", apply=_apply_faults, fmt=_fmt_faults,
    is_value=_faults_is_value, validate=_validate_faults,
    doc="fault-family name (repro.core.faults), (kind, kwargs), or None "
        "for the fault-free program; faulted and fault-free cells group "
        "into separate compiled structures")
register_axis(
    "seeds", apply=lambda draft, value: None,
    doc="seed count or explicit list; vmapped by the engine")
