"""llama4-scout-17b-a16e — 16-expert top-1 MoE with shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E] 48L, d_model=5120, 40 heads (GQA
kv=8), d_ff=8192 per expert, vocab=202048, MoE 16 experts top-1 + an
always-on shared expert (llama4 routing), early-fusion multimodal (text
path modeled; fusion stub not required by the assignment).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    top_k=1,
    shared_expert=True,
    rope_theta=500000.0,
    long_context_window=8192,
    norm="rmsnorm",
    act="silu",
    dtype_name="bfloat16",
    remat=True,
    citation="[hf:meta-llama/Llama-4-Scout-17B-16E]",
)
