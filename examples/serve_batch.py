"""Batched serving example: KV-cache greedy decode across architectures.

Runs reduced variants of a dense, an MoE, a hybrid-SSM and the enc-dec
arch through the same serve_step API and reports tokens/s.

    PYTHONPATH=src python examples/serve_batch.py
"""

from repro.launch.serve import main as serve_main

ARCHS = ["minitron-4b", "phi3.5-moe-42b-a6.6b", "zamba2-2.7b",
         "whisper-tiny"]


def main():
    for arch in ARCHS:
        serve_main(["--arch", arch, "--reduced", "--batch", "4",
                    "--prompt-len", "8", "--new-tokens", "24"])


if __name__ == "__main__":
    main()
