"""Property tests for the ravel boundary (DESIGN.md §5/§8).

The flat gradient path now starts *inside* ``grads_fn`` — a
RavelSpec-aware wrapper (:func:`repro.core.aggregation.
make_flat_grads_fn`) emits the ``(N, P)`` buffer directly, so these
properties pin the boundary itself: flatten/unflatten round-trip
identity over random nested pytree *structures* (not just flat dicts)
and mixed-dtype rejection, plus exact-zero contribution of masked rows
through the wrapped flat path even when the masked rows hold inf/NaN.

Skipped as a whole when ``hypothesis`` is absent from the container.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import aggregation  # noqa: E402

_shape = st.lists(st.integers(1, 5), min_size=0, max_size=3).map(tuple)

# Random *nested* pytree structures: leaves are shape tuples, nodes are
# dicts / tuples / lists.
_structure = st.recursive(
    _shape,
    lambda kids: st.one_of(
        st.dictionaries(st.sampled_from(list("abcdef")), kids,
                        min_size=1, max_size=3),
        st.lists(kids, min_size=1, max_size=3).map(tuple),
        st.lists(kids, min_size=1, max_size=3),
    ),
    max_leaves=6,
)


def _is_shape(x):
    return isinstance(x, tuple) and all(isinstance(i, int) for i in x)


def _build(structure, key, lead=(), dtypes=None):
    """Materialize a structure of shape-tuples into arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(structure, is_leaf=_is_shape)
    arrays = []
    for i, shp in enumerate(leaves):
        dt = jnp.float32 if dtypes is None else dtypes[i % len(dtypes)]
        arr = jax.random.normal(jax.random.fold_in(key, i), lead + shp)
        arrays.append(arr.astype(dt))
    return jax.tree_util.tree_unflatten(treedef, arrays)


@settings(max_examples=30, deadline=None)
@given(structure=_structure, seed=st.integers(0, 2**30))
def test_ravel_roundtrip_identity_random_structures(structure, seed):
    """flatten → unflatten is the identity (bitwise) for arbitrary
    nested dict/tuple/list pytrees, both the (P,) and (N, P) views."""
    key = jax.random.PRNGKey(seed)
    tree = _build(structure, key)
    spec = aggregation.ravel_spec(tree)
    vec = aggregation.ravel_pytree(tree, spec)
    assert vec.shape == (spec.total,)
    back = aggregation.unravel_pytree(vec, spec)
    assert jax.tree_util.tree_structure(back) == \
        jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    stacked = _build(structure, jax.random.fold_in(key, 1), lead=(4,))
    sspec = aggregation.ravel_spec(stacked, lead_axes=1)
    flat = aggregation.ravel_stacked(stacked, sspec)
    assert flat.shape == (4, sspec.total)
    back = aggregation.unravel_pytree(flat, sspec)
    for a, b in zip(jax.tree_util.tree_leaves(stacked),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=20, deadline=None)
@given(structure=_structure, seed=st.integers(0, 2**30),
       dtypes=st.permutations([jnp.float32, jnp.bfloat16]))
def test_mixed_dtype_trees_are_rejected(structure, seed, dtypes):
    """A pytree mixing leaf dtypes cannot concatenate — ravel_spec must
    raise (the trainer then falls back to the per-leaf path)."""
    n_leaves = len(jax.tree_util.tree_leaves(structure, is_leaf=_is_shape))
    if n_leaves < 2:
        structure = (structure, ())
    tree = _build(structure, jax.random.PRNGKey(seed), dtypes=list(dtypes))
    with pytest.raises(ValueError, match="dtype"):
        aggregation.ravel_spec(tree)


@settings(max_examples=25, deadline=None)
@given(structure=_structure, seed=st.integers(0, 2**30),
       n=st.integers(2, 8), use_kernel=st.booleans())
def test_masked_rows_contribute_exact_zero_through_flat_grads_fn(
        structure, seed, n, use_kernel):
    """The flat grads_fn path end-to-end: wrap a stacked-pytree grads_fn
    with make_flat_grads_fn, poison the masked-out client rows with
    inf/NaN, and require the reduction to be *bitwise* the reduction of
    the clean rows — the mask is a row select, not a multiply."""
    key = jax.random.PRNGKey(seed)
    params = _build(structure, key)
    spec = aggregation.ravel_spec(params)
    clean = _build(structure, jax.random.fold_in(key, 2), lead=(n,))
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (n,))
            < 0.6).astype(jnp.float32)
    mask = mask.at[0].set(1.0)  # at least one active row
    poison = jax.tree_util.tree_map(
        lambda x: jnp.where(
            mask.reshape((-1,) + (1,) * (x.ndim - 1)) > 0, x,
            jnp.full_like(x, jnp.inf) * jnp.where(x > 0, 1.0, jnp.nan)),
        clean)
    weights = jax.random.uniform(jax.random.fold_in(key, 4), (n,)) * mask

    gfn_clean = aggregation.make_flat_grads_fn(lambda p, k, t: clean,
                                               spec, n)
    gfn_poison = aggregation.make_flat_grads_fn(lambda p, k, t: poison,
                                                spec, n)
    k = jax.random.PRNGKey(0)
    g_clean = gfn_clean(params, k, 0)
    g_poison = gfn_poison(params, k, 0)
    assert g_clean.shape == g_poison.shape == (n, spec.total)

    ref = aggregation.reduce_flat(g_clean, weights, mask=mask)
    got = aggregation.reduce_flat(g_poison, weights, use_kernel=use_kernel,
                                  mask=mask)
    assert np.isfinite(np.asarray(got)).all()
    if use_kernel:
        np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                   rtol=1e-6, atol=1e-6)
    else:
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30), n=st.integers(1, 6),
       dim=st.integers(1, 7))
def test_flat_grads_fn_array_output_is_the_ravel(seed, n, dim):
    """A grads_fn emitting a single (N, ...) array takes the natively
    flat fast path — bitwise the ravel of the equivalent pytree."""
    key = jax.random.PRNGKey(seed)
    params = jax.random.normal(key, (dim,))
    spec = aggregation.ravel_spec(params)
    stacked = jax.random.normal(jax.random.fold_in(key, 1), (n, dim))
    gfn = aggregation.make_flat_grads_fn(lambda p, k, t: stacked, spec, n)
    out = gfn(params, jax.random.PRNGKey(0), 0)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(aggregation.ravel_stacked(stacked,
                                             aggregation.ravel_spec(
                                                 stacked, lead_axes=1))))
