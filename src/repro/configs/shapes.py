"""The four assigned input shapes + ShapeDtypeStruct input specs.

``input_specs(cfg, shape_name)`` returns the exact pytree of
``jax.ShapeDtypeStruct`` stand-ins the corresponding step function is
lowered with — weak-type-correct, shardable, zero allocation. Decode
shapes include the full decode state (KV caches / SSM states) as inputs:
``serve_step`` consumes ONE new token against a cache of ``seq_len``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


class InputShape(NamedTuple):
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

DEFAULT_N_CLIENTS = 32  # energy-harvesting client slots for train shapes


def effective_window(cfg: ArchConfig, shape: InputShape) -> int:
    """long_500k forces sliding-window attention on attention blocks
    (sub-quadratic requirement); other shapes use the config's window."""
    if shape.name == "long_500k":
        has_attn = any(k in ("attn_mlp", "attn_moe", "xattn")
                       for k, _, _ in cfg.resolved_superblock)
        if has_attn:
            return cfg.long_context_window
    return cfg.sliding_window


def _modality_specs(cfg: ArchConfig, batch: int):
    extra = {}
    if cfg.n_vision_tokens:
        extra["vision_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_vision_tokens, cfg.d_model), cfg.dtype)
    if cfg.enc_dec:
        extra["audio_feats"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_len, cfg.d_model), cfg.dtype)
    return extra


def train_input_specs(cfg: ArchConfig, shape: InputShape,
                      n_clients: int = DEFAULT_N_CLIENTS):
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
        "labels": jax.ShapeDtypeStruct((b, s), i32),
        "client_ids": jax.ShapeDtypeStruct((b,), i32),
    }
    specs.update(_modality_specs(cfg, b))
    sched = {
        "mask": jax.ShapeDtypeStruct((n_clients,), jnp.float32),
        "scale": jax.ShapeDtypeStruct((n_clients,), jnp.float32),
    }
    return specs, sched


def prefill_input_specs(cfg: ArchConfig, shape: InputShape):
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    specs.update(_modality_specs(cfg, b))
    return specs


def decode_input_specs(cfg: ArchConfig, shape: InputShape):
    # lazy import: repro.models imports repro.configs.base (cycle guard)
    from repro.models.transformer import decode_cache_len, init_decode_state
    b, s = shape.global_batch, shape.seq_len
    window = effective_window(cfg, shape)
    cache_len = decode_cache_len(cfg, s, window=window or None)
    states = jax.eval_shape(
        lambda: init_decode_state(cfg, b, cache_len))
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "states": states,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.enc_dec:
        specs["memory"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_len, cfg.d_model), cfg.dtype)
    return specs


def input_specs(cfg: ArchConfig, shape_name: str,
                n_clients: int = DEFAULT_N_CLIENTS):
    """Dispatch on the shape's mode. Returns (specs, mode)."""
    shape = INPUT_SHAPES[shape_name]
    if not cfg.supports_shape(shape_name):
        raise ValueError(f"{cfg.name} skips {shape_name} (see DESIGN.md §4)")
    if shape.mode == "train":
        return train_input_specs(cfg, shape, n_clients), "train"
    if shape.mode == "prefill":
        return prefill_input_specs(cfg, shape), "prefill"
    return decode_input_specs(cfg, shape), "decode"
