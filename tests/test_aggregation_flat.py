"""Flat single-pass aggregation tests (DESIGN.md §5).

Covers the shared raveler (ravel → reduce → unravel ≡ per-leaf
reference, across ragged leaf shapes that previously forced per-leaf
kernel padding), the one-kernel-call-per-step guarantee, mixed-dtype
fallback, and flat-carry simulator equivalence. Randomized-shape
property tests ride the hypothesis importorskip pattern of
``test_kernels_properties.py`` via plain parametrization here so the
module always runs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClientSimulator, aggregation, make_quadratic, make_scheduler
from repro.core.energy import BinaryArrivals, DeterministicArrivals
from repro.kernels.aggregate import ops as agg_ops
from repro.optim import adam, sgd

#: Ragged leaf layouts with odd (non-lane-aligned) sizes — each leaf
#: would previously get its own kernel launch and its own padding.
RAGGED_TREES = [
    {"w": (3, 5), "b": (7,), "k": (2, 3, 5)},
    {"a": (1,), "z": (13,), "m": (3, 1, 2)},
    {"only": (129,)},
    {"s": (), "v": (31,), "c": (5, 5)},
]


def _make_stacked(shapes: dict, n: int, seed: int):
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i, (name, shp) in enumerate(sorted(shapes.items())):
        tree[name] = jax.random.normal(jax.random.fold_in(key, i),
                                       (n,) + shp, jnp.float32)
    return tree


# ------------------------------------------------------------- raveler

@pytest.mark.parametrize("shapes", RAGGED_TREES)
def test_ravel_unravel_roundtrip(shapes):
    tree = _make_stacked(shapes, 4, 0)
    spec = aggregation.ravel_spec(tree, lead_axes=1)
    assert spec.total == sum(np.prod(s, dtype=int) for s in spec.shapes)
    flat = aggregation.ravel_stacked(tree, spec)
    assert flat.shape == (4, spec.total)
    back = aggregation.unravel_pytree(flat, spec)
    assert jax.tree_util.tree_structure(back) == \
        jax.tree_util.tree_structure(tree)
    for name in tree:
        np.testing.assert_array_equal(np.asarray(tree[name]),
                                      np.asarray(back[name]))


def test_ravel_spec_is_cached():
    tree = _make_stacked(RAGGED_TREES[0], 4, 0)
    assert aggregation.ravel_spec(tree, lead_axes=1) is \
        aggregation.ravel_spec(tree, lead_axes=1)


def test_ravel_spec_rejects_mixed_dtypes_and_empty():
    with pytest.raises(ValueError, match="single leaf dtype"):
        aggregation.ravel_spec(
            {"a": jnp.zeros((2,), jnp.float32),
             "b": jnp.zeros((2,), jnp.bfloat16)})
    with pytest.raises(ValueError, match="empty"):
        aggregation.ravel_spec({})


# ----------------------------------------- flat ≡ per-leaf equivalence

@pytest.mark.parametrize("shapes", RAGGED_TREES)
@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["matvec", "kernel"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_flat_matches_per_leaf_reference(shapes, use_kernel, seed):
    """ravel → one kernel/matvec → unravel ≡ per-leaf
    aggregate_client_grads, to float32 tolerance, across ragged leaves."""
    n = 6
    stacked = _make_stacked(shapes, n, seed)
    w = jax.random.uniform(jax.random.PRNGKey(100 + seed), (n,)) \
        * jnp.array([1.0, 0.0, 1.0, 1.0, 0.0, 1.0])  # masked clients
    ref = aggregation.aggregate_client_grads(stacked, w)
    got = aggregation.aggregate_client_grads_flat(stacked, w,
                                                  use_kernel=use_kernel)
    for name in ref:
        np.testing.assert_allclose(np.asarray(ref[name]),
                                   np.asarray(got[name]),
                                   rtol=2e-5, atol=2e-5)


def test_kernel_path_is_flat_single_call(monkeypatch):
    """aggregate_client_grads_kernel must issue exactly ONE kernel call
    for a multi-leaf pytree (previously one per leaf)."""
    calls = []
    real = agg_ops.masked_scaled_aggregate

    def counting(g, w, *a, **kw):
        calls.append(g.shape)
        return real(g, w, *a, **kw)

    monkeypatch.setattr(agg_ops, "masked_scaled_aggregate", counting)
    stacked = _make_stacked(RAGGED_TREES[0], 4, 0)
    total = sum(int(np.prod(s)) for s in RAGGED_TREES[0].values())
    aggregation.aggregate_client_grads_kernel(stacked, jnp.ones((4,)) / 4)
    assert calls == [(4, total)]


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["matvec", "kernel"])
def test_reduce_flat_out_dtype_bf16_to_f32(use_kernel):
    """bf16 client gradients aggregate into an f32 server update without
    a round-trip through bf16 (out_dtype override, both backends)."""
    n, p = 5, 37
    g = jax.random.normal(jax.random.PRNGKey(0), (n, p)).astype(jnp.bfloat16)
    w = jax.random.uniform(jax.random.PRNGKey(1), (n,))
    out = aggregation.reduce_flat(g, w, use_kernel=use_kernel,
                                  out_dtype=jnp.float32)
    assert out.dtype == jnp.float32
    ref = w @ np.asarray(g, np.float32)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-2, atol=2e-2)


def test_mixed_dtype_falls_back_per_leaf():
    n = 4
    stacked = {
        "f32": jax.random.normal(jax.random.PRNGKey(0), (n, 5), jnp.float32),
        "bf16": jax.random.normal(jax.random.PRNGKey(1), (n, 3)
                                  ).astype(jnp.bfloat16),
    }
    w = jnp.array([0.5, 0.25, 0.0, 0.25])
    ref = aggregation.aggregate_client_grads(stacked, w)
    for use_kernel in (False, True):
        got = aggregation.aggregate_client_grads_flat(stacked, w,
                                                      use_kernel=use_kernel)
        assert got["f32"].dtype == jnp.float32
        assert got["bf16"].dtype == jnp.bfloat16
        for name in ref:
            np.testing.assert_allclose(
                np.asarray(ref[name], np.float32),
                np.asarray(got[name], np.float32), rtol=2e-2, atol=2e-2)


# ------------------------------------------- simulator flat-carry loop

def _dict_problem(n=4):
    shapes = {"w": (3, 5), "b": (7,), "k": (2, 3, 5)}
    params = {name: jnp.full(shp, 0.5) for name, shp in shapes.items()}
    target = _make_stacked(shapes, 1, 9)

    def grads_fn(p, key, t):
        # Per-client noisy pull toward a fixed target; N stacked leaves.
        noise = _make_stacked(shapes, n, 3)
        return jax.tree_util.tree_map(
            lambda pl, tg, nz: jnp.broadcast_to(pl - tg[0], (n,) + pl.shape)
            + 0.01 * nz, p, target, noise)

    def loss_fn(p):
        return sum(jnp.sum((pl - tg[0]) ** 2)
                   for pl, tg in zip(jax.tree_util.tree_leaves(p),
                                     jax.tree_util.tree_leaves(target)))

    return params, grads_fn, loss_fn


@pytest.mark.parametrize("opt", [sgd(0.05), adam(0.05)], ids=["sgd", "adam"])
@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["matvec", "kernel"])
def test_flat_carry_run_matches_legacy(opt, use_kernel):
    """flat=None (auto) scan carry ≡ flat=False per-leaf carry, for both
    aggregation backends and a stateful optimizer (state in flat space)."""
    n = 4
    params, grads_fn, loss_fn = _dict_problem(n)
    mk = lambda flat: ClientSimulator(
        grads_fn=grads_fn, scheduler=make_scheduler("alg1", n),
        energy=DeterministicArrivals.periodic([1, 2, 4, 8], horizon=40),
        p=jnp.full((n,), 0.25), optimizer=opt, loss_fn=loss_fn,
        use_kernel=use_kernel, flat=flat)
    w_flat, h_flat = mk(None).run(jax.random.PRNGKey(2), params, 25)
    w_leaf, h_leaf = mk(False).run(jax.random.PRNGKey(2), params, 25)
    np.testing.assert_allclose(np.asarray(h_flat.loss),
                               np.asarray(h_leaf.loss),
                               rtol=2e-4, atol=1e-5)
    for name in w_flat:
        assert w_flat[name].shape == params[name].shape
        np.testing.assert_allclose(np.asarray(w_flat[name]),
                                   np.asarray(w_leaf[name]),
                                   rtol=2e-4, atol=1e-5)


def test_flat_carry_one_kernel_call_per_step(monkeypatch):
    """Tracing the whole scan loop with use_kernel=True must hit the
    kernel entry point exactly once — one launch per step regardless of
    the number of parameter leaves. A tagged plain sgd() optimizer
    routes through the *fused* reduce-and-update op (DESIGN.md §9); the
    unfused reduce must not run at all on that path."""
    calls = []
    real = agg_ops.masked_scaled_aggregate_update

    def counting(g, w, *a, **kw):
        calls.append(g.shape)
        return real(g, w, *a, **kw)

    def no_unfused(*a, **kw):
        raise AssertionError("unfused reduce reached on the fused sgd path")

    monkeypatch.setattr(agg_ops, "masked_scaled_aggregate_update", counting)
    monkeypatch.setattr(agg_ops, "masked_scaled_aggregate", no_unfused)
    n = 4
    params, grads_fn, loss_fn = _dict_problem(n)
    sim = ClientSimulator(
        grads_fn=grads_fn, scheduler=make_scheduler("alg1", n),
        energy=BinaryArrivals([0.5] * n), p=jnp.full((n,), 0.25),
        optimizer=sgd(0.05), use_kernel=True)
    sim.run(jax.random.PRNGKey(0), params, 10)
    # The scan body traces once; a per-leaf implementation would record
    # len(params) == 3 shapes here.
    total = 3 * 5 + 7 + 2 * 3 * 5
    assert calls == [(n, total)]


def test_flat_carry_stateful_optimizer_keeps_unfused_kernel(monkeypatch):
    """adam (untagged) must keep the reduce → update split: exactly one
    unfused kernel launch per step, never the fused sgd op."""
    calls = []
    real = agg_ops.masked_scaled_aggregate

    def counting(g, w, *a, **kw):
        calls.append(g.shape)
        return real(g, w, *a, **kw)

    def no_fused(*a, **kw):
        raise AssertionError("fused sgd op reached with a stateful optimizer")

    monkeypatch.setattr(agg_ops, "masked_scaled_aggregate", counting)
    monkeypatch.setattr(agg_ops, "masked_scaled_aggregate_update", no_fused)
    n = 4
    params, grads_fn, loss_fn = _dict_problem(n)
    sim = ClientSimulator(
        grads_fn=grads_fn, scheduler=make_scheduler("alg1", n),
        energy=BinaryArrivals([0.5] * n), p=jnp.full((n,), 0.25),
        optimizer=adam(0.05), use_kernel=True)
    sim.run(jax.random.PRNGKey(0), params, 10)
    total = 3 * 5 + 7 + 2 * 3 * 5
    assert calls == [(n, total)]


def test_flat_carry_tolerates_mixed_dtype_grads():
    """Uniform-dtype params with a grads_fn that emits one bf16 leaf:
    the flat carry casts gradients to the params dtype instead of
    crashing (regression: pre-flat per-leaf aggregation accepted this)."""
    n = 4
    params = {"a": jnp.full((3,), 0.5), "b": jnp.full((2, 2), 0.5)}

    def grads_fn(p, key, t):
        g = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x * 0.1, (n,) + x.shape), p)
        return {"a": g["a"], "b": g["b"].astype(jnp.bfloat16)}

    mk = lambda flat: ClientSimulator(
        grads_fn=grads_fn, scheduler=make_scheduler("alg1", n),
        energy=BinaryArrivals([0.5] * n), p=jnp.full((n,), 0.25),
        optimizer=sgd(0.1), flat=flat)
    w_flat, _ = mk(None).run(jax.random.PRNGKey(0), params, 10)
    w_leaf, _ = mk(False).run(jax.random.PRNGKey(0), params, 10)
    for name in params:
        assert w_flat[name].dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(w_flat[name]),
                                   np.asarray(w_leaf[name]),
                                   rtol=2e-2, atol=2e-2)


def test_flat_true_raises_on_mixed_dtype_params():
    params = {"a": jnp.zeros((3,), jnp.float32),
              "b": jnp.zeros((2,), jnp.bfloat16)}
    sim = ClientSimulator(
        grads_fn=lambda p, k, t: jax.tree_util.tree_map(
            lambda x: jnp.zeros((2,) + x.shape, x.dtype), p),
        scheduler=make_scheduler("alg1", 2),
        energy=BinaryArrivals([0.5, 0.5]), p=jnp.array([0.5, 0.5]),
        optimizer=sgd(0.1), flat=True)
    with pytest.raises(ValueError, match="single leaf dtype"):
        sim.run(jax.random.PRNGKey(0), params, 4)
    # flat=None quietly falls back to the per-leaf carry.
    sim.flat = None
    w, _ = sim.run(jax.random.PRNGKey(0), params, 4)
    assert w["a"].dtype == jnp.float32 and w["b"].dtype == jnp.bfloat16


def test_quadratic_flat_vs_legacy_end_to_end():
    """Single-array params (the paper's quadratic problems) through both
    carries and both aggregation backends, full trajectory equality."""
    prob = make_quadratic(jax.random.PRNGKey(0), n_clients=4, dim=8)
    det = DeterministicArrivals.periodic([1, 2, 4, 8], horizon=80)
    runs = {}
    for flat in (False, None):
        for uk in (False, True):
            sim = ClientSimulator(
                grads_fn=lambda p, k, t: prob.all_grads(p),
                scheduler=make_scheduler("alg1", 4), energy=det, p=prob.p,
                optimizer=sgd(0.02), loss_fn=prob.suboptimality,
                use_kernel=uk, flat=flat)
            w, _ = sim.run(jax.random.PRNGKey(5), jnp.zeros(8), 60)
            runs[(flat, uk)] = np.asarray(w)
    base = runs[(False, False)]
    for key, w in runs.items():
        np.testing.assert_allclose(base, w, rtol=1e-4, atol=1e-5,
                                   err_msg=str(key))
