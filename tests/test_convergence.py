"""Theorem 1 / convergence behaviour on closed-form quadratics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ClientSimulator,
    biased_fixed_point,
    error_floor,
    make_quadratic,
    make_scheduler,
    max_step_size,
    theorem1_bound,
    variance_constant,
)
from repro.core.energy import DeterministicArrivals
from repro.optim import sgd

TAUS = [1, 2, 4, 8]


def simulate(problem, scheduler_name, steps, eta, seed=0, noise=0.0,
             w0_scale=0.0):
    n = problem.n_clients
    det = DeterministicArrivals.periodic(
        [TAUS[i % 4] for i in range(n)], horizon=steps + 1)
    sch = make_scheduler(scheduler_name, n)

    def grads_fn(params, key, t):
        return problem.all_grads(params, key=key, noise=noise)

    sim = ClientSimulator(grads_fn=grads_fn, scheduler=sch, energy=det,
                          p=problem.p, optimizer=sgd(eta),
                          loss_fn=problem.suboptimality)
    w0 = jnp.full((problem.dim,), w0_scale)
    wT, hist = sim.run(jax.random.PRNGKey(seed), w0, steps)
    return np.asarray(wT), np.asarray(hist.loss)


@pytest.fixture(scope="module")
def problem():
    return make_quadratic(jax.random.PRNGKey(7), n_clients=8, dim=6,
                          hetero=1.0, cond=8.0)


def test_alg1_converges_to_global_optimum(problem):
    """Theorem 1 behaviour: geometric decay to the ηLC/(2μ) floor. With a
    far initialization the decay phase dominates and the floor is ≪ F(w⁰);
    shrinking η must shrink the floor (Remark 1)."""
    eta = 0.2 * max_step_size(problem.mu, problem.lsmooth)
    wT, loss = simulate(problem, "alg1", steps=3000, eta=eta, w0_scale=10.0)
    floor = loss[-500:].mean()
    assert floor < 0.02 * loss[0]
    _, loss_small = simulate(problem, "alg1", steps=6000, eta=eta / 4,
                             w0_scale=10.0)
    assert loss_small[-500:].mean() < 0.5 * floor


def test_oracle_reaches_optimum(problem):
    eta = 0.5 * max_step_size(problem.mu, problem.lsmooth)
    wT, loss = simulate(problem, "oracle", steps=2000, eta=eta)
    assert loss[-1] < 1e-5 * loss[0]
    np.testing.assert_allclose(wT, problem.w_star, atol=1e-3)


def test_benchmark1_converges_to_biased_point(problem):
    """Closed-form verification of the paper's bias claim."""
    eta = 0.5 * max_step_size(problem.mu, problem.lsmooth)
    wT, _ = simulate(problem, "benchmark1", steps=4000, eta=eta)
    q = np.array([1.0 / TAUS[i % 4] for i in range(problem.n_clients)])
    w_biased = np.asarray(biased_fixed_point(problem, q))
    d_biased = np.linalg.norm(wT - w_biased)
    d_star = np.linalg.norm(wT - np.asarray(problem.w_star))
    assert d_biased < 0.2 * d_star  # lands on the biased optimum
    # and the biased optimum is genuinely different
    assert np.linalg.norm(w_biased - np.asarray(problem.w_star)) > 0.1


def test_benchmark2_slow_but_unbiased(problem):
    """Benchmark 2 updates once per max(τ)=8 steps: during the decay phase
    Algorithm 1 (one noisy update every step) is far ahead — the paper's
    Fig-1 'slow convergence' effect."""
    eta = 0.2 * max_step_size(problem.mu, problem.lsmooth)
    _, loss_b2 = simulate(problem, "benchmark2", steps=400, eta=eta,
                          w0_scale=10.0)
    _, loss_a1 = simulate(problem, "alg1", steps=400, eta=eta, w0_scale=10.0)
    assert loss_a1[60:140].mean() < 0.2 * loss_b2[60:140].mean()


def test_theorem1_bound_holds(problem):
    """E[F(w^T)] − F* ≤ eq. (20) for η ≤ min{1/(2μ), 1/L}."""
    eta = 0.5 * max_step_size(problem.mu, problem.lsmooth)
    steps = 1200
    reps = 8
    finals = []
    for r in range(reps):
        _, loss = simulate(problem, "alg1", steps=steps, eta=eta, seed=r)
        finals.append(loss[-1])
    emp = float(np.mean(finals))

    t_max = np.array([TAUS[i % 4] for i in range(problem.n_clients)],
                     dtype=np.float32)
    radius = float(np.linalg.norm(problem.w_star)) * 1.5
    g2 = problem.grad_second_moment_bound(radius)
    c = float(variance_constant(problem.p, t_max, g2))
    f0_gap = float(problem.suboptimality(jnp.zeros(problem.dim)))
    bound = float(theorem1_bound(steps, f0_gap, problem.mu,
                                 problem.lsmooth, eta, c))
    assert emp <= bound
    assert bound > 0


def test_error_floor_scales_linearly_with_eta(problem):
    c = 1.0
    f1 = error_floor(problem.mu, problem.lsmooth, 0.01, c)
    f2 = error_floor(problem.mu, problem.lsmooth, 0.02, c)
    np.testing.assert_allclose(f2, 2 * f1)


def test_variance_constant_structure():
    """C (eq. 21) reduces to the G²·(Σp)² baseline when all T=1 and grows
    linearly in (T−1)·p²."""
    p = jnp.asarray([0.5, 0.5])
    base = float(variance_constant(p, jnp.asarray([1.0, 1.0]), 4.0))
    np.testing.assert_allclose(base, 4.0)  # (Σp)²·G²
    grown = float(variance_constant(p, jnp.asarray([5.0, 1.0]), 4.0))
    np.testing.assert_allclose(grown, 4.0 + 4 * 0.25 * 4.0)
