"""Lemma 1 (unbiasedness) — hypothesis property tests over random
schedules/periods. The deterministic Monte-Carlo checks live in
``test_unbiasedness.py``; this module is skipped as a whole when
``hypothesis`` is not installed in the container.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.energy import DeterministicArrivals  # noqa: E402
from repro.core.scheduling import make_scheduler  # noqa: E402

from test_unbiasedness import mean_weights  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(
    taus=st.lists(st.integers(1, 12), min_size=2, max_size=5),
    seed=st.integers(0, 2**30),
)
def test_alg1_unbiased_random_periods(taus, seed):
    n = len(taus)
    horizon = int(np.lcm.reduce(taus)) * 60
    horizon = min(max(horizon, 600), 6000)
    p = np.random.default_rng(seed).dirichlet([2.0] * n)
    det = DeterministicArrivals.periodic(taus, horizon=horizon)
    w = mean_weights(make_scheduler("alg1", n), det, p, horizon, seed=seed)
    np.testing.assert_allclose(w, p, rtol=0.35, atol=5e-3)


@settings(max_examples=15, deadline=None)
@given(
    schedule=st.lists(
        st.lists(st.booleans(), min_size=24, max_size=24),
        min_size=1, max_size=4),
    seed=st.integers(0, 2**30),
)
def test_alg1_unbiased_arbitrary_schedules(schedule, seed):
    """Arbitrary deterministic arrival patterns (not just periodic): the
    time-summed weight over the run must equal p_i × (#covered steps),
    because Alg-1 books exactly one appointment per inter-arrival interval
    with scale = interval length.

    Steps before a client's first arrival are uncovered by construction —
    the expectation identity holds per covered interval [I_i, Ī_i)."""
    sched = np.asarray(schedule, dtype=np.float32)
    n, horizon = sched.shape
    if sched.sum() == 0:
        return
    p = np.full((n,), 1.0 / n, dtype=np.float32)
    det = DeterministicArrivals(sched)
    reps = 40
    acc = np.zeros(n)
    for r in range(reps):
        w = mean_weights(make_scheduler("alg1", n), det, p, horizon,
                         seed=seed + r)
        acc += w * horizon
    acc /= reps
    covered = np.zeros(n)
    for i in range(n):
        ts = np.flatnonzero(sched[i])
        if len(ts):
            covered[i] = horizon - ts[0]
    np.testing.assert_allclose(acc, p * covered, rtol=0.25, atol=0.15)
