"""Input-shape / input_specs tests (pure eval_shape — no compilation)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import (
    INPUT_SHAPES,
    arch_names,
    effective_window,
    get_config,
    input_specs,
)


def test_shape_table_matches_assignment():
    t = INPUT_SHAPES
    assert (t["train_4k"].seq_len, t["train_4k"].global_batch) == (4096, 256)
    assert (t["prefill_32k"].seq_len, t["prefill_32k"].global_batch) == (32768, 32)
    assert (t["decode_32k"].seq_len, t["decode_32k"].global_batch) == (32768, 128)
    assert (t["long_500k"].seq_len, t["long_500k"].global_batch) == (524288, 1)


def test_train_specs_shapes():
    cfg = get_config("minitron-4b")
    (batch, sched), mode = input_specs(cfg, "train_4k")
    assert mode == "train"
    assert batch["tokens"].shape == (256, 4096)
    assert batch["labels"].shape == (256, 4096)
    assert batch["client_ids"].shape == (256,)
    assert sched["mask"].shape == sched["scale"].shape == (32,)


def test_decode_specs_have_full_length_cache():
    cfg = get_config("stablelm-1.6b")
    specs, mode = input_specs(cfg, "decode_32k")
    assert mode == "decode"
    assert specs["tokens"].shape == (128, 1)
    caches = jax.tree_util.tree_leaves(specs["states"])
    # full (non-windowed) KV cache: (layers, B, 32768, Hkv, Dh)
    assert any(l.shape[-3] == 32768 for l in caches)


def test_long500k_dense_uses_ring_buffer():
    cfg = get_config("command-r-35b")
    assert effective_window(cfg, INPUT_SHAPES["long_500k"]) == \
        cfg.long_context_window
    specs, _ = input_specs(cfg, "long_500k")
    caches = jax.tree_util.tree_leaves(specs["states"])
    for l in caches:
        assert l.shape[-3] == cfg.long_context_window  # window, not 524288


def test_long500k_ssm_state_is_constant_size():
    cfg = get_config("xlstm-1.3b")
    specs, _ = input_specs(cfg, "long_500k")
    total = sum(l.size for l in jax.tree_util.tree_leaves(specs["states"]))
    # state size independent of the 524288 context (sub-quadratic family)
    assert total < 2e9


def test_whisper_skips_long500k():
    cfg = get_config("whisper-tiny")
    assert not cfg.supports_shape("long_500k")
    with pytest.raises(ValueError):
        input_specs(cfg, "long_500k")
    specs, _ = input_specs(cfg, "decode_32k")
    assert "memory" in specs  # encoder memory is a serve-step input


def test_modality_stub_inputs():
    vlm = get_config("qwen2-vl-2b")
    (batch, _), _ = input_specs(vlm, "train_4k")
    assert batch["vision_embeds"].shape == (256, 256, 1536)
    aud = get_config("whisper-tiny")
    (batch, _), _ = input_specs(aud, "train_4k")
    assert batch["audio_feats"].shape == (256, 1500, 384)


def test_every_supported_pair_produces_specs():
    count = 0
    for name in arch_names():
        cfg = get_config(name)
        for sn in INPUT_SHAPES:
            if cfg.supports_shape(sn):
                input_specs(cfg, sn)
                count += 1
    assert count == 39  # 10×4 − whisper long_500k
