"""Checkpoint roundtrip / retention / validation tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
)


def tree():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3),
                   "b": jnp.ones((3,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    p = str(tmp_path / "ck.npz")
    t = tree()
    save_pytree(p, t)
    got = restore_pytree(p, jax.tree_util.tree_map(jnp.zeros_like, t))
    np.testing.assert_allclose(got["params"]["w"], t["params"]["w"])
    assert got["params"]["b"].dtype == np.dtype(jnp.bfloat16)
    assert int(got["step"]) == 7


def test_restore_shape_mismatch_raises(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_pytree(p, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore_pytree(p, {"w": jnp.ones((3, 2))})


def test_restore_missing_leaf_raises(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_pytree(p, {"w": jnp.ones((2,))})
    with pytest.raises(KeyError):
        restore_pytree(p, {"w": jnp.ones((2,)), "extra": jnp.ones((1,))})


def test_manager_retention_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        cm.save(s, tree())
    assert latest_step(str(tmp_path)) == 30
    files = sorted(os.listdir(str(tmp_path)))
    assert files == ["step_20.npz", "step_30.npz"]
    got, step = cm.restore(tree())
    assert step == 30
    got20, step20 = cm.restore(tree(), step=20)
    assert step20 == 20


def test_manager_empty_raises(tmp_path):
    cm = CheckpointManager(str(tmp_path / "none"))
    with pytest.raises(FileNotFoundError):
        cm.restore(tree())
