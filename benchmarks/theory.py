"""Benchmark: Theorem 1 — empirical suboptimality vs the analytic bound,
and the ηLC/(2μ) error floor sweep (Remark 1)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ClientSimulator,
    make_quadratic,
    make_scheduler,
    max_step_size,
    theorem1_bound,
    variance_constant,
)
from repro.core.energy import DeterministicArrivals
from repro.optim import sgd

TAUS = (1, 5, 10, 20)


def run() -> list[str]:
    t0 = time.time()
    n = 8
    problem = make_quadratic(jax.random.PRNGKey(3), n, dim=8, hetero=0.5)
    taus = [TAUS[i % 4] for i in range(n)]
    steps = 2000
    energy = DeterministicArrivals.periodic(taus, horizon=steps + 1)

    rows = []
    eta_max = max_step_size(problem.mu, problem.lsmooth)
    radius = float(jnp.linalg.norm(problem.w_star)) + 10.0
    g2 = problem.grad_second_moment_bound(radius)
    c = float(variance_constant(problem.p, jnp.asarray(taus, jnp.float32), g2))
    f0 = float(problem.suboptimality(jnp.full((8,), 5.0)))

    for frac in (0.1, 0.25, 0.5):
        eta = frac * eta_max
        finals = []
        for seed in range(5):
            sim = ClientSimulator(
                grads_fn=lambda p, k, t: problem.all_grads(p),
                scheduler=make_scheduler("alg1", n), energy=energy,
                p=problem.p, optimizer=sgd(eta),
                loss_fn=problem.suboptimality)
            _, hist = sim.run(jax.random.PRNGKey(seed), jnp.full((8,), 5.0),
                              steps)
            finals.append(float(np.asarray(hist.loss[-100:]).mean()))
        emp = float(np.mean(finals))
        bound = float(theorem1_bound(steps, f0, problem.mu, problem.lsmooth,
                                     eta, c))
        rows.append(
            f"theorem1_eta{frac},{(time.time() - t0) * 1e6:.0f},"
            f"empirical={emp:.4g};bound={bound:.4g};holds={emp <= bound}")
    return rows
