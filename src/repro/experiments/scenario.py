"""Scenario specs: one declarative cell of an experiment grid.

A :class:`Scenario` names a (scheduler × energy-process) pair plus the
shape of the client population; :meth:`Scenario.build` materializes the
two pytree components. Scenarios are *host-side specs* (plain
dataclasses, not pytrees) — the pytrees they build are what crosses
``jit`` / ``vmap`` boundaries.

The module also owns:

* :func:`make_energy_process` — the paper-§V energy-profile factory
  (previously a private helper of ``repro.launch.train``; it lives here
  so drivers, benchmarks, examples and tests all build arrival processes
  from one registry).
* a **grid registry** of named scenario lists (``fig1``,
  ``fig1_grid``, …) so benchmarks/examples refer to whole experiment
  grids by name: ``get_grid("fig1_grid", n_clients=40, horizon=1001)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from repro.core.energy import (
    BinaryArrivals,
    DeterministicArrivals,
    UniformArrivals,
)
from repro.core.scheduling import make_scheduler

#: Paper §V experimental profile: 4 client groups with periods (1, 5, 10, 20).
PAPER_TAUS = (1, 5, 10, 20)

ARRIVAL_KINDS = ("periodic", "binary", "uniform")


def default_taus(n_clients: int) -> np.ndarray:
    """Paper §V grouping generalized to N clients: client i ∈ group i mod 4."""
    return np.array([PAPER_TAUS[i % len(PAPER_TAUS)] for i in range(n_clients)])


def make_energy_process(kind: str, n_clients: int, horizon: int, taus=None):
    """Paper §V profile: 4 groups, periods (1, 5, 10, 20) — generalized to
    N clients by cycling the group periods (client i ∈ group i mod 4).

    The same per-client period vector τ parameterizes all three arrival
    families so a kind-sweep holds the mean energy rate fixed:
    ``periodic`` arrivals every τ_i steps, ``binary`` Bern(1/τ_i), and
    ``uniform`` one arrival per τ_i-window.
    """
    taus = default_taus(n_clients) if taus is None else np.asarray(taus)
    if kind == "periodic":
        return DeterministicArrivals.periodic(taus, horizon)
    if kind == "binary":
        return BinaryArrivals(1.0 / taus)
    if kind == "uniform":
        return UniformArrivals(taus)
    raise ValueError(f"unknown arrival kind {kind!r}; have {ARRIVAL_KINDS}")


@dataclasses.dataclass
class Scenario:
    """One experiment-grid cell: scheduler × arrival process × population.

    ``scheduler`` / ``arrivals`` are registry names; ``taus`` is the
    per-client period vector shared across arrival kinds (None → the
    paper's cycling (1, 5, 10, 20) profile); ``scheduler_kwargs`` feeds
    extra hyperparameters (e.g. battery capacity) to the scheduler
    factory.
    """

    name: str
    scheduler: str
    arrivals: str
    n_clients: int
    horizon: int
    taus: Sequence[int] | None = None
    scheduler_kwargs: dict = dataclasses.field(default_factory=dict)

    def build(self):
        """Materialize the (scheduler, energy) pytree pair."""
        scheduler = make_scheduler(self.scheduler, self.n_clients,
                                   **self.scheduler_kwargs)
        energy = make_energy_process(self.arrivals, self.n_clients,
                                     self.horizon, taus=self.taus)
        return scheduler, energy


def scenario_grid(
    schedulers: Iterable[str],
    arrivals: Iterable[str],
    n_clients: int,
    horizon: int,
    taus=None,
    scheduler_kwargs: dict | None = None,
) -> list[Scenario]:
    """Cross product of scheduler × arrival-kind names as Scenario cells."""
    return [
        Scenario(name=f"{s}_{a}", scheduler=s, arrivals=a,
                 n_clients=n_clients, horizon=horizon, taus=taus,
                 scheduler_kwargs=dict(scheduler_kwargs or {}))
        for s in schedulers
        for a in arrivals
    ]


#: Paper Figure-1 methods, in presentation order.
FIG1_SCHEDULERS = ("alg1", "benchmark1", "benchmark2", "oracle")

_GRID_REGISTRY: dict[str, Callable[..., list[Scenario]]] = {}


def register_grid(name: str):
    """Decorator: register a named scenario-grid factory."""

    def deco(fn):
        _GRID_REGISTRY[name] = fn
        return fn

    return deco


def get_grid(name: str, **kw) -> list[Scenario]:
    try:
        factory = _GRID_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario grid {name!r}; have {sorted(_GRID_REGISTRY)}"
        ) from None
    return factory(**kw)


def grid_names() -> list[str]:
    return sorted(_GRID_REGISTRY)


@register_grid("fig1")
def _fig1(n_clients: int = 40, horizon: int = 1001, taus=None) -> list[Scenario]:
    """Paper Figure 1 verbatim: 4 methods on periodic (eq. 37) arrivals."""
    return scenario_grid(FIG1_SCHEDULERS, ("periodic",), n_clients, horizon,
                         taus=taus)


@register_grid("fig1_grid")
def _fig1_grid(n_clients: int = 40, horizon: int = 1001, taus=None) -> list[Scenario]:
    """Scenario-diversity extension: 4 methods × all 3 arrival families."""
    return scenario_grid(FIG1_SCHEDULERS, ARRIVAL_KINDS, n_clients, horizon,
                         taus=taus)


@register_grid("capacity_sweep")
def _capacity_sweep(n_clients: int = 8, horizon: int = 2001,
                    capacities: Sequence[float] = (1.0, 2.0, 4.0),
                    taus=None) -> list[Scenario]:
    """Battery-capacity sweep for the beyond-paper adaptive scheduler —
    one leaf-stacked compiled computation for the whole sweep."""
    return [
        Scenario(name=f"battery_c{c:g}", scheduler="battery_adaptive",
                 arrivals="binary", n_clients=n_clients, horizon=horizon,
                 taus=taus, scheduler_kwargs={"capacity": float(c)})
        for c in capacities
    ]
