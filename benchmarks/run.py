"""Benchmark harness — one module per paper table/figure.

  fig1           paper Figure 1 grid (schedulers x arrivals x seeds)
  theory         Theorem 1 bound vs empirical (+ error-floor sweep)
  kernels_bench  kernel-adjacent micro-benchmarks
  roofline_table dry-run roofline terms per (arch x shape x mesh)
  serve_bench    Study service: batched throughput, request latency,
                 executable-cache hit rate, single-trace collapse
  multihost      simulated 2-process jax.distributed grid: per-step
                 collective cost, 1-host-vs-2-process overhead, bitwise
                 gather check

Prints ``name,us_per_call,derived`` CSV. Select with ``--only``. With
``--json PATH`` the rows are additionally written as structured JSON
(suite, name, us_per_call, parsed derived fields) so perf-trajectory
``BENCH_*.json`` files can accumulate across PRs. ``--bench-out [DIR]``
is the one-flag version of the ROADMAP's one-bench-file-per-PR rule: it
writes ``BENCH_<today>.json`` (same named-series schema as
``BENCH_2026-07-27.json``) into DIR (default: the repo root).

    PYTHONPATH=src python -m benchmarks.run [--only fig1,theory] [--fast] \
        [--json BENCH_out.json] [--bench-out]
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import sys
import traceback

#: Pinned BENCH_*.json series schema (regression-tested in
#: ``tests/test_bench_schema.py``): every series record carries at least
#: ``name`` (the series id compared across PRs), ``values`` (a flat dict
#: of every numeric/bool/str measurement, ``us_per_call`` included) and
#: ``units`` (unit per measured key; derived dimensionless fields are
#: omitted). ``suite`` / ``us_per_call`` / ``derived`` remain for
#: continuity with pre-schema BENCH files.
SCHEMA = "bench-series/v1"


def _parse_derived(derived: str) -> dict:
    """``k1=v1;k2=v2`` -> dict with numeric/bool values where possible."""
    out: dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            if part:
                out.setdefault("notes", []).append(part)
            continue
        k, v = part.split("=", 1)
        if v in ("True", "False"):
            out[k] = v == "True"
        else:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def _parse_row(suite: str, row: str) -> dict:
    name, us, derived = row.split(",", 2)
    try:
        us_val = float(us)
    except ValueError:
        us_val = None
    values = _parse_derived(derived)
    values.pop("notes", None)
    values["us_per_call"] = us_val
    return {"suite": suite, "name": name, "us_per_call": us_val,
            "derived": _parse_derived(derived),
            "values": values, "units": {"us_per_call": "us"}}


def check_distinct_timings(records, threshold: int = 3) -> None:
    """Reject mass-duplicated timings across distinct series names.

    Regression guard for the fig1 attribution bug, where every
    ``fig1_<scenario>`` row reported the identical grid-total
    microseconds — 12 series names, one number. A duplicated timing is
    legitimate only when the row *declares* its source via a
    ``timing_ref=<origin series>`` derived field (speedup/summary rows
    quote the measurement they annotate). Within one suite, ``threshold``
    or more distinct names sharing one non-zero ``us_per_call`` without
    such an attribution is an error. Zero/None values are exempt —
    derived series (crossovers, dry-run tables) use 0 as "not a timing".
    """
    groups: dict = {}
    for r in records:
        us = r.get("us_per_call")
        if not us:
            continue
        if "timing_ref" in (r.get("derived") or {}):
            continue
        groups.setdefault((r.get("suite"), us), set()).add(r.get("name"))
    bad = {k: sorted(names) for k, names in groups.items()
           if len(names) >= threshold}
    if bad:
        lines = [f"  suite={suite!r} us_per_call={us}: {names}"
                 for (suite, us), names in sorted(bad.items())]
        raise ValueError(
            "duplicated timing attributed to multiple series (add a "
            "timing_ref derived field or time each series honestly):\n"
            + "\n".join(lines))


def check_serve_series(records) -> None:
    """Validate the ``serve_*`` series family (suite ``serve_bench``).

    A serve series that silently drops its derived counters would turn
    the serving perf trajectory into bare wall times, so the schema is
    enforced here: ``serve_latency`` must carry an ordered p50/p99 pair,
    ``serve_cache`` a hit rate in [0, 1] with non-growing warm compiles,
    ``serve_collapse`` a positive compile count,
    ``serve_resume_latency`` a zero-recompile warm resume, and
    ``serve_resume_bitwise`` must actually be bitwise. Errors name the
    offending series.
    """
    want = {
        "serve_latency": ("p50_us", "p99_us"),
        "serve_cache": ("hit_rate",),
        "serve_collapse": ("compiles",),
        "serve_resume_uninterrupted": ("chunks",),
        "serve_resume_latency": ("resume_us", "overhead_pct",
                                 "new_compiles"),
        "serve_resume_bitwise": ("bitwise",),
    }
    by_name = {r.get("name"): r for r in records
               if r.get("suite") == "serve_bench"}
    if not by_name:
        return
    problems = []
    for name in by_name:
        if not str(name).startswith("serve_"):
            problems.append(
                f"series {name!r}: serve_bench series must be named "
                f"serve_*")
    for name, keys in want.items():
        rec = by_name.get(name)
        if rec is None:
            problems.append(f"series {name!r} missing from serve_bench run")
            continue
        derived = rec.get("derived") or {}
        missing = [k for k in keys if k not in derived]
        if missing:
            problems.append(
                f"series {name!r}: missing derived field(s) {missing}")
            continue
        if name == "serve_latency" and derived["p50_us"] > derived["p99_us"]:
            problems.append(
                f"series {name!r}: p50_us={derived['p50_us']} > "
                f"p99_us={derived['p99_us']}")
        if name == "serve_cache" and not 0 <= derived["hit_rate"] <= 1:
            problems.append(
                f"series {name!r}: hit_rate={derived['hit_rate']} outside "
                f"[0, 1]")
        if name == "serve_cache" and derived.get("warm_compiles", 0) > 0:
            problems.append(
                f"series {name!r}: warm_compiles="
                f"{derived['warm_compiles']} — repeat traffic recompiled")
        if name == "serve_collapse" and not derived["compiles"] >= 1:
            problems.append(
                f"series {name!r}: compiles={derived['compiles']} < 1")
        if name == "serve_resume_latency" \
                and derived["new_compiles"] != 0:
            problems.append(
                f"series {name!r}: new_compiles="
                f"{derived['new_compiles']} — a warm resume recompiled")
        if name == "serve_resume_bitwise" and not derived["bitwise"]:
            problems.append(
                f"series {name!r}: bitwise={derived['bitwise']} — resumed "
                f"responses drifted from the uninterrupted dispatch")
    if problems:
        raise ValueError("invalid serve_* series:\n  " +
                         "\n  ".join(problems))


def check_multihost_series(records) -> None:
    """Validate the ``multihost_*`` series family (suite ``multihost``).

    The acceptance contract of the multi-process path is encoded here:
    the 2-process gather run must stay the bitwise oracle
    (``multihost_bitwise``), both 2-process rows must actually span two
    processes and quote their single-host overhead, and the per-step
    collective-cost row must carry both reduction modes. Errors name the
    offending series.
    """
    by_name = {r.get("name"): r for r in records
               if r.get("suite") == "multihost"}
    if not by_name:
        return
    problems = []
    for name in by_name:
        if not str(name).startswith("multihost_"):
            problems.append(
                f"series {name!r}: multihost series must be named "
                f"multihost_*")
    want = {
        "multihost_baseline_1proc": ("processes", "devices"),
        "multihost_2proc_psum": ("processes", "overhead_pct", "us_per_step"),
        "multihost_2proc_gather": ("processes", "overhead_pct",
                                   "us_per_step"),
        "multihost_step_collective": ("psum_us_per_step",
                                      "gather_us_per_step"),
        "multihost_bitwise": ("bitwise",),
    }
    for name, keys in want.items():
        rec = by_name.get(name)
        if rec is None:
            problems.append(f"series {name!r} missing from multihost run")
            continue
        derived = rec.get("derived") or {}
        missing = [k for k in keys if k not in derived]
        if missing:
            problems.append(
                f"series {name!r}: missing derived field(s) {missing}")
            continue
        if name.startswith("multihost_2proc") and derived["processes"] != 2:
            problems.append(
                f"series {name!r}: processes={derived['processes']} — the "
                f"simulated run did not span two processes")
        if name == "multihost_bitwise" and not derived["bitwise"]:
            problems.append(
                f"series {name!r}: bitwise={derived['bitwise']} — the "
                f"2-process gather run drifted from the single-process "
                f"vmap engine")
    if problems:
        raise ValueError("invalid multihost_* series:\n  " +
                         "\n  ".join(problems))


def build_doc(selected, fast: bool, device_count: int, records, failed, *,
              host_devices: dict | None = None) -> dict:
    """The BENCH_*.json document — one pinned shape for every PR's
    perf-trajectory file.

    ``device_count`` is the *effective* ``jax.device_count()`` at write
    time — if ``ensure_host_device_count`` came too late (jax already
    imported) the series silently ran on whatever the backend had, and
    ``host_devices`` records that: ``requested`` (the placeholder count
    asked for, None if never requested) and ``applied`` (whether the
    flag actually took effect), so BENCH files taken under a failed pin
    are never silently compared against properly-sharded ones.
    """
    return {"schema": SCHEMA, "suites": list(selected), "fast": fast,
            "device_count": device_count,
            "host_devices": host_devices or {"requested": None,
                                             "applied": None},
            "failed": list(failed), "results": list(records)}


def bench_out_path(directory: str, date: str) -> str:
    """One BENCH file per PR: never clobber an earlier PR's series
    landed on the same date — uniquify with a numeric suffix that keeps
    counting past ``.2`` (``BENCH_d.json``, ``BENCH_d.2.json``,
    ``BENCH_d.3.json``, …)."""
    path = os.path.join(directory, f"BENCH_{date}.json")
    suffix = 2
    while os.path.exists(path):
        path = os.path.join(directory, f"BENCH_{date}.{suffix}.json")
        suffix += 1
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="shrink grid sizes (iterations/seeds) for CI-speed runs")
    ap.add_argument("--json", default="",
                    help="also write structured results to this JSON path")
    ap.add_argument("--bench-out", nargs="?", const=".", default="",
                    metavar="DIR",
                    help="write BENCH_<date>.json (the per-PR perf-trajectory "
                         "series) into DIR (default: current directory, i.e. "
                         "the repo root when run as documented)")
    args = ap.parse_args()

    suite_names = ("fig1", "theory", "kernels_bench", "roofline_table",
                   "serve_bench", "multihost")
    selected = [s.strip() for s in args.only.split(",") if s.strip()] \
        or list(suite_names)
    unknown = [s for s in selected if s not in suite_names]
    if unknown:
        raise SystemExit(f"unknown suites {unknown}; have {list(suite_names)}")

    host_devices = {"requested": None, "applied": None}
    if "fig1" in selected or "multihost" in selected:
        # 8 placeholder CPU devices so fig1's sharded grid series and
        # the multihost suite's single-process baseline run. Must happen
        # before the suite imports pull in jax, and only when those
        # suites are requested. Whether the pin actually took effect is
        # recorded in the JSON (with the effective device count) so
        # BENCH_* series taken under different backends are never
        # silently compared.
        from repro._env import ensure_host_device_count
        host_devices = {"requested": 8,
                        "applied": ensure_host_device_count(8)}
    sys.path.insert(0, ".")  # examples/ imports
    from benchmarks import (fig1, kernels_bench, multihost, roofline_table,
                            serve_bench, theory)

    fig1_kw = (dict(iters=40, seeds=8, n_clients=8) if args.fast
               else dict(iters=100, seeds=8, n_clients=8))
    suites = {
        "fig1": lambda: fig1.run(**fig1_kw),
        "theory": theory.run,
        "kernels_bench": kernels_bench.run,
        "roofline_table": roofline_table.run,
        "serve_bench": lambda: serve_bench.run(fast=args.fast),
        "multihost": lambda: multihost.run(fast=args.fast),
    }
    assert set(suites) == set(suite_names)  # one source of suite names

    print("name,us_per_call,derived")
    records, failed = [], []
    for name in selected:
        try:
            for row in suites[name]():
                print(row, flush=True)
                records.append(_parse_row(name, row))
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)

    try:
        check_distinct_timings(records)
    except ValueError:
        traceback.print_exc()
        failed.append("timing-attribution")

    try:
        check_serve_series(records)
    except ValueError:
        traceback.print_exc()
        failed.append("serve-series")

    try:
        check_multihost_series(records)
    except ValueError:
        traceback.print_exc()
        failed.append("multihost-series")

    out_paths = [p for p in (args.json,) if p]
    if args.bench_out:
        out_paths.append(
            bench_out_path(args.bench_out, datetime.date.today().isoformat()))
    if out_paths:
        import jax

        doc = build_doc(selected, args.fast, jax.device_count(), records,
                        failed, host_devices=host_devices)
        for path in out_paths:
            with open(path, "w") as f:
                json.dump(doc, f, indent=2)
                f.write("\n")
            print(f"wrote {path}", file=sys.stderr)

    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
