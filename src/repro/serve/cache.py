"""Keyed executable cache: (structure fingerprint, config) → jit runner.

The engine's process-global jit cache (:data:`repro.experiments.engine.
_run_group`) grows monotonically and can only be cleared wholesale —
fine for a benchmark, wrong for a service. :class:`ExecutableCache`
replaces it on the serve path (``execute_cells(...,
executable_cache=)``): each distinct (component structure, execution
config, step budget, eval hook) gets its **own** jit wrapper
(:func:`repro.experiments.engine.make_group_runner`), stored in a
bounded LRU (:mod:`repro._lru`). A cache hit makes repeat traffic pure
dispatch (the runner's jit cache holds the compiled program); eviction
drops the runner object, releasing its executables and pinned closures.

Compiles are counted by the runner's ``on_trace`` hook — the python body
executes exactly once per (re)trace — so ``stats()["compiles"]`` is a
jit-cache-entry count that needs no jax internals, and tests can assert
the single-trace collapse (a mixed-population batch of one structure
compiles once) directly.
"""

from __future__ import annotations

import threading

from repro._lru import LRUCache
from repro.experiments import engine


class ExecutableCache:
    """Bounded LRU of group runners, keyed on (structure, config, …).

    ``group_runner`` is the protocol :func:`repro.experiments.engine.
    execute_cells` calls per structure group: ``key`` is the engine's
    hashable trace signature (group key + raggedness); the cache widens
    it with the runner-defining arguments (``sim`` identity, step
    budget, eval hook) plus any :meth:`bind`-time extras (the serve
    layer binds the request's ExecutionConfig). Distinct batch *shapes*
    under one key re-trace inside the same runner — counted as compiles,
    not as new cache entries.
    """

    def __init__(self, maxsize: int = 32):
        self._lru = LRUCache(maxsize=maxsize)
        self._compiles = 0
        self._compile_lock = threading.Lock()

    def _on_trace(self) -> None:
        with self._compile_lock:
            self._compiles += 1

    def group_runner(self, key, *, sim, num_steps: int, eval_fn=None,
                     eval_every: int = 0, extra=()):
        full_key = (key, tuple(extra), sim, int(num_steps), eval_fn,
                    int(eval_every))
        return self._lru.get_or_create(
            full_key, lambda: engine.make_group_runner(
                sim=sim, num_steps=num_steps, eval_fn=eval_fn,
                eval_every=eval_every, on_trace=self._on_trace))

    def chunk_runner(self, key, *, sim, chunk: int, spec, extra=()):
        """Memoized :func:`repro.experiments.engine.make_chunk_runner`
        — the resumable path's analogue of :meth:`group_runner`. Keyed
        on (structure key, chunk length, flat spec, extras), so a warm
        resume of an interrupted dispatch — same structure, same
        checkpoint cadence — reuses the already-compiled chunk advance:
        zero new compiles (DESIGN.md §12)."""
        full_key = ("chunk", key, tuple(extra), sim, int(chunk), spec)
        return self._lru.get_or_create(
            full_key, lambda: engine.make_chunk_runner(
                sim=sim, chunk=chunk, spec=spec, on_trace=self._on_trace))

    def bind(self, *extra) -> "BoundExecutableCache":
        """A view whose keys are widened with ``extra`` (hashable) —
        e.g. one request's ExecutionConfig, so two configs never share
        an executable entry."""
        return BoundExecutableCache(self, extra)

    def fingerprint(self, key) -> str:
        """Response-visible digest of one structure key."""
        return engine.structure_fingerprint(key)

    def cache_entries(self) -> int:
        """Total jit-cache entries across the live runners — the
        compiled-program count the single-trace assertions probe."""
        return sum(r._cache_size() for r in self._lru.values())

    def stats(self) -> dict:
        return {**self._lru.stats(), "compiles": self._compiles}

    def clear(self) -> dict:
        """Drop every runner (their executables become collectable);
        returns the final stats snapshot."""
        stats = self.stats()
        self._lru.clear()
        return stats


class BoundExecutableCache:
    """:meth:`ExecutableCache.bind` view — same store, widened keys."""

    def __init__(self, cache: ExecutableCache, extra: tuple):
        self._cache = cache
        self._extra = tuple(extra)

    def group_runner(self, key, **kw):
        return self._cache.group_runner(key, extra=self._extra, **kw)

    def chunk_runner(self, key, **kw):
        return self._cache.chunk_runner(key, extra=self._extra, **kw)

    def stats(self) -> dict:
        return self._cache.stats()
