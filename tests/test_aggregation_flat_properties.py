"""Property-based flat-aggregation tests (randomized ragged pytrees).

Deterministic layout sweeps live in ``test_aggregation_flat.py``; this
module randomizes leaf count, leaf shapes (odd sizes that previously
forced per-leaf kernel padding) and weights, and is skipped as a whole
when ``hypothesis`` is not installed in the container.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import aggregation  # noqa: E402

_leaf_shapes = st.lists(
    st.lists(st.integers(1, 7), min_size=0, max_size=3).map(tuple),
    min_size=1, max_size=5)


@settings(max_examples=25, deadline=None)
@given(shapes=_leaf_shapes, n=st.integers(1, 9), seed=st.integers(0, 2**30),
       use_kernel=st.booleans())
def test_flat_aggregation_matches_per_leaf(shapes, n, seed, use_kernel):
    """ravel → one reduction → unravel ≡ per-leaf aggregate_client_grads
    for arbitrary ragged float32 pytrees, to float32 tolerance."""
    key = jax.random.PRNGKey(seed)
    stacked = {
        f"leaf{i}": jax.random.normal(jax.random.fold_in(key, i), (n,) + shp)
        for i, shp in enumerate(shapes)
    }
    w = jax.random.uniform(jax.random.fold_in(key, 999), (n,))
    ref = aggregation.aggregate_client_grads(stacked, w)
    got = aggregation.aggregate_client_grads_flat(stacked, w,
                                                  use_kernel=use_kernel)
    for name in ref:
        np.testing.assert_allclose(np.asarray(ref[name]),
                                   np.asarray(got[name]),
                                   rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(shapes=_leaf_shapes, n=st.integers(1, 6), seed=st.integers(0, 2**30))
def test_ravel_unravel_roundtrip_random_trees(shapes, n, seed):
    key = jax.random.PRNGKey(seed)
    tree = {
        f"leaf{i}": jax.random.normal(jax.random.fold_in(key, i), (n,) + shp)
        for i, shp in enumerate(shapes)
    }
    spec = aggregation.ravel_spec(tree, lead_axes=1)
    flat = aggregation.ravel_stacked(tree, spec)
    assert flat.shape == (n, spec.total)
    back = aggregation.unravel_pytree(flat, spec)
    for name in tree:
        np.testing.assert_array_equal(np.asarray(tree[name]),
                                      np.asarray(back[name]))
    # The (P,)-vector view used for the flat scan carry round-trips too.
    one = jax.tree_util.tree_map(lambda x: x[0], tree)
    spec0 = aggregation.ravel_spec(one)
    vec = aggregation.ravel_pytree(one, spec0)
    assert vec.shape == (spec0.total,)
    back0 = aggregation.unravel_pytree(vec, spec0)
    for name in one:
        np.testing.assert_array_equal(np.asarray(one[name]),
                                      np.asarray(back0[name]))
