"""Placement layer: device-sharded grid execution (DESIGN.md §5).

:func:`repro.experiments.run_grid` batches a structure-group's cells as
``vmap(scenarios) ∘ vmap(seeds)`` on one device. This module places the
same computation across a device mesh instead:

1. the (scenario S × seed R) cell block is **flattened** into one cell
   axis C = S·R (scheduler/energy leaves repeated over seeds, PRNG keys
   tiled over scenarios),
2. C is **padded** to a device-divisible count by repeating cell 0 — a
   valid cell, so the padded lanes run real arithmetic instead of
   producing NaNs — and the pad is sliced off before results are
   reshaped back to (S, R, ...),
3. the block executes under ``shard_map``: cells sharded along the
   mesh's single axis, ``params0`` replicated, each device running the
   same jitted ``vmap(ClientSimulator.run)`` over its local cells.

Single-device callers never enter this module — ``run_grid`` without a
``mesh`` (or with a 1-device mesh) takes the pure-vmap path bit-for-bit
unchanged. CPU CI exercises the sharded path via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(``tests/conftest.py``).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

#: Default mesh-axis name for the flattened (scenario × seed) cell axis.
CELL_AXIS = "cells"


def make_cell_mesh(n_devices: int | None = None, *,
                   axis_name: str = CELL_AXIS) -> Mesh:
    """1-D mesh over the first ``n_devices`` (default: all) devices.

    The cell axis is embarrassingly parallel, so grid sharding wants a
    flat mesh regardless of how production training meshes are shaped
    (``repro.launch.mesh`` re-exports this for drivers).
    """
    devices = jax.devices()
    if n_devices is not None:
        if not 1 <= n_devices <= len(devices):
            raise ValueError(
                f"n_devices={n_devices} outside [1, {len(devices)}]")
        devices = devices[:n_devices]
    return Mesh(np.array(devices), (axis_name,))


def _cell_axis(mesh: Mesh) -> str:
    if len(mesh.axis_names) != 1:
        raise ValueError(
            "grid sharding needs a 1-D mesh (the flattened cell axis); got "
            f"axes {mesh.axis_names} — build one with make_cell_mesh()")
    return mesh.axis_names[0]


def flatten_cells(scheduler, energy, keys, *, n_scenarios: int,
                  active=None, p=None):
    """(S-stacked components, (R, 2) keys) → C = S·R flat cell arrays.

    Cell ``c = s·R + r`` pairs scenario ``s`` with seed ``r``, matching
    ``x.reshape(S, R, ...)`` on the way back out. ``active`` / ``p`` are
    the optional (S, N_cap) ragged-population operands, repeated over
    seeds like the components (None passes through).
    """
    r = keys.shape[0]
    rep = lambda x: jnp.repeat(x, r, axis=0)
    sch_c = jax.tree_util.tree_map(rep, scheduler)
    en_c = jax.tree_util.tree_map(rep, energy)
    active_c = jax.tree_util.tree_map(rep, active)
    p_c = jax.tree_util.tree_map(rep, p)
    keys_c = jnp.tile(keys, (n_scenarios, 1))
    return sch_c, en_c, active_c, p_c, keys_c


def pad_cells(tree, n_cells: int, n_devices: int):
    """Pad the leading cell axis to a multiple of ``n_devices`` by
    repeating cell 0 (valid data — no NaN lanes); returns the padded
    tree and the padded count."""
    pad = (-n_cells) % n_devices
    if pad == 0:
        return tree, n_cells

    def _pad(x):
        return jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])])

    return jax.tree_util.tree_map(_pad, tree), n_cells + pad


@partial(jax.jit,
         static_argnames=("sim", "num_steps", "eval_fn", "eval_every", "mesh"))
def _run_group_sharded(scheduler, energy, active, p, params0, keys, *, sim,
                       num_steps: int, eval_fn=None, eval_every: int = 0,
                       mesh: Mesh):
    """shard_map'd twin of ``engine._run_group``.

    ``scheduler`` / ``energy`` / ``keys`` leaves carry a leading
    (device-divisible) flat cell axis, as do the optional
    ``active`` / ``p`` ragged-population operands (both None for
    uniform grids); ``params0`` is replicated. Each device vmaps the
    simulator scan over its local cells. Compiled once per (sim, group
    structure, mesh) — probe ``_run_group_sharded._cache_size()`` to
    assert trace counts.
    """
    from repro.experiments.engine import CellResult

    axis = _cell_axis(mesh)
    cells, replicated = PartitionSpec(axis), PartitionSpec()

    def local(sch, en, act, pw, ks, p0):
        def one(s, e, a, w, k):
            out = sim.run(k, p0, num_steps, scheduler=s, energy=e,
                          p=w, active_mask=a,
                          eval_fn=eval_fn, eval_every=eval_every)
            return CellResult(*out) if eval_fn is not None \
                else CellResult(*out, None)

        return jax.vmap(one, in_axes=(0, 0, 0, 0, 0))(sch, en, act, pw, ks)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(cells, cells, cells, cells, cells, replicated),
                   out_specs=cells, check_rep=False)
    return fn(scheduler, energy, active, p, keys, params0)


def clear_cache() -> None:
    """Drop compiled sharded-grid executables (see engine.clear_cache)."""
    _run_group_sharded.clear_cache()


def run_group_sharded(scheduler, energy, active, p, params0, keys, *, sim,
                      num_steps: int, n_scenarios: int, mesh: Mesh,
                      eval_fn=None, eval_every: int = 0):
    """Execute one structure-group's (S × R) cell block across ``mesh``.

    Flatten → pad → shard_map → slice off padding → reshape to (S, R).
    ``active`` / ``p`` are the optional (S, N_cap) ragged-population
    operands (engine-level client padding; DESIGN.md §7), sharded along
    the cell axis exactly like the components. Per-cell numerics match
    the vmap path to float32 reassociation tolerance (each cell is the
    same ``ClientSimulator.run`` under the same per-seed PRNG key).
    """
    _cell_axis(mesh)  # validate before any device work
    r = keys.shape[0]
    n_cells = n_scenarios * r
    sch_c, en_c, active_c, p_c, keys_c = flatten_cells(
        scheduler, energy, keys, n_scenarios=n_scenarios, active=active, p=p)
    (sch_c, en_c, active_c, p_c, keys_c), _ = pad_cells(
        (sch_c, en_c, active_c, p_c, keys_c), n_cells, mesh.size)
    out = _run_group_sharded(sch_c, en_c, active_c, p_c, params0, keys_c,
                             sim=sim, num_steps=num_steps, eval_fn=eval_fn,
                             eval_every=eval_every, mesh=mesh)
    return jax.tree_util.tree_map(
        lambda x: x[:n_cells].reshape((n_scenarios, r) + x.shape[1:]), out)
