"""Jit'd public wrapper for flash attention.

Accepts the model's (B, S, H, Dh) layout, transposes to the kernel's
(B, H, S, Dh), selects interpret mode off-TPU, and falls back to the ref
for shapes the kernel can't tile (tiny/unaligned smoke shapes).
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_Q,
    flash_attention_kernel,
)
from repro.kernels.flash_attention.ref import flash_attention_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Model layout: q (B,S,H,Dh); k,v (B,T,Hkv,Dh) -> (B,S,H,Dh)."""
    qh = q.swapaxes(1, 2)
    kh = k.swapaxes(1, 2)
    vh = v.swapaxes(1, 2)
    s, t = qh.shape[2], kh.shape[2]
    bq, bk = min(block_q, s), min(block_k, t)
    if s % bq or t % bk:
        out = flash_attention_ref(qh, kh, vh, causal=causal, window=window)
    else:
        out = flash_attention_kernel(qh, kh, vh, causal=causal, window=window,
                                     block_q=bq, block_k=bk,
                                     interpret=_interpret())
    return out.swapaxes(1, 2)
